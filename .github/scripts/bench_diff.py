#!/usr/bin/env python3
"""Diff two bench JSON-lines files (the CI BENCH_* artifacts).

Usage: bench_diff.py PREV.json CURR.json

Each line is one benchmark: {"group", "name", "median_ns", ...} as written
by the rust bench harness's --json sink.  Prints a per-bench delta table of
median times, flagging regressions > WARN_PCT.  Always exits 0 — the diff
is a reviewer signal (warn, don't fail): CI runners are noisy, and the
perf trajectory across PRs is what matters.
"""

import json
import sys

WARN_PCT = 25.0


def load(path):
    out = {}
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                key = f"{rec.get('group', '?')}/{rec.get('name', '?')}"
                if "median_ns" in rec:
                    out[key] = rec
    except OSError as e:
        print(f"bench-diff: cannot read {path}: {e}")
    return out


def fmt_ns(ns):
    for unit, scale in (("s", 1e9), ("ms", 1e6), ("us", 1e3)):
        if ns >= scale:
            return f"{ns / scale:.2f}{unit}"
    return f"{ns:.0f}ns"


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return
    prev, curr = load(sys.argv[1]), load(sys.argv[2])
    if not prev or not curr:
        print("bench-diff: nothing to compare (missing or empty input)")
        return
    names = sorted(set(prev) | set(curr))
    width = max(len(n) for n in names)
    warned = 0
    print(f"{'benchmark':<{width}}  {'prev':>10}  {'curr':>10}  {'delta':>8}")
    print("-" * (width + 34))
    for name in names:
        p, c = prev.get(name), curr.get(name)
        if p is None:
            print(f"{name:<{width}}  {'—':>10}  {fmt_ns(c['median_ns']):>10}  {'new':>8}")
            continue
        if c is None:
            print(f"{name:<{width}}  {fmt_ns(p['median_ns']):>10}  {'—':>10}  {'gone':>8}")
            continue
        pm, cm = p["median_ns"], c["median_ns"]
        pct = (cm - pm) / pm * 100.0 if pm > 0 else 0.0
        mark = ""
        if pct > WARN_PCT:
            mark = "  <-- regression?"
            warned += 1
        print(
            f"{name:<{width}}  {fmt_ns(pm):>10}  {fmt_ns(cm):>10}  {pct:>+7.1f}%{mark}"
        )
    if warned:
        print(
            f"\nbench-diff: {warned} benchmark(s) slowed by more than "
            f"{WARN_PCT:.0f}% vs the previous artifact (warn-only; "
            "runner noise is common — check the trajectory, not one point)."
        )
    else:
        print("\nbench-diff: no regressions beyond the warn threshold.")


if __name__ == "__main__":
    main()
