//! Staleness study (§B.1): how worker count and the staleness-filter
//! threshold shape (a) the fraction of usable probability weights and
//! (b) the version lag of the weights actually sampled.
//!
//! Reproduces the paper's two qualitative claims:
//!   * a threshold filters out a large fraction of weights (their 4-second
//!     threshold with 3 workers kept ~15%);
//!   * adding workers lowers average staleness.
//!
//! Run (after `make artifacts`):
//!     cargo run --release --example staleness_study

use anyhow::Result;
use issgd::config::RunConfig;
use issgd::coordinator::run_sim;

fn main() -> Result<()> {
    println!("workers  threshold(versions)  kept-frac  sampled-lag  final-loss");
    println!("{:-<68}", "");
    for &workers in &[1usize, 2, 3, 6] {
        for threshold in [None, Some(2u64), Some(1), Some(0)] {
            let mut cfg = RunConfig::tiny_test();
            cfg.steps = 60;
            cfg.n_workers = workers;
            cfg.staleness_threshold = threshold;
            cfg.param_push_every = 2;
            let out = run_sim(&cfg)?;
            let tail = |name: &str| out.rec.tail_mean(name, 0.5).unwrap_or(f64::NAN);
            let loss = out
                .rec
                .get("train_loss")
                .last()
                .map(|s| s.value)
                .unwrap_or(f64::NAN);
            println!(
                "{:>7}  {:>19}  {:>9.3}  {:>11.3}  {:>10.4}",
                workers,
                threshold.map(|t| t.to_string()).unwrap_or_else(|| "off".into()),
                tail("kept_frac"),
                tail("sampled_version_lag"),
                loss
            );
        }
    }
    println!(
        "\nreading: tighter thresholds keep fewer weights (kept-frac ↓) yet training still \
         converges on the kept subset; more workers refresh weights faster (sampled-lag ↓), \
         approaching the oracle as the paper argues in §B.1"
    );
    Ok(())
}
