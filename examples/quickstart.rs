//! Quickstart: the 60-second tour of the public API.
//!
//! Loads the AOT artifacts, synthesises an SVHN-like dataset, runs a short
//! deterministic ISSGD session (master + 3 simulated workers + in-memory
//! weight store), and prints the loss trajectory plus what the workers and
//! the store were doing.
//!
//! Run (after `make artifacts`):
//!     cargo run --release --example quickstart

use anyhow::Result;
use issgd::config::RunConfig;
use issgd::coordinator::run_sim;

fn main() -> Result<()> {
    // A run configuration = model config (which artifacts) + topology +
    // the paper's hyperparameters.  `tiny_test` trains a 64-dim 2-hidden-
    // layer MLP — small enough to converge in seconds on one CPU core.
    let mut cfg = RunConfig::tiny_test();
    cfg.steps = 80;
    cfg.n_workers = 3;
    cfg.smoothing = 1.0; // §B.3 additive smoothing on probability weights
    println!("running ISSGD: {} steps, {} workers, smoothing +{}", cfg.steps, cfg.n_workers, cfg.smoothing);

    let outcome = run_sim(&cfg)?;

    // Loss trajectory (every 10th step).
    println!("\nstep   train-loss");
    for s in outcome.rec.get("train_loss").iter().step_by(10) {
        println!("{:>4}   {:.4}", s.step, s.value);
    }
    let (train_e, valid_e, test_e) = outcome.final_err;
    println!("\nfinal prediction error: train {train_e:.4}  valid {valid_e:.4}  test {test_e:.4}");
    println!("workers scored {} examples in the background", outcome.scored);
    println!(
        "store: {} parameter publishes, {} weight pushes",
        outcome.store_stats.param_pushes, outcome.store_stats.weight_pushes
    );

    // The same config with trainer = sgd is the paper's baseline:
    let sgd = issgd::baseline::sgd_twin(&cfg);
    let sgd_out = run_sim(&sgd)?;
    let is_last = outcome.rec.get("train_loss").last().unwrap().value;
    let sgd_last = sgd_out.rec.get("train_loss").last().unwrap().value;
    println!("\nISSGD final train loss {is_last:.4} vs uniform SGD {sgd_last:.4}");
    Ok(())
}
