//! Variance monitoring (the Figure-4 machinery as a library feature):
//! train with ISSGD while measuring √Tr(Σ(q)) for the ideal, stale and
//! uniform proposals, and watch the paper's §4.2 inequality
//!     Tr(Σ(q_IDEAL)) ≤ Tr(Σ(q_STALE)) ≤ Tr(Σ(q_UNIF))
//! hold on a live trajectory.
//!
//! Run (after `make artifacts`):
//!     cargo run --release --example variance_monitor

use anyhow::Result;
use issgd::config::RunConfig;
use issgd::coordinator::run_sim;

fn main() -> Result<()> {
    let mut cfg = RunConfig::tiny_test();
    cfg.steps = 60;
    cfg.smoothing = 0.5; // light smoothing: closer to ideal ISSGD
    cfg.monitor_every = 10; // the expensive full-train-set scoring cadence
    cfg.monitor_alt_smoothing = 10.0; // fig-4 style alternate constant

    println!("training with the variance monitor every {} steps...\n", cfg.monitor_every);
    let outcome = run_sim(&cfg)?;

    let ideal = outcome.rec.get("var_ideal_sqrt");
    let stale = outcome.rec.get("var_stale_sqrt");
    let stale_alt = outcome.rec.get("var_stale_alt_sqrt");
    let unif = outcome.rec.get("var_unif_sqrt");

    println!("step   sqrt Tr(Σ):   ideal     stale(+0.5)  stale(+10)   uniform    ordering");
    let mut held = 0;
    for i in 0..ideal.len() {
        let ok = ideal[i].value <= stale[i].value + 1e-9 && stale[i].value <= unif[i].value + 1e-9;
        held += ok as u32;
        println!(
            "{:>4}              {:>9.4}  {:>9.4}    {:>9.4}  {:>9.4}    {}",
            ideal[i].step,
            ideal[i].value,
            stale[i].value,
            stale_alt[i].value,
            unif[i].value,
            if ok { "ideal ≤ stale ≤ unif ✓" } else { "violated (noisy weights)" }
        );
    }
    println!(
        "\nordering held at {held}/{} checkpoints; heavier smoothing (+10) pushes the stale \
         curve towards the uniform one — exactly the paper's fig-4a observation",
        ideal.len()
    );
    Ok(())
}
