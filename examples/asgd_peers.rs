//! ASGD peer mode — the paper's §6 future-work design, built and run:
//! no master, just K peers and a parameter server.  Every peer
//! contribution computes a weighted gradient AND the per-example norms of
//! its minibatch in one backward pass (`peer_step` artifact); gradients go
//! to the server (`apply_grad`), norms become shared importance weights.
//!
//! Compares plain ASGD (uniform minibatches) against the ISSGD+ASGD
//! combination at the same gradient budget.
//!
//! Run (after `make artifacts`):
//!     cargo run --release --example asgd_peers

use anyhow::Result;
use issgd::config::{RunConfig, TrainerKind};
use issgd::coordinator::peer::run_asgd_sim;
use issgd::runtime::{artifacts_dir, Engine};

fn main() -> Result<()> {
    let engine = Engine::load(&artifacts_dir("tiny"))?;
    let mut base = RunConfig::tiny_test();
    base.steps = 120; // total gradient contributions across peers
    base.n_workers = 3; // peers
    base.param_push_every = 4; // peers refresh params every 4 own-steps
    base.smoothing = 1.0;

    println!("3 peers + parameter server, 120 total gradient contributions\n");
    for (name, trainer) in [
        ("plain ASGD (uniform)", TrainerKind::UniformSgd),
        ("ISSGD+ASGD (§6 combo)", TrainerKind::Issgd),
    ] {
        let mut cfg = base.clone();
        cfg.trainer = trainer;
        let out = run_asgd_sim(&cfg, &engine)?;
        let losses = out.rec.get("train_loss");
        println!("{name}:");
        for s in losses.iter().step_by(20) {
            println!("  contribution {:>4}   loss {:.4}", s.step, s.value);
        }
        let (tr, va, te) = out.final_err;
        println!(
            "  final err train/valid/test: {tr:.4}/{va:.4}/{te:.4}; \
             server applied {} gradients, peers shared {} weight updates\n",
            out.store_stats.grad_applies, out.store_stats.weight_pushes
        );
    }
    println!(
        "reading: both modes train through a parameter server with stale params; \
         the combination additionally concentrates sampling on informative examples \
         using weights that cost nothing extra to produce (paper §6)."
    );
    Ok(())
}
