//! Distributed training, the paper's deployment shape: a TCP weight-store
//! "database" process boundary, a master thread, and worker threads that
//! each own a PJRT engine — all wired through the same binary here for
//! convenience (the `issgd db-server` / `issgd worker` subcommands run the
//! actors as real separate processes).
//!
//! Demonstrates the end-to-end driver deliverable: trains the SVHN-shaped
//! `small` MLP (3072→4×256→10, ~1M params) on the synthetic corpus for a
//! few hundred steps over a real socket, logging the loss curve.
//!
//! Run (after `make artifacts`):
//!     cargo run --release --example distributed_training

use std::sync::Arc;

use anyhow::Result;
use issgd::config::RunConfig;
use issgd::coordinator::{run_live, LiveOptions, Master};
use issgd::weightstore::client::Client;
use issgd::weightstore::server::Server;
use issgd::weightstore::MemStore;

fn main() -> Result<()> {
    let mut cfg = RunConfig::setting_a(); // lr 0.01, smoothing +10
    cfg.model = "small".into();
    cfg.n_examples = 2048;
    cfg.steps = 200;
    cfg.n_workers = 2; // one core: keep thread contention sane
    cfg.eval_every = 25;

    // 1. The database actor on a real TCP socket.
    let store = Arc::new(MemStore::new(Master::store_size(&cfg), cfg.init_weight));
    let server = Server::bind("127.0.0.1:0", store)?;
    let (addr, server_handle) = server.serve_in_background()?;
    println!("weight store (database actor) listening on {addr}");

    // 2. Master + workers, all talking to the store over TCP.
    let outcome = run_live(
        &cfg,
        &LiveOptions {
            store: None,
            store_addr: Some(addr.to_string()),
            worker_throttle: Some(std::time::Duration::from_millis(2)),
            wait_for_first_scores: true,
        },
    )?;

    println!("\nstep   train-loss   (eval) train-err  test-err");
    let evals = outcome.rec.get("eval_train_err");
    let test_evals = outcome.rec.get("eval_test_err");
    for (i, s) in outcome.rec.get("eval_train_loss").iter().enumerate() {
        println!(
            "{:>4}   {:>10.4}   {:>16.4}  {:>8.4}",
            s.step,
            s.value,
            evals[i].value,
            test_evals[i].value
        );
    }
    let (train_e, valid_e, test_e) = outcome.final_err;
    println!("\nfinal error: train {train_e:.4}  valid {valid_e:.4}  test {test_e:.4}");
    println!("workers scored {} examples concurrently with training", outcome.scored);
    println!(
        "store traffic: {} param publishes, {} weight pushes, {} snapshots",
        outcome.store_stats.param_pushes,
        outcome.store_stats.weight_pushes,
        outcome.store_stats.snapshot_fetches
    );

    // 3. Shut the database down.
    Client::connect(&addr.to_string())?.shutdown_server()?;
    server_handle.join().ok();
    Ok(())
}
