"""L1 Pallas kernel: fused per-example gradient-norm accumulation.

This is the compute hot-spot of the paper's Proposition 1 (the Goodfellow
per-example-gradient-norm trick).  For one fully-connected layer with
pre-activation input rows ``X[n, :]`` and backpropagated output gradient
rows ``G[n, :] = (dL/dY)[n, :]``, the squared L2 norm of the *per-example*
parameter gradient of that layer is::

    ||dL_n/dW||_F^2 = ||X[n,:]||_2^2 * ||G[n,:]||_2^2
    ||dL_n/db||_2^2 = ||G[n,:]||_2^2

so each layer contributes ``rowsq(X) * rowsq(G) + rowsq(G)`` to the
per-example squared gradient norm.  The fused kernel reads each X/G tile
exactly once, computes both row reductions on the VPU, and combines them
in-register — the naive chain (two full-array squares, two reductions,
one multiply, one add) would traverse HBM three times.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles the batch
dimension; each grid step pulls a ``(block_n, d_in)`` X tile and a
``(block_n, d_out)`` G tile into VMEM via BlockSpec.  With the default
``block_n = 128`` and the paper's widest layer (d = 3072) the VMEM
footprint is ``128*3072*4 + 128*2048*4 + 128*4 ≈ 2.6 MiB`` — comfortably
under the ~16 MiB budget, leaving room for double buffering.

On this image Pallas must run ``interpret=True`` (the CPU PJRT plugin
cannot execute Mosaic custom-calls); interpret-mode lowers the kernel to
plain HLO so it composes into the AOT artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-block size for the batch grid axis.  128 rows keeps the widest
# paper-config tile (128 x 3072 f32) at 1.5 MiB of VMEM.
DEFAULT_BLOCK_N = 128


def _layer_sqnorm_kernel(x_ref, g_ref, o_ref):
    """One grid step: o[n] = ||x[n,:]||^2 * ||g[n,:]||^2 + ||g[n,:]||^2."""
    x = x_ref[...]
    g = g_ref[...]
    # Row reductions in f32 regardless of input dtype: the products can
    # overflow bf16/f16 ranges for badly-scaled late-training gradients.
    rx = jnp.sum(x.astype(jnp.float32) * x.astype(jnp.float32), axis=1)
    rg = jnp.sum(g.astype(jnp.float32) * g.astype(jnp.float32), axis=1)
    o_ref[...] = rx * rg + rg


@functools.partial(jax.jit, static_argnames=("block_n",))
def layer_sqnorm(x: jax.Array, g: jax.Array, block_n: int = DEFAULT_BLOCK_N) -> jax.Array:
    """Per-example squared gradient norm contribution of one dense layer.

    Args:
      x: ``(N, d_in)`` layer inputs (post-activation of the previous layer).
      g: ``(N, d_out)`` backpropagated gradient at the layer output.
      block_n: batch tile size; the batch is padded up to a multiple.

    Returns:
      ``(N,)`` f32 vector: ``rowsq(x) * rowsq(g) + rowsq(g)`` — the W
      contribution (Frobenius) plus the b contribution of Proposition 1.
    """
    n = x.shape[0]
    if g.shape[0] != n:
        raise ValueError(f"batch mismatch: x has {n} rows, g has {g.shape[0]}")
    bn = min(block_n, n)
    pad = (-n) % bn
    if pad:
        # Zero rows contribute exactly zero to both reductions.
        x = jnp.pad(x, ((0, pad), (0, 0)))
        g = jnp.pad(g, ((0, pad), (0, 0)))
    grid = (x.shape[0] // bn,)
    out = pl.pallas_call(
        _layer_sqnorm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, x.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((bn, g.shape[1]), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0],), jnp.float32),
        interpret=True,
    )(x, g)
    return out[:n]


def mlp_sqnorms(activations, output_grads, block_n: int = DEFAULT_BLOCK_N) -> jax.Array:
    """Accumulate Proposition-1 contributions across all dense layers.

    Args:
      activations: list of per-layer input matrices ``X_l`` with ``N`` rows.
      output_grads: list of per-layer output gradients ``G_l = dL/dY_l``.

    Returns:
      ``(N,)`` per-example squared gradient norms over the full parameter
      vector (all W's and b's flattened, as the paper's SGD does).
    """
    if len(activations) != len(output_grads):
        raise ValueError("need one output gradient per layer input")
    acc = None
    for x, g in zip(activations, output_grads):
        term = layer_sqnorm(x, g, block_n=block_n)
        acc = term if acc is None else acc + term
    return acc
