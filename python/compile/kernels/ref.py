"""Pure-jnp oracles for the Pallas kernels and for Proposition 1.

Everything here is the *reference semantics* — slow, obvious, and used only
by pytest to validate the kernels and the manual backprop in model.py.
Nothing in this file is ever lowered into an artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def layer_sqnorm_ref(x: jax.Array, g: jax.Array) -> jax.Array:
    """Reference for kernels.per_example_norm.layer_sqnorm."""
    rx = jnp.sum(jnp.square(x.astype(jnp.float32)), axis=1)
    rg = jnp.sum(jnp.square(g.astype(jnp.float32)), axis=1)
    return rx * rg + rg


def fused_linear_ref(x: jax.Array, w: jax.Array, b: jax.Array, relu: bool = True) -> jax.Array:
    """Reference for kernels.fused_linear.fused_linear."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :].astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def mlp_forward_ref(params, x):
    """Plain-jnp MLP forward: ReLU hidden layers, raw logits at the end."""
    h = x
    for i, (w, b) in enumerate(params):
        h = jnp.dot(h, w) + b
        if i + 1 < len(params):
            h = jnp.maximum(h, 0.0)
    return h


def per_example_ce_ref(params, x, y_onehot):
    """Per-example softmax cross-entropy, no batch reduction."""
    logits = mlp_forward_ref(params, x)
    logz = jax.nn.logsumexp(logits, axis=1)
    ll = jnp.sum(logits * y_onehot, axis=1)
    return logz - ll


def ce_loss_ref(params, x, y_onehot):
    """Mean softmax cross-entropy over the batch."""
    return jnp.mean(per_example_ce_ref(params, x, y_onehot))


def weighted_ce_ref(params, x, y_onehot, coef):
    """The importance-weighted minibatch loss of paper §4.1.

    ``coef[m]`` carries the full IS scaling ``(1/N sum omega) / omega_{i_m}``
    (all ones recovers plain SGD), so the loss is ``mean(coef * ce)``.
    """
    return jnp.mean(coef * per_example_ce_ref(params, x, y_onehot))


def per_example_grad_sqnorm_ref(params, x, y_onehot):
    """Oracle for Proposition 1: per-example ||grad||^2 via vmap(grad).

    Materializes the full per-example gradient (exactly what the paper's
    trick avoids) and reduces it — the ground truth the fast path must match.
    """

    def single_loss(p, xi, yi):
        return per_example_ce_ref(p, xi[None, :], yi[None, :])[0]

    def single_sqnorm(xi, yi):
        grads = jax.grad(single_loss)(params, xi, yi)
        leaves = jax.tree_util.tree_leaves(grads)
        return sum(jnp.sum(jnp.square(g)) for g in leaves)

    return jax.vmap(single_sqnorm)(x, y_onehot)


def mean_grad_sqnorm_ref(params, x, y_onehot):
    """Oracle for grad_mean_sqnorm: ||grad of mean CE||_2^2 (flat params)."""
    grads = jax.grad(ce_loss_ref)(params, x, y_onehot)
    leaves = jax.tree_util.tree_leaves(grads)
    return sum(jnp.sum(jnp.square(g)) for g in leaves)
