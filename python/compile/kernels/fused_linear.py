"""L1 Pallas kernel: blocked fused ``act(X @ W + b)`` dense layer.

Used on the worker scoring path (``grad_norms``) where the forward pass of
the MLP dominates.  The kernel tiles the output into ``(block_m, block_n)``
MXU-shaped panels, keeps the full contraction dimension resident (the
paper's layers have K ≤ 3072, so an ``X`` tile of ``128 x 3072`` f32 is
1.5 MiB of VMEM), and fuses the bias add + ReLU into the epilogue so the
pre-activation never round-trips through HBM.

TPU mapping: the ``jnp.dot`` inside the kernel targets the MXU systolic
array with ``preferred_element_type=float32`` accumulation; the epilogue is
VPU work on the already-resident tile.  This replaces the CUDA
threadblock/shared-memory tiling a 2015 GPU implementation would use —
BlockSpec expresses the HBM→VMEM schedule declaratively.

interpret=True as everywhere on this image (CPU PJRT cannot run Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 256


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + b[None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("relu", "block_m", "block_n"))
def fused_linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    relu: bool = True,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
) -> jax.Array:
    """``relu(x @ w + b)`` (or affine only with ``relu=False``), Pallas-blocked.

    Args:
      x: ``(M, K)`` input rows.
      w: ``(K, N)`` weight matrix.
      b: ``(N,)`` bias.
      relu: fuse a ReLU epilogue (hidden layers) or not (logits layer).

    Returns:
      ``(M, N)`` activations, same dtype as ``x``.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: x is {x.shape}, w is {w.shape}")
    if b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")
    bm = min(block_m, m)
    bn = min(block_n, n)
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    xp = jnp.pad(x, ((0, pad_m), (0, 0))) if pad_m else x
    wp = jnp.pad(w, ((0, 0), (0, pad_n))) if pad_n else w
    bp = jnp.pad(b, (0, pad_n)) if pad_n else b
    grid = (xp.shape[0] // bm, wp.shape[1] // bn)
    out = pl.pallas_call(
        functools.partial(_fused_linear_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]
