"""L2: the paper's model — permutation-invariant MLP with manual backprop.

Why manual backprop instead of ``jax.grad``: Proposition 1 (the
Goodfellow per-example-gradient-norm trick) needs the per-layer pairs
``(X_l, dL/dY_l)`` — the layer *inputs* from the forward pass and the
backpropagated gradients at each layer *output*.  Writing the backward
pass explicitly exposes exactly those tensors, which we then feed to the
L1 Pallas kernel (``kernels.per_example_norm``).  pytest cross-checks the
whole construction against ``jax.grad`` / ``vmap(grad)`` oracles.

Four entry points are AOT-lowered (see aot.py); every one takes the
parameters as ``2*L`` leading arguments ``(W_0, b_0, ..., W_{L-1}, b_{L-1})``
so the rust runtime can keep them device-resident across steps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.fused_linear import fused_linear
from compile.kernels.per_example_norm import mlp_sqnorms


# ---------------------------------------------------------------------------
# Parameter handling
# ---------------------------------------------------------------------------

def layer_dims(dims):
    """``[(d_in, d_out), ...]`` per dense layer for a dims list like
    ``[3072, 2048, 2048, 2048, 2048, 10]``."""
    return list(zip(dims[:-1], dims[1:]))


def init_params(key, dims, scale: str = "he"):
    """He-initialised parameter list ``[(W, b), ...]`` (ReLU network)."""
    params = []
    for i, (din, dout) in enumerate(layer_dims(dims)):
        key, sub = jax.random.split(key)
        if scale == "he":
            std = jnp.sqrt(2.0 / din)
        else:
            std = jnp.sqrt(1.0 / din)
        w = jax.random.normal(sub, (din, dout), jnp.float32) * std
        b = jnp.zeros((dout,), jnp.float32)
        params.append((w, b))
    return params


def params_from_flat(flat_args):
    """Group the flat ``(W_0, b_0, W_1, b_1, ...)`` argument list."""
    if len(flat_args) % 2:
        raise ValueError("parameter list must have an even length (W,b pairs)")
    return [(flat_args[i], flat_args[i + 1]) for i in range(0, len(flat_args), 2)]


def params_to_flat(params):
    flat = []
    for w, b in params:
        flat.append(w)
        flat.append(b)
    return flat


# ---------------------------------------------------------------------------
# Forward / backward
# ---------------------------------------------------------------------------

def forward(params, x, use_pallas: bool = True):
    """MLP forward pass keeping every layer input for the backward pass.

    Returns ``(logits, xs, zs)`` where ``xs[l]`` is the input to layer ``l``
    and ``zs[l]`` its pre-activation (needed for the ReLU mask).
    Hidden layers run through the L1 Pallas ``fused_linear`` kernel; the
    logits layer is affine (no ReLU).
    """
    xs, zs = [], []
    h = x
    nlayers = len(params)
    for i, (w, b) in enumerate(params):
        xs.append(h)
        is_hidden = i + 1 < nlayers
        if use_pallas:
            z_act = fused_linear(h, w, b, relu=is_hidden)
            # The ReLU mask needs the *pre*-activation sign; for hidden
            # layers the fused kernel only returns post-ReLU values, but
            # relu(z) > 0  <=>  z > 0, so the mask is recoverable and we
            # store the post-activation as its own mask carrier.
            zs.append(z_act)
            h = z_act
        else:
            z = jnp.dot(h, w) + b
            zs.append(z)
            h = jnp.maximum(z, 0.0) if is_hidden else z
    return h, xs, zs


def _softmax_ce(logits, y_onehot):
    """Per-example CE and the softmax probabilities (reused by backward)."""
    m = jnp.max(logits, axis=1, keepdims=True)
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=1, keepdims=True)) + m
    logp = logits - lse
    ce = -jnp.sum(logp * y_onehot, axis=1)
    probs = jnp.exp(logp)
    return ce, probs


def backward(params, xs, zs, dlogits):
    """Manual backprop through the MLP given ``dL/dlogits``.

    Returns ``(grads, gs)``: ``grads`` is the ``[(gW, gb), ...]`` parameter
    gradient list and ``gs[l] = dL/dY_l`` the per-layer output gradients
    consumed by Proposition 1.
    """
    nlayers = len(params)
    grads = [None] * nlayers
    gs = [None] * nlayers
    g = dlogits
    for i in range(nlayers - 1, -1, -1):
        w, _b = params[i]
        gs[i] = g
        gw = jnp.dot(xs[i].T, g)
        gb = jnp.sum(g, axis=0)
        grads[i] = (gw, gb)
        if i > 0:
            g = jnp.dot(g, w.T)
            # ReLU mask: zs[i-1] holds post-ReLU activations for hidden
            # layers (see forward); relu(z) > 0 <=> z > 0.
            g = g * (zs[i - 1] > 0.0).astype(g.dtype)
    return grads, gs


# ---------------------------------------------------------------------------
# Entry points (AOT-lowered by aot.py)
# ---------------------------------------------------------------------------

def train_step(flat_params, x, y_onehot, coef, lr):
    """One importance-weighted SGD step.

    loss = mean_m coef[m] * CE(x_m)  — the paper's §4.1 minibatch loss with
    ``coef_m = (1/N sum_n omega_n) / omega_{i_m}`` (all-ones = plain SGD).

    Returns ``(new_flat_params..., loss)``.
    """
    params = params_from_flat(flat_params)
    m = x.shape[0]
    logits, xs, zs = forward(params, x)
    ce, probs = _softmax_ce(logits, y_onehot)
    loss = jnp.mean(coef * ce)
    dlogits = (probs - y_onehot) * (coef / m)[:, None]
    grads, _gs = backward(params, xs, zs, dlogits)
    lr = lr.reshape(())
    new_params = [(w - lr * gw, b - lr * gb) for (w, b), (gw, gb) in zip(params, grads)]
    return tuple(params_to_flat(new_params)) + (loss,)


def grad_norms(flat_params, x, y_onehot):
    """Per-example gradient *squared* norms + per-example losses.

    This is the worker scoring path: Proposition 1 via the Pallas kernel.
    The per-example loss uses unscaled CE (the paper's ``L(x_n)``), so the
    backward seed for example ``n`` is ``softmax - y`` with no 1/M factor.
    """
    params = params_from_flat(flat_params)
    logits, xs, zs = forward(params, x)
    ce, probs = _softmax_ce(logits, y_onehot)
    dlogits = probs - y_onehot
    # Per-layer output gradients WITHOUT forming per-example weight grads:
    # we only need the backpropagated G_l matrices.
    _grads, gs = backward(params, xs, zs, dlogits)
    sqnorms = mlp_sqnorms(xs, gs)
    return sqnorms, ce


def eval_step(flat_params, x, y_onehot):
    """``(sum CE, number correct)`` over the batch — used for figures 2-3."""
    params = params_from_flat(flat_params)
    logits, _xs, _zs = forward(params, x)
    ce, _probs = _softmax_ce(logits, y_onehot)
    pred = jnp.argmax(logits, axis=1)
    label = jnp.argmax(y_onehot, axis=1)
    ncorrect = jnp.sum((pred == label).astype(jnp.float32))
    return jnp.sum(ce), ncorrect


def grad_mean_sqnorm(flat_params, x, y_onehot):
    """``||grad of mean CE||_2^2`` over the flat parameter vector.

    Used by the master to approximate ``||g_TRUE||^2`` (paper §B.2) by
    averaging this quantity over minibatches.
    """
    params = params_from_flat(flat_params)
    m = x.shape[0]
    logits, xs, zs = forward(params, x)
    _ce, probs = _softmax_ce(logits, y_onehot)
    dlogits = (probs - y_onehot) / m
    grads, _gs = backward(params, xs, zs, dlogits)
    total = jnp.float32(0.0)
    for gw, gb in grads:
        total = total + jnp.sum(jnp.square(gw)) + jnp.sum(jnp.square(gb))
    return total


def peer_step(flat_params, x, y_onehot, coef):
    """ASGD/peer-mode entry point (paper §6's recommended combination).

    Unlike ``train_step`` (which applies the SGD update locally), a *peer*
    returns the raw weighted gradient so a parameter server can apply it
    asynchronously — and, "whenever a gradient contribution is computed,
    the importance weights can be obtained at the same time" (§6): the
    same backward pass also yields the per-example gradient norms of the
    *unweighted* loss via Proposition 1, to be shared as importance
    weights.

    Returns ``(grad_W0, grad_b0, ..., loss, sqnorms[M])``.

    The per-example norm recovery uses that backprop is row-independent
    across the batch: the weighted backward seeds each row with
    ``(coef_m / M) * (softmax - y)``, so the unweighted per-example squared
    norm is the weighted one divided by ``(coef_m / M)^2`` (guarded for
    padded rows with ``coef = 0``, which get weight 0).
    """
    params = params_from_flat(flat_params)
    m = x.shape[0]
    logits, xs, zs = forward(params, x)
    ce, probs = _softmax_ce(logits, y_onehot)
    loss = jnp.mean(coef * ce)
    scale = coef / m
    dlogits = (probs - y_onehot) * scale[:, None]
    grads, gs = backward(params, xs, zs, dlogits)
    sq_weighted = mlp_sqnorms(xs, gs)
    safe = jnp.where(scale > 0.0, scale, 1.0)
    sqnorms = jnp.where(scale > 0.0, sq_weighted / jnp.square(safe), 0.0)
    flat_grads = []
    for gw, gb in grads:
        flat_grads.append(gw)
        flat_grads.append(gb)
    return tuple(flat_grads) + (loss, sqnorms)
