"""AOT pipeline: lower the four L2 entry points to HLO text artifacts.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Usage (from the ``python/`` directory)::

    python -m compile.aot --config small --out ../artifacts
    python -m compile.aot --config paper --out ../artifacts

Writes ``<out>/<config>/{train_step,grad_norms,eval_step,grad_mean_sqnorm}.hlo.txt``
plus ``<out>/<config>/manifest.json`` describing every shape the rust
runtime needs.  Python never runs again after this: the rust binary loads
the text, compiles it on the PJRT CPU client, and owns the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# ---------------------------------------------------------------------------
# Model/batch configurations.  HLO artifacts are shape-specialised, so the
# minibatch sizes are fixed here and recorded in the manifest.
#
#   dims        : layer widths, input -> hidden... -> classes
#   batch_train : M, the master's SGD minibatch
#   batch_score : B, the worker scoring batch (per-example grad norms)
#   batch_eval  : E, the evaluation batch
#
# ``paper`` matches Alain et al. §5.1: permutation-invariant SVHN, 3072-dim
# inputs, 4 hidden layers of 2048 ReLU units, 10 classes.  ``small`` keeps
# the same shape family at CPU-friendly width; ``tiny`` is for unit tests.
# ---------------------------------------------------------------------------
CONFIGS = {
    "tiny": dict(dims=[64, 32, 32, 10], batch_train=8, batch_score=16, batch_eval=16),
    "small": dict(dims=[3072, 256, 256, 256, 256, 10], batch_train=64, batch_score=256, batch_eval=512),
    "paper": dict(dims=[3072, 2048, 2048, 2048, 2048, 10], batch_train=128, batch_score=256, batch_eval=512),
    "large": dict(dims=[3072, 4096, 4096, 4096, 4096, 10], batch_train=128, batch_score=256, batch_eval=512),
}

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def param_specs(dims):
    """ShapeDtypeStructs for the flat (W_0, b_0, ...) parameter arguments."""
    specs = []
    for din, dout in model.layer_dims(dims):
        specs.append(jax.ShapeDtypeStruct((din, dout), F32))
        specs.append(jax.ShapeDtypeStruct((dout,), F32))
    return specs


def entry_points(cfg):
    """(name, fn, arg_specs) for each AOT entry point of one config."""
    dims = cfg["dims"]
    nl = len(model.layer_dims(dims))
    d, c = dims[0], dims[-1]
    m, b, e = cfg["batch_train"], cfg["batch_score"], cfg["batch_eval"]
    ps = param_specs(dims)

    def wrap(core, nbatch_args):
        def f(*args):
            flat = args[: 2 * nl]
            rest = args[2 * nl :]
            return core(flat, *rest)

        return f

    xspec = lambda n: jax.ShapeDtypeStruct((n, d), F32)
    yspec = lambda n: jax.ShapeDtypeStruct((n, c), F32)

    return [
        (
            "train_step",
            wrap(model.train_step, 4),
            ps + [xspec(m), yspec(m), jax.ShapeDtypeStruct((m,), F32), jax.ShapeDtypeStruct((1,), F32)],
        ),
        ("grad_norms", wrap(model.grad_norms, 2), ps + [xspec(b), yspec(b)]),
        (
            "peer_step",
            wrap(model.peer_step, 3),
            ps + [xspec(m), yspec(m), jax.ShapeDtypeStruct((m,), F32)],
        ),
        ("eval_step", wrap(model.eval_step, 2), ps + [xspec(e), yspec(e)]),
        ("grad_mean_sqnorm", wrap(model.grad_mean_sqnorm, 2), ps + [xspec(m), yspec(m)]),
    ]


def lower_config(name: str, out_dir: str) -> dict:
    cfg = CONFIGS[name]
    cfg_dir = os.path.join(out_dir, name)
    os.makedirs(cfg_dir, exist_ok=True)
    artifacts = {}
    for ep_name, fn, specs in entry_points(cfg):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{ep_name}.hlo.txt"
        path = os.path.join(cfg_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        artifacts[ep_name] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"  {name}/{fname}: {len(text)} chars")

    dims = cfg["dims"]
    layers = [
        {"w_shape": [din, dout], "b_shape": [dout]}
        for din, dout in model.layer_dims(dims)
    ]
    n_params = sum(din * dout + dout for din, dout in model.layer_dims(dims))
    manifest = {
        "config": name,
        "dims": dims,
        "dtype": "f32",
        "n_classes": dims[-1],
        "input_dim": dims[0],
        "n_layers": len(layers),
        "n_params": n_params,
        "layers": layers,
        "batch_train": cfg["batch_train"],
        "batch_score": cfg["batch_score"],
        "batch_eval": cfg["batch_eval"],
        "artifacts": artifacts,
        # Argument conventions the rust runtime relies on:
        #   every entry point: 2*n_layers leading params (W_0, b_0, ...)
        #   train_step extras: x[M,d], y[M,C], coef[M], lr[1]
        #                      -> outputs (params'..., loss)
        #   grad_norms extras: x[B,d], y[B,C] -> (sqnorm[B], ce[B])
        #   peer_step extras : x[M,d], y[M,C], coef[M]
        #                      -> (grads..., loss, sqnorm[M])  (ASGD peers)
        #   eval_step  extras: x[E,d], y[E,C] -> (sum_ce, n_correct)
        #   grad_mean_sqnorm : x[M,d], y[M,C] -> (sqnorm,)
        "calling_convention": "flat-params-first",
    }
    with open(os.path.join(cfg_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="tiny,small", help="comma-separated config names, or 'all'")
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    names = list(CONFIGS) if args.config == "all" else args.config.split(",")
    for name in names:
        if name not in CONFIGS:
            raise SystemExit(f"unknown config {name!r}; have {list(CONFIGS)}")
        print(f"lowering config {name} (dims={CONFIGS[name]['dims']})")
        lower_config(name, args.out)
    print("AOT done.")


if __name__ == "__main__":
    main()
