"""peer_step (ASGD/peer-mode entry point, paper §6 extension) correctness:
gradients match jax.grad of the weighted loss, and the co-computed
per-example squared norms match the vmap(grad) oracle of the UNWEIGHTED
per-example losses.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

DIMS = [12, 16, 16, 5]


def setup(seed=0, n=8):
    key = jax.random.PRNGKey(seed)
    params = model.init_params(key, DIMS)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 100))
    x = jax.random.normal(k1, (n, DIMS[0]), jnp.float32)
    labels = jax.random.randint(k2, (n,), 0, DIMS[-1])
    y = jax.nn.one_hot(labels, DIMS[-1], dtype=jnp.float32)
    return params, x, y


class TestPeerStep:
    def test_gradients_match_jax_grad(self):
        params, x, y = setup(1)
        coef = jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (8,))) + 0.25
        flat = model.params_to_flat(params)
        out = model.peer_step(flat, x, y, coef)
        nl = len(params)
        grads_flat, loss, sqnorms = out[: 2 * nl], out[2 * nl], out[2 * nl + 1]

        want_loss = ref.weighted_ce_ref(params, x, y, coef)
        np.testing.assert_allclose(float(loss), float(want_loss), rtol=1e-5)

        want = model.params_to_flat(jax.grad(ref.weighted_ce_ref)(params, x, y, coef))
        for got, w in zip(grads_flat, want):
            np.testing.assert_allclose(np.asarray(got), np.asarray(w), rtol=1e-4, atol=1e-6)
        assert sqnorms.shape == (8,)

    def test_sqnorms_match_unweighted_oracle(self):
        params, x, y = setup(2)
        # Non-trivial coefficients including a padded (zero) slot.
        coef = jnp.array([1.0, 2.0, 0.5, 3.0, 0.25, 1.5, 4.0, 0.0], jnp.float32)
        flat = model.params_to_flat(params)
        out = model.peer_step(flat, x, y, coef)
        sqnorms = np.asarray(out[-1])
        want = np.asarray(ref.per_example_grad_sqnorm_ref(params, x, y))
        # Slots with coef == 0 report weight 0 by convention.
        np.testing.assert_allclose(sqnorms[:7], want[:7], rtol=1e-3, atol=1e-6)
        assert sqnorms[7] == 0.0

    def test_applying_returned_gradient_matches_train_step(self):
        # params - lr * peer_grad == train_step(params) — the two entry
        # points must agree so a parameter server reproduces local SGD.
        params, x, y = setup(3)
        coef = jnp.ones((8,), jnp.float32)
        lr = 0.07
        flat = model.params_to_flat(params)
        peer = model.peer_step(flat, x, y, coef)
        nl = len(params)
        stepped = model.train_step(flat, x, y, coef, jnp.array([lr], jnp.float32))
        for g, p0, p1 in zip(peer[: 2 * nl], flat, stepped[:-1]):
            np.testing.assert_allclose(
                np.asarray(p0) - lr * np.asarray(g),
                np.asarray(p1),
                rtol=1e-4,
                atol=1e-6,
            )

    def test_zero_coef_contributes_nothing(self):
        params, x, y = setup(4)
        flat = model.params_to_flat(params)
        nl = len(params)
        all_zero = model.peer_step(flat, x, y, jnp.zeros((8,), jnp.float32))
        for g in all_zero[: 2 * nl]:
            assert np.allclose(np.asarray(g), 0.0)
        assert float(all_zero[2 * nl]) == 0.0
