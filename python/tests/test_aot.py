"""AOT pipeline checks: lowering produces loadable HLO text + sane manifest."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_config("tiny", str(out))
    return str(out), manifest


class TestLowering:
    def test_all_entry_points_emitted(self, tiny_artifacts):
        out, manifest = tiny_artifacts
        for name in ("train_step", "grad_norms", "eval_step", "grad_mean_sqnorm"):
            path = os.path.join(out, "tiny", manifest["artifacts"][name]["file"])
            assert os.path.exists(path)
            text = open(path).read()
            assert "ENTRY" in text, f"{name} HLO text has no ENTRY computation"
            assert "HloModule" in text

    def test_manifest_contents(self, tiny_artifacts):
        out, manifest = tiny_artifacts
        on_disk = json.load(open(os.path.join(out, "tiny", "manifest.json")))
        assert on_disk == manifest
        assert manifest["dims"] == [64, 32, 32, 10]
        assert manifest["n_layers"] == 3
        assert manifest["n_params"] == 64 * 32 + 32 + 32 * 32 + 32 + 32 * 10 + 10
        assert manifest["calling_convention"] == "flat-params-first"

    def test_train_step_signature_shapes(self, tiny_artifacts):
        # The ENTRY line must carry 2L params + x,y,coef,lr operands.
        out, manifest = tiny_artifacts
        text = open(os.path.join(out, "tiny", "train_step.hlo.txt")).read()
        m = manifest["batch_train"]
        d = manifest["input_dim"]
        assert f"f32[{m},{d}]" in text, "train minibatch operand shape missing"
        assert f"f32[{m}]" in text, "coef operand shape missing"

    def test_unknown_config_rejected(self):
        with pytest.raises(KeyError):
            aot.lower_config("nonexistent", "/tmp")


class TestConfigTable:
    def test_paper_config_matches_paper(self):
        cfg = aot.CONFIGS["paper"]
        assert cfg["dims"] == [3072, 2048, 2048, 2048, 2048, 10]

    def test_all_configs_have_batches(self):
        for name, cfg in aot.CONFIGS.items():
            for k in ("dims", "batch_train", "batch_score", "batch_eval"):
                assert k in cfg, (name, k)
            assert len(cfg["dims"]) >= 3
