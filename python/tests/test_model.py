"""L2 correctness: the manual-backprop MLP vs jax.grad / vmap(grad) oracles.

The critical checks:
  * forward (through the Pallas fused_linear) == plain-jnp forward
  * train_step's parameter update == SGD on jax.grad of the weighted loss
  * grad_norms (Proposition 1 via the Pallas kernel) == vmap(grad) sqnorms
  * the importance-weighted gradient estimator is UNBIASED (paper Thm 1)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

DIMS = [12, 16, 16, 5]  # tiny 2-hidden-layer MLP for oracle-speed tests


def setup(seed=0, n=9, dims=DIMS):
    key = jax.random.PRNGKey(seed)
    params = model.init_params(key, dims)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 100))
    x = jax.random.normal(k1, (n, dims[0]), jnp.float32)
    labels = jax.random.randint(k2, (n,), 0, dims[-1])
    y = jax.nn.one_hot(labels, dims[-1], dtype=jnp.float32)
    return params, x, y


class TestForward:
    @settings(deadline=None, max_examples=10)
    @given(seed=st.integers(0, 1000), n=st.integers(1, 40))
    def test_matches_plain_jnp(self, seed, n):
        params, x, _ = setup(seed, n)
        logits, xs, zs = model.forward(params, x)
        want = ref.mlp_forward_ref(params, x)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(want), rtol=1e-4, atol=1e-5)
        assert len(xs) == len(params) and len(zs) == len(params)
        # xs[0] is the input itself; later xs are post-ReLU, thus >= 0
        np.testing.assert_allclose(np.asarray(xs[0]), np.asarray(x))
        for h in xs[1:]:
            assert np.all(np.asarray(h) >= 0.0)


class TestTrainStep:
    def test_gradient_matches_jax_grad(self):
        params, x, y = setup(3, 8)
        coef = jnp.abs(jax.random.normal(jax.random.PRNGKey(7), (8,))) + 0.5
        lr = jnp.array([0.05], jnp.float32)
        flat = model.params_to_flat(params)
        out = model.train_step(flat, x, y, coef, lr)
        new_flat, loss = out[:-1], out[-1]

        want_loss = ref.weighted_ce_ref(params, x, y, coef)
        np.testing.assert_allclose(float(loss), float(want_loss), rtol=1e-5)

        grads = jax.grad(ref.weighted_ce_ref)(params, x, y, coef)
        want_flat = [p - 0.05 * g for p, g in zip(flat, model.params_to_flat(grads))]
        for got, want in zip(new_flat, want_flat):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-6)

    def test_unit_coef_is_plain_sgd(self):
        params, x, y = setup(4, 6)
        flat = model.params_to_flat(params)
        lr = jnp.array([0.1], jnp.float32)
        out = model.train_step(flat, x, y, jnp.ones((6,), jnp.float32), lr)
        grads = jax.grad(ref.ce_loss_ref)(params, x, y)
        want = [p - 0.1 * g for p, g in zip(flat, model.params_to_flat(grads))]
        for got, w in zip(out[:-1], want):
            np.testing.assert_allclose(np.asarray(got), np.asarray(w), rtol=1e-4, atol=1e-6)

    def test_loss_decreases_over_steps(self):
        params, x, y = setup(5, 8)
        flat = model.params_to_flat(params)
        coef = jnp.ones((8,), jnp.float32)
        lr = jnp.array([0.05], jnp.float32)
        losses = []
        for _ in range(30):
            out = model.train_step(flat, x, y, coef, lr)
            flat, loss = list(out[:-1]), float(out[-1])
            losses.append(loss)
        assert losses[-1] < losses[0] * 0.5, losses

    def test_importance_weighted_estimator_is_unbiased(self):
        # Theorem 1 sanity: E_q[(p/q) g] == E_p[g].  Build a 4-example
        # "dataset", a non-uniform proposal, and average the weighted
        # single-example gradients over the exact proposal distribution.
        params, x, y = setup(6, 4)
        omega = np.array([0.5, 1.0, 2.0, 4.0], np.float64)
        zbar = omega.mean()
        grads_true = jax.grad(ref.ce_loss_ref)(params, x, y)
        flat_true = np.concatenate([np.asarray(g).ravel() for g in model.params_to_flat(grads_true)])

        probs = omega / omega.sum()
        acc = None
        for n in range(4):
            coef = jnp.zeros((4,), jnp.float32).at[n].set(zbar / omega[n])
            # gradient of mean(coef * ce) with only example n active = coef_n/4 * grad ce_n
            g = jax.grad(ref.weighted_ce_ref)(params, x, y, coef)
            flat = np.concatenate([np.asarray(t).ravel() for t in model.params_to_flat(g)])
            # minibatch of size 1 drawn as example n has weight probs[n]; the
            # 1/M=1/4 in weighted_ce_ref must be undone (M=1 here): scale by 4.
            contrib = probs[n] * 4.0 * flat
            acc = contrib if acc is None else acc + contrib
        np.testing.assert_allclose(acc, flat_true, rtol=1e-4, atol=1e-7)


class TestGradNorms:
    @settings(deadline=None, max_examples=8)
    @given(seed=st.integers(0, 500), n=st.integers(1, 20))
    def test_matches_vmap_grad_oracle(self, seed, n):
        params, x, y = setup(seed, n)
        flat = model.params_to_flat(params)
        sqnorms, ce = model.grad_norms(flat, x, y)
        want_sq = ref.per_example_grad_sqnorm_ref(params, x, y)
        want_ce = ref.per_example_ce_ref(params, x, y)
        np.testing.assert_allclose(np.asarray(sqnorms), np.asarray(want_sq), rtol=1e-3, atol=1e-6)
        np.testing.assert_allclose(np.asarray(ce), np.asarray(want_ce), rtol=1e-4, atol=1e-6)

    def test_deeper_model(self):
        dims = [12, 16, 16, 16, 16, 5]  # 4 hidden layers like the paper
        params, x, y = setup(11, 7, dims)
        flat = model.params_to_flat(params)
        sqnorms, _ = model.grad_norms(flat, x, y)
        want = ref.per_example_grad_sqnorm_ref(params, x, y)
        np.testing.assert_allclose(np.asarray(sqnorms), np.asarray(want), rtol=1e-3, atol=1e-6)


class TestEvalStep:
    def test_counts_and_loss(self):
        params, x, y = setup(8, 20)
        flat = model.params_to_flat(params)
        sumloss, ncorrect = model.eval_step(flat, x, y)
        logits = ref.mlp_forward_ref(params, x)
        want_correct = np.sum(np.argmax(np.asarray(logits), 1) == np.argmax(np.asarray(y), 1))
        want_loss = float(jnp.sum(ref.per_example_ce_ref(params, x, y)))
        assert float(ncorrect) == want_correct
        np.testing.assert_allclose(float(sumloss), want_loss, rtol=1e-4)
        assert 0 <= float(ncorrect) <= 20


class TestGradMeanSqnorm:
    def test_matches_oracle(self):
        params, x, y = setup(9, 10)
        flat = model.params_to_flat(params)
        got = model.grad_mean_sqnorm(flat, x, y)
        want = ref.mean_grad_sqnorm_ref(params, x, y)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-4)


class TestParamPlumbing:
    def test_flat_roundtrip(self):
        params, _, _ = setup(1)
        flat = model.params_to_flat(params)
        back = model.params_from_flat(flat)
        assert len(back) == len(params)
        for (w, b), (w2, b2) in zip(params, back):
            assert w is w2 and b is b2

    def test_odd_flat_raises(self):
        with pytest.raises(ValueError):
            model.params_from_flat([jnp.zeros((2, 2))])

    def test_init_shapes(self):
        params = model.init_params(jax.random.PRNGKey(0), [7, 5, 3])
        assert [tuple(w.shape) for w, _ in params] == [(7, 5), (5, 3)]
        assert [tuple(b.shape) for _, b in params] == [(5,), (3,)]
        # He init: biases zero, weights non-degenerate
        for _, b in params:
            assert np.all(np.asarray(b) == 0.0)
