"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the compute layer: every kernel
must match ref.py to float32 tolerance across a hypothesis-driven sweep of
shapes, including batch sizes that do not divide the block size.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_linear import fused_linear
from compile.kernels.per_example_norm import layer_sqnorm, mlp_sqnorms

SETTINGS = dict(deadline=None, max_examples=15)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# per_example_norm.layer_sqnorm
# ---------------------------------------------------------------------------

class TestLayerSqnorm:
    @settings(**SETTINGS)
    @given(
        n=st.integers(1, 300),
        din=st.integers(1, 80),
        dout=st.integers(1, 40),
        block=st.sampled_from([8, 32, 128]),
    )
    def test_matches_ref_shape_sweep(self, n, din, dout, block):
        x = rand(n * 7 + din, n, din)
        g = rand(n * 13 + dout, n, dout)
        got = layer_sqnorm(x, g, block_n=block)
        want = ref.layer_sqnorm_ref(x, g)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_block_exact_multiple(self):
        x, g = rand(0, 256, 64), rand(1, 256, 32)
        np.testing.assert_allclose(
            np.asarray(layer_sqnorm(x, g, block_n=128)),
            np.asarray(ref.layer_sqnorm_ref(x, g)),
            rtol=1e-5,
        )

    def test_zero_gradient_rows_give_zero(self):
        x = rand(2, 17, 8)
        g = jnp.zeros((17, 4), jnp.float32)
        assert np.allclose(np.asarray(layer_sqnorm(x, g)), 0.0)

    def test_zero_input_rows_keep_bias_term(self):
        # X = 0 kills the W contribution but not the b contribution.
        x = jnp.zeros((9, 5), jnp.float32)
        g = rand(3, 9, 6)
        want = np.sum(np.square(np.asarray(g)), axis=1)
        np.testing.assert_allclose(np.asarray(layer_sqnorm(x, g)), want, rtol=1e-5)

    def test_batch_mismatch_raises(self):
        with pytest.raises(ValueError):
            layer_sqnorm(rand(0, 4, 3), rand(1, 5, 3))

    def test_scaling_is_quartic_in_x_g(self):
        # sqnorm(aX, bG) = a^2 b^2 rx rg + b^2 rg
        x, g = rand(4, 12, 7), rand(5, 12, 3)
        base_rx = np.sum(np.square(np.asarray(x)), axis=1)
        base_rg = np.sum(np.square(np.asarray(g)), axis=1)
        got = np.asarray(layer_sqnorm(2.0 * x, 3.0 * g))
        want = 4.0 * 9.0 * base_rx * base_rg + 9.0 * base_rg
        np.testing.assert_allclose(got, want, rtol=1e-4)


class TestMlpSqnorms:
    def test_accumulates_layers(self):
        xs = [rand(0, 33, 10), rand(1, 33, 6)]
        gs = [rand(2, 33, 6), rand(3, 33, 4)]
        got = np.asarray(mlp_sqnorms(xs, gs))
        want = sum(np.asarray(ref.layer_sqnorm_ref(x, g)) for x, g in zip(xs, gs))
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_length_mismatch_raises(self):
        with pytest.raises(ValueError):
            mlp_sqnorms([rand(0, 4, 3)], [])


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------

class TestFusedLinear:
    @settings(**SETTINGS)
    @given(
        m=st.integers(1, 200),
        k=st.integers(1, 64),
        n=st.integers(1, 300),
        relu=st.booleans(),
    )
    def test_matches_ref_shape_sweep(self, m, k, n, relu):
        x = rand(m + 17, m, k)
        w = rand(k + 31, k, n)
        b = rand(n + 43, n)
        got = fused_linear(x, w, b, relu=relu)
        want = ref.fused_linear_ref(x, w, b, relu=relu)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_relu_clamps_negative(self):
        x = -jnp.ones((4, 3), jnp.float32)
        w = jnp.eye(3, dtype=jnp.float32)
        b = jnp.zeros((3,), jnp.float32)
        assert np.all(np.asarray(fused_linear(x, w, b, relu=True)) == 0.0)
        assert np.all(np.asarray(fused_linear(x, w, b, relu=False)) == -1.0)

    def test_shape_validation(self):
        with pytest.raises(ValueError):
            fused_linear(rand(0, 4, 3), rand(1, 5, 2), jnp.zeros((2,), jnp.float32))
        with pytest.raises(ValueError):
            fused_linear(rand(0, 4, 3), rand(1, 3, 2), jnp.zeros((3,), jnp.float32))

    def test_block_sizes_do_not_change_result(self):
        x, w, b = rand(0, 100, 24), rand(1, 24, 70), rand(2, 70)
        a = fused_linear(x, w, b, block_m=32, block_n=32)
        c = fused_linear(x, w, b, block_m=128, block_n=256)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-4, atol=1e-5)
