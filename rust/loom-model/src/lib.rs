//! Loom models of the weight-store's cross-thread protocols.
//!
//! These do not run the real `MemStore` (loom needs its own `Mutex`/atomic
//! types); each model re-states one protocol from
//! `src/weightstore/mod.rs` in loom primitives and lets loom enumerate
//! every legal interleaving + memory-model outcome.  The protocols:
//!
//! 1. **Sequence claim under the shard write lock** — `push_weights`
//!    claims the global write sequence while holding the shard's write
//!    lock, so a reader that observed counter value `w` and then takes the
//!    shard lock must see the entries stamped `w` (the module's "no lost
//!    updates" guarantee).
//! 2. **Cursor pin vs compaction** — `save_cursor` and `compact_before`
//!    serialize on the cursors mutex; a pin present when the compactor
//!    reads the map clamps the floor, a pin saved after may not.
//! 3. **Floor publish ordering** — `compact_before` publishes the raised
//!    floor *before* re-tagging per-entry sequences, and re-tags only ever
//!    raise, so an incremental reader can never have a changed entry
//!    hidden from it.
//!
//! The same contracts are exercised without loom (exhaustive *serial*
//! interleavings over the real store) by `rust/tests/interleave_model.rs`,
//! which runs in tier-1; this crate is built only in the CI `loom` job.

#[cfg(test)]
mod models {
    use loom::sync::atomic::{AtomicU64, Ordering};
    use loom::sync::{Arc, Mutex};
    use loom::thread;

    /// Protocol 1: the writer claims the next write sequence *while
    /// holding* the shard write lock.  Invariant: a reader that observed
    /// the claimed counter value and then acquires the shard lock sees the
    /// stamped entry — the cursor it hands out can never cover a write the
    /// shard does not yet show.
    #[test]
    fn seq_claim_under_shard_lock_is_visible() {
        loom::model(|| {
            let counter = Arc::new(AtomicU64::new(1));
            let shard = Arc::new(Mutex::new(1u64)); // the entry's write seq
            let c2 = Arc::clone(&counter);
            let s2 = Arc::clone(&shard);
            let writer = thread::spawn(move || {
                let mut entry = s2.lock().unwrap();
                let w = c2.fetch_add(1, Ordering::AcqRel) + 1;
                *entry = w;
            });
            // Reader: observe the counter (this becomes delta.seq), then
            // scan the shard under its lock.
            let head = counter.load(Ordering::Acquire);
            let entry = *shard.lock().unwrap();
            assert!(
                head < 2 || entry >= 2,
                "cursor {head} covers write 2 but the shard still shows {entry}"
            );
            writer.join().unwrap();
        });
    }

    /// Protocol 2: `save_cursor` and `compact_before` serialize on the
    /// cursors mutex.  If the pin was in the map when the compactor read
    /// it, the floor is clamped to the pin; if not, the floor may take the
    /// full limit — but never anything in between.
    #[test]
    fn pin_present_at_fold_clamps_the_floor() {
        const PIN: u64 = 2;
        const LIMIT: u64 = 5;
        loom::model(|| {
            let cursors = Arc::new(Mutex::new(None::<u64>));
            let floor = Arc::new(AtomicU64::new(0));
            let cu = Arc::clone(&cursors);
            let consumer = thread::spawn(move || {
                *cu.lock().unwrap() = Some(PIN);
            });
            let saw_pin = {
                let pins = cursors.lock().unwrap();
                let clamp = pins.unwrap_or(u64::MAX);
                let target = LIMIT.min(clamp);
                if target > floor.load(Ordering::Acquire) {
                    floor.store(target, Ordering::Release);
                }
                pins.is_some()
            };
            consumer.join().unwrap();
            let f = floor.load(Ordering::Acquire);
            let expected = if saw_pin { PIN } else { LIMIT };
            assert_eq!(f, expected, "floor {f} disagrees with pin visibility");
        });
    }

    /// Protocol 3: the compactor publishes the raised floor before
    /// re-tagging entries, and re-tags only ever raise a sequence.  An
    /// incremental reader (cursor not below the floor it observed) must
    /// still be shown every entry written after its cursor — the re-tag
    /// can widen the delta (idempotent re-delivery) but never hide it.
    #[test]
    fn floor_publish_never_hides_a_write() {
        const CURSOR: u64 = 1;
        const TARGET: u64 = 3;
        loom::model(|| {
            let floor = Arc::new(AtomicU64::new(0));
            let entry_seq = Arc::new(AtomicU64::new(2)); // written after CURSOR
            let f2 = Arc::clone(&floor);
            let e2 = Arc::clone(&entry_seq);
            let compactor = thread::spawn(move || {
                // Publish first, then fold (the order the code comments
                // insist on); the fold only raises.
                f2.store(TARGET, Ordering::Release);
                let s = e2.load(Ordering::Acquire);
                if s < TARGET {
                    e2.store(TARGET, Ordering::Release);
                }
            });
            let f = floor.load(Ordering::Acquire);
            if f <= CURSOR {
                // Incremental service: the changed entry must be visible.
                let s = entry_seq.load(Ordering::Acquire);
                assert!(
                    s > CURSOR,
                    "incremental fetch at cursor {CURSOR} lost the entry (seq {s})"
                );
            }
            // else: full fallback — trivially delivers everything.
            compactor.join().unwrap();
        });
    }
}
