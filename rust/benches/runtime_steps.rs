//! Runtime bench: per-entry-point PJRT execution latency — the L3-visible
//! cost of each AOT artifact, plus host-side batch assembly, isolating
//! where a master step's time goes (EXPERIMENTS.md §Perf).
//!
//! Uses `tiny` artifacts by default; set `ISSGD_BENCH_MODEL=small` for the
//! SVHN-shaped model.

use issgd::bench::Harness;
use issgd::data::{BatchBuilder, SynthDataset, SynthSpec};
use issgd::model::ParamSet;
use issgd::runtime::{artifacts_dir, Engine};
use issgd::util::rng::Pcg64;

fn main() {
    let model = std::env::var("ISSGD_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let dir = artifacts_dir(&model);
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping runtime_steps bench: no artifacts for {model} (run `make artifacts`)");
        return;
    }
    let engine = Engine::load(&dir).expect("engine");
    let m = engine.manifest().clone();
    let mut h = Harness::from_env(&format!("runtime[{model}]"));

    let spec = if m.input_dim == 64 {
        SynthSpec::tiny(2048)
    } else {
        SynthSpec {
            dim: m.input_dim,
            ..SynthSpec::svhn_like(2048)
        }
    };
    let data = SynthDataset::generate(7, spec);
    let mut rng = Pcg64::seeded(3);
    let mut params = ParamSet::init_he(&m, &mut rng);

    // Host-side batch assembly.
    let mut tb = BatchBuilder::new(m.batch_train, m.input_dim, m.n_classes);
    let idx = rng.sample_with_replacement(2048, m.batch_train);
    h.bench_throughput(&format!("batch_fill/m={}", m.batch_train), m.batch_train as u64, || {
        std::hint::black_box(tb.fill(&data, &idx));
    });

    // train_step.
    let coef = vec![1.0f32; m.batch_train];
    tb.fill(&data, &idx);
    h.bench_throughput(&format!("train_step/m={}", m.batch_train), m.batch_train as u64, || {
        engine
            .train_step(&mut params, &tb.x, &tb.y, &coef, 1e-4)
            .unwrap();
    });

    // grad_norms (the worker hot path).
    let mut sb = BatchBuilder::new(m.batch_score, m.input_dim, m.n_classes);
    let sidx: Vec<usize> = (0..m.batch_score).collect();
    sb.fill(&data, &sidx);
    h.bench_throughput(&format!("grad_norms/b={}", m.batch_score), m.batch_score as u64, || {
        std::hint::black_box(engine.grad_norms(&params, &sb.x, &sb.y).unwrap());
    });

    // eval_step.
    let mut eb = BatchBuilder::new(m.batch_eval, m.input_dim, m.n_classes);
    let eidx: Vec<usize> = (0..m.batch_eval).collect();
    eb.fill(&data, &eidx);
    h.bench_throughput(&format!("eval_step/e={}", m.batch_eval), m.batch_eval as u64, || {
        std::hint::black_box(engine.eval_step(&params, &eb.x, &eb.y).unwrap());
    });

    // grad_mean_sqnorm.
    h.bench(&format!("grad_mean_sqnorm/m={}", m.batch_train), || {
        std::hint::black_box(engine.grad_mean_sqnorm(&params, &tb.x, &tb.y).unwrap());
    });

    // Params host<->literal serialisation (per-step overhead today).
    h.bench("params/to_bytes", || {
        std::hint::black_box(params.to_bytes());
    });

    h.finish();
}
