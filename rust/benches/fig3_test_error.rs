//! Paper bench — Figure 3: test prediction error curves, ISSGD vs SGD.
//! Smoke scale for `cargo bench`; full scale via `issgd experiment fig3`.

use issgd::experiments::{fig3, ExperimentScale};

fn main() {
    let scale = ExperimentScale::smoke();
    println!("== fig3 (smoke scale) ==");
    let t0 = std::time::Instant::now();
    match fig3::run(&scale) {
        Ok(()) => println!("fig3 bench done in {:.1}s", t0.elapsed().as_secs_f64()),
        Err(e) => eprintln!("fig3 bench skipped/failed: {e:#} (run `make artifacts`)"),
    }
}
