//! Paper bench — Figure 2: training loss + train prediction error, ISSGD
//! vs SGD, both hyperparameter settings, median over seeds.  `cargo bench`
//! runs this at smoke scale (tiny artifacts); the full-scale version is
//! `issgd experiment fig2 --model small`.

use issgd::experiments::{fig2, ExperimentScale};

fn main() {
    let scale = ExperimentScale::smoke();
    println!("== fig2 (smoke scale: {:?} seeds, {} steps) ==", scale.seeds, scale.steps);
    let t0 = std::time::Instant::now();
    match fig2::run(&scale) {
        Ok(runs) => {
            let q = runs.b_issgd.quartiles("eval_train_loss");
            let sgd_q = runs.b_sgd.quartiles("eval_train_loss");
            if let (Some(is_last), Some(sgd_last)) = (q.median.last(), sgd_q.median.last()) {
                println!(
                    "setting b final median train loss: issgd {is_last:.4} vs sgd {sgd_last:.4} \
                     (paper fig2: issgd descends faster)"
                );
            }
            println!("fig2 bench done in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("fig2 bench skipped/failed: {e:#} (run `make artifacts`)"),
    }
}
