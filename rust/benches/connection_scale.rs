//! Connection-scale bench: the event-loop store server under ~1k
//! concurrent TCP clients (the thread-per-connection design it replaced
//! topped out at a few hundred), reporting per-operation push/fetch
//! latency with p99 — the tail is what slow-client eviction and request
//! pipelining are supposed to protect.  `--quick` shrinks the fleet so
//! the CI smoke stays cheap; the full run feeds BENCH_pr8.json.

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use issgd::bench::Harness;
use issgd::weightstore::client::{Client, ClientOptions};
use issgd::weightstore::server::Server;
use issgd::weightstore::{MemStore, WeightStore};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("ISSGD_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    // Full run: 1000 live sockets against one event loop.  Quick run: a
    // fleet small enough for the CI smoke but still far beyond what one
    // thread-per-connection server tick could interleave.
    let (n_clients, rounds) = if quick { (64usize, 3usize) } else { (1000usize, 5usize) };
    let n_threads = 8usize.min(n_clients);
    let mut h = Harness::from_env("connection_scale");

    let n_weights = 1024usize;
    let server = Server::bind("127.0.0.1:0", Arc::new(MemStore::new(n_weights, 1.0))).unwrap();
    let (addr, handle) = server.serve_in_background().unwrap();
    let addr = addr.to_string();

    // Ramp every client up before timing any operation, so the samples
    // measure steady-state latency rather than connect storms.
    let barrier = Arc::new(Barrier::new(n_threads + 1));
    let t_ramp = Instant::now();
    let mut joins = Vec::new();
    for t in 0..n_threads {
        let addr = addr.clone();
        let barrier = Arc::clone(&barrier);
        let share = n_clients / n_threads + usize::from(t < n_clients % n_threads);
        joins.push(std::thread::spawn(move || {
            let clients: Vec<Client> = (0..share)
                .map(|_| Client::connect_with(&addr, ClientOptions::default()).unwrap())
                .collect();
            barrier.wait();
            let weights = [1.0f32; 16];
            let mut push_lat: Vec<Duration> = Vec::with_capacity(share * rounds);
            let mut fetch_lat: Vec<Duration> = Vec::with_capacity(share * rounds);
            for round in 0..rounds {
                for (i, client) in clients.iter().enumerate() {
                    let start = (t * 131 + i * 17) % (n_weights - weights.len());
                    let t0 = Instant::now();
                    client.push_weights(start, &weights, (round + 1) as u64).unwrap();
                    push_lat.push(t0.elapsed());
                    let t1 = Instant::now();
                    std::hint::black_box(client.fetch_weights_since(0).unwrap());
                    fetch_lat.push(t1.elapsed());
                }
            }
            (push_lat, fetch_lat)
        }));
    }
    barrier.wait();
    let ramp = t_ramp.elapsed();

    let mut push_lat: Vec<Duration> = Vec::new();
    let mut fetch_lat: Vec<Duration> = Vec::new();
    for j in joins {
        let (p, f) = j.join().unwrap();
        push_lat.extend(p);
        fetch_lat.extend(f);
    }
    println!(
        "connection_scale: {n_clients} clients connected in {ramp:?} \
         ({} push + {} fetch samples over {rounds} rounds)",
        push_lat.len(),
        fetch_lat.len()
    );
    h.record_samples(&format!("push_weights/conns={n_clients}"), &push_lat, Some(1));
    h.record_samples(&format!("fetch_since/conns={n_clients}"), &fetch_lat, Some(1));

    let ctl = Client::connect(&addr).unwrap();
    ctl.shutdown_server().unwrap();
    handle.join().unwrap();
    h.finish();
}
