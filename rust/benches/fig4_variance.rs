//! Paper bench — Figure 4: √Tr(Σ(q)) for q_IDEAL / q_STALE (two smoothing
//! constants) / q_UNIF during ISSGD training.  Also asserts the §4.2
//! ordering ideal ≤ stale on every checkpoint (a hard invariant).

use issgd::experiments::{fig4, ExperimentScale};

fn main() {
    let scale = ExperimentScale::smoke();
    println!("== fig4 (smoke scale) ==");
    let t0 = std::time::Instant::now();
    match fig4::run_monitored(&scale) {
        Ok(runs) => {
            fig4::emit(&runs).unwrap();
            for (panel, mr) in [("a", &runs.a), ("b", &runs.b)] {
                let ideal = mr.quartiles("var_ideal_sqrt");
                let stale = mr.quartiles("var_stale_sqrt");
                for i in 0..ideal.steps.len() {
                    assert!(
                        ideal.median[i] <= stale.median[i] * 1.001 + 1e-9,
                        "panel {panel}: ideal > stale at checkpoint {i}"
                    );
                }
            }
            println!("fig4 bench done in {:.1}s (ordering invariant held)", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("fig4 bench skipped/failed: {e:#} (run `make artifacts`)"),
    }
}
