//! Extension bench — §6: ISSGD vs ASGD vs ISSGD+ASGD at a matched
//! gradient-computation budget (smoke scale).  Sanity: every arm must
//! actually train (finite, reduced loss).

use issgd::experiments::{asgd, ExperimentScale};

fn main() {
    let scale = ExperimentScale::smoke();
    println!("== asgd combo (smoke scale) ==");
    let t0 = std::time::Instant::now();
    match asgd::run(&scale) {
        Ok(rows) => {
            assert_eq!(rows.len(), 4);
            for r in &rows {
                assert!(
                    r.final_train_loss.is_finite() && r.final_train_loss < 2.5,
                    "{} did not train: loss {}",
                    r.method,
                    r.final_train_loss
                );
            }
            println!("asgd bench done in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("asgd bench skipped/failed: {e:#} (run `make artifacts`)"),
    }
}
