//! Substrate bench: the weight store — in-proc engine vs TCP transport
//! (DESIGN.md §6 ablation "in-proc vs TCP round-trip overhead").

use std::sync::Arc;

use issgd::bench::Harness;
use issgd::weightstore::client::Client;
use issgd::weightstore::server::Server;
use issgd::weightstore::{MemStore, WeightStore};

fn main() {
    let mut h = Harness::from_env("weightstore");
    let n = 16_384usize;

    // -- in-proc -----------------------------------------------------------
    let mem = MemStore::new(n, 1.0);
    let weights: Vec<f32> = (0..256).map(|i| i as f32).collect();
    let mut v = 0u64;
    h.bench_throughput("memstore/push_weights/256", 256, || {
        mem.push_weights(0, &weights, 1).unwrap();
    });
    h.bench(&format!("memstore/snapshot/n={n}"), || {
        std::hint::black_box(mem.fetch_weights().unwrap());
    });
    let blob = vec![0u8; 4 * 1_000_000]; // ~1M-param f32 model
    h.bench("memstore/push_params/4MB", || {
        v += 1;
        mem.push_params(v, blob.clone()).unwrap();
    });
    h.bench("memstore/fetch_params/4MB", || {
        std::hint::black_box(mem.fetch_params(0).unwrap());
    });

    // -- TCP ---------------------------------------------------------------
    let server = Server::bind("127.0.0.1:0", Arc::new(MemStore::new(n, 1.0))).unwrap();
    let (addr, handle) = server.serve_in_background().unwrap();
    let client = Client::connect(&addr.to_string()).unwrap();
    let mut v = 0u64;
    h.bench_throughput("tcp/push_weights/256", 256, || {
        client.push_weights(0, &weights, 1).unwrap();
    });
    h.bench(&format!("tcp/snapshot/n={n}"), || {
        std::hint::black_box(client.fetch_weights().unwrap());
    });
    h.bench("tcp/push_params/4MB", || {
        v += 1;
        client.push_params(v, blob.clone()).unwrap();
    });
    h.bench("tcp/fetch_params/4MB", || {
        std::hint::black_box(client.fetch_params(0).unwrap());
    });
    h.bench("tcp/now_rtt", || {
        std::hint::black_box(client.now().unwrap());
    });
    client.shutdown_server().unwrap();
    handle.join().unwrap();

    h.finish();
}
