//! Substrate bench: the weight store — in-proc engine vs TCP transport
//! (DESIGN.md §6 ablation "in-proc vs TCP round-trip overhead"), the
//! delta-vs-snapshot ablation behind the master's incremental fetch, the
//! layer-wise params-sync ablation behind `fetch_params_since`, and the
//! durable backend's journaling/compaction cost (including the p99 push
//! latency guard proving compaction left the hot path).

use std::sync::Arc;

use issgd::bench::Harness;
use issgd::model::ParamSet;
use issgd::runtime::{LayerSpec, Manifest};
use issgd::util::rng::Pcg64;
use issgd::weightstore::client::Client;
use issgd::weightstore::faulty::{FaultSpec, FaultyStore};
use issgd::weightstore::protocol::Response;
use issgd::weightstore::server::Server;
use issgd::weightstore::{MemStore, WeightStore};

fn main() {
    let mut h = Harness::from_env("weightstore");
    let n = 16_384usize;

    // -- in-proc -----------------------------------------------------------
    let mem = MemStore::new(n, 1.0);
    let weights: Vec<f32> = (0..256).map(|i| i as f32).collect();
    let mut v = 0u64;
    h.bench_throughput("memstore/push_weights/256", 256, || {
        mem.push_weights(0, &weights, 1).unwrap();
    });
    h.bench(&format!("memstore/snapshot/n={n}"), || {
        std::hint::black_box(mem.fetch_weights().unwrap());
    });
    let blob = vec![0u8; 4 * 1_000_000]; // ~1M-param f32 model
    h.bench("memstore/push_params/4MB", || {
        v += 1;
        mem.push_params(v, blob.clone()).unwrap();
    });
    h.bench("memstore/fetch_params/4MB", || {
        std::hint::black_box(mem.fetch_params(0).unwrap());
    });

    // -- TCP ---------------------------------------------------------------
    let server = Server::bind("127.0.0.1:0", Arc::new(MemStore::new(n, 1.0))).unwrap();
    let (addr, handle) = server.serve_in_background().unwrap();
    let client = Client::connect(&addr.to_string()).unwrap();
    let mut v = 0u64;
    h.bench_throughput("tcp/push_weights/256", 256, || {
        client.push_weights(0, &weights, 1).unwrap();
    });
    h.bench(&format!("tcp/snapshot/n={n}"), || {
        std::hint::black_box(client.fetch_weights().unwrap());
    });
    h.bench("tcp/push_params/4MB", || {
        v += 1;
        client.push_params(v, blob.clone()).unwrap();
    });
    h.bench("tcp/fetch_params/4MB", || {
        std::hint::black_box(client.fetch_params(0).unwrap());
    });
    h.bench("tcp/now_rtt", || {
        std::hint::black_box(client.now().unwrap());
    });
    client.shutdown_server().unwrap();
    handle.join().unwrap();

    // -- delta vs snapshot (the master's per-step fetch) -------------------
    //
    // One "master step" at N = 100k with 1% weight churn: workers refresh
    // `churn` contiguous weights, the master pulls.  The old path cloned
    // the full 3×N snapshot; the delta path moves only the changed rows.
    let n_big = 100_000usize;
    let churn = n_big / 100;
    let big = MemStore::new(n_big, 1.0);
    let fresh: Vec<f32> = (0..churn).map(|i| 1.0 + (i % 7) as f32).collect();
    // Absorb the initial full table so the steady state is measured.
    let mut cursor = big.fetch_weights_since(0).unwrap().seq;
    let mut off = 0usize;
    h.bench(&format!("memstore/step_snapshot/n={n_big}"), || {
        big.push_weights(off, &fresh, 1).unwrap();
        off = (off + churn) % n_big;
        std::hint::black_box(big.fetch_weights().unwrap());
    });
    h.bench(&format!("memstore/step_delta/n={n_big}/churn=1%"), || {
        big.push_weights(off, &fresh, 1).unwrap();
        off = (off + churn) % n_big;
        let d = big.fetch_weights_since(cursor).unwrap();
        cursor = d.seq;
        std::hint::black_box(d);
    });
    // Wire-level bytes for one master step of each strategy.
    big.push_weights(off, &fresh, 1).unwrap();
    let delta = big.fetch_weights_since(cursor).unwrap();
    let delta_bytes = Response::WeightsDelta(delta).encode().len();
    let snap_bytes = Response::Weights(big.fetch_weights().unwrap()).encode().len();
    println!(
        "weightstore/bytes_per_step: snapshot {} B vs delta {} B ({:.1}x fewer)",
        snap_bytes,
        delta_bytes,
        snap_bytes as f64 / delta_bytes as f64
    );
    assert!(
        snap_bytes >= 10 * delta_bytes,
        "delta fetch must move >=10x fewer bytes than a snapshot at 1% churn"
    );

    // -- layer-wise params sync (master→worker propagation) ----------------
    //
    // A large-config manifest (64 × 256×256 layers ≈ 16.8 MB of f32s) with
    // 2 of 64 layers (~3%) dirty per publish — the sparse-update workload
    // the layer-delta path exists for.  The old path shipped the whole
    // blob per fetch; `fetch_params_since` ships only the dirty chunks.
    let specs: Vec<LayerSpec> = (0..64).map(|_| LayerSpec { d_in: 256, d_out: 256 }).collect();
    let manifest = Manifest::synthetic_for_tests(specs);
    let pset = ParamSet::init_he(&manifest, &mut Pcg64::seeded(42));
    let chunks = pset.to_layer_chunks();
    let pstore = MemStore::new(1, 1.0);
    let mut pv = 1u64;
    pstore.push_params_layers(pv, true, &chunks).unwrap();
    let mut which = 0usize;
    h.bench("memstore/params_step_full_blob/64x256x256", || {
        // Baseline: publish whole blob, fetch whole blob (the old shape).
        pv += 1;
        pstore.push_params(pv, pset.to_bytes()).unwrap();
        std::hint::black_box(pstore.fetch_params(0).unwrap());
    });
    // Re-establish the layer layout after the blob baseline clobbered it.
    pv += 1;
    pstore.push_params_layers(pv, true, &chunks).unwrap();
    let mut consumer_v = pv;
    h.bench("memstore/params_step_delta/64x256x256/2-dirty", || {
        pv += 1;
        let dirty = [chunks[which % 64].clone(), chunks[(which + 31) % 64].clone()];
        which += 1;
        pstore.push_params_layers(pv, false, &dirty).unwrap();
        let d = pstore.fetch_params_since(consumer_v).unwrap().unwrap();
        consumer_v = d.version;
        std::hint::black_box(d);
    });
    // Wire-level bytes for one propagation step of each strategy.
    pv += 1;
    pstore
        .push_params_layers(pv, false, &[chunks[0].clone(), chunks[1].clone()])
        .unwrap();
    let delta = pstore.fetch_params_since(consumer_v).unwrap().unwrap();
    let delta_bytes = Response::ParamsDelta(Some(delta)).encode().len();
    let full_bytes = Response::Params(pstore.fetch_params(0).unwrap()).encode().len();
    println!(
        "weightstore/params_bytes_per_step: full blob {} B vs layer delta {} B ({:.1}x fewer)",
        full_bytes,
        delta_bytes,
        full_bytes as f64 / delta_bytes as f64
    );
    assert!(
        full_bytes >= 10 * delta_bytes,
        "params delta must move >=10x fewer bytes than the full blob at ~3% dirty layers"
    );

    // -- FaultyStore decorator overhead ------------------------------------
    //
    // The chaos decorator sits on the hot path in fault-injection tests;
    // with a quiet spec it must be a near-free passthrough (one atomic
    // tick + one branch per op, no RNG draw).
    let plain = MemStore::new(n, 1.0);
    let mut v = 0u64;
    let direct = h.bench_throughput("memstore/plain_push/256", 256, || {
        v += 1;
        plain.push_weights(0, &weights, v).unwrap();
    });
    let wrapped = FaultyStore::new(
        Arc::new(MemStore::new(n, 1.0)) as Arc<dyn WeightStore>,
        FaultSpec::quiet(1),
    );
    let mut v = 0u64;
    let decorated = h.bench_throughput("faulty/quiet_push/256", 256, || {
        v += 1;
        wrapped.push_weights(0, &weights, v).unwrap();
    });
    println!(
        "weightstore/faulty_overhead: plain {:?} vs quiet-decorated {:?}",
        direct.median, decorated.median
    );

    // -- durable backend (journal + snapshot persistence) ------------------
    //
    // The mem-vs-durable push/fetch gap is the journaling tax (one frame
    // encode + buffered write per push); the compaction bench prices a
    // full fold-checkpoint-GC cycle at this table size.  These feed the
    // BENCH_pr4.json perf-trajectory artifact in CI (--json).
    use issgd::weightstore::durable::{DurableOptions, DurableStore};
    let dir = std::env::temp_dir().join(format!("issgd-bench-durable-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dur = DurableStore::create(
        &dir,
        n,
        1.0,
        DurableOptions {
            segment_bytes: 8 << 20,
            compact_after_bytes: 0, // explicit compaction only: priced below
            ..DurableOptions::default()
        },
    )
    .unwrap();
    let mut v = 0u64;
    let dur_push = h.bench_throughput("durable/push_weights/256", 256, || {
        v += 1;
        dur.push_weights(0, &weights, v).unwrap();
    });
    println!(
        "weightstore/durable_overhead: plain {:?} vs journaled {:?} per 256-weight push",
        direct.median, dur_push.median
    );
    // A pinned consumer's steady-state step: push + incremental fetch +
    // cursor save (the pin is what keeps compaction cursor-safe).
    let mut cursor = dur.fetch_weights_since(0).unwrap().seq;
    h.bench(&format!("durable/step_delta/n={n}"), || {
        dur.push_weights(0, &weights, 1).unwrap();
        let d = dur.fetch_weights_since(cursor).unwrap();
        cursor = d.seq;
        dur.save_cursor("bench", cursor).unwrap();
        std::hint::black_box(d);
    });
    h.bench(&format!("durable/compact/n={n}"), || {
        dur.push_weights(0, &weights, 1).unwrap();
        // Advance the pin to the head first, or the stale step_delta
        // cursor would clamp the fold and the bench would stop measuring
        // a real fold-checkpoint-GC cycle after its first iteration.
        dur.save_cursor("bench", dur.write_seq()).unwrap();
        dur.compact().unwrap();
    });
    h.bench(&format!("durable/snapshot_fetch/n={n}"), || {
        std::hint::black_box(dur.fetch_weights().unwrap());
    });
    // Price one synchronous fold-checkpoint-GC cycle: the cost the push
    // path used to pay inline whenever it crossed the threshold.
    let mut compact_costs: Vec<std::time::Duration> = Vec::new();
    for _ in 0..5 {
        dur.push_weights(0, &weights, 1).unwrap();
        dur.save_cursor("bench", dur.write_seq()).unwrap();
        let t = std::time::Instant::now();
        dur.compact().unwrap();
        compact_costs.push(t.elapsed());
    }
    compact_costs.sort();
    let compact_median = compact_costs[compact_costs.len() / 2];
    drop(dur);
    let _ = std::fs::remove_dir_all(&dir);

    // -- background compaction: the push path must not pay the cycle ------
    //
    // Threshold-triggered compaction now runs on a background thread; the
    // push hot path pays at most the seal+dump memcpy.  Guard: across a
    // run that crosses the threshold many times, p99 push latency stays
    // far below the cost of one inline compaction cycle (measured above).
    let dir2 = std::env::temp_dir().join(format!("issgd-bench-durable-bg-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir2);
    let bg = DurableStore::create(
        &dir2,
        n,
        1.0,
        DurableOptions {
            segment_bytes: 1 << 16,
            compact_after_bytes: 1 << 18, // trigger every ~32 pushes
            ..DurableOptions::default()
        },
    )
    .unwrap();
    bg.save_cursor("bench", bg.write_seq()).unwrap();
    let mut lat: Vec<std::time::Duration> = Vec::with_capacity(1200);
    for i in 0..1200u64 {
        let t = std::time::Instant::now();
        bg.push_weights(0, &weights, i + 1).unwrap();
        lat.push(t.elapsed());
        if i % 16 == 0 {
            // Keep the pin moving so the background fold makes progress.
            bg.save_cursor("bench", bg.write_seq()).unwrap();
        }
    }
    bg.quiesce_compactor();
    let compactions = bg.compactions();
    lat.sort();
    let p50 = lat[lat.len() / 2];
    let p99 = lat[lat.len() * 99 / 100];
    println!(
        "durable/bg_push_latency: p50 {:?} p99 {:?} over {} pushes ({} background compactions; inline compact cycle median {:?})",
        p50,
        p99,
        lat.len(),
        compactions,
        compact_median
    );
    assert!(compactions >= 2, "background compactor never triggered");
    assert!(
        p99 < compact_median.max(std::time::Duration::from_micros(200)) / 2,
        "p99 push latency {p99:?} still spikes near the inline compaction cost {compact_median:?}"
    );
    drop(bg);
    let _ = std::fs::remove_dir_all(&dir2);

    h.finish();
}
