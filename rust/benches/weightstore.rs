//! Substrate bench: the weight store — in-proc engine vs TCP transport
//! (DESIGN.md §6 ablation "in-proc vs TCP round-trip overhead"), plus the
//! delta-vs-snapshot ablation behind the master's incremental fetch.

use std::sync::Arc;

use issgd::bench::Harness;
use issgd::weightstore::client::Client;
use issgd::weightstore::faulty::{FaultSpec, FaultyStore};
use issgd::weightstore::protocol::Response;
use issgd::weightstore::server::Server;
use issgd::weightstore::{MemStore, WeightStore};

fn main() {
    let mut h = Harness::from_env("weightstore");
    let n = 16_384usize;

    // -- in-proc -----------------------------------------------------------
    let mem = MemStore::new(n, 1.0);
    let weights: Vec<f32> = (0..256).map(|i| i as f32).collect();
    let mut v = 0u64;
    h.bench_throughput("memstore/push_weights/256", 256, || {
        mem.push_weights(0, &weights, 1).unwrap();
    });
    h.bench(&format!("memstore/snapshot/n={n}"), || {
        std::hint::black_box(mem.fetch_weights().unwrap());
    });
    let blob = vec![0u8; 4 * 1_000_000]; // ~1M-param f32 model
    h.bench("memstore/push_params/4MB", || {
        v += 1;
        mem.push_params(v, blob.clone()).unwrap();
    });
    h.bench("memstore/fetch_params/4MB", || {
        std::hint::black_box(mem.fetch_params(0).unwrap());
    });

    // -- TCP ---------------------------------------------------------------
    let server = Server::bind("127.0.0.1:0", Arc::new(MemStore::new(n, 1.0))).unwrap();
    let (addr, handle) = server.serve_in_background().unwrap();
    let client = Client::connect(&addr.to_string()).unwrap();
    let mut v = 0u64;
    h.bench_throughput("tcp/push_weights/256", 256, || {
        client.push_weights(0, &weights, 1).unwrap();
    });
    h.bench(&format!("tcp/snapshot/n={n}"), || {
        std::hint::black_box(client.fetch_weights().unwrap());
    });
    h.bench("tcp/push_params/4MB", || {
        v += 1;
        client.push_params(v, blob.clone()).unwrap();
    });
    h.bench("tcp/fetch_params/4MB", || {
        std::hint::black_box(client.fetch_params(0).unwrap());
    });
    h.bench("tcp/now_rtt", || {
        std::hint::black_box(client.now().unwrap());
    });
    client.shutdown_server().unwrap();
    handle.join().unwrap();

    // -- delta vs snapshot (the master's per-step fetch) -------------------
    //
    // One "master step" at N = 100k with 1% weight churn: workers refresh
    // `churn` contiguous weights, the master pulls.  The old path cloned
    // the full 3×N snapshot; the delta path moves only the changed rows.
    let n_big = 100_000usize;
    let churn = n_big / 100;
    let big = MemStore::new(n_big, 1.0);
    let fresh: Vec<f32> = (0..churn).map(|i| 1.0 + (i % 7) as f32).collect();
    // Absorb the initial full table so the steady state is measured.
    let mut cursor = big.fetch_weights_since(0).unwrap().seq;
    let mut off = 0usize;
    h.bench(&format!("memstore/step_snapshot/n={n_big}"), || {
        big.push_weights(off, &fresh, 1).unwrap();
        off = (off + churn) % n_big;
        std::hint::black_box(big.fetch_weights().unwrap());
    });
    h.bench(&format!("memstore/step_delta/n={n_big}/churn=1%"), || {
        big.push_weights(off, &fresh, 1).unwrap();
        off = (off + churn) % n_big;
        let d = big.fetch_weights_since(cursor).unwrap();
        cursor = d.seq;
        std::hint::black_box(d);
    });
    // Wire-level bytes for one master step of each strategy.
    big.push_weights(off, &fresh, 1).unwrap();
    let delta = big.fetch_weights_since(cursor).unwrap();
    let delta_bytes = Response::WeightsDelta(delta).encode().len();
    let snap_bytes = Response::Weights(big.fetch_weights().unwrap()).encode().len();
    println!(
        "weightstore/bytes_per_step: snapshot {} B vs delta {} B ({:.1}x fewer)",
        snap_bytes,
        delta_bytes,
        snap_bytes as f64 / delta_bytes as f64
    );
    assert!(
        snap_bytes >= 10 * delta_bytes,
        "delta fetch must move >=10x fewer bytes than a snapshot at 1% churn"
    );

    // -- FaultyStore decorator overhead ------------------------------------
    //
    // The chaos decorator sits on the hot path in fault-injection tests;
    // with a quiet spec it must be a near-free passthrough (one atomic
    // tick + one branch per op, no RNG draw).
    let plain = MemStore::new(n, 1.0);
    let mut v = 0u64;
    let direct = h.bench_throughput("memstore/plain_push/256", 256, || {
        v += 1;
        plain.push_weights(0, &weights, v).unwrap();
    });
    let wrapped = FaultyStore::new(
        Arc::new(MemStore::new(n, 1.0)) as Arc<dyn WeightStore>,
        FaultSpec::quiet(1),
    );
    let mut v = 0u64;
    let decorated = h.bench_throughput("faulty/quiet_push/256", 256, || {
        v += 1;
        wrapped.push_weights(0, &weights, v).unwrap();
    });
    println!(
        "weightstore/faulty_overhead: plain {:?} vs quiet-decorated {:?}",
        direct.median, decorated.median
    );

    // -- durable backend (journal + snapshot persistence) ------------------
    //
    // The mem-vs-durable push/fetch gap is the journaling tax (one frame
    // encode + buffered write per push); the compaction bench prices a
    // full fold-checkpoint-GC cycle at this table size.  These feed the
    // BENCH_pr4.json perf-trajectory artifact in CI (--json).
    use issgd::weightstore::durable::{DurableOptions, DurableStore};
    let dir = std::env::temp_dir().join(format!("issgd-bench-durable-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dur = DurableStore::create(
        &dir,
        n,
        1.0,
        DurableOptions {
            segment_bytes: 8 << 20,
            compact_after_bytes: 0, // explicit compaction only: priced below
            fsync: false,
        },
    )
    .unwrap();
    let mut v = 0u64;
    let dur_push = h.bench_throughput("durable/push_weights/256", 256, || {
        v += 1;
        dur.push_weights(0, &weights, v).unwrap();
    });
    println!(
        "weightstore/durable_overhead: plain {:?} vs journaled {:?} per 256-weight push",
        direct.median, dur_push.median
    );
    // A pinned consumer's steady-state step: push + incremental fetch +
    // cursor save (the pin is what keeps compaction cursor-safe).
    let mut cursor = dur.fetch_weights_since(0).unwrap().seq;
    h.bench(&format!("durable/step_delta/n={n}"), || {
        dur.push_weights(0, &weights, 1).unwrap();
        let d = dur.fetch_weights_since(cursor).unwrap();
        cursor = d.seq;
        dur.save_cursor("bench", cursor).unwrap();
        std::hint::black_box(d);
    });
    h.bench(&format!("durable/compact/n={n}"), || {
        dur.push_weights(0, &weights, 1).unwrap();
        // Advance the pin to the head first, or the stale step_delta
        // cursor would clamp the fold and the bench would stop measuring
        // a real fold-checkpoint-GC cycle after its first iteration.
        dur.save_cursor("bench", dur.write_seq()).unwrap();
        dur.compact().unwrap();
    });
    h.bench(&format!("durable/snapshot_fetch/n={n}"), || {
        std::hint::black_box(dur.fetch_weights().unwrap());
    });
    drop(dur);
    let _ = std::fs::remove_dir_all(&dir);

    h.finish();
}
