//! Substrate bench: multinomial samplers — the ablation behind the
//! master's choice of a Fenwick tree over an alias table (DESIGN.md §6).
//!
//! Workloads: pure sampling at several N; point update + sample (the
//! master's actual access pattern: weights mutate continuously); alias
//! rebuild cost; full minibatch draw.

use issgd::bench::Harness;
use issgd::config::StalenessUnit;
use issgd::coordinator::ProposalMaintainer;
use issgd::sampler::{draw_minibatch, AliasSampler, FenwickSampler};
use issgd::util::rng::Pcg64;
use issgd::weightstore::WeightDelta;

fn weights(n: usize, rng: &mut Pcg64) -> Vec<f64> {
    (0..n).map(|_| 0.01 + rng.next_f64() * 10.0).collect()
}

fn main() {
    let mut h = Harness::from_env("sampler");
    let mut rng = Pcg64::seeded(1);

    for &n in &[1usize << 10, 1 << 14, 1 << 18] {
        let w = weights(n, &mut rng);
        let fen = FenwickSampler::new(&w);
        let alias = AliasSampler::new(&w).unwrap();
        let draws = 10_000u64;

        h.bench_throughput(&format!("fenwick/sample/n={n}"), draws, || {
            for _ in 0..draws {
                std::hint::black_box(fen.sample(&mut rng));
            }
        });
        h.bench_throughput(&format!("alias/sample/n={n}"), draws, || {
            for _ in 0..draws {
                std::hint::black_box(alias.sample(&mut rng));
            }
        });
        // The master's real pattern: interleaved updates + draws.
        let mut fen_mut = FenwickSampler::new(&w);
        h.bench_throughput(&format!("fenwick/update+sample/n={n}"), draws, || {
            for _ in 0..draws {
                let i = rng.next_below(n as u64) as usize;
                fen_mut.update(i, rng.next_f64() * 10.0);
                std::hint::black_box(fen_mut.sample(&mut rng));
            }
        });
        // Alias must rebuild to absorb an update.
        h.bench(&format!("alias/rebuild/n={n}"), || {
            std::hint::black_box(AliasSampler::new(&w).unwrap());
        });
    }

    // Full minibatch draw with IS coefficients (the per-step hot path).
    let w = weights(1 << 14, &mut rng);
    let fen = FenwickSampler::new(&w);
    h.bench_throughput("draw_minibatch/m=128/n=16384", 128, || {
        std::hint::black_box(draw_minibatch(&fen, &mut rng, 128));
    });
    // Fenwick rebuild from a fresh snapshot (what the master did per step
    // before the delta-aware store; kept as the baseline).
    h.bench(&format!("fenwick/build/n={}", 1 << 14), || {
        std::hint::black_box(FenwickSampler::new(&w));
    });

    // -- master proposal maintenance ---------------------------------------
    //
    // Absorbing a k-entry delta must cost O(k log N), not O(N): at fixed
    // churn k the absorb time should barely move across a 64x range of N,
    // while the old full-rebuild baseline scales linearly with N.
    let k = 1_024usize;
    for &n in &[1usize << 14, 1 << 17, 1 << 20] {
        let w = weights(n, &mut rng);
        let mut p = ProposalMaintainer::new(n, 10.0, None, StalenessUnit::Versions);
        p.absorb(
            &WeightDelta {
                seq: 1,
                n: n as u64,
                full: true,
                indices: (0..n as u64).collect(),
                weights: w.clone(),
                stamps: vec![0; n],
                param_versions: vec![0; n],
            },
            0,
        )
        .unwrap();
        let mut off = 0usize;
        let mut seq = 1u64;
        h.bench_throughput(&format!("proposal/absorb/n={n}/k={k}"), k as u64, || {
            seq += 1;
            let delta = WeightDelta {
                seq,
                n: n as u64,
                full: false,
                indices: (off..off + k).map(|i| i as u64).collect(),
                weights: (0..k).map(|i| 0.01 + (i % 13) as f64).collect(),
                stamps: vec![seq; k],
                param_versions: vec![seq; k],
            };
            p.absorb(&delta, seq).unwrap();
            off = (off + k) % n;
        });
        h.bench(&format!("proposal/full_rebuild/n={n}"), || {
            std::hint::black_box(FenwickSampler::new(&w));
        });
    }

    h.finish();
}
