//! Strategy-pipeline bench: one maintainer step — delta absorb plus a
//! minibatch draw — per registered proposal strategy at N = 100k.
//!
//! Substrate-level (no AOT artifacts): a synthetic score stream stands in
//! for the workers.  What this pins down is the *dispatch* cost of the
//! trait-based pipeline: every strategy pays the same O(changes · log N)
//! absorb, the nonlinear masses (power, exp3) pay their transform per
//! touched entry, and the presample-top-k draw policy pays its factor×
//! over-draw.  A strategy whose step drifts an order of magnitude from
//! grad-norm's would show up here before any experiment runs.

use issgd::bench::Harness;
use issgd::config::StalenessUnit;
use issgd::coordinator::ProposalMaintainer;
use issgd::sampler::strategy::StrategyKind;
use issgd::util::rng::Pcg64;
use issgd::weightstore::{MemStore, WeightStore};

fn main() {
    let mut h = Harness::from_env("strategy_matrix");
    let n = 100_000usize;
    let m = 16usize; // one minibatch of score churn + one draw per step

    for (k, &kind) in StrategyKind::all().iter().enumerate() {
        let store = MemStore::new(n, 1.0);
        let vals: Vec<f32> = (0..m).map(|i| 1.0 + (i % 7) as f32).collect();
        let mut p = ProposalMaintainer::new_with_strategy(
            n,
            1.0,
            None,
            StalenessUnit::Versions,
            kind.strategy(),
        );
        let d = store.fetch_weights_since(0).unwrap();
        p.absorb(&d, 0).unwrap();
        let mut rng = Pcg64::seeded(0x5EED + k as u64);
        let mut off = 0usize;
        h.bench(&format!("step/{}/n={n}/k={m}", kind.name()), || {
            store.push_weights(off, &vals, 1).unwrap();
            off = (off + m) % (n - m);
            let d = store.fetch_weights_since(p.cursor()).unwrap();
            p.absorb(&d, 0).unwrap();
            let (idx, coefs, _) = p.draw_minibatch(&mut rng, m);
            std::hint::black_box((idx, coefs, p.ess_ratio()));
        });
    }

    h.finish();
}
