//! Proposal-maintenance bench: the peer/ASGD hot loop before and after the
//! port to `ProposalMaintainer`.
//!
//! One "peer step" at N = 100k with a minibatch's worth of churn: the old
//! path fetched the full snapshot, ran two O(N) passes (scored-mean prior,
//! smoothing) and rebuilt a `FenwickSampler` from scratch; the new path
//! pulls the delta since its cursor and absorbs O(changes · log N) point
//! updates into the shared maintainer.  The assert at the end is the PR's
//! acceptance criterion: absorb must beat the rebuild, with a wide margin
//! to spare.

use issgd::bench::Harness;
use issgd::config::StalenessUnit;
use issgd::coordinator::ProposalMaintainer;
use issgd::sampler::{FenwickSampler, Smoothing};
use issgd::weightstore::{MemStore, WeightStore};

fn main() {
    let mut h = Harness::from_env("proposal");
    let n = 100_000usize;
    let m = 16usize; // one peer minibatch of weight churn per step
    let store = MemStore::new(n, 1.0);
    let vals: Vec<f32> = (0..m).map(|i| 1.0 + (i % 7) as f32).collect();

    // -- old peer path: snapshot + two O(N) passes + sampler rebuild ------
    let mut off = 0usize;
    let rebuild = h.bench(&format!("peer_step_rebuild/n={n}"), || {
        store.push_weights(off, &vals, 1).unwrap();
        off = (off + m) % (n - m);
        let snap = store.fetch_weights().unwrap();
        let smooth = Smoothing::new(1.0);
        let scored: Vec<f64> = snap
            .param_versions
            .iter()
            .zip(&snap.weights)
            .filter(|(&v, _)| v > 0)
            .map(|(_, &w)| w)
            .collect();
        let prior = if scored.is_empty() {
            1.0
        } else {
            scored.iter().sum::<f64>() / scored.len() as f64
        };
        let weights: Vec<f64> = snap
            .weights
            .iter()
            .zip(&snap.param_versions)
            .map(|(&w, &v)| smooth.apply(if v > 0 { w } else { prior }))
            .collect();
        std::hint::black_box(FenwickSampler::new(&weights));
    });

    // -- new peer path: delta fetch + incremental absorb ------------------
    let mut p = ProposalMaintainer::with_coverage_prior(n, 1.0, None, StalenessUnit::Versions);
    let d = store.fetch_weights_since(0).unwrap();
    p.absorb(&d, 0).unwrap();
    let absorb = h.bench(&format!("peer_step_absorb/n={n}/k={m}"), || {
        store.push_weights(off, &vals, 1).unwrap();
        off = (off + m) % (n - m);
        let d = store.fetch_weights_since(p.cursor()).unwrap();
        p.absorb(&d, 0).unwrap();
        std::hint::black_box(p.last_changes());
    });

    println!(
        "proposal/peer_step: rebuild {:?} vs absorb {:?} ({:.1}x faster)",
        rebuild.median,
        absorb.median,
        rebuild.median.as_secs_f64() / absorb.median.as_secs_f64().max(1e-12)
    );
    assert!(
        absorb.median * 2 < rebuild.median,
        "incremental peer-step absorb must beat the O(N) rebuild at N={n}"
    );

    h.finish();
}
