//! Paper bench — §B.1 staleness ablation: kept-weight fraction and sampled
//! version lag across worker counts and staleness thresholds.  Checks the
//! paper's qualitative claims: tighter thresholds keep fewer weights, and
//! more workers keep weights fresher.

use issgd::experiments::{staleness, ExperimentScale};

fn main() {
    let scale = ExperimentScale::smoke();
    println!("== staleness sweep (smoke scale) ==");
    let t0 = std::time::Instant::now();
    match staleness::run_sweep(&scale, &[1, 3], &[None, Some(1)]) {
        Ok(rows) => {
            staleness::emit(&rows).unwrap();
            // Claim 1: a threshold never keeps MORE than no threshold.
            let kept = |w: usize, t: Option<u64>| {
                rows.iter()
                    .find(|r| r.workers == w && r.threshold == t)
                    .map(|r| r.kept_frac)
                    .unwrap()
            };
            for &w in &[1usize, 3] {
                assert!(
                    kept(w, Some(1)) <= kept(w, None) + 1e-9,
                    "threshold increased kept fraction for {w} workers?!"
                );
            }
            // Claim 2: more workers -> fresher weights (lower sampled lag).
            let lag = |w: usize| {
                rows.iter()
                    .find(|r| r.workers == w && r.threshold.is_none())
                    .map(|r| r.sampled_lag)
                    .unwrap()
            };
            assert!(
                lag(3) <= lag(1) + 0.5,
                "more workers should not increase staleness: lag(3)={} lag(1)={}",
                lag(3),
                lag(1)
            );
            println!("staleness bench done in {:.1}s (claims held)", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("staleness bench skipped/failed: {e:#} (run `make artifacts`)"),
    }
}
