//! Paper bench — Table 1: final test error for SGD vs ISSGD with the
//! setting picked by validation error, averaged over the last 10% of
//! iterations (the paper's protocol).

use issgd::experiments::{table1, ExperimentScale};

fn main() {
    let scale = ExperimentScale::smoke();
    println!("== table1 (smoke scale) ==");
    let t0 = std::time::Instant::now();
    match table1::run(&scale) {
        Ok(rows) => {
            assert_eq!(rows.len(), 2);
            for r in &rows {
                assert!(
                    r.test_err.is_finite() && (0.0..=1.0).contains(&r.test_err),
                    "nonsense test error {r:?}",
                    r = r.test_err
                );
            }
            println!("table1 bench done in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("table1 bench skipped/failed: {e:#} (run `make artifacts`)"),
    }
}
