//! Mini live driver (analyzer fixture): wall-clock use sanctioned by a
//! line pragma — exercises the allowlist path of the determinism lint.

pub fn deadline_passed() -> bool {
    // analyze: allow(wallclock): live mode genuinely waits on wall time
    let start = std::time::Instant::now();
    start.elapsed().as_secs() > 60
}
