//! Mini sim driver (analyzer fixture): virtual time only, fully
//! deterministic — the determinism lint must stay green here.

pub fn run(steps: u64) -> u64 {
    let mut t = 0u64;
    for _ in 0..steps {
        t = t.wrapping_add(1);
    }
    t
}
