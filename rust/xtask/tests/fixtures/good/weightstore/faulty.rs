//! Mini fault-injection decorator (analyzer fixture).

use std::sync::Mutex;

use super::{MemStore, WeightStore};

pub struct FaultyStore {
    inner: MemStore,
    rng: Mutex<u64>,
}

impl FaultyStore {
    fn roll(&self) -> u64 {
        let mut rng = self.rng.lock().unwrap();
        *rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *rng
    }
}

impl WeightStore for FaultyStore {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<(), String> {
        if self.roll() % 7 == 0 {
            return Err(String::from("injected fault"));
        }
        self.inner.push_params(version, bytes)
    }

    fn fetch_params(&self, than: u64) -> Result<Vec<u8>, String> {
        self.inner.fetch_params(than)
    }

    fn now(&self) -> Result<u64, String> {
        self.inner.now()
    }
}
