//! Mini durable store (analyzer fixture).

use std::sync::Mutex;

use super::{MemStore, WeightStore};

pub enum Record {
    Params(Vec<u8>),
}

pub struct LogState {
    pub frames: Vec<Vec<u8>>,
}

pub struct DurableStore {
    mem: MemStore,
    log: Mutex<LogState>,
}

impl DurableStore {
    fn append(&self, log: &mut LogState, rec: &Record) {
        match rec {
            Record::Params(b) => log.frames.push(b.to_vec()),
        }
    }

    fn apply_record(&self, rec: &Record) -> Result<(), String> {
        match rec {
            Record::Params(b) => self.mem.push_params(0, b.to_vec()),
        }
    }
}

impl WeightStore for DurableStore {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<(), String> {
        let mut log = self.log.lock().unwrap();
        self.mem.push_params(version, bytes.to_vec())?;
        self.append(&mut log, &Record::Params(bytes));
        Ok(())
    }

    fn fetch_params(&self, than: u64) -> Result<Vec<u8>, String> {
        self.mem.fetch_params(than)
    }

    fn now(&self) -> Result<u64, String> {
        self.mem.now()
    }
}
