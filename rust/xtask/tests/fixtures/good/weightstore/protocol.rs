//! Mini wire protocol (analyzer fixture — this tree is read by the
//! lints, never compiled).
//!
//! # Opcode table
//!
//! | op   | request       | op   | response |
//! |------|---------------|------|----------|
//! | 0x01 | `PushParams`  | 0x80 | `Ok`     |
//! | 0x02 | `FetchParams` | 0x81 | `Err`    |
//! | 0x06 | `Now`         | 0x85 | `Now`    |
//! | 0x0F | `Shutdown`    |      |          |

pub enum Request {
    PushParams { version: u64, bytes: Vec<u8> },
    FetchParams { than: u64 },
    Now,
    Shutdown,
}

pub enum Response {
    Ok,
    Err(String),
    Now(u64),
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::new();
        match self {
            Request::PushParams { version, bytes } => {
                p.push(0x01);
                p.extend_from_slice(&version.to_le_bytes());
                p.extend_from_slice(bytes);
            }
            Request::FetchParams { than } => {
                p.push(0x02);
                p.extend_from_slice(&than.to_le_bytes());
            }
            Request::Now => p.push(0x06),
            Request::Shutdown => p.push(0x0F),
        }
        p
    }

    pub fn decode(buf: &[u8]) -> Option<Request> {
        match *buf.first()? {
            0x01 => Some(Request::PushParams {
                version: 0,
                bytes: buf.get(9..)?.to_vec(),
            }),
            0x02 => Some(Request::FetchParams { than: 0 }),
            0x06 => Some(Request::Now),
            0x0F => Some(Request::Shutdown),
            _ => None,
        }
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Response::Ok => vec![0x80],
            Response::Err(e) => {
                let mut p = vec![0x81];
                p.extend_from_slice(e.as_bytes());
                p
            }
            Response::Now(t) => {
                let mut p = vec![0x85];
                p.extend_from_slice(&t.to_le_bytes());
                p
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Option<Response> {
        match *buf.first()? {
            0x80 => Some(Response::Ok),
            0x81 => Some(Response::Err(String::new())),
            0x85 => Some(Response::Now(0)),
            _ => None,
        }
    }
}
