//! Mini server dispatch (analyzer fixture).

use super::protocol::{Request, Response};
use super::WeightStore;

pub fn dispatch(store: &dyn WeightStore, req: Request) -> Response {
    match req {
        Request::PushParams { version, bytes } => match store.push_params(version, bytes) {
            Ok(()) => Response::Ok,
            Err(e) => Response::Err(e),
        },
        Request::FetchParams { than } => match store.fetch_params(than) {
            Ok(_bytes) => Response::Ok,
            Err(e) => Response::Err(e),
        },
        Request::Now => match store.now() {
            Ok(t) => Response::Now(t),
            Err(e) => Response::Err(e),
        },
        Request::Shutdown => Response::Ok,
    }
}
