//! Mini TCP client proxy (analyzer fixture).

use std::sync::Mutex;

use super::protocol::Request;
use super::WeightStore;

pub struct Client {
    stream: Mutex<Vec<u8>>,
}

impl Client {
    pub fn shutdown(&self) {
        let mut stream = self.stream.lock().unwrap();
        stream.extend_from_slice(&Request::Shutdown.encode());
    }
}

impl WeightStore for Client {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<(), String> {
        let mut stream = self.stream.lock().unwrap();
        stream.extend_from_slice(&Request::PushParams { version, bytes }.encode());
        Ok(())
    }

    fn fetch_params(&self, than: u64) -> Result<Vec<u8>, String> {
        let mut stream = self.stream.lock().unwrap();
        stream.extend_from_slice(&Request::FetchParams { than }.encode());
        Ok(Vec::new())
    }

    fn now(&self) -> Result<u64, String> {
        let mut stream = self.stream.lock().unwrap();
        stream.extend_from_slice(&Request::Now.encode());
        Ok(0)
    }
}
