//! Mini telemetry schema (analyzer fixture).
//!
//! Metric names follow the `subsystem.metric` grammar: exactly two
//! dot-separated lowercase `snake_case` segments, each starting with a
//! letter.  The telemetry lint checks every literal instrument call
//! against this grammar and — for files under `weightstore/` — against
//! the canonical schema below.

/// Canonical store-process metric schema: `(name, kind)` with kind
/// `'c'` counter, `'g'` gauge, `'h'` histogram.
pub const STORE_METRICS: &[(&str, char)] = &[("server.ticks", 'c')];
