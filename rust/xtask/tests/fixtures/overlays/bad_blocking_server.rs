//! Overlay for weightstore/server.rs: `tick` smuggles blocking calls
//! into the event loop through a helper two edges below `serve` — a
//! thread sleep and a file sync.  The blocking lint must flag both with
//! a serve-rooted witness path.

use super::protocol::{Request, Response};
use super::WeightStore;

pub fn serve(store: &dyn WeightStore, frames: &[Vec<u8>]) -> Vec<Response> {
    let mut out = Vec::new();
    for frame in frames {
        out.push(tick(store, frame));
    }
    out
}

fn tick(store: &dyn WeightStore, frame: &[u8]) -> Response {
    let resp = match Request::decode(frame) {
        Some(req) => dispatch(store, req),
        None => Response::Err(String::from("malformed frame")),
    };
    settle();
    resp
}

/// "Durability" done in the worst possible place: inline in the tick.
fn settle() {
    std::thread::sleep(std::time::Duration::from_millis(1));
    if let Ok(f) = std::fs::File::open("journal.log") {
        let _ = f.sync_all();
    }
}

pub fn dispatch(store: &dyn WeightStore, req: Request) -> Response {
    match req {
        Request::PushParams { version, bytes } => match store.push_params(version, bytes) {
            Ok(()) => Response::Ok,
            Err(e) => Response::Err(e),
        },
        Request::FetchParams { than } => match store.fetch_params(than) {
            Ok(_bytes) => Response::Ok,
            Err(e) => Response::Err(e),
        },
        Request::Now => match store.now() {
            Ok(t) => Response::Now(t),
            Err(e) => Response::Err(e),
        },
        Request::Shutdown => Response::Ok,
    }
}
