//! Overlay for weightstore/mod.rs: the trait grows a `stats` method that
//! no impl provides and the server never dispatches — the trait-wiring
//! lint must fail for every impl plus the server.
//!
//! lock-order: log -> cursors -> params -> shards

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

pub mod client;
pub mod durable;
pub mod faulty;
pub mod protocol;
pub mod server;

pub trait WeightStore: Send + Sync {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<(), String>;
    fn fetch_params(&self, than: u64) -> Result<Vec<u8>, String>;
    fn now(&self) -> Result<u64, String>;
    fn stats(&self) -> Result<u64, String>;
}

pub struct MemStore {
    params: Mutex<Vec<u8>>,
    shards: Vec<RwLock<Vec<f64>>>,
    cursors: Mutex<BTreeMap<String, u64>>,
    version: AtomicU64,
}

impl MemStore {
    pub fn compact(&self) {
        let cursors = self.cursors.lock().unwrap();
        let _pin = cursors.values().min();
        for lock in &self.shards {
            let mut sh = lock.write().unwrap();
            sh.clear();
        }
    }
}

impl WeightStore for MemStore {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<(), String> {
        let mut slot = self.params.lock().unwrap();
        *slot = bytes;
        self.version.store(version, Ordering::Release);
        Ok(())
    }

    fn fetch_params(&self, _than: u64) -> Result<Vec<u8>, String> {
        let slot = self.params.lock().unwrap();
        Ok(slot.to_vec())
    }

    fn now(&self) -> Result<u64, String> {
        Ok(self.version.load(Ordering::Acquire))
    }
}
