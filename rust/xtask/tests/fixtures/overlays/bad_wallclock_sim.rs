//! Overlay for coordinator/sim.rs: calls `Instant::now` with no pragma —
//! the determinism lint must fail pointing at the exact line.

pub fn run(steps: u64) -> u64 {
    let start = std::time::Instant::now();
    let mut t = 0u64;
    for _ in 0..steps {
        t = t.wrapping_add(1);
    }
    t.wrapping_add(start.elapsed().as_secs())
}
