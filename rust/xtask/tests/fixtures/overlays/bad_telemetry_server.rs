//! Overlay for weightstore/server.rs: three telemetry-conformance
//! violations in one tick — a metric name that breaks the
//! `subsystem.metric` grammar, a store-process metric missing from
//! `telemetry::STORE_METRICS`, and a registered name used with the
//! wrong instrument kind.  The telemetry lint must flag all three.

use super::protocol::{Request, Response};
use super::WeightStore;

pub fn serve(store: &dyn WeightStore, frames: &[Vec<u8>]) -> Vec<Response> {
    let mut out = Vec::new();
    for frame in frames {
        out.push(tick(store, frame));
    }
    out
}

fn tick(store: &dyn WeightStore, frame: &[u8]) -> Response {
    crate::telemetry::counter("Server.Ticks").inc();
    crate::telemetry::counter("server.frames_total").inc();
    crate::telemetry::histogram("server.ticks").observe(1.0);
    match Request::decode(frame) {
        Some(req) => dispatch(store, req),
        None => Response::Err(String::from("malformed frame")),
    }
}

pub fn dispatch(store: &dyn WeightStore, req: Request) -> Response {
    match req {
        Request::PushParams { version, bytes } => match store.push_params(version, bytes) {
            Ok(()) => Response::Ok,
            Err(e) => Response::Err(e),
        },
        Request::FetchParams { than } => match store.fetch_params(than) {
            Ok(_bytes) => Response::Ok,
            Err(e) => Response::Err(e),
        },
        Request::Now => match store.now() {
            Ok(t) => Response::Now(t),
            Err(e) => Response::Err(e),
        },
        Request::Shutdown => Response::Ok,
    }
}
