//! Overlay for weightstore/server.rs: the frame parse path panics on
//! malformed input instead of surfacing `Response::Err` — a decode
//! `.unwrap()` and an unvalidated range slice, both transitively below
//! `serve`.  The panics lint must flag both sites.

use super::protocol::{Request, Response};
use super::WeightStore;

pub fn serve(store: &dyn WeightStore, frames: &[Vec<u8>]) -> Vec<Response> {
    let mut out = Vec::new();
    for frame in frames {
        out.push(tick(store, frame));
    }
    out
}

fn tick(store: &dyn WeightStore, frame: &[u8]) -> Response {
    dispatch(store, parse(frame))
}

fn parse(frame: &[u8]) -> Request {
    Request::decode(header(frame)).unwrap()
}

fn header(frame: &[u8]) -> &[u8] {
    &frame[0..9]
}

pub fn dispatch(store: &dyn WeightStore, req: Request) -> Response {
    match req {
        Request::PushParams { version, bytes } => match store.push_params(version, bytes) {
            Ok(()) => Response::Ok,
            Err(e) => Response::Err(e),
        },
        Request::FetchParams { than } => match store.fetch_params(than) {
            Ok(_bytes) => Response::Ok,
            Err(e) => Response::Err(e),
        },
        Request::Now => match store.now() {
            Ok(t) => Response::Now(t),
            Err(e) => Response::Err(e),
        },
        Request::Shutdown => Response::Ok,
    }
}
