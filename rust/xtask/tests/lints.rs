//! Fixture-based tests for the analyzer.
//!
//! `tests/fixtures/good/` is a miniature source tree (same layout and
//! naming as the real one) that every lint must pass.  Each negative
//! test copies it to a temp dir, overlays exactly one broken file from
//! `tests/fixtures/overlays/`, and asserts the targeted lint fires with
//! a pointable span.  Finally the whole real tree under `rust/src` must
//! be green — that assertion is what makes `cargo test` a CI gate for
//! the lints themselves.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use xtask::{lints, Finding, Tree};

fn fixtures() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn copy_tree(src: &Path, dst: &Path) {
    fs::create_dir_all(dst).unwrap();
    for entry in fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        let from = entry.path();
        let to = dst.join(entry.file_name());
        if entry.file_type().unwrap().is_dir() {
            copy_tree(&from, &to);
        } else {
            fs::copy(&from, &to).unwrap();
        }
    }
}

static NEXT: AtomicUsize = AtomicUsize::new(0);

/// Copy `fixtures/good` into a fresh temp dir, optionally overlaying one
/// broken file, and load it.  Dir names use pid + a counter so parallel
/// test threads never collide without needing any randomness.
fn load_with_overlay(overlay: Option<(&str, &str)>) -> Tree {
    let dir = std::env::temp_dir().join(format!(
        "xtask-fixture-{}-{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = fs::remove_dir_all(&dir);
    copy_tree(&fixtures().join("good"), &dir);
    if let Some((overlay_name, target_rel)) = overlay {
        fs::copy(
            fixtures().join("overlays").join(overlay_name),
            dir.join(target_rel),
        )
        .unwrap();
    }
    let tree = Tree::load(&dir).unwrap();
    let _ = fs::remove_dir_all(&dir);
    tree
}

fn render(findings: &[Finding]) -> String {
    findings.iter().map(|f| format!("  {f}\n")).collect()
}

/// Every finding must carry a pointable span: a real file and a 1-based
/// line number.
fn assert_spans(findings: &[Finding]) {
    for f in findings {
        assert!(!f.file.is_empty(), "finding without a file: {f}");
        assert!(f.line >= 1, "finding without a line: {f}");
    }
}

#[test]
fn good_fixture_tree_is_green() {
    let tree = load_with_overlay(None);
    let findings = lints::run_all(&tree);
    assert!(
        findings.is_empty(),
        "expected green fixture tree, got:\n{}",
        render(&findings)
    );
}

#[test]
fn real_source_tree_is_green() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
    let tree = Tree::load(&root).unwrap();
    let findings = lints::run_all(&tree);
    assert!(
        findings.is_empty(),
        "expected green real tree, got:\n{}",
        render(&findings)
    );
}

#[test]
fn protocol_lint_catches_unwired_opcode() {
    let tree = load_with_overlay(Some(("bad_protocol.rs", "weightstore/protocol.rs")));
    let findings = lints::run_one(&tree, "protocol").unwrap();
    assert_spans(&findings);
    for peer in ["server.rs", "client.rs"] {
        assert!(
            findings
                .iter()
                .any(|f| f.msg.contains("Request::FetchWeights") && f.msg.contains(peer)),
            "expected `FetchWeights not handled in {peer}` finding, got:\n{}",
            render(&findings)
        );
    }
    // Only the wiring gap should fire: table, encode, decode all agree.
    assert_eq!(
        findings.len(),
        2,
        "unexpected extra findings:\n{}",
        render(&findings)
    );
}

#[test]
fn traits_lint_catches_unimplemented_method() {
    let tree = load_with_overlay(Some(("bad_trait_mod.rs", "weightstore/mod.rs")));
    let findings = lints::run_one(&tree, "traits").unwrap();
    assert_spans(&findings);
    for backend in ["MemStore", "DurableStore", "FaultyStore", "Client"] {
        assert!(
            findings
                .iter()
                .any(|f| f.msg.contains("stats") && f.msg.contains(backend)),
            "expected `{backend} missing stats` finding, got:\n{}",
            render(&findings)
        );
    }
    assert!(
        findings
            .iter()
            .any(|f| f.msg.contains("stats") && f.msg.contains("server")),
        "expected server-dispatch finding for stats, got:\n{}",
        render(&findings)
    );
}

#[test]
fn locks_lint_catches_inversion() {
    let tree = load_with_overlay(Some(("bad_locks_mod.rs", "weightstore/mod.rs")));
    let findings = lints::run_one(&tree, "locks").unwrap();
    assert_spans(&findings);
    assert!(
        findings.iter().any(|f| {
            f.msg.contains("shards") && f.msg.contains("cursors") && f.file.ends_with("mod.rs")
        }),
        "expected shards-before-cursors inversion finding, got:\n{}",
        render(&findings)
    );
}

#[test]
fn determinism_lint_catches_unsanctioned_wallclock() {
    let tree = load_with_overlay(Some(("bad_wallclock_sim.rs", "coordinator/sim.rs")));
    let findings = lints::run_one(&tree, "determinism").unwrap();
    assert_spans(&findings);
    let hit = findings
        .iter()
        .find(|f| f.msg.contains("Instant::now") && f.file.ends_with("coordinator/sim.rs"))
        .unwrap_or_else(|| panic!("expected Instant::now finding, got:\n{}", render(&findings)));
    // The overlay calls Instant::now on its line 5; the span must point there.
    assert_eq!(hit.line, 5, "finding points at the wrong line: {hit}");
}

#[test]
fn pragma_sanctions_wallclock_in_good_tree() {
    // fixtures/good/coordinator/live.rs calls Instant::now under a line
    // pragma; the determinism lint must stay silent for it.
    let tree = load_with_overlay(None);
    let findings = lints::run_one(&tree, "determinism").unwrap();
    assert!(
        findings.is_empty(),
        "pragma failed to sanction wall-clock use:\n{}",
        render(&findings)
    );
}

#[test]
fn blocking_lint_catches_sleep_and_sync_in_tick_path() {
    let tree = load_with_overlay(Some(("bad_blocking_server.rs", "weightstore/server.rs")));
    let findings = lints::run_one(&tree, "blocking").unwrap();
    assert_spans(&findings);
    // The overlay sleeps on its line 28 and syncs on line 30, two call
    // edges below serve; the witness path must name the root.
    for (name, line) in [("sleep", 28), ("sync_all", 30)] {
        let hit = findings
            .iter()
            .find(|f| {
                f.msg.contains(&format!("`{name}(…)`"))
                    && f.file.ends_with("weightstore/server.rs")
            })
            .unwrap_or_else(|| panic!("expected `{name}` finding, got:\n{}", render(&findings)));
        assert_eq!(hit.line, line, "finding points at the wrong line: {hit}");
        assert!(
            hit.msg.contains("serve -> tick -> settle"),
            "witness path should walk from serve: {hit}"
        );
    }
    assert_eq!(
        findings.len(),
        2,
        "unexpected extra findings:\n{}",
        render(&findings)
    );
}

#[test]
fn panics_lint_catches_decode_unwrap_and_range_index() {
    let tree = load_with_overlay(Some(("bad_panics_server.rs", "weightstore/server.rs")));
    let findings = lints::run_one(&tree, "panics").unwrap();
    assert_spans(&findings);
    // Overlay line 22: `Request::decode(…).unwrap()`; line 26: `frame[0..9]`.
    let unwrap_hit = findings
        .iter()
        .find(|f| f.msg.contains("`.unwrap(…)`"))
        .unwrap_or_else(|| panic!("expected `.unwrap()` finding, got:\n{}", render(&findings)));
    assert_eq!(unwrap_hit.line, 22, "finding points at the wrong line: {unwrap_hit}");
    let range_hit = findings
        .iter()
        .find(|f| f.msg.contains("range indexing `[0..9]`"))
        .unwrap_or_else(|| panic!("expected range-index finding, got:\n{}", render(&findings)));
    assert_eq!(range_hit.line, 26, "finding points at the wrong line: {range_hit}");
    assert!(
        range_hit.msg.contains("serve -> tick -> parse -> header"),
        "witness path should walk from serve: {range_hit}"
    );
    // The good tree's poison unwraps (`.lock().unwrap()`) must NOT fire:
    // only the two injected sites are findings.
    assert_eq!(
        findings.len(),
        2,
        "unexpected extra findings:\n{}",
        render(&findings)
    );
}

#[test]
fn telemetry_lint_catches_grammar_membership_and_kind() {
    let tree = load_with_overlay(Some(("bad_telemetry_server.rs", "weightstore/server.rs")));
    let findings = lints::run_one(&tree, "telemetry").unwrap();
    assert_spans(&findings);
    // Line 19 breaks the grammar (and is therefore also undeclared),
    // line 20 is a grammar-clean name missing from STORE_METRICS, and
    // line 21 uses a declared counter as a histogram.
    assert!(
        findings.iter().any(|f| f.line == 19 && f.msg.contains("grammar")),
        "expected grammar finding on line 19, got:\n{}",
        render(&findings)
    );
    assert!(
        findings
            .iter()
            .any(|f| f.line == 20 && f.msg.contains("not declared in")),
        "expected STORE_METRICS membership finding on line 20, got:\n{}",
        render(&findings)
    );
    assert!(
        findings
            .iter()
            .any(|f| f.line == 21 && f.msg.contains("declared 'c'")),
        "expected kind-mismatch finding on line 21, got:\n{}",
        render(&findings)
    );
    assert_eq!(
        findings.len(),
        4,
        "unexpected extra findings:\n{}",
        render(&findings)
    );
}

#[test]
fn unknown_lint_name_is_rejected() {
    let tree = load_with_overlay(None);
    assert!(lints::run_one(&tree, "no-such-lint").is_none());
}
