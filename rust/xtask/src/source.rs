//! Source model for the analyzer: file loading, a line-preserving
//! comment/string scrubber, allowlist pragmas, and small token utilities.
//!
//! The analyzer deliberately has no dependencies (no `syn` — builds must
//! work offline), so it operates on scrubbed text: comments, string
//! literals and char literals are blanked with spaces (newlines kept), so
//! byte offsets and line numbers in the scrubbed text match the original
//! file exactly.  Every lint that looks for tokens (`0x04`, `.lock()`,
//! `Instant::now`) runs over scrubbed text and therefore cannot be fooled
//! by doc comments or log strings; lints that read the opcode doc table
//! use the raw text.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One `.rs` file under the analyzed source root.
pub struct SourceFile {
    /// Path relative to the source root, with `/` separators.
    pub rel: String,
    /// Original file contents.
    pub raw: String,
    /// Contents with comments/strings/chars blanked; same length and line
    /// structure as `raw`.
    pub code: String,
    /// `code` with `#[cfg(test)] mod … { … }` bodies additionally blanked.
    /// Conformance lints (protocol/trait/locks) use this so fixture bytes
    /// inside unit tests (e.g. a bogus `0xEE` opcode) are not mistaken for
    /// production protocol surface.  The determinism lint scans `code`:
    /// tests are held to the same wall-clock rules as the library.
    pub code_sans_tests: String,
    /// Byte offset of the start of each line (for offset → line mapping).
    line_starts: Vec<usize>,
    /// Allowlist pragmas parsed from comments (see [`Allows`]).
    pub allows: Allows,
}

/// Parsed `analyze: allow…` pragmas for one file.
///
/// Syntax (inside any comment):
///   `// analyze: allow(key[, key…]): reason`          — allows the pragma's
///       own line and the line directly below it (so a full-line comment
///       immediately above the offending line covers it).
///   `// analyze: allow-module(key[, key…]): reason`   — allows the whole file.
///
/// A non-empty reason is mandatory; a pragma without one is itself a
/// finding (reported by the loader).
#[derive(Default)]
pub struct Allows {
    line: BTreeMap<usize, BTreeSet<String>>,
    module: BTreeSet<String>,
}

impl Allows {
    /// Is `key` allowed on 1-based line `line`?
    pub fn allowed(&self, line: usize, key: &str) -> bool {
        if self.module.contains(key) {
            return true;
        }
        let hit = |l: usize| self.line.get(&l).is_some_and(|s| s.contains(key));
        hit(line) || (line > 1 && hit(line - 1))
    }
}

/// A single lint finding, pointable to `file:line`.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub lint: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.lint, self.msg)
    }
}

/// All `.rs` files under one source root.
pub struct Tree {
    pub root: PathBuf,
    pub files: Vec<SourceFile>,
    /// Findings produced while loading (malformed pragmas).
    pub load_findings: Vec<Finding>,
}

impl Tree {
    pub fn load(root: &Path) -> io::Result<Tree> {
        let mut paths = Vec::new();
        collect_rs(root, &mut paths)?;
        paths.sort();
        let mut files = Vec::new();
        let mut load_findings = Vec::new();
        for p in paths {
            let raw = fs::read_to_string(&p)?;
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            files.push(SourceFile::parse(rel, raw, &mut load_findings));
        }
        Ok(Tree {
            root: root.to_path_buf(),
            files,
            load_findings,
        })
    }

    pub fn get(&self, rel_suffix: &str) -> Option<&SourceFile> {
        self.files
            .iter()
            .find(|f| f.rel == rel_suffix || f.rel.ends_with(rel_suffix))
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

impl SourceFile {
    pub fn parse(rel: String, raw: String, findings: &mut Vec<Finding>) -> SourceFile {
        let allows = parse_pragmas(&rel, &raw, findings);
        let code = scrub(&raw);
        let code_sans_tests = strip_test_mods(&code);
        let line_starts = line_starts(&raw);
        SourceFile {
            rel,
            raw,
            code,
            code_sans_tests,
            line_starts,
            allows,
        }
    }

    /// 1-based line number of a byte offset.
    pub fn line_of(&self, offset: usize) -> usize {
        match self.line_starts.binary_search(&offset) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }
}

fn line_starts(s: &str) -> Vec<usize> {
    let mut v = vec![0usize];
    for (i, b) in s.bytes().enumerate() {
        if b == b'\n' {
            v.push(i + 1);
        }
    }
    v
}

fn parse_pragmas(rel: &str, raw: &str, findings: &mut Vec<Finding>) -> Allows {
    let mut allows = Allows::default();
    for (idx, line) in raw.lines().enumerate() {
        let lineno = idx + 1;
        for (marker, module_wide) in [("analyze: allow-module(", true), ("analyze: allow(", false)]
        {
            let Some(pos) = line.find(marker) else { continue };
            // Pragmas must live in comments; anything else is someone
            // writing the literal string, which we ignore.
            if !line[..pos].contains("//") {
                continue;
            }
            let rest = &line[pos + marker.len()..];
            let Some(close) = rest.find(')') else {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: lineno,
                    lint: "pragma",
                    msg: "malformed allow pragma: missing ')'".into(),
                });
                continue;
            };
            let keys: Vec<String> = rest[..close]
                .split(',')
                .map(|k| k.trim().to_string())
                .filter(|k| !k.is_empty())
                .collect();
            let after = rest[close + 1..].trim_start();
            let reason_ok = after.starts_with(':') && !after[1..].trim().is_empty();
            if keys.is_empty() || !reason_ok {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: lineno,
                    lint: "pragma",
                    msg: "malformed allow pragma: need `allow(key): non-empty reason`".into(),
                });
                continue;
            }
            for k in keys {
                if module_wide {
                    allows.module.insert(k);
                } else {
                    allows.line.entry(lineno).or_default().insert(k);
                }
            }
        }
    }
    allows
}

/// Blank comments, string literals and char literals with spaces,
/// preserving newlines and byte length.
pub fn scrub(src: &str) -> String {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let blank = |out: &mut Vec<u8>, from: usize, to: usize| {
        for x in out.iter_mut().take(to).skip(from) {
            if *x != b'\n' {
                *x = b' ';
            }
        }
    };
    let prev_is_ident = |i: usize| i > 0 && is_ident_byte(b[i - 1]);
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        // Line comment (also covers `///` and `//!`).
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let start = i;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            blank(&mut out, start, i);
            continue;
        }
        // Block comment, nested.
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start = i;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            blank(&mut out, start, i);
            continue;
        }
        // Raw strings r"…", r#"…"#, br"…" (and raw identifiers r#foo,
        // which fall through to plain code).
        if (c == b'r' || c == b'b') && !prev_is_ident(i) {
            let mut j = i;
            if b[j] == b'b' && j + 1 < n && b[j + 1] == b'r' {
                j += 1;
            }
            if b[j] == b'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while k < n && b[k] == b'#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && b[k] == b'"' {
                    let start = i;
                    i = k + 1;
                    'raw: while i < n {
                        if b[i] == b'"' {
                            let mut h = 0usize;
                            while h < hashes && i + 1 + h < n && b[i + 1 + h] == b'#' {
                                h += 1;
                            }
                            if h == hashes {
                                i += 1 + hashes;
                                break 'raw;
                            }
                        }
                        i += 1;
                    }
                    blank(&mut out, start, i);
                    continue;
                }
            }
        }
        // Byte string b"…" and plain "…".
        if c == b'"' || (c == b'b' && !prev_is_ident(i) && i + 1 < n && b[i + 1] == b'"') {
            let start = i;
            i += if c == b'b' { 2 } else { 1 };
            while i < n {
                if b[i] == b'\\' {
                    i += 2;
                } else if b[i] == b'"' {
                    i += 1;
                    break;
                } else {
                    i += 1;
                }
            }
            blank(&mut out, start, i.min(n));
            continue;
        }
        // Char literal b'…' / '…' vs lifetime 'a.
        if c == b'\'' || (c == b'b' && !prev_is_ident(i) && i + 1 < n && b[i + 1] == b'\'') {
            let q = if c == b'b' { i + 1 } else { i };
            if q + 1 < n {
                let nx = b[q + 1];
                let is_char = if nx == b'\\' {
                    true
                } else if nx < 0x80 {
                    // `'x'` (any single ASCII char incl. punctuation) is a
                    // char literal iff the very next byte closes it;
                    // otherwise it's a lifetime/label like `'a`.
                    q + 2 < n && b[q + 2] == b'\''
                } else {
                    // Multi-byte scalar: can't be a lifetime.
                    true
                };
                if is_char {
                    let start = i;
                    i = q + 1;
                    while i < n {
                        if b[i] == b'\\' {
                            i += 2;
                        } else if b[i] == b'\'' {
                            i += 1;
                            break;
                        } else {
                            i += 1;
                        }
                    }
                    blank(&mut out, start, i.min(n));
                    continue;
                }
            }
        }
        i += 1;
    }
    // Blanking is ASCII-space only, so the result is valid UTF-8 wherever
    // the input was; fall back to lossy for robustness.
    String::from_utf8(out).unwrap_or_else(|e| String::from_utf8_lossy(e.as_bytes()).into_owned())
}

/// Blank the bodies of `#[cfg(test)] mod … { … }` items in scrubbed code.
pub fn strip_test_mods(code: &str) -> String {
    let mut out = code.as_bytes().to_vec();
    let mut search_from = 0usize;
    while let Some(pos) = find_token_from(code, "cfg", search_from) {
        search_from = pos + 3;
        // Require the `#[cfg(test)]` shape around the token.
        let rest = &code[pos..];
        if !rest.starts_with("cfg(test)") {
            continue;
        }
        // Find the following `mod` token, then its opening brace.
        let Some(mod_pos) = find_token_from(code, "mod", pos) else { continue };
        if mod_pos > pos + 200 {
            continue; // cfg(test) on something other than a nearby mod
        }
        let Some(open) = code[mod_pos..].find('{').map(|o| mod_pos + o) else { continue };
        let Some(close) = matching_brace(code.as_bytes(), open) else { continue };
        for x in out.iter_mut().take(close).skip(open + 1) {
            if *x != b'\n' {
                *x = b' ';
            }
        }
        search_from = close;
    }
    String::from_utf8(out).unwrap_or_else(|e| String::from_utf8_lossy(e.as_bytes()).into_owned())
}

/// Index of the `}` matching the `{` at `open` (input must be scrubbed).
pub fn matching_brace(b: &[u8], open: usize) -> Option<usize> {
    debug_assert_eq!(b[open], b'{');
    let mut depth = 0i64;
    for (i, &c) in b.iter().enumerate().skip(open) {
        if c == b'{' {
            depth += 1;
        } else if c == b'}' {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
    }
    None
}

pub fn is_ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Find `tok` at an identifier boundary, starting at byte `from`.
pub fn find_token_from(code: &str, tok: &str, from: usize) -> Option<usize> {
    let b = code.as_bytes();
    let t = tok.as_bytes();
    let mut i = from;
    while let Some(off) = code.get(i..)?.find(tok) {
        let pos = i + off;
        let pre_ok = pos == 0 || !is_ident_byte(b[pos - 1]);
        let post = pos + t.len();
        let post_ok = post >= b.len() || !is_ident_byte(b[post]);
        // For tokens that themselves start/end with non-ident bytes
        // (e.g. `Instant::now`), the boundary checks above still apply to
        // the first/last byte, which is what we want.
        if pre_ok && post_ok {
            return Some(pos);
        }
        i = pos + 1;
    }
    None
}

/// All boundary-correct occurrences of `tok`.
pub fn find_all_tokens(code: &str, tok: &str) -> Vec<usize> {
    let mut v = Vec::new();
    let mut from = 0usize;
    while let Some(pos) = find_token_from(code, tok, from) {
        v.push(pos);
        from = pos + 1;
    }
    v
}

/// Skip ASCII whitespace forward from `i`, returning the next index.
pub fn skip_ws(b: &[u8], mut i: usize) -> usize {
    while i < b.len() && b[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// Skip ASCII whitespace backward from `i` (exclusive), returning the index
/// of the last non-ws byte, or None if none.
pub fn prev_non_ws(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i;
    while j > 0 {
        j -= 1;
        if !b[j].is_ascii_whitespace() {
            return Some(j);
        }
    }
    None
}

/// The maximal identifier ending at byte `end` (inclusive), if any.
pub fn ident_ending_at(b: &[u8], end: usize) -> Option<(usize, String)> {
    if !is_ident_byte(b[end]) {
        return None;
    }
    let mut start = end;
    while start > 0 && is_ident_byte(b[start - 1]) {
        start -= 1;
    }
    Some((start, String::from_utf8_lossy(&b[start..=end]).into_owned()))
}

/// The maximal identifier starting at byte `start`, if any.
pub fn ident_starting_at(b: &[u8], start: usize) -> Option<String> {
    if start >= b.len() || !is_ident_byte(b[start]) || b[start].is_ascii_digit() {
        return None;
    }
    let mut end = start;
    while end < b.len() && is_ident_byte(b[end]) {
        end += 1;
    }
    Some(String::from_utf8_lossy(&b[start..end]).into_owned())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrub_blanks_comments_and_strings() {
        let src = "let x = \"0x04\"; // 0x05\nlet y = 0x06; /* 0x07 */ let c = '\\n';";
        let s = scrub(src);
        assert_eq!(s.len(), src.len());
        assert!(!s.contains("0x04"));
        assert!(!s.contains("0x05"));
        assert!(s.contains("0x06"));
        assert!(!s.contains("0x07"));
        assert_eq!(s.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn scrub_handles_raw_strings_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let r = r#\"lock()\"#; let q = 'q'; }";
        let s = scrub(src);
        assert!(!s.contains("lock()"));
        assert!(s.contains("fn f<'a>"));
        assert!(!s.contains("'q'"));
    }

    #[test]
    fn strip_test_mods_blanks_bodies() {
        let src = "fn real() { a(); }\n#[cfg(test)]\nmod tests {\n    fn t() { bad(); }\n}\n";
        let s = strip_test_mods(&scrub(src));
        assert!(s.contains("real"));
        assert!(!s.contains("bad"));
    }

    #[test]
    fn pragma_parsing() {
        let mut f = Vec::new();
        let sf = SourceFile::parse(
            "x.rs".into(),
            "// analyze: allow(wallclock): timer is wall-time by design\nlet t = 1;\n// analyze: allow(oops)\n".into(),
            &mut f,
        );
        assert!(sf.allows.allowed(1, "wallclock"));
        assert!(sf.allows.allowed(2, "wallclock"));
        assert!(!sf.allows.allowed(3, "wallclock"));
        assert_eq!(f.len(), 1, "missing reason is a finding: {f:?}");
    }
}
