//! Protocol-surface exhaustiveness lint.
//!
//! Cross-checks five surfaces that must agree for every opcode:
//!   1. the opcode doc table in `weightstore/protocol.rs`'s module header,
//!   2. the encode side (the opcode byte is written somewhere in code),
//!   3. the decode side (a `0xNN =>` match arm exists),
//!   4. the server dispatch and client proxy (`Request::Variant` appears
//!      in `server.rs` and `client.rs`),
//!   5. the durable journal: every *mutating* request variant maps to a
//!      `Record` variant that is both appended inside
//!      `impl WeightStore for DurableStore` and replayed in `apply_record`.
//!
//! A new opcode that misses any surface — including the doc table — fails
//! CI with a finding pointing at the omission.  `FaultyStore` passthrough
//! and `MemStore` execution are covered by the trait-wiring lint (every
//! trait method implemented by every backend), since requests reach the
//! backends through trait methods, not opcodes.

use std::collections::{BTreeMap, BTreeSet};

use crate::source::{find_token_from, matching_brace, Finding, SourceFile, Tree};

/// Request variants that do not mutate store state and therefore need no
/// journal record.  A variant in neither this list nor [`JOURNAL_MAP`]
/// produces a finding, which forces the author of a new opcode to decide
/// its durability story explicitly.
const READ_ONLY: &[&str] = &[
    "FetchParams",
    "FetchParamsSince",
    "ParamsVersion",
    "FetchWeights",
    "FetchWeightsSince",
    "LoadCursor",
    "Now",
    "Stats",
    "FetchMetrics",
    "Shutdown",
];

/// Mutating request variant → journal `Record` variant.
const JOURNAL_MAP: &[(&str, &str)] = &[
    ("PushParams", "Params"),
    ("PushParamsLayers", "ParamsLayers"),
    ("PushWeights", "Delta"),
    ("ApplyGrad", "Grad"),
    ("SaveCursor", "Cursor"),
    ("DropCursor", "DropCursor"),
];

pub fn run(tree: &Tree) -> Vec<Finding> {
    let mut findings = Vec::new();
    let Some(proto) = tree.get("weightstore/protocol.rs") else {
        findings.push(Finding {
            file: "weightstore/protocol.rs".into(),
            line: 1,
            lint: "protocol",
            msg: "file not found; protocol lint cannot run".into(),
        });
        return findings;
    };

    let (req_table, resp_table) = parse_doc_table(proto);
    if req_table.is_empty() || resp_table.is_empty() {
        findings.push(Finding {
            file: proto.rel.clone(),
            line: 1,
            lint: "protocol",
            msg: "opcode doc table missing or empty (expected `//! | 0xNN | \\`Name\\` | …` rows)"
                .into(),
        });
        return findings;
    }
    let table: BTreeMap<u8, (String, usize)> =
        req_table.iter().chain(resp_table.iter()).cloned().map(|(op, name, line)| (op, (name, line))).collect();

    // --- opcode literals in code: decode arms vs encode writes ---------
    let code = &proto.code_sans_tests;
    let b = code.as_bytes();
    let mut decode_arms: BTreeSet<u8> = BTreeSet::new();
    let mut encode_refs: BTreeSet<u8> = BTreeSet::new();
    for (pos, op) in hex_byte_literals(code) {
        let line = proto.line_of(pos);
        if !table.contains_key(&op) && !proto.allows.allowed(line, "opcode-table") {
            findings.push(Finding {
                file: proto.rel.clone(),
                line,
                lint: "protocol",
                msg: format!("opcode 0x{op:02X} used in code but absent from the module doc table"),
            });
        }
        // `0xNN =>` is a decode arm; anything else is the encode side.
        let mut j = pos + 4;
        while j < b.len() && b[j].is_ascii_whitespace() {
            j += 1;
        }
        if j + 1 < b.len() && b[j] == b'=' && b[j + 1] == b'>' {
            decode_arms.insert(op);
        } else {
            encode_refs.insert(op);
        }
    }
    for (&op, (name, line)) in &table {
        if !decode_arms.contains(&op) {
            findings.push(Finding {
                file: proto.rel.clone(),
                line: *line,
                lint: "protocol",
                msg: format!("opcode 0x{op:02X} `{name}` has no decode arm (`0x{op:02X} =>`)"),
            });
        }
        if !encode_refs.contains(&op) {
            findings.push(Finding {
                file: proto.rel.clone(),
                line: *line,
                lint: "protocol",
                msg: format!("opcode 0x{op:02X} `{name}` is never written on the encode side"),
            });
        }
    }

    // --- enum variants ↔ doc table -------------------------------------
    let req_variants = enum_variants(proto, "Request");
    let resp_variants = enum_variants(proto, "Response");
    check_table_matches_enum(&mut findings, proto, "Request", &req_table, &req_variants);
    check_table_matches_enum(&mut findings, proto, "Response", &resp_table, &resp_variants);

    // --- every Request variant wired through server dispatch + client --
    for peer in ["weightstore/server.rs", "weightstore/client.rs"] {
        let Some(file) = tree.get(peer) else {
            findings.push(Finding {
                file: peer.into(),
                line: 1,
                lint: "protocol",
                msg: "file not found; cannot check Request variant wiring".into(),
            });
            continue;
        };
        for (name, line) in &req_variants {
            let pat = format!("Request::{name}");
            if find_token_from(&file.code_sans_tests, &pat, 0).is_none() {
                findings.push(Finding {
                    file: proto.rel.clone(),
                    line: *line,
                    lint: "protocol",
                    msg: format!("Request::{name} is not handled in {}", file.rel),
                });
            }
        }
    }

    // --- durable journal coverage for mutating variants ----------------
    if let Some(durable) = tree.get("weightstore/durable.rs") {
        let dcode = &durable.code_sans_tests;
        let journal: BTreeMap<&str, &str> = JOURNAL_MAP.iter().cloned().collect();
        let impl_span = impl_block_span(dcode, "WeightStore", "DurableStore");
        let replay_span = fn_span(dcode, "apply_record");
        for (name, line) in &req_variants {
            if READ_ONLY.contains(&name.as_str()) {
                continue;
            }
            let Some(record) = journal.get(name.as_str()) else {
                findings.push(Finding {
                    file: proto.rel.clone(),
                    line: *line,
                    lint: "protocol",
                    msg: format!(
                        "Request::{name} is neither in the read-only list nor the journal map; \
                         a new mutating opcode must declare its journal Record (extend \
                         xtask/src/lints/protocol.rs JOURNAL_MAP)"
                    ),
                });
                continue;
            };
            let pat = format!("Record::{record}");
            let in_span = |span: Option<(usize, usize)>| {
                span.is_some_and(|(s, e)| {
                    find_token_from(dcode, &pat, s).is_some_and(|p| p < e)
                })
            };
            if !in_span(impl_span) {
                findings.push(Finding {
                    file: durable.rel.clone(),
                    line: 1,
                    lint: "protocol",
                    msg: format!(
                        "mutating Request::{name} has no `{pat}` append inside \
                         `impl WeightStore for DurableStore`"
                    ),
                });
            }
            if !in_span(replay_span) {
                findings.push(Finding {
                    file: durable.rel.clone(),
                    line: 1,
                    lint: "protocol",
                    msg: format!("journal `{pat}` (for Request::{name}) is not replayed in `apply_record`"),
                });
            }
        }
    } else {
        findings.push(Finding {
            file: "weightstore/durable.rs".into(),
            line: 1,
            lint: "protocol",
            msg: "file not found; cannot check journal coverage".into(),
        });
    }

    findings
}

fn check_table_matches_enum(
    findings: &mut Vec<Finding>,
    proto: &SourceFile,
    enum_name: &str,
    table: &[(u8, String, usize)],
    variants: &[(String, usize)],
) {
    let tnames: BTreeSet<&str> = table.iter().map(|(_, n, _)| n.as_str()).collect();
    let vnames: BTreeSet<&str> = variants.iter().map(|(n, _)| n.as_str()).collect();
    for (_, name, line) in table {
        if !vnames.contains(name.as_str()) {
            findings.push(Finding {
                file: proto.rel.clone(),
                line: *line,
                lint: "protocol",
                msg: format!("doc table lists `{name}` but enum {enum_name} has no such variant"),
            });
        }
    }
    for (name, line) in variants {
        if !tnames.contains(name.as_str()) {
            findings.push(Finding {
                file: proto.rel.clone(),
                line: *line,
                lint: "protocol",
                msg: format!("enum {enum_name} variant `{name}` missing from the module doc table"),
            });
        }
    }
}

/// Parse the module-header opcode table.  Rows pair a request and a
/// response column:
///
/// ```text
/// //! | 0x01 | `PushParams` | 0x80 | `Ok` |
/// ```
///
/// Requests (opcode < 0x80) and responses (>= 0x80) are returned
/// separately; header/separator rows and empty cells parse to nothing.
#[allow(clippy::type_complexity)]
fn parse_doc_table(proto: &SourceFile) -> (Vec<(u8, String, usize)>, Vec<(u8, String, usize)>) {
    let mut req = Vec::new();
    let mut resp = Vec::new();
    for (idx, line) in proto.raw.lines().enumerate() {
        let t = line.trim_start();
        if !t.starts_with("//!") {
            continue;
        }
        let cells: Vec<&str> = t.trim_start_matches("//!").split('|').collect();
        for pair in [(1usize, 2usize), (3, 4)] {
            let (ci, cn) = pair;
            if cells.len() <= cn {
                continue;
            }
            let Some(op) = parse_hex_byte(cells[ci].trim()) else { continue };
            let name = cells[cn].trim().trim_matches('`').to_string();
            if name.is_empty() || !name.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
                continue;
            }
            let row = (op, name, idx + 1);
            if op < 0x80 {
                req.push(row);
            } else {
                resp.push(row);
            }
        }
    }
    (req, resp)
}

fn parse_hex_byte(s: &str) -> Option<u8> {
    let hex = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X"))?;
    if hex.len() != 2 {
        return None;
    }
    u8::from_str_radix(hex, 16).ok()
}

/// All bare `0xNN` (exactly two hex digit, no suffix) literals in
/// scrubbed code.  Suffixed literals like `0x87u8` are intentionally
/// excluded: opcode bytes in this codebase are written bare, and test
/// fixtures deliberately use suffixed forms for non-opcode bytes.
fn hex_byte_literals(code: &str) -> Vec<(usize, u8)> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 3 < b.len() {
        let boundary = i == 0 || !crate::source::is_ident_byte(b[i - 1]);
        if boundary && b[i] == b'0' && b[i + 1] == b'x' {
            let d = &b[i + 2..];
            if d.len() >= 2 && d[0].is_ascii_hexdigit() && d[1].is_ascii_hexdigit() {
                let more = d.len() > 2 && crate::source::is_ident_byte(d[2]);
                if !more {
                    if let Ok(v) = u8::from_str_radix(std::str::from_utf8(&d[..2]).unwrap(), 16) {
                        out.push((i, v));
                    }
                }
                i += 4;
                continue;
            }
        }
        i += 1;
    }
    out
}

/// Variant names (with lines) of `enum <name>` in scrubbed, test-stripped
/// code: identifiers at brace depth 1 / paren depth 0 whose previous
/// non-ws byte is `{` or `,`.
pub fn enum_variants(file: &SourceFile, name: &str) -> Vec<(String, usize)> {
    let code = &file.code_sans_tests;
    let b = code.as_bytes();
    let Some(kw) = find_enum_decl(code, name) else { return Vec::new() };
    let Some(open) = code[kw..].find('{').map(|o| kw + o) else { return Vec::new() };
    let Some(close) = matching_brace(b, open) else { return Vec::new() };
    let mut out = Vec::new();
    let mut brace = 0i64;
    let mut paren = 0i64;
    let mut prev_sig = b'{'; // last significant byte seen
    let mut i = open + 1;
    while i < close {
        let c = b[i];
        match c {
            b'{' => brace += 1,
            b'}' => brace -= 1,
            b'(' => paren += 1,
            b')' => paren -= 1,
            _ => {}
        }
        if brace == 0 && paren == 0 && crate::source::is_ident_byte(c) && !c.is_ascii_digit() {
            if prev_sig == b'{' || prev_sig == b',' {
                if let Some(ident) = crate::source::ident_starting_at(b, i) {
                    if ident.chars().next().is_some_and(|ch| ch.is_ascii_uppercase()) {
                        out.push((ident.clone(), file.line_of(i)));
                    }
                    prev_sig = b'?'; // consume: fields after `Name` don't match
                    i += ident.len();
                    continue;
                }
            }
        }
        if !c.is_ascii_whitespace() {
            prev_sig = c;
            // A full ident counts as one significant token; skip it so its
            // tail bytes don't update prev_sig byte-by-byte.
            if crate::source::is_ident_byte(c) {
                while i + 1 < close && crate::source::is_ident_byte(b[i + 1]) {
                    i += 1;
                }
                prev_sig = b'?';
            }
        }
        i += 1;
    }
    out
}

fn find_enum_decl(code: &str, name: &str) -> Option<usize> {
    let mut from = 0usize;
    while let Some(pos) = find_token_from(code, "enum", from) {
        from = pos + 4;
        let b = code.as_bytes();
        let j = crate::source::skip_ws(b, pos + 4);
        if let Some(ident) = crate::source::ident_starting_at(b, j) {
            if ident == name {
                return Some(pos);
            }
        }
    }
    None
}

/// Byte span (start, end) of the body of `impl <trait_name> for <type_name>`.
pub fn impl_block_span(code: &str, trait_name: &str, type_name: &str) -> Option<(usize, usize)> {
    let b = code.as_bytes();
    let mut from = 0usize;
    while let Some(pos) = find_token_from(code, "impl", from) {
        from = pos + 4;
        let head_end = code[pos..].find('{')? + pos;
        let head = &code[pos..head_end];
        if find_token_from(head, trait_name, 0).is_some()
            && find_token_from(head, "for", 0).is_some()
            && find_token_from(head, type_name, 0).is_some()
        {
            let close = matching_brace(b, head_end)?;
            return Some((head_end, close));
        }
    }
    None
}

/// Byte span of the body of `fn <name>`.
pub fn fn_span(code: &str, name: &str) -> Option<(usize, usize)> {
    let b = code.as_bytes();
    let mut from = 0usize;
    while let Some(pos) = find_token_from(code, "fn", from) {
        from = pos + 2;
        let j = crate::source::skip_ws(b, pos + 2);
        let Some(ident) = crate::source::ident_starting_at(b, j) else { continue };
        if ident != name {
            continue;
        }
        // Scan to the body `{` (or `;` for a bare declaration).
        let mut k = j + ident.len();
        while k < b.len() && b[k] != b'{' && b[k] != b';' {
            k += 1;
        }
        if k >= b.len() || b[k] == b';' {
            continue;
        }
        let close = matching_brace(b, k)?;
        return Some((k, close));
    }
    None
}
