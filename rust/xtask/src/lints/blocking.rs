//! Blocking-in-event-loop analysis.
//!
//! The store server is one thread and one `poll(2)` loop; a blocking
//! syscall inside a tick stalls every connected master/worker/peer at
//! once.  This lint walks the shared call graph ([`crate::callgraph`])
//! from `serve()` in `weightstore/server.rs` and flags any *blocking
//! operation* in a reachable function body:
//!
//! - `sync_all(…)` / `sync_data(…)` — file sync (also what inline
//!   compaction would reach; the background compactor is the sanctioned
//!   seam and is only *signaled* from the tick path);
//! - `sleep(…)` — `thread::sleep` and friends;
//! - `connect(…)` / `connect_timeout(…)` — blocking TCP dials;
//! - `.wait(…)` / `.wait_timeout(…)` / `.wait_while(…)` — condvar waits.
//!
//! Two scope decisions keep the walk honest:
//!
//! - **Seams.** `after_append` only `notify_one`s the compactor thread —
//!   notifications are non-blocking, so `compactor_loop` and everything
//!   behind it is simply not reachable through call edges.  No special
//!   carve-out is needed; if someone ever calls `compact_now` from the
//!   tick path, the sync sites inside it light up.
//! - **Client exclusion.** Union-by-name resolution would drag
//!   `weightstore/client.rs` (the *other end* of the wire: blocking
//!   `read_exact`/`connect_timeout`/backoff sleeps by design) into the
//!   serve graph through the shared `WeightStore` method names.  The
//!   server never fronts a remote `Client` — its backends are the
//!   in-process stores — so edges into `client.rs` are cut.  A future
//!   proxy deployment must revisit this lint first.
//!
//! Nonblocking-socket `read`/`write` in the loop itself are fine (the
//! sockets are `set_nonblocking(true)`); the tokens above are the calls
//! that block regardless of socket mode.  Waive a deliberate site with
//! `// analyze: allow(blocking): reason` — e.g. the opt-in
//! `DurableOptions::fsync` append path, whose cost is measured by the
//! `journal.fsync_ns` histogram.

use crate::callgraph::Graph;
use crate::source::{ident_starting_at, is_ident_byte, prev_non_ws, skip_ws, Finding, Tree};

const KEY: &str = "blocking";

/// Bare or method calls that block the calling thread.
const BLOCKING_CALLS: &[(&str, &str)] = &[
    ("sync_all", "file sync"),
    ("sync_data", "file sync"),
    ("sleep", "thread sleep"),
    ("connect", "blocking TCP connect"),
    ("connect_timeout", "blocking TCP connect"),
];

/// Method calls (dot-preceded only) that block: condvar waits.  Bare
/// `wait` would also match unrelated helpers, so these require a `.`.
const BLOCKING_METHODS: &[(&str, &str)] = &[
    ("wait", "condvar wait"),
    ("wait_timeout", "condvar wait"),
    ("wait_while", "condvar wait"),
];

pub fn run(tree: &Tree) -> Vec<Finding> {
    let mut findings = Vec::new();
    let Some(server) = tree.get("weightstore/server.rs") else {
        // Trees without a server (partial fixtures) have no event loop to
        // protect.
        return findings;
    };
    let server_rel = server.rel.clone();

    let graph = Graph::build(tree);
    let roots = graph.fns_named_in("serve", "weightstore/server.rs");
    if roots.is_empty() {
        findings.push(Finding {
            file: server_rel,
            line: 1,
            lint: "blocking",
            msg: "no `fn serve` found in weightstore/server.rs — the blocking lint has no \
                  event-loop root"
                .into(),
        });
        return findings;
    }
    let reach = graph.reach(&roots, |j| {
        // Cut edges into the client side of the wire (see module docs).
        !graph.file_of(j).rel.ends_with("weightstore/client.rs")
    });

    for i in reach.all() {
        let file = graph.file_of(i);
        let b = file.code_sans_tests.as_bytes();
        let body = graph.fns[i].body;
        let nested = graph.nested_spans(i);
        let mut k = body.0;
        while k <= body.1 {
            if let Some(&(_, e)) = nested.iter().find(|(s, _)| *s == k) {
                k = e + 1;
                continue;
            }
            if !is_ident_byte(b[k]) || b[k].is_ascii_digit() || (k > 0 && is_ident_byte(b[k - 1]))
            {
                k += 1;
                continue;
            }
            let Some(name) = ident_starting_at(b, k) else {
                k += 1;
                continue;
            };
            let after = skip_ws(b, k + name.len());
            let is_call = after < b.len() && b[after] == b'(';
            let dotted = prev_non_ws(b, k).is_some_and(|p| b[p] == b'.');
            let what = if is_call {
                BLOCKING_CALLS
                    .iter()
                    .find(|(n, _)| *n == name)
                    .or_else(|| {
                        if dotted {
                            BLOCKING_METHODS.iter().find(|(n, _)| *n == name)
                        } else {
                            None
                        }
                    })
                    .map(|(_, w)| *w)
            } else {
                None
            };
            if let Some(what) = what {
                let line = file.line_of(k);
                if !file.allows.allowed(line, KEY) {
                    findings.push(Finding {
                        file: file.rel.clone(),
                        line,
                        lint: "blocking",
                        msg: format!(
                            "{what} `{name}(…)` is reachable from the event-loop tick \
                             ({}); move it behind the background-compactor/offload seam or \
                             waive with `analyze: allow(blocking): reason`",
                            reach.path(&graph, i)
                        ),
                    });
                }
            }
            k += name.len();
        }
    }
    findings
}
