//! Telemetry-conformance analysis.
//!
//! The telemetry registry keys instruments by string name; nothing in the
//! type system stops a typo'd name, a third dotted segment, or one name
//! registered as a counter here and a histogram there (which panics at
//! runtime — the registry's kind guard).  This lint makes those static:
//!
//! - every `counter("…")` / `gauge("…")` / `histogram("…")` literal must
//!   match the `subsystem.metric` grammar — exactly two dot-separated
//!   lowercase `snake_case` segments (the grammar documented in
//!   `telemetry/mod.rs`);
//! - every metric literal in store-process code (files under
//!   `weightstore/`) must appear in `telemetry::STORE_METRICS` with a
//!   matching kind — the canonical schema a `FetchMetrics` scrape
//!   pre-registers at `serve()` start;
//! - no name may be used with conflicting instrument kinds anywhere in
//!   the tree, and `STORE_METRICS` itself must be well-formed (valid
//!   kind chars, grammar-clean names, no duplicates).
//!
//! Sites are located in test-stripped scrubbed code (so `test.unit.*`
//! names inside `#[cfg(test)]` modules are exempt) but the literal text
//! is read from the raw file at the same offsets.  Trees without a
//! `telemetry/mod.rs` (partial fixtures) skip the membership check.
//! Waive a deliberate site with `// analyze: allow(telemetry): reason`.

use std::collections::BTreeMap;

use crate::source::{find_token_from, ident_ending_at, prev_non_ws, skip_ws, Finding, Tree};

const KEY: &str = "telemetry";

const INSTRUMENTS: &[(&str, char)] = &[("counter", 'c'), ("gauge", 'g'), ("histogram", 'h')];

fn kind_word(k: char) -> &'static str {
    match k {
        'c' => "counter",
        'g' => "gauge",
        'h' => "histogram",
        _ => "?",
    }
}

pub fn run(tree: &Tree) -> Vec<Finding> {
    let mut findings = Vec::new();

    // --- canonical schema ------------------------------------------------
    let store_metrics = parse_store_metrics(tree, &mut findings);

    // --- every literal call site ----------------------------------------
    // name → (kind, file, line) of the first site, for conflict reports.
    let mut first_use: BTreeMap<String, (char, String, usize)> = BTreeMap::new();
    for file in &tree.files {
        let code = &file.code_sans_tests;
        let cb = code.as_bytes();
        let rb = file.raw.as_bytes();
        for &(inst, kind) in INSTRUMENTS {
            let mut from = 0usize;
            while let Some(pos) = find_token_from(code, inst, from) {
                from = pos + inst.len();
                // Must be a call, not a definition or a type name.
                let open = skip_ws(cb, pos + inst.len());
                if open >= cb.len() || cb[open] != b'(' {
                    continue;
                }
                let is_def = prev_non_ws(cb, pos)
                    .and_then(|p| ident_ending_at(cb, p))
                    .is_some_and(|(_, kw)| kw == "fn");
                if is_def {
                    continue;
                }
                // The argument must be a string literal — read it from the
                // raw text (literals are blanked in scrubbed code).
                let q = skip_ws(rb, open + 1);
                if q >= rb.len() || rb[q] != b'"' {
                    continue; // non-literal name (registry internals)
                }
                let Some(rel_end) = file.raw[q + 1..].find('"') else { continue };
                let name = &file.raw[q + 1..q + 1 + rel_end];
                let line = file.line_of(pos);
                let waived = file.allows.allowed(line, KEY);

                if !grammar_ok(name) && !waived {
                    findings.push(Finding {
                        file: file.rel.clone(),
                        line,
                        lint: "telemetry",
                        msg: format!(
                            "metric name {name:?} does not match the `subsystem.metric` \
                             grammar (two dot-separated lowercase snake_case segments)"
                        ),
                    });
                }
                if let Some(schema) = &store_metrics {
                    if file.rel.starts_with("weightstore/") && !waived {
                        match schema.get(name) {
                            None => findings.push(Finding {
                                file: file.rel.clone(),
                                line,
                                lint: "telemetry",
                                msg: format!(
                                    "store-process metric {name:?} is not declared in \
                                     telemetry::STORE_METRICS — a FetchMetrics scrape would \
                                     not expose it until first use; add it to the canonical \
                                     schema"
                                ),
                            }),
                            Some(&k) if k != kind => findings.push(Finding {
                                file: file.rel.clone(),
                                line,
                                lint: "telemetry",
                                msg: format!(
                                    "metric {name:?} used as a {} here but declared '{k}' \
                                     ({}) in telemetry::STORE_METRICS",
                                    kind_word(kind),
                                    kind_word(k),
                                ),
                            }),
                            Some(_) => {}
                        }
                    }
                }
                let prior = first_use
                    .get(name)
                    .map(|(k0, f0, l0)| (*k0, f0.clone(), *l0));
                match prior {
                    None => {
                        first_use
                            .insert(name.to_string(), (kind, file.rel.clone(), line));
                    }
                    Some((k0, f0, l0)) if k0 != kind && !waived => {
                        findings.push(Finding {
                            file: file.rel.clone(),
                            line,
                            lint: "telemetry",
                            msg: format!(
                                "metric {name:?} used as a {} here but as a {} at {f0}:{l0} \
                                 — the registry panics on kind mismatch",
                                kind_word(kind),
                                kind_word(k0),
                            ),
                        });
                    }
                    Some(_) => {}
                }
            }
        }
    }
    findings
}

fn grammar_ok(name: &str) -> bool {
    let mut parts = name.split('.');
    let seg_ok = |s: &str| {
        !s.is_empty()
            && s.as_bytes()[0].is_ascii_lowercase()
            && s.bytes().all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'_')
    };
    match (parts.next(), parts.next(), parts.next()) {
        (Some(a), Some(b), None) => seg_ok(a) && seg_ok(b),
        _ => false,
    }
}

/// Parse the `STORE_METRICS: &[(&str, char)]` table out of
/// `telemetry/mod.rs` raw text.  Returns None when the tree has no such
/// table (partial fixture trees), which disables the membership check.
fn parse_store_metrics(tree: &Tree, findings: &mut Vec<Finding>) -> Option<BTreeMap<String, char>> {
    let file = tree.get("telemetry/mod.rs")?;
    let pos = find_token_from(&file.raw, "STORE_METRICS", 0)?;
    let close = file.raw[pos..].find("];").map(|o| pos + o)?;
    let table = &file.raw[pos..close];
    let mut schema = BTreeMap::new();
    let mut from = 0usize;
    while let Some(off) = table[from..].find("(\"") {
        let name_start = from + off + 2;
        let Some(name_len) = table[name_start..].find('"') else { break };
        let name = &table[name_start..name_start + name_len];
        let rest = &table[name_start + name_len..];
        let line = file.line_of(pos + name_start);
        let kind = rest
            .find('\'')
            .and_then(|q| rest[q + 1..].chars().next())
            .unwrap_or('?');
        if !matches!(kind, 'c' | 'g' | 'h') {
            findings.push(Finding {
                file: file.rel.clone(),
                line,
                lint: "telemetry",
                msg: format!(
                    "STORE_METRICS entry {name:?} has invalid kind {kind:?} (want 'c'/'g'/'h')"
                ),
            });
        }
        if !grammar_ok(name) {
            findings.push(Finding {
                file: file.rel.clone(),
                line,
                lint: "telemetry",
                msg: format!(
                    "STORE_METRICS entry {name:?} does not match the `subsystem.metric` grammar"
                ),
            });
        }
        if schema.insert(name.to_string(), kind).is_some() {
            findings.push(Finding {
                file: file.rel.clone(),
                line,
                lint: "telemetry",
                msg: format!("STORE_METRICS declares {name:?} twice"),
            });
        }
        from = name_start + name_len;
    }
    Some(schema)
}
