//! Determinism lint: bans wall-clock reads and nondeterministic
//! primitives everywhere in the tree unless a pragma sanctions the site.
//!
//! The repo's simulation/virtual-time layers (`coordinator/sim.rs`,
//! `coordinator/peer.rs`, `weightstore/faulty.rs`, the experiment
//! drivers) promise bit-exact reruns from a seed; a single stray
//! `Instant::now()` or `HashMap` iteration silently breaks that.  Rather
//! than maintain a module list (which rots as files move), the lint bans
//! the primitives tree-wide and requires every *sanctioned* wall-clock
//! use — live drivers, the phase timer, the metrics recorder — to carry
//! an `analyze: allow(…)` pragma with a reason, which doubles as
//! documentation of why that site cannot leak into a virtual-time path.

use crate::source::{find_all_tokens, Finding, Tree};

/// (pragma key, banned token, rationale shown in the finding)
const BANNED: &[(&str, &str, &str)] = &[
    (
        "wallclock",
        "Instant::now",
        "wall-clock read; sim/virtual-time paths must use FaultClock/store.now()",
    ),
    (
        "wallclock",
        "SystemTime::now",
        "wall-clock read; sim/virtual-time paths must use FaultClock/store.now()",
    ),
    (
        "nondet-rng",
        "thread_rng",
        "OS-seeded RNG; use the seeded util::rng instead",
    ),
    (
        "nondet-rng",
        "from_entropy",
        "OS-seeded RNG; use the seeded util::rng instead",
    ),
    (
        "nondet-rng",
        "RandomState",
        "randomized hasher; iteration order varies across runs",
    ),
    (
        "unordered-iter",
        "HashMap",
        "iteration order is unspecified; use BTreeMap for anything iterated",
    ),
    (
        "unordered-iter",
        "HashSet",
        "iteration order is unspecified; use BTreeSet for anything iterated",
    ),
];

pub fn run(tree: &Tree) -> Vec<Finding> {
    let mut findings = Vec::new();
    for file in &tree.files {
        for &(key, token, why) in BANNED {
            for pos in find_all_tokens(&file.code, token) {
                let line = file.line_of(pos);
                if file.allows.allowed(line, key) {
                    continue;
                }
                findings.push(Finding {
                    file: file.rel.clone(),
                    line,
                    lint: "determinism",
                    msg: format!("`{token}` is banned ({why}); pragma: `// analyze: allow({key}): reason`"),
                });
            }
        }
    }
    findings
}
