//! The lint registry.  Each lint takes the loaded [`Tree`] and returns
//! findings; `run_all` is what `cargo run -p xtask -- analyze` executes
//! and what the green-tree test asserts is empty.

pub mod blocking;
pub mod determinism;
pub mod locks;
pub mod panics;
pub mod protocol;
pub mod telemetry;
pub mod traits;

use crate::source::{Finding, Tree};

pub const LINTS: &[(&str, fn(&Tree) -> Vec<Finding>)] = &[
    ("protocol", protocol::run),
    ("traits", traits::run),
    ("determinism", determinism::run),
    ("locks", locks::run),
    ("blocking", blocking::run),
    ("panics", panics::run),
    ("telemetry", telemetry::run),
];

pub fn run_all(tree: &Tree) -> Vec<Finding> {
    let mut findings = tree.load_findings.clone();
    for (_, lint) in LINTS {
        findings.extend(lint(tree));
    }
    findings.sort();
    findings.dedup();
    findings
}

pub fn run_one(tree: &Tree, name: &str) -> Option<Vec<Finding>> {
    let (_, lint) = LINTS.iter().find(|(n, _)| *n == name)?;
    let mut findings = lint(tree);
    findings.sort();
    findings.dedup();
    Some(findings)
}
