//! Panic-freedom analysis for the serving paths.
//!
//! A panic inside the single-threaded event loop kills every connected
//! peer; a panic inside `Client`/`ClientPool` kills a worker mid-step.
//! The PR 8 contract says malformed input must surface as
//! `Response::Err` (server) or an `Err` return (client) — never as a
//! process abort.  This lint walks the shared call graph
//! ([`crate::callgraph`]) from two root sets:
//!
//! - `serve()` in `weightstore/server.rs` (covers `process_frames`,
//!   `dispatch`, and — by union-of-candidates resolution — every
//!   backend's `WeightStore` method bodies);
//! - every function in `weightstore/client.rs` (the request paths a
//!   worker drives).
//!
//! In each reachable body it flags:
//!
//! - `.unwrap()` / `.expect(…)` — **except** when chained directly onto a
//!   lock acquisition (`.lock()`, `.read()`, `.write()`, condvar
//!   `.wait(…)` / `.wait_timeout(…)`): those unwrap `LockResult` poison,
//!   which only fires after another thread has *already* panicked —
//!   deliberate fail-stop propagation, a separate failure domain owned by
//!   the loom/TSan suites, not input-dependent control flow;
//! - panicking macros: `panic!`, `unreachable!`, `todo!`,
//!   `unimplemented!`, `assert!`, `assert_eq!`, `assert_ne!`
//!   (`debug_assert*` is compiled out of release servers and allowed);
//! - **range** slice indexing `x[a..b]` (incl. `[..b]` / `[a..]`) — the
//!   frame-slicing bug class; write `x.get(a..b)` and handle `None`.
//!   Plain single-element indexing `x[i]` is *not* flagged: it is
//!   pervasive and almost always loop- or length-bounded; the lint aims
//!   at unvalidated wire-length arithmetic, which arrives as ranges.
//!
//! Waive a deliberate site with `// analyze: allow(panics): reason` —
//! e.g. the telemetry kind-mismatch guards, whose impossibility is
//! proven statically by the `telemetry` lint.

use crate::callgraph::Graph;
use crate::source::{
    ident_ending_at, ident_starting_at, is_ident_byte, prev_non_ws, skip_ws, Finding, Tree,
};

const KEY: &str = "panics";

/// Receiver methods whose `Result` is lock-poison (see module docs):
/// `.lock().unwrap()` et al. are exempt.
const POISON_SOURCES: &[&str] = &["lock", "read", "write", "wait", "wait_timeout", "wait_while"];

const PANIC_MACROS: &[&str] = &[
    "panic",
    "unreachable",
    "todo",
    "unimplemented",
    "assert",
    "assert_eq",
    "assert_ne",
];

pub fn run(tree: &Tree) -> Vec<Finding> {
    let mut findings = Vec::new();
    let graph = Graph::build(tree);

    let mut roots = graph.fns_named_in("serve", "weightstore/server.rs");
    for i in 0..graph.fns.len() {
        if graph.file_of(i).rel.ends_with("weightstore/client.rs") {
            roots.push(i);
        }
    }
    if roots.is_empty() {
        // Nothing to protect in this tree.
        return findings;
    }
    let reach = graph.reach(&roots, |_| true);

    for i in reach.all() {
        let file = graph.file_of(i);
        let b = file.code_sans_tests.as_bytes();
        let body = graph.fns[i].body;
        let nested = graph.nested_spans(i);
        let mut k = body.0;
        while k <= body.1 {
            if let Some(&(_, e)) = nested.iter().find(|(s, _)| *s == k) {
                k = e + 1;
                continue;
            }
            // Range slice indexing: `expr[ … .. … ]`.
            if b[k] == b'[' && is_index_bracket(b, k) {
                if let Some(close) = matching_bracket(b, k) {
                    if let Some(range) = range_inside(&file.code_sans_tests[k + 1..close]) {
                        let line = file.line_of(k);
                        if !file.allows.allowed(line, KEY) {
                            findings.push(Finding {
                                file: file.rel.clone(),
                                line,
                                lint: "panics",
                                msg: format!(
                                    "range indexing `[{range}]` can panic on malformed \
                                     bounds and is reachable from a serving path \
                                     ({}); use `.get(…)` and surface an error",
                                    reach.path(&graph, i)
                                ),
                            });
                        }
                    }
                }
                k += 1;
                continue;
            }
            if !is_ident_byte(b[k]) || b[k].is_ascii_digit() || (k > 0 && is_ident_byte(b[k - 1]))
            {
                k += 1;
                continue;
            }
            let Some(name) = ident_starting_at(b, k) else {
                k += 1;
                continue;
            };
            let after = skip_ws(b, k + name.len());
            let site = if (name == "unwrap" || name == "expect")
                && after < b.len()
                && b[after] == b'('
                && prev_non_ws(b, k).is_some_and(|p| b[p] == b'.')
                && !is_poison_unwrap(b, k)
            {
                Some(format!("`.{name}(…)`"))
            } else if PANIC_MACROS.contains(&name.as_str())
                && after < b.len()
                && b[after] == b'!'
            {
                Some(format!("`{name}!`"))
            } else {
                None
            };
            if let Some(site) = site {
                let line = file.line_of(k);
                if !file.allows.allowed(line, KEY) {
                    findings.push(Finding {
                        file: file.rel.clone(),
                        line,
                        lint: "panics",
                        msg: format!(
                            "{site} is reachable from a serving path ({}); malformed input \
                             must surface as Response::Err / an Err return, not a panic",
                            reach.path(&graph, i)
                        ),
                    });
                }
            }
            k += name.len();
        }
    }
    findings
}

/// Is the `unwrap`/`expect` whose ident starts at `k` chained directly
/// onto a poison-carrying acquisition (`….lock().unwrap()`)?
fn is_poison_unwrap(b: &[u8], k: usize) -> bool {
    // k points at `unwrap`; the previous non-ws byte is the `.`.
    let Some(dot) = prev_non_ws(b, k) else { return false };
    if b[dot] != b'.' {
        return false;
    }
    // Receiver must end with a call: `… name ( … ) . unwrap()`.
    let Some(close) = prev_non_ws(b, dot) else { return false };
    if b[close] != b')' {
        return false;
    }
    // Walk back to the matching `(`.
    let mut depth = 1i64;
    let mut j = close;
    while j > 0 && depth > 0 {
        j -= 1;
        if b[j] == b')' {
            depth += 1;
        } else if b[j] == b'(' {
            depth -= 1;
        }
    }
    if depth != 0 || j == 0 {
        return false;
    }
    let Some(end) = prev_non_ws(b, j) else { return false };
    ident_ending_at(b, end).is_some_and(|(_, name)| POISON_SOURCES.contains(&name.as_str()))
}

/// Is `b[k] == b'['` an *index* bracket (postfix on an expression) rather
/// than an array literal / type / attribute / slice pattern?
fn is_index_bracket(b: &[u8], k: usize) -> bool {
    match prev_non_ws(b, k) {
        Some(p) => is_ident_byte(b[p]) || b[p] == b')' || b[p] == b']',
        None => false,
    }
}

/// Index of the `]` matching the `[` at `open`.
fn matching_bracket(b: &[u8], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (i, &c) in b.iter().enumerate().skip(open) {
        if c == b'[' {
            depth += 1;
        } else if c == b']' {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
    }
    None
}

/// If the bracket interior is a range expression with at least one bound
/// (`a..b`, `..b`, `a..`, `a..=b`), return it trimmed.  A bare `..` (full
/// slice, cannot panic) and non-range interiors return None.
fn range_inside(interior: &str) -> Option<&str> {
    // Only consider `..` at bracket nesting depth 0 of the interior.
    let ib = interior.as_bytes();
    let mut depth = 0i64;
    let mut has_range = false;
    let mut i = 0;
    while i < ib.len() {
        match ib[i] {
            b'[' | b'(' => depth += 1,
            b']' | b')' => depth -= 1,
            b'.' if depth == 0 && i + 1 < ib.len() && ib[i + 1] == b'.' => {
                has_range = true;
                i += 1;
            }
            _ => {}
        }
        i += 1;
    }
    let trimmed = interior.trim();
    if has_range && trimmed != ".." {
        Some(trimmed)
    } else {
        None
    }
}
