//! Lock-order analysis.
//!
//! Extracts every `.lock()` / `.read()` / `.write()` acquisition site in
//! the tree (empty argument lists only, which cleanly separates
//! `Mutex/RwLock` guards from `io::Read::read(&mut buf)`), classifies each
//! site into a named lock *class* (`cursors`, `shards`, `log`, …), tracks
//! which guards are held across statements and one level of calls
//! (iterated to a fixpoint over the shared name-resolved call graph in
//! [`crate::callgraph`]), and checks the resulting inter-class
//! acquisition graph against the canonical order declared in
//! `weightstore/mod.rs`:
//!
//! ```text
//! //! lock-order: compact_serial -> log -> signal -> cursors -> params -> shards
//! ```
//!
//! Findings: acquiring a class that precedes an already-held class in the
//! declared order (inversion), any cycle in the class graph (covers
//! classes outside the declared chain), and acquisition sites the
//! classifier cannot name at all.  `// analyze: allow(lock-order): reason`
//! on the acquiring line waives a deliberate inversion.
//!
//! The analysis is intra-procedural with call summaries: a guard bound by
//! `let` is considered held until its enclosing block closes (or an
//! explicit `drop(guard)`), a guard in expression position is released at
//! the end of its statement, and calls made while holding a guard
//! contribute the callee's (transitive) acquisition set as edges.  Call
//! resolution policy (local-first, then union of same-named candidates;
//! `mem` scoping; the never-resolved std idiom list) lives in
//! [`crate::callgraph`].

use std::collections::{BTreeMap, BTreeSet};

use crate::callgraph::{call_at, receiver_chain, Graph};
use crate::source::{
    find_token_from, ident_starting_at, is_ident_byte, skip_ws, Finding, Tree,
};

#[derive(Debug)]
enum Event {
    Open,
    Close,
    Acquire {
        off: usize,
        class: Option<String>,
        bound: bool,
        binder: Option<String>,
    },
    Call {
        off: usize,
        name: String,
        mem_scoped: bool,
    },
    Release {
        binder: String,
    },
}

pub fn run(tree: &Tree) -> Vec<Finding> {
    let mut findings = Vec::new();

    // --- declared order ------------------------------------------------
    let declared = declared_order(tree);
    if declared.is_empty() {
        findings.push(Finding {
            file: "weightstore/mod.rs".into(),
            line: 1,
            lint: "locks",
            msg: "no `lock-order: a -> b -> …` declaration found in the module docs".into(),
        });
    }
    let pos_of = |class: &str| declared.iter().position(|c| c == class);

    // --- shared call graph + per-function event streams ------------------
    let graph = Graph::build(tree);
    let events: Vec<Vec<Event>> = (0..graph.fns.len())
        .map(|i| {
            let file = graph.file_of(i);
            let nested = graph.nested_spans(i);
            scan_body(&file.code_sans_tests, graph.fns[i].body, &nested, &declared)
        })
        .collect();

    // --- summaries: fixpoint over the call graph -------------------------
    let mut summaries: Vec<BTreeSet<String>> = events
        .iter()
        .map(|evs| {
            evs.iter()
                .filter_map(|e| match e {
                    Event::Acquire { class: Some(c), .. } => Some(c.clone()),
                    _ => None,
                })
                .collect()
        })
        .collect();
    graph.propagate(&mut summaries, |caller, callee| {
        let before = caller.len();
        caller.extend(callee.iter().cloned());
        caller.len() != before
    });

    // --- replay: edges + unclassifiable sites ---------------------------
    // edge (held-class, acquired-class) → first site (file, line)
    let mut edges: BTreeMap<(String, String), (String, usize)> = BTreeMap::new();
    for i in 0..graph.fns.len() {
        let file = graph.file_of(i);
        let mut depth = 0i64;
        let mut held: Vec<(String, i64, Option<String>)> = Vec::new();
        for e in &events[i] {
            match e {
                Event::Open => depth += 1,
                Event::Close => {
                    depth -= 1;
                    held.retain(|(_, d, _)| *d <= depth);
                }
                Event::Release { binder } => {
                    held.retain(|(_, _, b)| b.as_deref() != Some(binder.as_str()));
                }
                Event::Acquire { off, class, bound, binder } => {
                    let line = file.line_of(*off);
                    let Some(class) = class else {
                        if !file.allows.allowed(line, "lock-order") {
                            findings.push(Finding {
                                file: file.rel.clone(),
                                line,
                                lint: "locks",
                                msg: format!(
                                    "cannot classify this lock acquisition (in `fn {}`); name \
                                     the receiver after its lock class or add a pragma",
                                    graph.fns[i].name
                                ),
                            });
                        }
                        continue;
                    };
                    if !file.allows.allowed(line, "lock-order") {
                        for (h, _, _) in &held {
                            if h != class {
                                edges
                                    .entry((h.clone(), class.clone()))
                                    .or_insert((file.rel.clone(), line));
                            }
                        }
                    }
                    if *bound {
                        held.push((class.clone(), depth, binder.clone()));
                    }
                }
                Event::Call { off, name, mem_scoped } => {
                    if held.is_empty() {
                        continue;
                    }
                    let line = file.line_of(*off);
                    if file.allows.allowed(line, "lock-order") {
                        continue;
                    }
                    for j in graph.resolve(Some(graph.fns[i].file), name, *mem_scoped) {
                        for c in summaries[j].iter() {
                            for (h, _, _) in &held {
                                if h != c {
                                    edges
                                        .entry((h.clone(), c.clone()))
                                        .or_insert((file.rel.clone(), line));
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    // --- order inversions ------------------------------------------------
    for ((a, b), (file, line)) in &edges {
        if let (Some(pa), Some(pb)) = (pos_of(a), pos_of(b)) {
            if pa > pb {
                findings.push(Finding {
                    file: file.clone(),
                    line: *line,
                    lint: "locks",
                    msg: format!(
                        "lock-order inversion: `{b}` acquired while holding `{a}` \
                         (declared order says {b} -> … -> {a})"
                    ),
                });
            }
        }
    }

    // --- cycles over the full class graph --------------------------------
    if let Some(cycle) = find_cycle(&edges) {
        let key = (cycle[0].clone(), cycle[1].clone());
        let (file, line) = edges.get(&key).cloned().unwrap_or(("".into(), 1));
        findings.push(Finding {
            file,
            line,
            lint: "locks",
            msg: format!(
                "lock acquisition cycle: {} (potential deadlock)",
                cycle.join(" -> ")
            ),
        });
    }

    findings
}

fn declared_order(tree: &Tree) -> Vec<String> {
    let Some(modfile) = tree.get("weightstore/mod.rs") else { return Vec::new() };
    for line in modfile.raw.lines() {
        let Some(pos) = line.find("lock-order:") else { continue };
        let rest = &line[pos + "lock-order:".len()..];
        if !rest.contains("->") {
            continue;
        }
        return rest
            .split("->")
            .map(|c| c.trim().trim_matches('`').to_string())
            .filter(|c| !c.is_empty() && c.bytes().all(is_ident_byte))
            .collect();
    }
    Vec::new()
}

/// Walk one function body, emitting events in source order.  `nested`
/// spans (inner `fn` items) are skipped — their events belong to the
/// inner function.
fn scan_body(
    code: &str,
    body: (usize, usize),
    nested: &[(usize, usize)],
    known_classes: &[String],
) -> Vec<Event> {
    let b = code.as_bytes();
    let for_map = for_bindings(&code[body.0..body.1], known_classes);
    let mut ev = Vec::new();
    let mut i = body.0;
    while i <= body.1 {
        if let Some(&(_, e)) = nested.iter().find(|(s, _)| *s == i) {
            i = e + 1;
            continue;
        }
        let c = b[i];
        if c == b'{' {
            ev.push(Event::Open);
            i += 1;
            continue;
        }
        if c == b'}' {
            ev.push(Event::Close);
            i += 1;
            continue;
        }
        // `.lock()` / `.read()` / `.write()` with an empty argument list.
        if c == b'.' {
            if let Some(end) = match_guard_call(b, i) {
                let chain = receiver_chain(b, i);
                let (_, stmt) = statement_before(code, body.0, i);
                let class = classify(&chain, stmt, &for_map, known_classes);
                let (bound, binder) = let_binding(stmt);
                ev.push(Event::Acquire {
                    off: i + 1,
                    class,
                    bound,
                    binder,
                });
                i = end;
                continue;
            }
        }
        // Identifier: candidate call (or `drop(guard)` release).
        if let Some(site) = call_at(b, i) {
            if site.name == "drop" {
                let after = skip_ws(b, i + site.name.len());
                let j = skip_ws(b, after + 1);
                if let Some(arg) = ident_starting_at(b, j) {
                    let k = skip_ws(b, j + arg.len());
                    if k < b.len() && b[k] == b')' {
                        ev.push(Event::Release { binder: arg });
                    }
                }
            } else {
                ev.push(Event::Call {
                    off: site.off,
                    name: site.name.clone(),
                    mem_scoped: site.mem_scoped,
                });
            }
            i += site.name.len();
            continue;
        }
        if is_ident_byte(c) {
            while i <= body.1 && is_ident_byte(b[i]) {
                i += 1;
            }
            continue;
        }
        i += 1;
    }
    ev
}

/// If `b[dot]` starts `.lock()`, `.read()` or `.write()` (empty parens),
/// return the index just past the `)`.
fn match_guard_call(b: &[u8], dot: usize) -> Option<usize> {
    let j = dot + 1;
    let name = ident_starting_at(b, j)?;
    if name != "lock" && name != "read" && name != "write" {
        return None;
    }
    let k = skip_ws(b, j + name.len());
    if k >= b.len() || b[k] != b'(' {
        return None;
    }
    let m = skip_ws(b, k + 1);
    if m >= b.len() || b[m] != b')' {
        return None;
    }
    Some(m + 1)
}

/// The statement text strictly before byte `at`: from the last `;`, `{`
/// or `}` (within the body) to `at`.
fn statement_before(code: &str, body_start: usize, at: usize) -> (usize, &str) {
    let b = code.as_bytes();
    let mut j = at;
    while j > body_start {
        let c = b[j - 1];
        if c == b';' || c == b'{' || c == b'}' {
            break;
        }
        j -= 1;
    }
    (j, &code[j..at])
}

/// Does the statement bind the guard (`let g = …`)?  Returns the binder
/// ident (first ident after `let`, skipping `mut`); `let _ = …` does not
/// bind (the guard drops immediately).
fn let_binding(stmt: &str) -> (bool, Option<String>) {
    let Some(pos) = find_token_from(stmt, "let", 0) else { return (false, None) };
    let b = stmt.as_bytes();
    let mut j = skip_ws(b, pos + 3);
    // `let _ = …` drops the value at once; `let _named` holds it.
    if j < b.len() && b[j] == b'_' && (j + 1 >= b.len() || !is_ident_byte(b[j + 1])) {
        return (false, None);
    }
    if let Some(m) = ident_starting_at(b, j) {
        if m == "mut" {
            j = skip_ws(b, j + 3);
        }
    }
    let binder = ident_starting_at(b, j);
    (true, binder)
}

/// Map loop binders to lock classes: `for (i, lock) in &self.shards { …`
/// maps both `i` and `lock` to `shards`.
fn for_bindings(body: &str, known: &[String]) -> BTreeMap<String, String> {
    let mut map = BTreeMap::new();
    let b = body.as_bytes();
    let mut from = 0usize;
    while let Some(pos) = find_token_from(body, "for", from) {
        from = pos + 3;
        let Some(inpos) = find_token_from(body, "in", pos + 3) else { continue };
        if inpos > pos + 120 {
            continue;
        }
        let pattern = &body[pos + 3..inpos];
        let Some(brace) = body[inpos..].find('{').map(|o| inpos + o) else { continue };
        if brace > inpos + 240 {
            continue;
        }
        let expr = &body[inpos + 2..brace];
        let Some(class) = known.iter().find(|k| find_token_from(expr, k, 0).is_some()) else {
            continue;
        };
        let pb = pattern.as_bytes();
        let mut i = 0usize;
        while i < pb.len() {
            if is_ident_byte(pb[i]) && !pb[i].is_ascii_digit() && (i == 0 || !is_ident_byte(pb[i - 1]))
            {
                if let Some(id) = ident_starting_at(pb, i) {
                    let l = id.len();
                    if id != "mut" && id != "ref" {
                        map.insert(id, class.clone());
                    }
                    i += l;
                    continue;
                }
            }
            i += 1;
        }
    }
    map
}

/// Classify an acquisition site into a lock class.
fn classify(
    chain: &[String],
    stmt: &str,
    for_map: &BTreeMap<String, String>,
    known: &[String],
) -> Option<String> {
    // 1. A known class name anywhere in the receiver chain, nearest first.
    if let Some(c) = chain.iter().find(|id| known.iter().any(|k| k == *id)) {
        return Some(c.clone());
    }
    // 2. The nearest receiver is a loop binder over a known class.
    if let Some(first) = chain.first() {
        if let Some(c) = for_map.get(first) {
            return Some(c.clone());
        }
    }
    // 3. The statement mentions a known class (`let g: … = self.shards
    //    .iter().map(|l| l.read()…)`).
    if let Some(k) = known.iter().find(|k| find_token_from(stmt, k, 0).is_some()) {
        return Some(k.clone());
    }
    // 4. Ad-hoc class named after the receiver field (`self.rng` → `rng`).
    //    Single-letter closure params don't qualify.
    if let Some(first) = chain.first() {
        if first != "self" && first.len() >= 2 {
            return Some(first.clone());
        }
    }
    None
}

/// First cycle in the class graph, as a node path `a -> b -> … -> a`.
fn find_cycle(edges: &BTreeMap<(String, String), (String, usize)>) -> Option<Vec<String>> {
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    let mut nodes: BTreeSet<&str> = BTreeSet::new();
    for (a, b) in edges.keys() {
        adj.entry(a).or_default().push(b);
        nodes.insert(a);
        nodes.insert(b);
    }
    // Iterative DFS with colors: 0 unvisited, 1 on stack, 2 done.
    let mut color: BTreeMap<&str, u8> = nodes.iter().map(|n| (*n, 0u8)).collect();
    fn dfs<'a>(
        n: &'a str,
        adj: &BTreeMap<&'a str, Vec<&'a str>>,
        color: &mut BTreeMap<&'a str, u8>,
        stack: &mut Vec<&'a str>,
    ) -> Option<Vec<String>> {
        color.insert(n, 1);
        stack.push(n);
        if let Some(nbrs) = adj.get(n) {
            for &m in nbrs {
                match color.get(m).copied().unwrap_or(0) {
                    0 => {
                        if let Some(c) = dfs(m, adj, color, stack) {
                            return Some(c);
                        }
                    }
                    1 => {
                        let start = stack.iter().position(|&x| x == m).unwrap_or(0);
                        let mut cycle: Vec<String> =
                            stack[start..].iter().map(|s| s.to_string()).collect();
                        cycle.push(m.to_string());
                        return Some(cycle);
                    }
                    _ => {}
                }
            }
        }
        stack.pop();
        color.insert(n, 2);
        None
    }
    let node_list: Vec<&str> = nodes.into_iter().collect();
    for n in node_list {
        if color.get(n).copied() == Some(0) {
            let mut stack = Vec::new();
            if let Some(c) = dfs(n, &adj, &mut color, &mut stack) {
                return Some(c);
            }
        }
    }
    None
}
