//! Trait-wiring completeness lint.
//!
//! The `WeightStore` trait is the store's entire behavioural surface:
//! every backend (`MemStore`, `DurableStore`, `FaultyStore`, the TCP
//! `Client`) must implement every method, and the TCP server must
//! dispatch every method (`store.<method>(…)` in `server.rs`).  A method
//! added to the trait without touching all five places compiles fine
//! today — trait methods have no defaults here, but a forgotten server
//! arm or a decorator that silently diverges is exactly the class of bug
//! that corrupts the paper's unbiasedness contract.  This lint makes the
//! omission a CI failure with a pointable span.

use crate::source::{find_token_from, matching_brace, Finding, SourceFile, Tree};

/// Backends that must implement the full trait.  Discovered impls outside
/// this list are linted too (completeness is universal); this list only
/// adds "the impl must exist somewhere" on top.
const REQUIRED_IMPLS: &[&str] = &["MemStore", "DurableStore", "FaultyStore", "Client"];

pub fn run(tree: &Tree) -> Vec<Finding> {
    let mut findings = Vec::new();
    let Some(modfile) = tree.get("weightstore/mod.rs") else {
        findings.push(Finding {
            file: "weightstore/mod.rs".into(),
            line: 1,
            lint: "traits",
            msg: "file not found; trait lint cannot run".into(),
        });
        return findings;
    };
    let methods = trait_methods(modfile, "WeightStore");
    if methods.is_empty() {
        findings.push(Finding {
            file: modfile.rel.clone(),
            line: 1,
            lint: "traits",
            msg: "trait WeightStore not found or has no methods".into(),
        });
        return findings;
    }

    // Discover every `impl WeightStore for <Type>` in the tree.
    let mut impls: Vec<(String, &SourceFile, usize)> = Vec::new(); // (type, file, line)
    for file in &tree.files {
        for (ty, line, span) in trait_impls(file, "WeightStore") {
            let body = &file.code_sans_tests[span.0..span.1];
            for (m, _) in &methods {
                if !has_fn(body, m) {
                    findings.push(Finding {
                        file: file.rel.clone(),
                        line,
                        lint: "traits",
                        msg: format!("impl WeightStore for {ty} is missing `fn {m}`"),
                    });
                }
            }
            impls.push((ty, file, line));
        }
    }
    for required in REQUIRED_IMPLS {
        if !impls.iter().any(|(ty, _, _)| ty == required) {
            findings.push(Finding {
                file: modfile.rel.clone(),
                line: 1,
                lint: "traits",
                msg: format!("no `impl WeightStore for {required}` found anywhere in the tree"),
            });
        }
    }

    // Server dispatch: every trait method must be called on the store.
    match tree.get("weightstore/server.rs") {
        Some(server) => {
            for (m, decl_line) in &methods {
                if !has_store_call(&server.code_sans_tests, m) {
                    findings.push(Finding {
                        file: modfile.rel.clone(),
                        line: *decl_line,
                        lint: "traits",
                        msg: format!(
                            "trait method `{m}` has no server dispatch (`store.{m}(…)` in {})",
                            server.rel
                        ),
                    });
                }
            }
        }
        None => findings.push(Finding {
            file: "weightstore/server.rs".into(),
            line: 1,
            lint: "traits",
            msg: "file not found; cannot check server dispatch".into(),
        }),
    }

    findings
}

/// Method names (with declaration lines) of `trait <name>`.
pub fn trait_methods(file: &SourceFile, name: &str) -> Vec<(String, usize)> {
    let code = &file.code_sans_tests;
    let b = code.as_bytes();
    let mut from = 0usize;
    while let Some(pos) = find_token_from(code, "trait", from) {
        from = pos + 5;
        let j = crate::source::skip_ws(b, pos + 5);
        let Some(ident) = crate::source::ident_starting_at(b, j) else { continue };
        if ident != name {
            continue;
        }
        let Some(open) = code[pos..].find('{').map(|o| pos + o) else { return Vec::new() };
        let Some(close) = matching_brace(b, open) else { return Vec::new() };
        let mut out = Vec::new();
        let mut k = open;
        while let Some(fnpos) = find_token_from(code, "fn", k) {
            if fnpos >= close {
                break;
            }
            k = fnpos + 2;
            let nj = crate::source::skip_ws(b, fnpos + 2);
            if let Some(m) = crate::source::ident_starting_at(b, nj) {
                out.push((m, file.line_of(fnpos)));
            }
        }
        return out;
    }
    Vec::new()
}

/// Every `impl <trait> for <Type>` in a file: (type name, line, body span).
fn trait_impls(file: &SourceFile, trait_name: &str) -> Vec<(String, usize, (usize, usize))> {
    let code = &file.code_sans_tests;
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(pos) = find_token_from(code, "impl", from) {
        from = pos + 4;
        let Some(open) = code[pos..].find('{').map(|o| pos + o) else { break };
        let head = &code[pos..open];
        let Some(tpos) = find_token_from(head, trait_name, 0) else { continue };
        let Some(forpos) = find_token_from(head, "for", tpos) else { continue };
        let hb = head.as_bytes();
        let tj = crate::source::skip_ws(hb, forpos + 3);
        let Some(ty) = crate::source::ident_starting_at(hb, tj) else { continue };
        let Some(close) = matching_brace(b, open) else { continue };
        out.push((ty, file.line_of(pos), (open, close)));
        from = close;
    }
    out
}

/// Does `body` define `fn <name>`?
fn has_fn(body: &str, name: &str) -> bool {
    let b = body.as_bytes();
    let mut from = 0usize;
    while let Some(pos) = find_token_from(body, "fn", from) {
        from = pos + 2;
        let j = crate::source::skip_ws(b, pos + 2);
        if crate::source::ident_starting_at(b, j).is_some_and(|m| m == name) {
            return true;
        }
    }
    false
}

/// Does the code contain `store.<method>(` (whitespace-tolerant)?
fn has_store_call(code: &str, method: &str) -> bool {
    let b = code.as_bytes();
    let mut from = 0usize;
    while let Some(pos) = find_token_from(code, method, from) {
        from = pos + 1;
        // Forward: next non-ws must open the call.
        let j = crate::source::skip_ws(b, pos + method.len());
        if j >= b.len() || b[j] != b'(' {
            continue;
        }
        // Backward: `.`, then the receiver ident `store`.
        let Some(dot) = crate::source::prev_non_ws(b, pos) else { continue };
        if b[dot] != b'.' {
            continue;
        }
        let Some(recv_end) = crate::source::prev_non_ws(b, dot) else { continue };
        if let Some((_, recv)) = crate::source::ident_ending_at(b, recv_end) {
            if recv == "store" {
                return true;
            }
        }
    }
    false
}
