//! Name-resolved call graph shared by the reachability lints.
//!
//! Built once per tree from scrubbed, test-stripped text (so byte offsets
//! and line numbers still match the original files): every named `fn`
//! with a braced body becomes a node, every `ident(`-shaped token inside
//! a body becomes a call site, and calls resolve *by name* to the union
//! of same-named definitions.  Two deliberate precision tweaks carried
//! over from the locks lint, where this machinery was born:
//!
//! - a list of ubiquitous std idioms ([`UNRESOLVED_CALLS`]: `new`,
//!   `push`, `insert`, `open`, …) is never resolved — attributing
//!   `Vec::new()` to `Master::new` (or `OpenOptions::open` to
//!   `Durable::open`) would wire the whole graph to itself;
//! - calls through a `…mem…` receiver resolve only into
//!   `weightstore/mod.rs` (the durable backend's inner `MemStore`);
//! - resolution is **local-first**: when the caller's own file defines the
//!   called name, only those definitions are candidates.  `dispatch(…)`
//!   inside `server.rs` means the server's dispatch, not the same-named
//!   CLI dispatcher in `main.rs`; without this, the whole coordinator
//!   world rides into the serve graph on three shared names.
//!
//! Union resolution is conservative in the right direction for
//! reachability lints: `store.push_params(…)` through `&dyn WeightStore`
//! reaches *every* backend's `push_params`, which is exactly the set of
//! bodies a server tick might execute.  On top of the graph this module
//! offers:
//!
//! - [`Graph::resolve`] — candidates for one call site;
//! - [`Graph::reach`] — BFS from root functions with a predecessor map,
//!   so findings can print the witness chain (`serve -> process_frames ->
//!   dispatch -> …`); an edge filter lets a lint cut sanctioned seams
//!   (e.g. the background compactor) out of the walk;
//! - [`Graph::propagate`] — generic fixpoint propagation of per-function
//!   summaries along call edges (callee summary absorbed into caller),
//!   used by the locks lint for held-class summaries.

use std::collections::BTreeMap;

use crate::source::{
    find_token_from, ident_ending_at, ident_starting_at, is_ident_byte, matching_brace,
    prev_non_ws, skip_ws, SourceFile, Tree,
};

/// Call names never resolved through the name-based call graph: std
/// idioms so common that resolving them to same-named repo functions
/// would connect unrelated code (e.g. `Vec::new()` → `Master::new`).
pub const UNRESOLVED_CALLS: &[&str] = &[
    "new", "default", "clone", "from", "into", "drop", "with_capacity", "to_string", "to_vec",
    "fmt", "len", "is_empty", "load", "store", "push", "pop", "insert", "remove", "get", "min",
    "max", "iter", "next", "eq", "hash", "cmp", "wait", "join", "collect", "map", "filter",
    "unwrap", "expect", "ok", "take", "contains", "open", "create",
];

/// One named `fn` with a braced body.
#[derive(Debug)]
pub struct FnDef {
    /// Index into `tree.files`.
    pub file: usize,
    pub name: String,
    /// Byte span of the body (from `{` to matching `}`), in
    /// `code_sans_tests` coordinates.
    pub body: (usize, usize),
}

/// One call site inside a function body.
#[derive(Debug)]
pub struct CallSite {
    /// Byte offset of the callee identifier, in `code_sans_tests`
    /// coordinates of the enclosing file.
    pub off: usize,
    pub name: String,
    /// Called through a `…mem…` receiver (resolves only into
    /// `weightstore/mod.rs`).
    pub mem_scoped: bool,
}

/// The tree-wide call graph: function table plus per-function call sites.
pub struct Graph<'t> {
    pub tree: &'t Tree,
    pub fns: Vec<FnDef>,
    by_name: BTreeMap<String, Vec<usize>>,
    /// `calls[i]` are the call sites inside `fns[i]`, in source order,
    /// with nested `fn` items excluded (their calls belong to them).
    pub calls: Vec<Vec<CallSite>>,
}

impl<'t> Graph<'t> {
    pub fn build(tree: &'t Tree) -> Graph<'t> {
        let mut fns: Vec<FnDef> = Vec::new();
        for (fi, file) in tree.files.iter().enumerate() {
            collect_fns(fi, &file.code_sans_tests, &mut fns);
        }
        let mut by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, f) in fns.iter().enumerate() {
            by_name.entry(f.name.clone()).or_default().push(i);
        }
        let mut calls = Vec::with_capacity(fns.len());
        for i in 0..fns.len() {
            let nested = nested_spans(&fns, i);
            let code = &tree.files[fns[i].file].code_sans_tests;
            calls.push(collect_calls(code, fns[i].body, &nested));
        }
        Graph {
            tree,
            fns,
            by_name,
            calls,
        }
    }

    /// The source file containing `fns[i]`.
    pub fn file_of(&self, i: usize) -> &SourceFile {
        &self.tree.files[self.fns[i].file]
    }

    /// Spans of `fn` items nested inside `fns[i]`'s body (to be skipped
    /// when scanning the body — their contents belong to them).
    pub fn nested_spans(&self, i: usize) -> Vec<(usize, usize)> {
        nested_spans(&self.fns, i)
    }

    /// Indices of functions named `name` defined in a file whose path
    /// ends with `file_suffix`.
    pub fn fns_named_in(&self, name: &str, file_suffix: &str) -> Vec<usize> {
        self.fns
            .iter()
            .enumerate()
            .filter(|(i, f)| f.name == name && self.file_of(*i).rel.ends_with(file_suffix))
            .map(|(i, _)| i)
            .collect()
    }

    /// Candidate definitions for a call, minus [`UNRESOLVED_CALLS`]:
    ///
    /// - `mem`-scoped calls resolve only into `weightstore/mod.rs`;
    /// - otherwise **local-first**: if the caller's own file defines the
    ///   name, only those definitions are candidates (`dispatch(…)` inside
    ///   `server.rs` means the server's dispatch, not a same-named CLI
    ///   dispatcher elsewhere);
    /// - only then the tree-wide union of same-named functions.
    pub fn resolve(&self, caller_file: Option<usize>, name: &str, mem_scoped: bool) -> Vec<usize> {
        if UNRESOLVED_CALLS.contains(&name) {
            return Vec::new();
        }
        let Some(cands) = self.by_name.get(name) else { return Vec::new() };
        if mem_scoped {
            return cands
                .iter()
                .copied()
                .filter(|&i| self.file_of(i).rel.ends_with("weightstore/mod.rs"))
                .collect();
        }
        if let Some(cf) = caller_file {
            let local: Vec<usize> = cands
                .iter()
                .copied()
                .filter(|&i| self.fns[i].file == cf)
                .collect();
            if !local.is_empty() {
                return local;
            }
        }
        cands.to_vec()
    }

    /// Propagate per-function summaries along call edges until fixpoint:
    /// each caller absorbs every resolved callee's summary.  `absorb`
    /// returns whether the caller's summary changed.
    pub fn propagate<T: Clone>(&self, summaries: &mut [T], absorb: impl Fn(&mut T, &T) -> bool) {
        assert_eq!(summaries.len(), self.fns.len());
        loop {
            let mut changed = false;
            for i in 0..self.fns.len() {
                for call in &self.calls[i] {
                    for j in self.resolve(Some(self.fns[i].file), &call.name, call.mem_scoped) {
                        if i == j {
                            continue;
                        }
                        let callee = summaries[j].clone();
                        if absorb(&mut summaries[i], &callee) {
                            changed = true;
                        }
                    }
                }
            }
            if !changed {
                break;
            }
        }
    }

    /// BFS over call edges from `roots`.  `allow_callee(j)` can veto
    /// walking *into* `fns[j]` (sanctioned seams); vetoed functions are
    /// not reached and not scanned further.
    pub fn reach(&self, roots: &[usize], allow_callee: impl Fn(usize) -> bool) -> Reach {
        let mut pred: Vec<Option<(usize, usize)>> = vec![None; self.fns.len()];
        let mut reached = vec![false; self.fns.len()];
        let mut queue: Vec<usize> = Vec::new();
        for &r in roots {
            if !reached[r] {
                reached[r] = true;
                queue.push(r);
            }
        }
        let mut qi = 0;
        while qi < queue.len() {
            let i = queue[qi];
            qi += 1;
            for call in &self.calls[i] {
                for j in self.resolve(Some(self.fns[i].file), &call.name, call.mem_scoped) {
                    if !reached[j] && allow_callee(j) {
                        reached[j] = true;
                        pred[j] = Some((i, call.off));
                        queue.push(j);
                    }
                }
            }
        }
        Reach { reached, pred }
    }
}

/// Result of a reachability walk: which functions are reached, plus a
/// predecessor map for witness-chain reconstruction.
pub struct Reach {
    reached: Vec<bool>,
    pred: Vec<Option<(usize, usize)>>,
}

impl Reach {
    pub fn contains(&self, i: usize) -> bool {
        self.reached[i]
    }

    /// Indices of all reached functions.
    pub fn all(&self) -> Vec<usize> {
        (0..self.reached.len()).filter(|&i| self.reached[i]).collect()
    }

    /// Witness chain from a root to `fns[i]`, e.g.
    /// `serve -> process_frames -> dispatch`.
    pub fn path(&self, g: &Graph<'_>, i: usize) -> String {
        let mut names = vec![g.fns[i].name.clone()];
        let mut cur = i;
        // The pred map is acyclic by construction (set once, BFS), but
        // cap the walk defensively.
        for _ in 0..self.pred.len() {
            match self.pred[cur] {
                Some((p, _)) => {
                    names.push(g.fns[p].name.clone());
                    cur = p;
                }
                None => break,
            }
        }
        names.reverse();
        names.join(" -> ")
    }
}

/// Append every named `fn` with a braced body in `code` to `fns`.
pub fn collect_fns(file: usize, code: &str, fns: &mut Vec<FnDef>) {
    let b = code.as_bytes();
    let mut from = 0usize;
    while let Some(pos) = find_token_from(code, "fn", from) {
        from = pos + 2;
        let j = skip_ws(b, pos + 2);
        let Some(name) = ident_starting_at(b, j) else { continue };
        let mut k = j + name.len();
        while k < b.len() && b[k] != b'{' && b[k] != b';' {
            k += 1;
        }
        if k >= b.len() || b[k] == b';' {
            continue;
        }
        let Some(close) = matching_brace(b, k) else { continue };
        fns.push(FnDef {
            file,
            name,
            body: (k, close),
        });
    }
}

fn nested_spans(fns: &[FnDef], i: usize) -> Vec<(usize, usize)> {
    let f = &fns[i];
    fns.iter()
        .filter(|g| g.file == f.file && g.body.0 > f.body.0 && g.body.1 < f.body.1)
        .map(|g| g.body)
        .collect()
}

/// If an identifier starts at `b[i]` and forms a call (`name(` with no
/// `!` — macros are not calls — and not a `fn name(` definition), return
/// the call site.
pub fn call_at(b: &[u8], i: usize) -> Option<CallSite> {
    if !is_ident_byte(b[i]) || b[i].is_ascii_digit() || (i > 0 && is_ident_byte(b[i - 1])) {
        return None;
    }
    let name = ident_starting_at(b, i)?;
    let after = skip_ws(b, i + name.len());
    if after >= b.len() || b[after] != b'(' {
        return None;
    }
    let is_def = prev_non_ws(b, i)
        .and_then(|p| ident_ending_at(b, p))
        .is_some_and(|(_, kw)| kw == "fn");
    if is_def {
        return None;
    }
    let mem_scoped = prev_non_ws(b, i)
        .filter(|&d| b[d] == b'.')
        .map(|d| receiver_chain(b, d).iter().any(|id| id == "mem"))
        .unwrap_or(false);
    Some(CallSite {
        off: i,
        name,
        mem_scoped,
    })
}

/// All call sites in one body, source order, skipping `nested` fn spans.
fn collect_calls(code: &str, body: (usize, usize), nested: &[(usize, usize)]) -> Vec<CallSite> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut i = body.0;
    while i <= body.1 {
        if let Some(&(_, e)) = nested.iter().find(|(s, _)| *s == i) {
            i = e + 1;
            continue;
        }
        if let Some(site) = call_at(b, i) {
            i += site.name.len();
            out.push(site);
            continue;
        }
        if is_ident_byte(b[i]) {
            // Skip the rest of a non-call identifier in one step.
            while i <= body.1 && is_ident_byte(b[i]) {
                i += 1;
            }
            continue;
        }
        i += 1;
    }
    out
}

/// Identifiers of the receiver expression ending just before `dot`,
/// nearest-first: `self.core.log.lock()` → ["log", "core", "self"].
/// Bracketed index expressions are skipped (`self.shards[s]` → ["shards",
/// "self"] — `s` is an index, not a receiver).
pub fn receiver_chain(b: &[u8], dot: usize) -> Vec<String> {
    let mut out = Vec::new();
    let mut j = match prev_non_ws(b, dot) {
        Some(j) => j,
        None => return out,
    };
    loop {
        match b[j] {
            b']' | b')' => {
                let (open, close) = if b[j] == b']' { (b'[', b']') } else { (b'(', b')') };
                let mut depth = 1i64;
                while j > 0 && depth > 0 {
                    j -= 1;
                    if b[j] == close {
                        depth += 1;
                    } else if b[j] == open {
                        depth -= 1;
                    }
                }
                if j == 0 {
                    return out;
                }
                j -= 1;
            }
            _ if is_ident_byte(b[j]) => {
                let Some((start, ident)) = ident_ending_at(b, j) else { return out };
                out.push(ident);
                if start == 0 {
                    return out;
                }
                j = start - 1;
            }
            b'.' => {
                let Some(p) = prev_non_ws(b, j) else { return out };
                j = p;
            }
            b':' => {
                // `::` path separator continues the chain; a lone `:`
                // (type ascription) ends it.
                if j > 0 && b[j - 1] == b':' {
                    let Some(p) = prev_non_ws(b, j - 1) else { return out };
                    j = p;
                } else {
                    return out;
                }
            }
            _ => return out,
        }
        // Skip whitespace between chain elements.
        while j > 0 && b[j].is_ascii_whitespace() {
            j -= 1;
        }
        if b[j].is_ascii_whitespace() {
            return out;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::source::SourceFile;
    use std::path::PathBuf;

    fn tree_of(files: &[(&str, &str)]) -> Tree {
        let mut findings = Vec::new();
        Tree {
            root: PathBuf::from("."),
            files: files
                .iter()
                .map(|(rel, src)| SourceFile::parse(rel.to_string(), src.to_string(), &mut findings))
                .collect(),
            load_findings: findings,
        }
    }

    #[test]
    fn reach_walks_call_chain_and_reports_path() {
        let tree = tree_of(&[(
            "a.rs",
            "fn serve() { tick(); }\nfn tick() { helper(); }\nfn helper() { leaf(); }\nfn leaf() {}\nfn island() {}\n",
        )]);
        let g = Graph::build(&tree);
        let roots = g.fns_named_in("serve", "a.rs");
        assert_eq!(roots.len(), 1);
        let reach = g.reach(&roots, |_| true);
        let leaf = g.fns_named_in("leaf", "a.rs")[0];
        let island = g.fns_named_in("island", "a.rs")[0];
        assert!(reach.contains(leaf));
        assert!(!reach.contains(island));
        assert_eq!(reach.path(&g, leaf), "serve -> tick -> helper -> leaf");
    }

    #[test]
    fn reach_edge_filter_cuts_seams() {
        let tree = tree_of(&[(
            "a.rs",
            "fn serve() { seam(); }\nfn seam() { leaf(); }\nfn leaf() {}\n",
        )]);
        let g = Graph::build(&tree);
        let roots = g.fns_named_in("serve", "a.rs");
        let seam = g.fns_named_in("seam", "a.rs")[0];
        let leaf = g.fns_named_in("leaf", "a.rs")[0];
        let reach = g.reach(&roots, |j| j != seam);
        assert!(!reach.contains(seam));
        assert!(!reach.contains(leaf), "cutting a seam cuts everything behind it");
    }

    #[test]
    fn unresolved_idioms_and_macros_are_not_edges() {
        let tree = tree_of(&[(
            "a.rs",
            "fn serve() { let v: Vec<u8> = Vec::new(); v.len(); helper!(); }\nfn new() { leaf(); }\nfn helper() { leaf(); }\nfn leaf() {}\n",
        )]);
        let g = Graph::build(&tree);
        let roots = g.fns_named_in("serve", "a.rs");
        let reach = g.reach(&roots, |_| true);
        let leaf = g.fns_named_in("leaf", "a.rs")[0];
        assert!(!reach.contains(leaf), "`new` is unresolved and `helper!` is a macro");
    }

    #[test]
    fn resolution_is_local_first() {
        let tree = tree_of(&[
            (
                "server.rs",
                "fn serve() { dispatch(); }\nfn dispatch() { local_leaf(); }\nfn local_leaf() {}\n",
            ),
            ("cli.rs", "fn dispatch() { cli_leaf(); }\nfn cli_leaf() {}\n"),
        ]);
        let g = Graph::build(&tree);
        let roots = g.fns_named_in("serve", "server.rs");
        let reach = g.reach(&roots, |_| true);
        assert!(reach.contains(g.fns_named_in("local_leaf", "server.rs")[0]));
        assert!(
            !reach.contains(g.fns_named_in("cli_leaf", "cli.rs")[0]),
            "a local `dispatch` definition shadows the cross-file union"
        );
    }

    #[test]
    fn propagate_reaches_fixpoint_transitively() {
        let tree = tree_of(&[(
            "a.rs",
            "fn a() { b(); }\nfn b() { c(); }\nfn c() {}\n",
        )]);
        let g = Graph::build(&tree);
        let mut sums: Vec<Vec<&str>> = g
            .fns
            .iter()
            .map(|f| if f.name == "c" { vec!["mark"] } else { vec![] })
            .collect();
        g.propagate(&mut sums, |caller, callee| {
            let mut changed = false;
            for m in callee {
                if !caller.contains(m) {
                    caller.push(m);
                    changed = true;
                }
            }
            changed
        });
        let a = g.fns_named_in("a", "a.rs")[0];
        assert_eq!(sums[a], vec!["mark"]);
    }
}
