use std::path::PathBuf;
use std::process::ExitCode;

use xtask::diag;
use xtask::lints;
use xtask::Tree;

const USAGE: &str = "\
usage: cargo run -p xtask -- analyze [--root <dir>] [--lint <name>]
                                     [--format text|json|github]
                                     [--baseline <path>] [--write-baseline]

  analyze            run every lint over the source tree (default root:
                     ./src or ./rust/src, whichever exists)
  --root <dir>       analyze a different tree (used by the fixture tests)
  --lint <name>      run a single lint: protocol | traits | determinism |
                     locks | blocking | panics | telemetry
  --format <fmt>     text (default) | json | github (workflow annotations)
  --baseline <path>  findings baseline to diff against (default:
                     xtask/analyze-baseline.json next to the source root);
                     only findings NOT in the baseline fail the run
  --write-baseline   rewrite the baseline from the current findings
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd = None;
    let mut root: Option<PathBuf> = None;
    let mut lint: Option<String> = None;
    let mut format = "text".to_string();
    let mut baseline_path: Option<PathBuf> = None;
    let mut write_baseline = false;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "analyze" => cmd = Some("analyze"),
            "--root" => root = it.next().map(PathBuf::from),
            "--lint" => lint = it.next().cloned(),
            "--format" => format = it.next().cloned().unwrap_or_default(),
            "--baseline" => baseline_path = it.next().map(PathBuf::from),
            "--write-baseline" => write_baseline = true,
            "-h" | "--help" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("unknown argument `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    if cmd != Some("analyze") {
        eprint!("{USAGE}");
        return ExitCode::from(2);
    }
    if !matches!(format.as_str(), "text" | "json" | "github") {
        eprintln!("unknown --format `{format}` (want text|json|github)\n{USAGE}");
        return ExitCode::from(2);
    }
    let root = root.unwrap_or_else(|| {
        for cand in ["src", "rust/src", "../src"] {
            let p = PathBuf::from(cand);
            if p.join("lib.rs").exists() {
                return p;
            }
        }
        PathBuf::from("src")
    });
    let tree = match Tree::load(&root) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: cannot load source tree at {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    let findings = match &lint {
        Some(name) => match lints::run_one(&tree, name) {
            Some(f) => f,
            None => {
                eprintln!("unknown lint `{name}`\n{USAGE}");
                return ExitCode::from(2);
            }
        },
        None => lints::run_all(&tree),
    };

    // The baseline lives next to the analyzed tree: <root>/../xtask/….
    let baseline_path = baseline_path.unwrap_or_else(|| {
        root.parent()
            .unwrap_or(&root)
            .join("xtask/analyze-baseline.json")
    });
    if write_baseline {
        let refs: Vec<&xtask::Finding> = findings.iter().collect();
        if let Err(e) = std::fs::write(&baseline_path, diag::to_json(&refs)) {
            eprintln!("error: cannot write baseline {}: {e}", baseline_path.display());
            return ExitCode::from(2);
        }
        println!(
            "analyze: wrote {} finding(s) to {}",
            findings.len(),
            baseline_path.display()
        );
        return ExitCode::SUCCESS;
    }
    let baseline = match std::fs::read_to_string(&baseline_path) {
        Ok(text) => match diag::parse_baseline(&text) {
            Ok(b) => b,
            Err(e) => {
                eprintln!(
                    "error: malformed baseline {}: {e}",
                    baseline_path.display()
                );
                return ExitCode::from(2);
            }
        },
        Err(_) => Vec::new(), // no baseline file = empty baseline
    };
    let (fresh, known, stale) = diag::diff(&findings, &baseline);

    match format.as_str() {
        "json" => print!("{}", diag::to_json(&fresh)),
        "github" => {
            // Annotation paths are repo-root-relative: when analyzing
            // e.g. `rust/src`, the root itself is the prefix.
            let prefix = root.to_string_lossy().replace('\\', "/");
            let prefix = prefix.trim_start_matches("./");
            for f in &fresh {
                println!("{}", diag::github_annotation(f, prefix));
            }
            for f in &known {
                println!(
                    "::warning file={prefix}/{},line={}::[{}] baselined: {}",
                    f.file, f.line, f.lint, f.msg
                );
            }
        }
        _ => {
            for f in &fresh {
                println!("{f}");
            }
        }
    }
    if !known.is_empty() {
        eprintln!(
            "analyze: {} baselined finding(s) suppressed (burn them down: fix and \
             `--write-baseline` to shrink {})",
            known.len(),
            baseline_path.display()
        );
    }
    if !stale.is_empty() {
        eprintln!(
            "analyze: {} stale baseline entr(y/ies) no longer fire — shrink the baseline \
             with `--write-baseline`",
            stale.len()
        );
    }
    if fresh.is_empty() {
        if format == "text" {
            println!(
                "analyze: {} files, {} lints, 0 new findings ({} baselined)",
                tree.files.len(),
                lint.map_or(lints::LINTS.len(), |_| 1),
                known.len()
            );
        }
        ExitCode::SUCCESS
    } else {
        eprintln!("analyze: {} new finding(s)", fresh.len());
        ExitCode::FAILURE
    }
}
