use std::path::PathBuf;
use std::process::ExitCode;

use xtask::lints;
use xtask::Tree;

const USAGE: &str = "\
usage: cargo run -p xtask -- analyze [--root <dir>] [--lint <name>]

  analyze            run every lint over the source tree (default root:
                     ./src or ./rust/src, whichever exists)
  --root <dir>       analyze a different tree (used by the fixture tests)
  --lint <name>      run a single lint: protocol | traits | determinism | locks
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd = None;
    let mut root: Option<PathBuf> = None;
    let mut lint: Option<String> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "analyze" => cmd = Some("analyze"),
            "--root" => root = it.next().map(PathBuf::from),
            "--lint" => lint = it.next().cloned(),
            "-h" | "--help" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("unknown argument `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    if cmd != Some("analyze") {
        eprint!("{USAGE}");
        return ExitCode::from(2);
    }
    let root = root.unwrap_or_else(|| {
        for cand in ["src", "rust/src", "../src"] {
            let p = PathBuf::from(cand);
            if p.join("lib.rs").exists() {
                return p;
            }
        }
        PathBuf::from("src")
    });
    let tree = match Tree::load(&root) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: cannot load source tree at {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    let findings = match &lint {
        Some(name) => match lints::run_one(&tree, name) {
            Some(f) => f,
            None => {
                eprintln!("unknown lint `{name}`\n{USAGE}");
                return ExitCode::from(2);
            }
        },
        None => lints::run_all(&tree),
    };
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!(
            "analyze: {} files, {} lints, 0 findings",
            tree.files.len(),
            lint.map_or(lints::LINTS.len(), |_| 1)
        );
        ExitCode::SUCCESS
    } else {
        eprintln!("analyze: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}
