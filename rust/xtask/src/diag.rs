//! Diagnostics layer: structured output formats and the findings
//! baseline.
//!
//! `analyze` can render findings three ways:
//!
//! - `text` (default) — `file:line: [lint] msg`, one per line;
//! - `json` — a machine-readable array (same schema as the baseline);
//! - `github` — `::error file=…,line=…::…` workflow annotations, so CI
//!   findings land on the touched lines of a pull request.
//!
//! The *baseline* (`xtask/analyze-baseline.json`, checked in) turns
//! "shrink, don't grow" into a gate: `analyze` exits nonzero only for
//! findings **not** in the baseline, so legacy findings can be burned
//! down incrementally while new ones fail CI immediately.
//! `--write-baseline` rewrites the file from the current findings (which
//! is also how it shrinks).  Baseline entries match on `(file, lint,
//! msg)` — line numbers drift with unrelated edits and are recorded for
//! humans only.  The repo's target state is an *empty* baseline: every
//! deliberate waiver should be a reasoned in-source pragma instead.
//!
//! Everything here is hand-rolled (the crate has no dependencies); the
//! JSON reader accepts exactly the subset the writer emits.

use std::fmt::Write as _;

use crate::source::Finding;

/// One accepted legacy finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BaselineEntry {
    pub file: String,
    pub line: usize,
    pub lint: String,
    pub msg: String,
}

impl BaselineEntry {
    pub fn matches(&self, f: &Finding) -> bool {
        self.file == f.file && self.lint == f.lint && self.msg == f.msg
    }
}

/// Split findings into (new, baselined) against the baseline, and report
/// stale baseline entries that no longer fire.
pub fn diff<'f>(
    findings: &'f [Finding],
    baseline: &[BaselineEntry],
) -> (Vec<&'f Finding>, Vec<&'f Finding>, Vec<BaselineEntry>) {
    let mut fresh = Vec::new();
    let mut known = Vec::new();
    for f in findings {
        if baseline.iter().any(|b| b.matches(f)) {
            known.push(f);
        } else {
            fresh.push(f);
        }
    }
    let stale = baseline
        .iter()
        .filter(|b| !findings.iter().any(|f| b.matches(f)))
        .cloned()
        .collect();
    (fresh, known, stale)
}

/// Serialize findings as the baseline/`--format json` document.
pub fn to_json(findings: &[&Finding]) -> String {
    let mut out = String::from("[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "\n  {{\"file\": {}, \"line\": {}, \"lint\": {}, \"msg\": {}}}",
            json_str(&f.file),
            f.line,
            json_str(f.lint),
            json_str(&f.msg)
        );
    }
    out.push_str(if findings.is_empty() { "]\n" } else { "\n]\n" });
    out
}

/// One GitHub workflow annotation. `prefix` is the path from the repo
/// root to the analyzed source root (annotations are repo-relative).
pub fn github_annotation(f: &Finding, prefix: &str) -> String {
    let path = if prefix.is_empty() {
        f.file.clone()
    } else {
        format!("{}/{}", prefix.trim_end_matches('/'), f.file)
    };
    // Annotation messages must escape %, CR and LF.
    let msg = format!("[{}] {}", f.lint, f.msg)
        .replace('%', "%25")
        .replace('\r', "%0D")
        .replace('\n', "%0A");
    format!("::error file={path},line={}::{msg}", f.line)
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Parse a baseline document: an array of `{file, line, lint, msg}`
/// objects (the exact subset `to_json` writes).
pub fn parse_baseline(text: &str) -> Result<Vec<BaselineEntry>, String> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.ws();
    p.expect(b'[')?;
    let mut out = Vec::new();
    p.ws();
    if p.peek() == Some(b']') {
        p.i += 1;
        return Ok(out);
    }
    loop {
        out.push(p.object()?);
        p.ws();
        match p.next()? {
            b',' => p.ws(),
            b']' => break,
            c => return Err(format!("expected ',' or ']', got '{}'", c as char)),
        }
    }
    Ok(out)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn next(&mut self) -> Result<u8, String> {
        let c = self.peek().ok_or("unexpected end of baseline json")?;
        self.i += 1;
        Ok(c)
    }
    fn expect(&mut self, want: u8) -> Result<(), String> {
        let got = self.next()?;
        if got != want {
            return Err(format!("expected '{}', got '{}'", want as char, got as char));
        }
        Ok(())
    }
    fn object(&mut self) -> Result<BaselineEntry, String> {
        self.ws();
        self.expect(b'{')?;
        let mut entry = BaselineEntry {
            file: String::new(),
            line: 0,
            lint: String::new(),
            msg: String::new(),
        };
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            match key.as_str() {
                "line" => entry.line = self.number()?,
                "file" => entry.file = self.string()?,
                "lint" => entry.lint = self.string()?,
                "msg" => entry.msg = self.string()?,
                other => return Err(format!("unknown baseline key {other:?}")),
            }
            self.ws();
            match self.next()? {
                b',' => continue,
                b'}' => break,
                c => return Err(format!("expected ',' or '}}', got '{}'", c as char)),
            }
        }
        Ok(entry)
    }
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.next()? {
                b'"' => return Ok(out),
                b'\\' => match self.next()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.next()?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| format!("bad \\u digit '{}'", d as char))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => return Err(format!("unsupported escape '\\{}'", c as char)),
                },
                c => {
                    // Multi-byte UTF-8 passes through byte by byte; the
                    // source was a valid &str, so re-assembly is safe via
                    // a byte buffer.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        // Collect the full scalar's continuation bytes.
                        let mut buf = vec![c];
                        while self.peek().is_some_and(|n| (0x80..0xC0).contains(&n)) {
                            buf.push(self.next()?);
                        }
                        out.push_str(&String::from_utf8_lossy(&buf));
                    }
                }
            }
        }
    }
    fn number(&mut self) -> Result<usize, String> {
        let start = self.i;
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.i == start {
            return Err("expected a number".into());
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| "bad number".into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(file: &str, line: usize, msg: &str) -> Finding {
        Finding {
            file: file.into(),
            line,
            lint: "panics",
            msg: msg.into(),
        }
    }

    #[test]
    fn json_round_trips_through_the_baseline_parser() {
        let f1 = finding("a.rs", 3, "has \"quotes\" and \\slashes\\");
        let f2 = finding("b/c.rs", 99, "plain");
        let doc = to_json(&[&f1, &f2]);
        let parsed = parse_baseline(&doc).unwrap();
        assert_eq!(parsed.len(), 2);
        assert!(parsed[0].matches(&f1));
        assert!(parsed[1].matches(&f2));
        assert!(!parsed[0].matches(&f2));
    }

    #[test]
    fn empty_baseline_parses() {
        assert_eq!(parse_baseline("[]").unwrap(), vec![]);
        assert_eq!(parse_baseline(" [\n]\n").unwrap(), vec![]);
    }

    #[test]
    fn diff_partitions_new_known_stale() {
        let f1 = finding("a.rs", 3, "old");
        let f2 = finding("a.rs", 9, "new");
        let base = parse_baseline(&to_json(&[&f1, &finding("gone.rs", 1, "fixed")])).unwrap();
        let findings = vec![f1.clone(), f2.clone()];
        let (fresh, known, stale) = diff(&findings, &base);
        assert_eq!(fresh, vec![&f2]);
        assert_eq!(known, vec![&f1]);
        assert_eq!(stale.len(), 1);
        assert_eq!(stale[0].file, "gone.rs");
    }

    #[test]
    fn github_annotation_escapes_and_prefixes() {
        let f = finding("a.rs", 7, "50% bad\nline two");
        let ann = github_annotation(&f, "rust/src");
        assert_eq!(
            ann,
            "::error file=rust/src/a.rs,line=7::[panics] 50%25 bad%0Aline two"
        );
        assert!(github_annotation(&f, "").starts_with("::error file=a.rs,"));
    }

    #[test]
    fn baseline_line_numbers_do_not_affect_matching() {
        let entry = BaselineEntry {
            file: "a.rs".into(),
            line: 1,
            lint: "panics".into(),
            msg: "m".into(),
        };
        assert!(entry.matches(&finding("a.rs", 42, "m")));
    }
}
