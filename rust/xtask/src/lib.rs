//! `xtask` — the repo's own static-analysis pass.
//!
//! Run as `cargo run -p xtask -- analyze` (CI gates on its exit status).
//! Seven lints enforce invariants the compiler can't:
//!
//! * `protocol` — opcode table / encode / decode / server / client /
//!   durable-journal exhaustiveness for `weightstore/protocol.rs`.
//! * `traits` — every `WeightStore` method implemented by every backend
//!   and dispatched by the TCP server.
//! * `determinism` — no wall-clock or nondeterministic primitives outside
//!   pragma-sanctioned sites.
//! * `locks` — the inter-lock acquisition graph respects the canonical
//!   order declared in `weightstore/mod.rs` and is cycle-free.
//! * `blocking` — no blocking operation reachable from the server
//!   event-loop tick path (call-graph reachability from `serve()`).
//! * `panics` — no `unwrap`/`expect`/panicking macro/range-index
//!   reachable from server dispatch or `Client`/`ClientPool` paths.
//! * `telemetry` — metric-name grammar, `STORE_METRICS` membership, and
//!   cross-site instrument-kind consistency.
//!
//! The reachability lints share the name-resolved call graph in
//! [`callgraph`].  Findings diff against a checked-in baseline
//! (`xtask/analyze-baseline.json`, see [`diag`]) so CI fails on growth
//! only.  See `xtask/README.md` for pragma syntax and how to add a lint.

pub mod callgraph;
pub mod diag;
pub mod lints;
pub mod source;

pub use source::{Finding, Tree};
