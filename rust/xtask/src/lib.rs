//! `xtask` — the repo's own static-analysis pass.
//!
//! Run as `cargo run -p xtask -- analyze` (CI gates on its exit status).
//! Four lints enforce invariants the compiler can't:
//!
//! * `protocol` — opcode table / encode / decode / server / client /
//!   durable-journal exhaustiveness for `weightstore/protocol.rs`.
//! * `traits` — every `WeightStore` method implemented by every backend
//!   and dispatched by the TCP server.
//! * `determinism` — no wall-clock or nondeterministic primitives outside
//!   pragma-sanctioned sites.
//! * `locks` — the inter-lock acquisition graph respects the canonical
//!   order declared in `weightstore/mod.rs` and is cycle-free.
//!
//! See `xtask/README.md` for pragma syntax and how to add a lint.

pub mod lints;
pub mod source;

pub use source::{Finding, Tree};
