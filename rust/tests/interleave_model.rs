//! Exhaustive interleaving model of the compaction / cursor-pin contract.
//!
//! `MemStore` operations are individually linearizable (every op runs
//! under the store's internal locks), so any concurrent execution of a
//! writer+compactor thread and consumer threads is equivalent to *some*
//! serial interleaving of their op sequences.  This test enumerates every
//! such interleaving (a few thousand schedules) and replays each one
//! against a fresh store, checking the contracts the module docs promise:
//!
//! * **Pin honoured** — a consumer that saved its cursor is never demoted
//!   to a full fetch by a concurrent `compact_before`, no matter where the
//!   compaction lands in the schedule.
//! * **Floor vs pin** — the compaction floor never passes the oldest
//!   saved cursor, and never moves backwards.
//! * **No lost updates** — after a final drain, every consumer's mirror
//!   equals the store's own snapshot bit-for-bit.
//!
//! The genuinely-parallel versions of these interleavings (where the ops
//! themselves race inside the store) are covered by the loom models in
//! `rust/loom-model/` (CI-only: loom is an external dependency) and the
//! nightly ThreadSanitizer job.

use issgd::weightstore::{MemStore, WeightSnapshot, WeightStore};

#[derive(Clone, Copy, Debug)]
enum Op {
    /// Writer: push a 2-entry run at `start = 2 * k` with values keyed
    /// off `k` (so every push changes observable state).
    Push(u64),
    /// Compactor: `compact_before(limit)` plus floor/pin checks.
    Compact(u64),
    /// Consumer `id`: fetch-since, apply to mirror, save cursor.
    Sync(usize),
}

const N: usize = 8;

struct Consumer {
    name: &'static str,
    cursor: u64,
    saved: bool,
    mirror: WeightSnapshot,
}

impl Consumer {
    fn new(name: &'static str) -> Self {
        Consumer {
            name,
            cursor: 0,
            saved: false,
            mirror: WeightSnapshot::default(),
        }
    }

    fn sync(&mut self, store: &MemStore, trace: &[Op]) {
        let d = store.fetch_weights_since(self.cursor).unwrap();
        if self.saved {
            assert!(
                !d.full,
                "consumer {} (cursor {}) demoted to full despite its pin; schedule: {trace:?}",
                self.name, self.cursor
            );
        }
        d.apply_to(&mut self.mirror).unwrap();
        self.cursor = d.seq;
        store.save_cursor(self.name, self.cursor).unwrap();
        self.saved = true;
    }
}

fn run_schedule(trace: &[Op]) {
    let store = MemStore::new(N, 1.0);
    let mut consumers = [Consumer::new("a"), Consumer::new("b")];
    for (i, op) in trace.iter().enumerate() {
        match *op {
            Op::Push(k) => {
                let w = [(10 + k) as f32, (100 + k + i as u64) as f32];
                store.push_weights((2 * k) as usize, &w, k + 1).unwrap();
            }
            Op::Compact(limit) => {
                let before = store.compact_floor();
                let pin = consumers
                    .iter()
                    .filter(|c| c.saved)
                    .map(|c| c.cursor)
                    .min();
                let floor = store.compact_before(limit);
                assert!(floor >= before, "floor moved backwards; schedule: {trace:?}");
                assert_eq!(store.compact_floor(), floor);
                if let Some(p) = pin {
                    assert!(
                        floor <= p,
                        "floor {floor} passed oldest pin {p}; schedule: {trace:?}"
                    );
                }
            }
            Op::Sync(id) => consumers[id].sync(&store, trace),
        }
    }
    // Final drain: every consumer catches up and must mirror the store.
    let snap = store.fetch_weights().unwrap();
    for c in consumers.iter_mut() {
        c.sync(&store, trace);
        assert_eq!(
            c.mirror, snap,
            "consumer {} mirror diverged from the store; schedule: {trace:?}",
            c.name
        );
    }
}

fn interleave(seqs: &[Vec<Op>], idx: &mut Vec<usize>, trace: &mut Vec<Op>, count: &mut u64) {
    let mut advanced = false;
    for t in 0..seqs.len() {
        if idx[t] < seqs[t].len() {
            advanced = true;
            let op = seqs[t][idx[t]];
            idx[t] += 1;
            trace.push(op);
            interleave(seqs, idx, trace, count);
            trace.pop();
            idx[t] -= 1;
        }
    }
    if !advanced {
        *count += 1;
        run_schedule(trace);
    }
}

#[test]
fn all_interleavings_respect_pin_floor_and_delivery() {
    // Writer/compactor thread: pushes interleaved with an early bounded
    // compaction and a late unbounded one (limit past the write counter,
    // so it is clamped by pins / the counter itself).
    let writer = vec![
        Op::Push(0),
        Op::Compact(3),
        Op::Push(1),
        Op::Compact(99),
        Op::Push(2),
    ];
    // Two consumers with different cadences: "a" syncs three times (pins
    // early in most schedules), "b" twice (often first-syncs *after* a
    // compaction — exercising the full-fallback path).
    let a = vec![Op::Sync(0), Op::Sync(0), Op::Sync(0)];
    let b = vec![Op::Sync(1), Op::Sync(1)];

    let seqs = [writer, a, b];
    let mut idx = vec![0; seqs.len()];
    let mut trace = Vec::new();
    let mut count = 0u64;
    interleave(&seqs, &mut idx, &mut trace, &mut count);
    // 10 ops in three per-thread orders: 10! / (5! 3! 2!) schedules.
    assert_eq!(count, 2520, "schedule enumeration is broken");
}
