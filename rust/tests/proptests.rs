//! Property-based tests over the coordinator substrates (proptest is not
//! available offline, so `prop` below is a miniature equivalent: seeded
//! random cases, failure reporting with the case seed for reproduction).
//!
//! Invariants covered:
//!  * Fenwick and alias samplers draw from exactly the weight distribution
//!    (χ²-style tolerance) and agree with each other.
//!  * Fenwick prefix sums match a naive scan after arbitrary updates.
//!  * Importance-sampling coefficients make the minibatch estimator
//!    unbiased for arbitrary positive weight vectors.
//!  * Tr(Σ) estimators: ideal ≤ stale for any weights (Cauchy-Schwarz),
//!    equality when weights ∝ norms; smoothing → ∞ drives stale → unif.
//!  * Wire protocol round-trips arbitrary messages byte-exactly.
//!  * JSON round-trips arbitrary values.
//!  * Synthetic data shards compose to the full dataset.

use issgd::sampler::{draw_minibatch, AliasSampler, FenwickSampler};
use issgd::util::json::Json;
use issgd::util::rng::Pcg64;
use issgd::variance::trace_sigma;
use issgd::weightstore::protocol::{Request, Response};
use issgd::weightstore::{MemStore, WeightDelta, WeightSnapshot, WeightStore};

/// Run `cases` random property cases; panic with the case seed on failure.
fn prop(name: &str, cases: u64, mut f: impl FnMut(&mut Pcg64)) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Pcg64::seeded(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property {name:?} failed on case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

fn random_weights(rng: &mut Pcg64, max_len: usize) -> Vec<f64> {
    let n = 1 + rng.next_below(max_len as u64) as usize;
    (0..n)
        .map(|_| {
            // Mix zeros, small and large weights.
            match rng.next_below(4) {
                0 => 0.0,
                1 => rng.next_f64() * 1e-3,
                2 => rng.next_f64(),
                _ => rng.next_f64() * 1e3,
            }
        })
        .collect()
}

#[test]
fn fenwick_prefix_sums_match_naive_after_updates() {
    prop("fenwick-prefix", 40, |rng| {
        let mut w = random_weights(rng, 200);
        let mut s = FenwickSampler::new(&w);
        // Apply a burst of random point updates.
        for _ in 0..50 {
            let i = rng.next_below(w.len() as u64) as usize;
            let nv = rng.next_f64() * 10.0;
            w[i] = nv;
            s.update(i, nv);
        }
        let mut acc = 0.0;
        for i in 0..w.len() {
            acc += w[i];
            let got = s.prefix_sum(i + 1);
            assert!(
                (got - acc).abs() <= 1e-9 * acc.abs().max(1.0),
                "prefix {i}: {got} vs {acc}"
            );
        }
    });
}

#[test]
fn fenwick_and_alias_agree_on_distribution() {
    prop("sampler-agreement", 8, |rng| {
        let mut w = random_weights(rng, 30);
        if w.iter().sum::<f64>() <= 0.0 {
            w[0] = 1.0;
        }
        let total: f64 = w.iter().sum();
        let fen = FenwickSampler::new(&w);
        let alias = AliasSampler::new(&w).unwrap();
        let draws = 30_000;
        let mut cf = vec![0f64; w.len()];
        let mut ca = vec![0f64; w.len()];
        for _ in 0..draws {
            cf[fen.sample(rng).unwrap()] += 1.0;
            ca[alias.sample(rng)] += 1.0;
        }
        for i in 0..w.len() {
            let expect = w[i] / total;
            let got_f = cf[i] / draws as f64;
            let got_a = ca[i] / draws as f64;
            let tol = 0.02 + 3.0 * (expect * (1.0 - expect) / draws as f64).sqrt();
            assert!((got_f - expect).abs() < tol, "fenwick idx {i}: {got_f} vs {expect}");
            assert!((got_a - expect).abs() < tol, "alias idx {i}: {got_a} vs {expect}");
            if w[i] == 0.0 {
                assert_eq!(cf[i], 0.0);
                assert_eq!(ca[i], 0.0);
            }
        }
    });
}

#[test]
fn importance_estimator_unbiased_for_arbitrary_weights() {
    // E_q[coef * f(i)] must equal mean_i f(i) for any positive weights.
    prop("is-unbiased", 6, |rng| {
        let n = 3 + rng.next_below(10) as usize;
        let w: Vec<f64> = (0..n).map(|_| 0.05 + rng.next_f64() * 5.0).collect();
        let f: Vec<f64> = (0..n).map(|_| rng.next_f64() * 10.0 - 5.0).collect();
        let truth: f64 = f.iter().sum::<f64>() / n as f64;
        let s = FenwickSampler::new(&w);
        let rounds = 60_000;
        let mut acc = 0.0;
        for _ in 0..rounds {
            let (idx, coefs, _) = draw_minibatch(&s, rng, 1);
            acc += coefs[0] as f64 * f[idx[0]];
        }
        let est = acc / rounds as f64;
        // Standard error of the IS estimator with these weights:
        let mean_w: f64 = w.iter().sum::<f64>() / n as f64;
        let second: f64 = (0..n)
            .map(|i| w[i] / (n as f64 * mean_w) * (mean_w / w[i] * f[i]).powi(2))
            .sum();
        let se = ((second - truth * truth).max(0.0) / rounds as f64).sqrt();
        assert!(
            (est - truth).abs() < 6.0 * se + 0.02,
            "est {est} truth {truth} se {se}"
        );
    });
}

#[test]
fn variance_ideal_never_exceeds_stale() {
    // Cauchy-Schwarz: (mean ||g||)² ≤ (mean w)(mean ||g||²/w) for ANY w>0.
    prop("var-cauchy-schwarz", 60, |rng| {
        let n = 2 + rng.next_below(50) as usize;
        let sqnorms: Vec<f64> = (0..n).map(|_| rng.next_f64() * 100.0).collect();
        let weights: Vec<f64> = (0..n).map(|_| 1e-6 + rng.next_f64() * 10.0).collect();
        let r = trace_sigma(&sqnorms, &weights, 0.0);
        assert!(
            r.ideal_raw <= r.stale_raw * (1.0 + 1e-9) + 1e-9,
            "ideal {} > stale {}",
            r.ideal_raw,
            r.stale_raw
        );
    });
}

#[test]
fn variance_optimal_weights_reach_the_bound() {
    prop("var-optimality", 40, |rng| {
        let n = 2 + rng.next_below(30) as usize;
        let sqnorms: Vec<f64> = (0..n).map(|_| 0.01 + rng.next_f64() * 50.0).collect();
        let optimal: Vec<f64> = sqnorms.iter().map(|s| s.sqrt()).collect();
        let r = trace_sigma(&sqnorms, &optimal, 0.0);
        assert!(
            (r.ideal_raw - r.stale_raw).abs() <= 1e-9 * r.ideal_raw.max(1.0),
            "optimal weights should achieve the ideal bound"
        );
        // ...and any perturbation can only increase the stale term.
        let perturbed: Vec<f64> = optimal
            .iter()
            .map(|w| w * (0.5 + rng.next_f64()))
            .collect();
        let r2 = trace_sigma(&sqnorms, &perturbed, 0.0);
        assert!(r2.stale_raw >= r.stale_raw * (1.0 - 1e-9));
    });
}

#[test]
fn variance_smoothing_limit_is_uniform() {
    // w + c with c → ∞ behaves like uniform weights: stale → unif.
    prop("var-smoothing-limit", 40, |rng| {
        let n = 2 + rng.next_below(30) as usize;
        let sqnorms: Vec<f64> = (0..n).map(|_| rng.next_f64() * 10.0).collect();
        let base: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let smoothed: Vec<f64> = base.iter().map(|w| w + 1e9).collect();
        let r = trace_sigma(&sqnorms, &smoothed, 0.0);
        assert!(
            (r.stale_raw - r.unif_raw).abs() <= 1e-6 * r.unif_raw.max(1e-12),
            "stale {} vs unif {}",
            r.stale_raw,
            r.unif_raw
        );
    });
}

#[test]
fn protocol_roundtrips_random_messages() {
    prop("protocol-roundtrip", 60, |rng| {
        let n = rng.next_below(100) as usize;
        let weights: Vec<f32> = (0..n).map(|_| rng.next_f32() * 100.0).collect();
        let req = Request::PushWeights {
            start: rng.next_u64() % 10_000,
            param_version: rng.next_u64() % 1000,
            weights,
        };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);

        let m = rng.next_below(50) as usize;
        let snap = WeightSnapshot {
            weights: (0..m).map(|_| rng.next_f64()).collect(),
            stamps: (0..m).map(|_| rng.next_u64()).collect(),
            param_versions: (0..m).map(|_| rng.next_u64() % 64).collect(),
        };
        let resp = Response::Weights(snap);
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);

        let blob: Vec<u8> = (0..rng.next_below(300)).map(|_| rng.next_u64() as u8).collect();
        let req = Request::PushParams {
            version: rng.next_u64(),
            bytes: blob,
        };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
    });
}

// ---------------------------------------------------------------------------
// backend conformance harness
// ---------------------------------------------------------------------------
//
// The delta-replay and multi-consumer properties are *backend contracts*,
// not MemStore implementation details: the same generic bodies run against
// every in-process backend (MemStore, DurableStore over a temp dir), so a
// new backend cannot silently weaken the cursor semantics.

mod common;
use common::TempDir;

/// One store under conformance test: the trait handle plus the probe the
/// properties need (current global write sequence) and the tempdir guard
/// keeping a durable backend's files alive for the case's duration.
struct TestStore {
    store: std::sync::Arc<dyn WeightStore>,
    write_seq: Box<dyn Fn() -> u64>,
    _dir: Option<TempDir>,
}

fn durable_opts() -> issgd::weightstore::durable::DurableOptions {
    issgd::weightstore::durable::DurableOptions {
        segment_bytes: 1 << 16,
        compact_after_bytes: 0, // conformance runs exercise the journal, not the compactor
        ..issgd::weightstore::durable::DurableOptions::default()
    }
}

/// Every in-process backend the conformance properties run against.
fn backends(tag: &'static str) -> Vec<(&'static str, Box<dyn Fn(usize, f64) -> TestStore>)> {
    use issgd::weightstore::durable::DurableStore;
    use std::sync::Arc;
    vec![
        (
            "mem",
            Box::new(|n: usize, init: f64| {
                let s = Arc::new(MemStore::new(n, init));
                let probe = Arc::clone(&s);
                TestStore {
                    store: s,
                    write_seq: Box::new(move || probe.write_seq()),
                    _dir: None,
                }
            }) as Box<dyn Fn(usize, f64) -> TestStore>,
        ),
        (
            "durable",
            Box::new(move |n: usize, init: f64| {
                let dir = TempDir::new(tag);
                let s = Arc::new(DurableStore::create(&dir.0, n, init, durable_opts()).unwrap());
                let probe = Arc::clone(&s);
                TestStore {
                    store: s,
                    write_seq: Box::new(move || probe.write_seq()),
                    _dir: Some(dir),
                }
            }),
        ),
    ]
}

fn delta_replay_reconstructs_generic(label: &str, mk: &dyn Fn(usize, f64) -> TestStore) {
    // For any cursor ever handed out: snapshot-at-cursor + delta-since-cursor
    // must equal the final table exactly.
    prop(&format!("delta-replay-{label}"), 20, |rng| {
        let n = 1 + rng.next_below(300) as usize;
        let ts = mk(n, rng.next_f64());
        let store = &ts.store;
        // Checkpoints: (cursor, snapshot consistent with that cursor).
        let mut checkpoints: Vec<(u64, WeightSnapshot)> = Vec::new();
        let d0 = store.fetch_weights_since(0).unwrap();
        checkpoints.push((d0.seq, d0.to_snapshot().unwrap()));
        for round in 0..30u64 {
            let start = rng.next_below(n as u64) as usize;
            let len = 1 + rng.next_below((n - start).min(40) as u64 + 1) as usize;
            let len = len.min(n - start);
            let vals: Vec<f32> = (0..len).map(|_| rng.next_f32().abs()).collect();
            store.push_weights(start, &vals, round + 1).unwrap();
            if rng.next_below(3) == 0 {
                // Checkpoint mid-stream: a full snapshot plus the cursor
                // current at the same (quiescent) moment.
                let snap = store.fetch_weights().unwrap();
                let cursor = (ts.write_seq)();
                checkpoints.push((cursor, snap));
            }
        }
        let truth = store.fetch_weights().unwrap();
        for (cursor, mut snap) in checkpoints {
            let delta = store.fetch_weights_since(cursor).unwrap();
            delta.apply_to(&mut snap).unwrap();
            assert_eq!(snap, truth, "replay from seq {cursor} diverged");
        }
        // And a stale consumer that replays everything from zero.
        let fresh = store.fetch_weights_since(0).unwrap().to_snapshot().unwrap();
        assert_eq!(fresh, truth);
    });
}

#[test]
fn delta_replay_from_any_seq_reconstructs_snapshot() {
    for (label, mk) in backends("replay") {
        delta_replay_reconstructs_generic(label, mk.as_ref());
    }
}

fn delta_replay_concurrent_generic(label: &str, mk: &dyn Fn(usize, f64) -> TestStore) {
    // A reader chases the cursor while writers hammer overlapping ranges;
    // after the writers finish, one final delta must land the reader's
    // mirror exactly on the store's table (no lost or phantom writes).
    prop(&format!("delta-concurrent-{label}"), 6, |rng| {
        use std::sync::Arc;
        let n = 200 + rng.next_below(400) as usize;
        let ts = mk(n, 0.0);
        let store = Arc::clone(&ts.store);
        let d0 = store.fetch_weights_since(0).unwrap();
        let mut mirror = d0.to_snapshot().unwrap();
        let mut cursor = d0.seq;
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let store = Arc::clone(&store);
            let seed = rng.next_u64();
            handles.push(std::thread::spawn(move || {
                let mut rng = Pcg64::seeded(seed);
                for round in 0..120u64 {
                    let start = rng.next_below(n as u64) as usize;
                    let len = 1 + rng.next_below(30).min((n - start - 1) as u64) as usize;
                    let vals: Vec<f32> = (0..len)
                        .map(|i| (t * 1_000_000 + round * 100 + i as u64) as f32)
                        .collect();
                    store.push_weights(start, &vals, round + 1).unwrap();
                }
            }));
        }
        for _ in 0..40 {
            let d = store.fetch_weights_since(cursor).unwrap();
            d.apply_to(&mut mirror).unwrap();
            cursor = d.seq;
        }
        for h in handles {
            h.join().unwrap();
        }
        let d = store.fetch_weights_since(cursor).unwrap();
        d.apply_to(&mut mirror).unwrap();
        assert_eq!(mirror, store.fetch_weights().unwrap());
    });
}

#[test]
fn delta_replay_survives_concurrent_pushers() {
    for (label, mk) in backends("concurrent") {
        delta_replay_concurrent_generic(label, mk.as_ref());
    }
}

#[test]
fn faulty_store_replay_converges_for_any_schedule() {
    // dslab-style chaos property: wrap a MemStore in a FaultyStore with an
    // arbitrary seeded fault schedule (transient errors, withheld deltas,
    // partial/reordered delivery, latency).  A cursor-replaying consumer
    // that simply tolerates errors must, once all deltas eventually
    // deliver, reconstruct the exact oracle table — faults delay and
    // reorder information but never lose or corrupt it.
    use issgd::weightstore::faulty::{FaultSpec, FaultyStore};
    use std::sync::Arc;
    prop("faulty-replay", 12, |rng| {
        let n = 20 + rng.next_below(200) as usize;
        let spec = FaultSpec::quiet(rng.next_u64())
            .with_errors(rng.next_f64() * 0.5)
            .with_withholding(rng.next_f64() * 0.5)
            .with_partial_deltas(rng.next_f64() * 0.5)
            .with_latency(1 + rng.next_below(20), rng.next_below(50));
        let inner = Arc::new(MemStore::new(n, 1.0));
        let store = FaultyStore::new(inner.clone() as Arc<dyn WeightStore>, spec);
        let mut mirror = WeightSnapshot::default();
        let mut cursor = 0u64;
        let mut fetch_errors = 0u64;
        for round in 0..60u64 {
            // Writer: random runs straight into the inner store (writes
            // themselves are not under test here — delivery is).
            let start = rng.next_below(n as u64) as usize;
            let len = 1 + rng.next_below((n - start).min(16) as u64) as usize;
            let vals: Vec<f32> = (0..len).map(|_| rng.next_f32().abs() + 0.01).collect();
            inner.push_weights(start, &vals, round + 1).unwrap();
            // Consumer: chase the cursor through the fault schedule.
            match store.fetch_weights_since(cursor) {
                Ok(d) => {
                    d.apply_to(&mut mirror).unwrap();
                    cursor = d.seq;
                }
                Err(_) => fetch_errors += 1,
            }
        }
        // Outage over: drain and compare against the ground truth.
        store.set_enabled(false);
        let d = store.fetch_weights_since(cursor).unwrap();
        d.apply_to(&mut mirror).unwrap();
        cursor = d.seq;
        assert_eq!(mirror, inner.fetch_weights().unwrap(), "replay diverged");
        // Converged: the cursor reached the store's write sequence and the
        // next fetch is empty.
        assert_eq!(cursor, inner.write_seq());
        let idle = store.fetch_weights_since(cursor).unwrap();
        assert!(idle.is_empty());
        // Sanity: the schedule (usually) actually did something; at least
        // the op counter must have ticked deterministically.
        let fs = store.fault_stats();
        assert!(fs.ops > 0);
        assert_eq!(fs.injected_errors, fetch_errors);
    });
}

fn multi_consumer_generic(label: &str, mk: &dyn Fn(usize, f64) -> TestStore) {
    // ROADMAP item: several masters/consumers sharing one store.  Cursors
    // are client-side state, so any number of consumers may interleave
    // `fetch_weights_since` calls at different cadences — each must
    // independently converge on the same table.
    use issgd::config::StalenessUnit;
    use issgd::coordinator::ProposalMaintainer;
    prop(&format!("multi-consumer-{label}"), 8, |rng| {
        let n = 40 + rng.next_below(160) as usize;
        let ts = mk(n, 1.0);
        let store = &ts.store;
        // Three consumers: a plain snapshot mirror, a master-mode
        // maintainer, and a peer-mode (coverage-prior) maintainer.
        let mut mirror = WeightSnapshot::default();
        let mut mirror_cursor = 0u64;
        let mut pa = ProposalMaintainer::new(n, 0.5, None, StalenessUnit::Versions);
        let mut pb =
            ProposalMaintainer::with_coverage_prior(n, 0.5, None, StalenessUnit::Versions);
        for round in 0..80u64 {
            let start = rng.next_below(n as u64) as usize;
            let len = 1 + rng.next_below((n - start).min(12) as u64) as usize;
            let vals: Vec<f32> = (0..len).map(|_| rng.next_f32().abs()).collect();
            store.push_weights(start, &vals, round + 1).unwrap();
            if round % 2 == 0 {
                let d = store.fetch_weights_since(mirror_cursor).unwrap();
                d.apply_to(&mut mirror).unwrap();
                mirror_cursor = d.seq;
            }
            if round % 3 == 0 {
                let d = store.fetch_weights_since(pa.cursor()).unwrap();
                pa.absorb(&d, 0).unwrap();
            }
            if round % 5 == 0 {
                let d = store.fetch_weights_since(pb.cursor()).unwrap();
                pb.absorb(&d, 0).unwrap();
            }
        }
        // Drain each cursor; every consumer lands on the same table.
        let truth = store.fetch_weights().unwrap();
        let d = store.fetch_weights_since(mirror_cursor).unwrap();
        d.apply_to(&mut mirror).unwrap();
        let d = store.fetch_weights_since(pa.cursor()).unwrap();
        pa.absorb(&d, 0).unwrap();
        let d = store.fetch_weights_since(pb.cursor()).unwrap();
        pb.absorb(&d, 0).unwrap();
        assert_eq!(mirror, truth);
        assert_eq!(*pa.raw(), truth);
        assert_eq!(*pb.raw(), truth);
        // The master-mode sampler must equal its from-scratch rebuild.
        for i in 0..n {
            let expect = truth.weights[i] + 0.5;
            assert!(
                (pa.sampler().weight(i) - expect).abs() < 1e-9,
                "consumer A weight {i}: {} vs {expect}",
                pa.sampler().weight(i)
            );
        }
    });
}

#[test]
fn multi_consumer_cursors_reconstruct_identically() {
    for (label, mk) in backends("multi") {
        multi_consumer_generic(label, mk.as_ref());
    }
}

fn strategy_conformance_generic(label: &str, mk: &dyn Fn(usize, f64) -> TestStore) {
    // Backend × strategy contract: for EVERY registered proposal strategy,
    // a maintainer chasing any backend's cursor must (a) keep all sampling
    // masses finite, positive and equal to the pure `mass(raw, c)` law
    // (incremental absorbs may never drift off the rebuild), and (b) emit
    // importance coefficients exactly when — and only when — the strategy
    // declares itself unbiased (coef = mean mass / drawn mass; biased
    // strategies pin 1.0).
    use issgd::config::StalenessUnit;
    use issgd::coordinator::ProposalMaintainer;
    use issgd::sampler::strategy::StrategyKind;
    prop(&format!("strategy-conformance-{label}"), 4, |rng| {
        let n = 20 + rng.next_below(120) as usize;
        let c = 0.25;
        for &kind in StrategyKind::all() {
            let strat = kind.strategy();
            let ts = mk(n, 1.0);
            let store = &ts.store;
            let mut master =
                ProposalMaintainer::new_with_strategy(n, c, None, StalenessUnit::Versions, strat);
            let mut prior = ProposalMaintainer::with_coverage_prior_strategy(
                n,
                c,
                None,
                StalenessUnit::Versions,
                strat,
            );
            for round in 0..40u64 {
                let start = rng.next_below(n as u64) as usize;
                let len = 1 + rng.next_below((n - start).min(12) as u64) as usize;
                let vals: Vec<f32> = (0..len).map(|_| rng.next_f32().abs() + 0.01).collect();
                store.push_weights(start, &vals, round + 1).unwrap();
                if round % 2 == 0 {
                    let d = store.fetch_weights_since(master.cursor()).unwrap();
                    master.absorb(&d, 0).unwrap();
                }
                if round % 3 == 0 {
                    let d = store.fetch_weights_since(prior.cursor()).unwrap();
                    prior.absorb(&d, 0).unwrap();
                }
            }
            // Drain both cursors so each saw every write.
            let d = store.fetch_weights_since(master.cursor()).unwrap();
            master.absorb(&d, 0).unwrap();
            let d = store.fetch_weights_since(prior.cursor()).unwrap();
            prior.absorb(&d, 0).unwrap();
            // (a) masses obey the pure law; positive scores + c > 0 must
            // leave every example samplable under every strategy.
            for i in 0..n {
                let w = master.sampler().weight(i);
                let expect = strat.mass(master.raw().weights[i], c);
                assert!(w.is_finite() && w > 0.0, "{}: mass {w} at {i}", kind.name());
                assert!(
                    (w - expect).abs() <= 1e-9 * expect.abs().max(1.0),
                    "{}: incremental mass {w} != mass(raw) {expect} at {i}",
                    kind.name()
                );
                let pw = prior.effective_weight(i);
                assert!(
                    pw.is_finite() && pw > 0.0,
                    "{}: prior-mode mass {pw} at {i}",
                    kind.name()
                );
            }
            // (b) the coefficient contract follows the declaration.
            let mut r = Pcg64::seeded(rng.next_u64());
            let m = 8.min(n);
            let (idx, coefs, mean_w) = master.draw_minibatch(&mut r, m);
            assert_eq!(idx.len(), m);
            assert_eq!(coefs.len(), m);
            for (k, &i) in idx.iter().enumerate() {
                let want = if strat.unbiased() {
                    (mean_w / master.effective_weight(i)) as f32
                } else {
                    1.0
                };
                assert!(
                    (coefs[k] - want).abs() <= 1e-6 * want.abs().max(1.0),
                    "{}: coef {} vs {want} (unbiased={})",
                    kind.name(),
                    coefs[k],
                    strat.unbiased()
                );
            }
        }
    });
}

#[test]
fn proposal_strategies_conform_across_backends() {
    for (label, mk) in backends("strategy") {
        strategy_conformance_generic(label, mk.as_ref());
    }
}

// ---------------------------------------------------------------------------
// params-delta conformance
// ---------------------------------------------------------------------------

fn rand_bytes(rng: &mut Pcg64, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.next_u64() as u8).collect()
}

/// Apply a params delta onto a named-layer mirror (layout fixed by the
/// first full delta).  Returns the new version cursor.
fn apply_params_delta(
    mirror: &mut Vec<Vec<u8>>,
    names: &[String],
    d: &issgd::weightstore::ParamsDelta,
) -> u64 {
    if d.full {
        assert_eq!(
            d.layers.iter().map(|l| l.name.as_str()).collect::<Vec<_>>(),
            names.iter().map(String::as_str).collect::<Vec<_>>(),
            "full delta layout disagrees"
        );
        *mirror = d.layers.iter().map(|l| l.bytes.clone()).collect();
    } else {
        assert!(!mirror.is_empty(), "partial delta before any full sync");
        for l in &d.layers {
            let i = names.iter().position(|n| *n == l.name).expect("unknown layer");
            mirror[i] = l.bytes.clone();
        }
    }
    d.version
}

fn params_delta_roundtrip_generic(label: &str, mk: &dyn Fn(usize, f64) -> TestStore) {
    // For any interleaving of partial layer pushes, full republishes and
    // consumer fetch cadences: replaying params deltas from any version
    // cursor reconstructs exactly the store's blob — and the fallback
    // tiers (cursor 0, below the floor, from the future) behave as
    // documented.
    prop(&format!("params-delta-{label}"), 8, |rng| {
        let ts = mk(4, 1.0);
        let store = &ts.store;
        let k = 2 + rng.next_below(5) as usize;
        let names: Vec<String> = (0..k).map(|i| format!("L{i}")).collect();
        let sizes: Vec<usize> = (0..k).map(|_| 4 * (1 + rng.next_below(8) as usize)).collect();
        let full_set = |rng: &mut Pcg64, names: &[String], sizes: &[usize]| {
            names
                .iter()
                .zip(sizes)
                .map(|(n, &s)| (n.clone(), rand_bytes(rng, s)))
                .collect::<Vec<_>>()
        };
        let mut version = 1u64;
        store.push_params_layers(version, true, &full_set(rng, &names, &sizes)).unwrap();
        // Two consumers at different cadences; a third never syncs until
        // the end (bootstrap-from-zero must still land on the truth).
        let mut fast: Vec<Vec<u8>> = Vec::new();
        let mut fast_v = 0u64;
        let mut slow: Vec<Vec<u8>> = Vec::new();
        let mut slow_v = 0u64;
        let mut last_full_version = version;
        for round in 0..40u64 {
            if rng.next_below(8) == 0 {
                // Full republish: raises the params floor.
                version += 1;
                store.push_params_layers(version, true, &full_set(rng, &names, &sizes)).unwrap();
                last_full_version = version;
            } else {
                let i = rng.next_below(k as u64) as usize;
                version += 1;
                store
                    .push_params_layers(
                        version,
                        false,
                        &[(names[i].clone(), rand_bytes(rng, sizes[i]))],
                    )
                    .unwrap();
            }
            if round % 2 == 0 {
                if let Some(d) = store.fetch_params_since(fast_v).unwrap() {
                    fast_v = apply_params_delta(&mut fast, &names, &d);
                }
            }
            if round % 7 == 0 {
                if let Some(d) = store.fetch_params_since(slow_v).unwrap() {
                    slow_v = apply_params_delta(&mut slow, &names, &d);
                }
            }
        }
        // Drain every consumer; each lands on the store's blob exactly.
        let truth = store.fetch_params(0).unwrap().unwrap();
        for (mirror, v) in [(&mut fast, &mut fast_v), (&mut slow, &mut slow_v)] {
            if let Some(d) = store.fetch_params_since(*v).unwrap() {
                *v = apply_params_delta(mirror, &names, &d);
            }
            assert_eq!(*v, truth.0);
            assert_eq!(mirror.concat(), truth.1, "consumer mirror diverged");
            // Up to date ⇒ None.
            assert!(store.fetch_params_since(*v).unwrap().is_none());
        }
        let mut fresh: Vec<Vec<u8>> = Vec::new();
        let d = store.fetch_params_since(0).unwrap().unwrap();
        assert!(d.full, "bootstrap must be served the full layout");
        apply_params_delta(&mut fresh, &names, &d);
        assert_eq!(fresh.concat(), truth.1);
        // A future cursor (restarted store) degrades to full.
        let d = store.fetch_params_since(u64::MAX).unwrap().unwrap();
        assert!(d.full);
        assert_eq!(d.version, truth.0);
        // The floor contract: any cursor below the last full republish
        // (the layout-definition point) is served full — per-layer
        // history does not span a layout reset.
        if last_full_version > 1 {
            let d = store.fetch_params_since(last_full_version - 1).unwrap().unwrap();
            assert!(d.full, "below-floor cursor served an incremental delta");
        }
    });
}

#[test]
fn params_delta_replay_from_any_version_reconstructs_blob() {
    for (label, mk) in backends("params") {
        params_delta_roundtrip_generic(label, mk.as_ref());
    }
}

// ---------------------------------------------------------------------------
// durable crash recovery
// ---------------------------------------------------------------------------

#[test]
fn durable_recovery_from_truncated_log_is_a_prefix_replay() {
    // The crash-recovery contract: for ANY byte-level truncation of the
    // journal, reopen recovers exactly the table a reference MemStore
    // reaches by replaying some *prefix* of the op schedule — never a
    // corrupted or interleaved state.  (Pushes only, so each op is exactly
    // one journal frame and the recovered write sequence identifies the
    // surviving prefix length.)
    use issgd::weightstore::durable::{DurableOptions, DurableStore};
    prop("durable-truncate", 10, |rng| {
        let dir = TempDir::new("trunc");
        let n = 10 + rng.next_below(120) as usize;
        let opts = DurableOptions {
            segment_bytes: u64::MAX, // keep one live segment: tear anywhere in it
            compact_after_bytes: 0,
            ..DurableOptions::default()
        };
        let store = DurableStore::create(&dir.0, n, 1.0, opts.clone()).unwrap();
        let mut ops: Vec<(usize, Vec<f32>, u64)> = Vec::new();
        for round in 0..(5 + rng.next_below(40)) {
            let start = rng.next_below(n as u64) as usize;
            let len = 1 + rng.next_below((n - start).min(12) as u64) as usize;
            let vals: Vec<f32> = (0..len).map(|_| rng.next_f32().abs()).collect();
            store.push_weights(start, &vals, round + 1).unwrap();
            ops.push((start, vals, round + 1));
        }
        drop(store); // crash: every append was already flushed

        // Tear the live segment at an arbitrary byte offset.
        let segs = issgd::weightstore::segment::list_numbered(&dir.0, "seg-", ".log").unwrap();
        let (_, seg) = segs.last().unwrap();
        let len = std::fs::metadata(seg).unwrap().len();
        let cut = rng.next_below(len + 1);
        {
            let f = std::fs::OpenOptions::new().write(true).open(seg).unwrap();
            f.set_len(cut).unwrap();
        }

        let recovered = DurableStore::open(&dir.0, opts).unwrap();
        // Which prefix survived is readable off the write sequence (init
        // state is seq 1, each push claims the next).
        let m = (recovered.write_seq() - 1) as usize;
        assert!(m <= ops.len(), "recovered more ops than were written");
        let reference = MemStore::new(n, 1.0);
        for (start, vals, pv) in ops.iter().take(m) {
            reference.push_weights(*start, vals, *pv).unwrap();
        }
        let got = recovered.fetch_weights().unwrap();
        let want = reference.fetch_weights().unwrap();
        // Stamps are wall-clock on the reference, journal-exact on the
        // recovered store — compare everything else, then the delta
        // structure (same per-entry write sequences ⇒ same delivery sets).
        assert_eq!(got.weights, want.weights);
        assert_eq!(got.param_versions, want.param_versions);
        assert_eq!(recovered.write_seq(), reference.write_seq());
        let dr = recovered.fetch_weights_since(1).unwrap();
        let df = reference.fetch_weights_since(1).unwrap();
        assert_eq!(dr.indices, df.indices);
        assert_eq!(dr.weights, df.weights);
        assert_eq!(dr.param_versions, df.param_versions);
        // The recovered store keeps working past the tear.
        recovered.push_weights(0, &[42.0], 99).unwrap();
        assert_eq!(recovered.fetch_weights().unwrap().weights[0], 42.0);
        assert_eq!(recovered.write_seq(), reference.write_seq() + 1);
    });
}

#[test]
fn faulty_wrapped_durable_store_converges_and_persists() {
    // FaultyStore over DurableStore: the chaos decorator's replay contract
    // must hold over the persistent backend, the injected faults must
    // never wound the journal, and a crash after the outage must recover
    // the exact pre-crash table (stamps included — the journal is exact).
    use issgd::weightstore::durable::{DurableOptions, DurableStore};
    use issgd::weightstore::faulty::{FaultSpec, FaultyStore};
    use std::sync::Arc;
    prop("faulty-durable", 6, |rng| {
        let dir = TempDir::new("faulty");
        let n = 20 + rng.next_below(100) as usize;
        let opts = DurableOptions {
            segment_bytes: 1 << 13,
            compact_after_bytes: 1 << 14, // let the compactor race the chaos
            ..DurableOptions::default()
        };
        let spec = FaultSpec::quiet(rng.next_u64())
            .with_errors(rng.next_f64() * 0.4)
            .with_withholding(rng.next_f64() * 0.4)
            .with_partial_deltas(rng.next_f64() * 0.4)
            .with_latency(1 + rng.next_below(20), rng.next_below(50));
        let inner = Arc::new(DurableStore::create(&dir.0, n, 1.0, opts.clone()).unwrap());
        let store = FaultyStore::new(inner.clone() as Arc<dyn WeightStore>, spec);
        let mut mirror = WeightSnapshot::default();
        let mut cursor = 0u64;
        for round in 0..60u64 {
            // Writer: straight into the durable store (delivery, not
            // write acceptance, is under chaos here).
            let start = rng.next_below(n as u64) as usize;
            let len = 1 + rng.next_below((n - start).min(16) as u64) as usize;
            let vals: Vec<f32> = (0..len).map(|_| rng.next_f32().abs() + 0.01).collect();
            inner.push_weights(start, &vals, round + 1).unwrap();
            // Consumer: chase the cursor through the fault schedule,
            // pinning compaction at the last absorbed position (saved via
            // the reliable handle so the pin itself is deterministic).
            if let Ok(d) = store.fetch_weights_since(cursor) {
                d.apply_to(&mut mirror).unwrap();
                cursor = d.seq;
                inner.save_cursor("chaos", cursor).unwrap();
            }
        }
        // Outage over: drain and compare against the ground truth.
        store.set_enabled(false);
        let d = store.fetch_weights_since(cursor).unwrap();
        d.apply_to(&mut mirror).unwrap();
        cursor = d.seq;
        assert_eq!(mirror, inner.fetch_weights().unwrap(), "replay diverged");
        assert_eq!(cursor, inner.write_seq());
        inner.save_cursor("chaos", cursor).unwrap();

        // Crash + reopen: the journal reproduces the table bit-exactly and
        // the pinned consumer resumes incrementally.
        let want = inner.fetch_weights().unwrap();
        let want_seq = inner.write_seq();
        drop(store);
        drop(inner);
        let back = DurableStore::open(&dir.0, opts).unwrap();
        assert_eq!(back.fetch_weights().unwrap(), want);
        assert_eq!(back.write_seq(), want_seq);
        assert_eq!(back.load_cursor("chaos").unwrap(), Some(cursor));
        let d = back.fetch_weights_since(cursor).unwrap();
        assert!(!d.full, "pinned consumer demoted to full resync after crash");
        assert!(d.is_empty());
    });
}

#[test]
fn faulty_params_deltas_converge_and_survive_reopen() {
    // Params join the chaos surface: an arbitrary schedule of withheld
    // incremental params deltas (plus transient errors) may only delay
    // layer propagation, never lose or corrupt it — and a crash + reopen
    // of the durable backend reproduces the layers, their per-layer
    // versions, and the consumer's cursor position bit-exactly.
    use issgd::weightstore::durable::DurableStore;
    use issgd::weightstore::faulty::{FaultSpec, FaultyStore};
    use std::sync::Arc;
    prop("faulty-params-durable", 6, |rng| {
        let dir = TempDir::new("fparams");
        let opts = durable_opts();
        let k = 2 + rng.next_below(4) as usize;
        let names: Vec<String> = (0..k).map(|i| format!("L{i}")).collect();
        let sizes: Vec<usize> = (0..k).map(|_| 4 * (1 + rng.next_below(6) as usize)).collect();
        let inner = Arc::new(DurableStore::create(&dir.0, 4, 1.0, opts.clone()).unwrap());
        let store = FaultyStore::new(
            inner.clone() as Arc<dyn WeightStore>,
            FaultSpec::quiet(rng.next_u64())
                .with_errors(rng.next_f64() * 0.4)
                .with_withholding(0.3 + rng.next_f64() * 0.5),
        );
        let mut version = 1u64;
        let full: Vec<(String, Vec<u8>)> = names
            .iter()
            .zip(&sizes)
            .map(|(n, &s)| (n.clone(), rand_bytes(rng, s)))
            .collect();
        inner.push_params_layers(version, true, &full).unwrap();
        let mut mine: Vec<Vec<u8>> = Vec::new();
        let mut mine_v = 0u64;
        let mut withheld_or_failed = 0u64;
        for _ in 0..50u64 {
            // Writer: partial layer updates straight into the durable
            // store (delivery, not acceptance, is under chaos).
            let i = rng.next_below(k as u64) as usize;
            version += 1;
            inner
                .push_params_layers(version, false, &[(names[i].clone(), rand_bytes(rng, sizes[i]))])
                .unwrap();
            // Consumer: chase the version cursor through the schedule.
            match store.fetch_params_since(mine_v) {
                Ok(Some(d)) => mine_v = apply_params_delta(&mut mine, &names, &d),
                Ok(None) => withheld_or_failed += 1, // withheld or idle
                Err(_) => withheld_or_failed += 1,
            }
        }
        // Outage over: one clean fetch lands the mirror on the truth.
        store.set_enabled(false);
        if let Some(d) = store.fetch_params_since(mine_v).unwrap() {
            mine_v = apply_params_delta(&mut mine, &names, &d);
        }
        let truth = inner.fetch_params(0).unwrap().unwrap();
        assert_eq!(mine_v, truth.0);
        assert_eq!(mine.concat(), truth.1, "params replay diverged");
        let _ = withheld_or_failed; // schedule-dependent; convergence is the invariant

        // Crash + reopen: blob, per-layer versions and the up-to-date
        // consumer's position all survive.
        drop(store);
        drop(inner);
        let back = DurableStore::open(&dir.0, opts).unwrap();
        assert_eq!(back.fetch_params(0).unwrap().unwrap(), truth);
        assert!(back.fetch_params_since(mine_v).unwrap().is_none());
        // A mid-stream cursor is owed exactly the layers written since.
        if version > 2 {
            let mid = 1 + rng.next_below(version - 1);
            let before = {
                // Reference: rebuild the owed set from the reopened store
                // itself via a full fetch at cursor 0 (absolute layers),
                // then check the incremental answer is a subset carrying
                // only layers newer than `mid`.
                back.fetch_params_since(mid).unwrap()
            };
            if let Some(d) = before {
                for l in &d.layers {
                    assert!(d.full || l.version > mid, "layer {:?} not newer than {mid}", l.name);
                }
            }
        }
    });
}

#[test]
fn protocol_roundtrips_random_deltas() {
    prop("delta-protocol-roundtrip", 40, |rng| {
        let k = rng.next_below(60) as usize;
        // A full delta must carry exactly n entries (decoder invariant).
        let full = rng.next_below(2) == 1;
        let n = if full { k as u64 } else { rng.next_u64() % 1_000_000 };
        let delta = WeightDelta {
            seq: rng.next_u64(),
            n,
            full,
            indices: (0..k as u64).map(|_| rng.next_u64() % 1_000_000).collect(),
            weights: (0..k).map(|_| rng.next_f64() * 100.0).collect(),
            stamps: (0..k).map(|_| rng.next_u64()).collect(),
            param_versions: (0..k).map(|_| rng.next_u64() % 512).collect(),
        };
        let resp = Response::WeightsDelta(delta);
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);

        let req = Request::FetchWeightsSince { seq: rng.next_u64() };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);

        // Truncations must error, never panic.
        let enc = resp.encode();
        let cut = rng.next_below(enc.len() as u64) as usize;
        assert!(Response::decode(&enc[..cut]).is_err());
    });
}

#[test]
fn protocol_rejects_random_mutations() {
    // Flipping the opcode or truncating must never decode into a *different
    // valid* message silently mis-sized fields — it must error or decode to
    // the same payload type with different contents, never panic.
    prop("protocol-fuzz", 60, |rng| {
        let req = Request::PushWeights {
            start: 5,
            param_version: 9,
            weights: vec![1.0, 2.0, 3.0],
        };
        let mut enc = req.encode();
        let cut = 1 + rng.next_below(enc.len() as u64 - 1) as usize;
        let _ = Request::decode(&enc[..cut]); // must not panic
        let idx = rng.next_below(enc.len() as u64) as usize;
        enc[idx] ^= 1 << rng.next_below(8);
        let _ = Request::decode(&enc); // must not panic
    });
}

#[test]
fn json_roundtrips_random_values() {
    fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.next_u64() % 1_000_000) as f64 / 8.0),
            3 => {
                let len = rng.next_below(12) as usize;
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.next_below(96) as u8 + 32;
                        c as char
                    })
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.next_below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut map = std::collections::BTreeMap::new();
                for i in 0..rng.next_below(5) {
                    map.insert(format!("k{i}"), random_json(rng, depth - 1));
                }
                Json::Obj(map)
            }
        }
    }
    prop("json-roundtrip", 80, |rng| {
        let v = random_json(rng, 3);
        let compact = Json::parse(&v.to_string()).unwrap();
        assert_eq!(compact, v);
        let pretty = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(pretty, v);
    });
}

#[test]
fn synth_shards_compose_to_full_dataset() {
    use issgd::data::{shards, Dataset, SynthDataset, SynthSpec};
    prop("shard-compose", 6, |rng| {
        let n = 50 + rng.next_below(200) as usize;
        let k = 1 + rng.next_below(8) as usize;
        let seed = rng.next_u64();
        let full = SynthDataset::generate(seed, SynthSpec::tiny(n));
        for shard in shards(n, k) {
            let part =
                SynthDataset::generate_range(seed, SynthSpec::tiny(n), shard.start, shard.end);
            for (i, g) in shard.indices().enumerate() {
                assert_eq!(part.features(i), full.features(g));
                assert_eq!(part.label(i), full.label(g));
            }
        }
    });
}

#[test]
fn telemetry_registry_loses_no_increments_under_threads() {
    use issgd::telemetry;
    // The registry is process-global and this binary's tests run in
    // parallel, so: names unique to this test, delta-based assertions.
    let c = telemetry::counter("test.prop.conc_counter");
    let h = telemetry::histogram("test.prop.conc_hist");
    prop("telemetry-concurrency", 4, |rng| {
        let threads = 2 + rng.next_below(6) as usize;
        let per_thread = 100 + rng.next_below(400);
        let (c_before, h_before) = {
            let snap = telemetry::snapshot();
            (
                snap.counters["test.prop.conc_counter"],
                snap.histograms["test.prop.conc_hist"].clone(),
            )
        };
        let stop = std::sync::atomic::AtomicBool::new(false);
        std::thread::scope(|s| {
            // Monitor: successive snapshots must be monotone per metric
            // while the writers hammer away.
            s.spawn(|| {
                let mut last_c = 0u64;
                let mut last_h = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let snap = telemetry::snapshot();
                    let now_c = snap.counters["test.prop.conc_counter"];
                    let now_h = snap.histograms["test.prop.conc_hist"].count;
                    assert!(now_c >= last_c, "counter went backwards: {last_c} -> {now_c}");
                    assert!(now_h >= last_h, "hist count went backwards: {last_h} -> {now_h}");
                    last_c = now_c;
                    last_h = now_h;
                    std::thread::sleep(std::time::Duration::from_micros(100));
                }
            });
            let mut workers = Vec::new();
            for t in 0..threads as u64 {
                workers.push(s.spawn(move || {
                    for _ in 0..per_thread {
                        c.inc();
                        // Fixed per-thread value so the sum delta below is
                        // exactly predictable.
                        h.record(t + 1);
                    }
                }));
            }
            for w in workers {
                w.join().unwrap();
            }
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
        });
        let snap = telemetry::snapshot();
        let c_after = snap.counters["test.prop.conc_counter"];
        let h_after = &snap.histograms["test.prop.conc_hist"];
        let expected = threads as u64 * per_thread;
        assert_eq!(c_after - c_before, expected, "lost counter increments");
        assert_eq!(h_after.count - h_before.count, expected, "lost histogram records");
        let expected_sum: u64 = (1..=threads as u64).map(|t| t * per_thread).sum();
        assert_eq!(h_after.sum - h_before.sum, expected_sum, "lost histogram sum");
    });
}
