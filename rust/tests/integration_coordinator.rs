//! Integration: the full master/worker/store topology over the tiny
//! artifacts — deterministic sim mode, exact vs relaxed sync, ISSGD vs
//! SGD, and the §4.2 variance ordering on a real training trajectory.

use issgd::config::{RunConfig, SyncMode, TrainerKind};
use issgd::coordinator::{run_sim_with_engine, Master, WorkerState};
use issgd::data::shards;
use issgd::runtime::{artifacts_dir, Engine};
use issgd::weightstore::{MemStore, WeightStore};
use std::sync::Arc;

fn engine() -> Engine {
    let dir = artifacts_dir("tiny");
    assert!(
        dir.join("manifest.json").exists(),
        "run `make artifacts` first"
    );
    Engine::load(&dir).expect("engine")
}

fn base_cfg() -> RunConfig {
    RunConfig::tiny_test()
}

fn make_workers(
    master: &Master,
    engine: &Engine,
    store_dyn: Arc<dyn WeightStore>,
    n: usize,
) -> Vec<WorkerState> {
    shards(master.train_idx.len(), n)
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            WorkerState::new(
                id,
                shard,
                engine.manifest(),
                Arc::clone(&master.data),
                Arc::new(master.train_idx.clone()),
                store_dyn.clone(),
            )
        })
        .collect()
}

#[test]
fn sim_run_is_deterministic() {
    let e = engine();
    let cfg = base_cfg();
    let a = run_sim_with_engine(&cfg, &e).unwrap();
    let b = run_sim_with_engine(&cfg, &e).unwrap();
    let la: Vec<f64> = a.rec.get("train_loss").iter().map(|s| s.value).collect();
    let lb: Vec<f64> = b.rec.get("train_loss").iter().map(|s| s.value).collect();
    assert_eq!(la, lb, "same seed must give identical loss traces");
    assert_eq!(a.final_err, b.final_err);
    assert_eq!(a.scored, b.scored);
}

#[test]
fn different_seeds_diverge() {
    let e = engine();
    let mut cfg = base_cfg();
    let a = run_sim_with_engine(&cfg, &e).unwrap();
    cfg.seed = 99;
    let b = run_sim_with_engine(&cfg, &e).unwrap();
    let la: Vec<f64> = a.rec.get("train_loss").iter().map(|s| s.value).collect();
    let lb: Vec<f64> = b.rec.get("train_loss").iter().map(|s| s.value).collect();
    assert_ne!(la, lb);
}

#[test]
fn issgd_trains_to_low_loss() {
    let e = engine();
    let mut cfg = base_cfg();
    cfg.steps = 60;
    let out = run_sim_with_engine(&cfg, &e).unwrap();
    let losses = out.rec.get("train_loss");
    let first = losses.first().unwrap().value;
    let last = losses.last().unwrap().value;
    assert!(last < first * 0.3, "ISSGD failed to train: {first} -> {last}");
    assert!(out.final_err.0 < 0.2, "train error too high: {:?}", out.final_err);
    assert!(out.scored > 0, "workers never scored");
}

#[test]
fn sgd_baseline_trains_too() {
    let e = engine();
    let mut cfg = base_cfg();
    cfg.trainer = TrainerKind::UniformSgd;
    cfg.steps = 60;
    let out = run_sim_with_engine(&cfg, &e).unwrap();
    let losses = out.rec.get("train_loss");
    assert!(losses.last().unwrap().value < losses.first().unwrap().value * 0.5);
}

#[test]
fn exact_mode_keeps_weights_fresh() {
    let e = engine();
    let mut cfg = base_cfg();
    cfg.sync = SyncMode::Exact;
    cfg.param_push_every = 5;
    cfg.steps = 20;
    let store: Arc<MemStore> = Arc::new(MemStore::new(Master::store_size(&cfg), cfg.init_weight));
    let store_dyn: Arc<dyn WeightStore> = store.clone();
    let mut master = Master::new(cfg.clone(), &e, store_dyn.clone()).unwrap();
    let mut workers = make_workers(&master, &e, store_dyn, cfg.n_workers);

    for _ in 0..cfg.steps {
        let pushed = master.maybe_push_params().unwrap();
        if pushed {
            for w in &mut workers {
                w.sweep_full(&e).unwrap();
            }
            // Barrier invariant: every weight carries the current version.
            let snap = store.fetch_weights().unwrap();
            for &v in &snap.param_versions {
                assert_eq!(v, master.version, "stale weight after exact barrier");
            }
        }
        master.train_one_step(&e).unwrap();
    }
}

#[test]
fn relaxed_mode_has_bounded_version_lag() {
    let e = engine();
    let mut cfg = base_cfg();
    cfg.steps = 40;
    cfg.param_push_every = 5;
    cfg.worker_batches_per_step = 2;
    let out = run_sim_with_engine(&cfg, &e).unwrap();
    let lags = out.rec.get("sampled_version_lag");
    assert!(!lags.is_empty(), "no staleness diagnostics recorded");
    // Weights can lag but must stay bounded: workers sweep a ~146-example
    // shard in ~10 batches of 16 and refresh params every master step, so
    // the lag stays well under the total number of pushes (8).
    for s in lags {
        assert!(s.value <= 6.0, "version lag {} at step {}", s.value, s.step);
    }
}

#[test]
fn variance_ordering_on_real_trajectory() {
    // §4.2: Tr(Σ(q_IDEAL)) ≤ Tr(Σ(q_STALE)) ≤ Tr(Σ(q_UNIF)) when weights
    // are reasonable.  Check at several points of a real ISSGD run (raw
    // second-moment terms: the shared -||g_true||² cannot flip the order).
    let e = engine();
    let mut cfg = base_cfg();
    cfg.steps = 30;
    cfg.smoothing = 0.5;
    let store: Arc<MemStore> = Arc::new(MemStore::new(Master::store_size(&cfg), cfg.init_weight));
    let store_dyn: Arc<dyn WeightStore> = store.clone();
    let mut master = Master::new(cfg.clone(), &e, store_dyn).unwrap();
    let mut workers = make_workers(&master, &e, store.clone(), cfg.n_workers);

    let mut checked = 0;
    for step in 0..cfg.steps {
        master.maybe_push_params().unwrap();
        for w in &mut workers {
            w.advance(&e, 2).unwrap();
        }
        master.train_one_step(&e).unwrap();
        if step % 10 == 5 {
            let (actual, _alt) = master.monitor_variance(&e).unwrap();
            assert!(
                actual.ideal_raw <= actual.stale_raw * 1.001 + 1e-9,
                "ideal {} > stale {} at step {step}",
                actual.ideal_raw,
                actual.stale_raw
            );
            assert!(
                actual.stale_raw <= actual.unif_raw * 1.05 + 1e-9,
                "stale {} > unif {} at step {step}",
                actual.stale_raw,
                actual.unif_raw
            );
            checked += 1;
        }
    }
    assert!(checked >= 2);
}

#[test]
fn staleness_filter_reduces_kept_fraction() {
    let e = engine();
    let mut cfg = base_cfg();
    cfg.steps = 30;
    cfg.param_push_every = 2;
    cfg.staleness_threshold = Some(0); // only weights at the current version
    let out = run_sim_with_engine(&cfg, &e).unwrap();
    let kept = out.rec.get("kept_frac");
    assert!(!kept.is_empty());
    let tail = &kept[kept.len() / 2..];
    let mean: f64 = tail.iter().map(|s| s.value).sum::<f64>() / tail.len() as f64;
    assert!(
        mean < 0.9,
        "threshold 0 should filter a meaningful fraction, kept {mean}"
    );
    // And training must still work on the kept subset.
    let losses = out.rec.get("train_loss");
    assert!(losses.last().unwrap().value < losses.first().unwrap().value);
}

#[test]
fn smoothing_infinity_approximates_uniform() {
    // §B.3: huge smoothing constant ⇒ coefficients ≈ 1 ⇒ ISSGD ≈ SGD.
    // Verify via the recorded kept fraction + final metrics staying sane,
    // and that coefs drive identical-looking convergence.
    let e = engine();
    let mut cfg = base_cfg();
    cfg.smoothing = 1e9;
    cfg.steps = 40;
    let out = run_sim_with_engine(&cfg, &e).unwrap();
    let losses = out.rec.get("train_loss");
    assert!(losses.last().unwrap().value < losses.first().unwrap().value * 0.5);
}

#[test]
fn live_threaded_cluster_round_trips() {
    use issgd::coordinator::{run_live, LiveOptions};
    let mut cfg = base_cfg();
    cfg.steps = 15;
    let out = run_live(
        &cfg,
        &LiveOptions {
            store: None,
            store_addr: None,
            worker_throttle: Some(std::time::Duration::from_millis(1)),
            wait_for_first_scores: true,
        },
    )
    .unwrap();
    assert_eq!(out.rec.get("train_loss").len(), 15);
    assert!(out.scored > 0, "live workers never scored");
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    let e = engine();
    let mut cfg = base_cfg();
    cfg.steps = 10;
    let store: Arc<MemStore> = Arc::new(MemStore::new(Master::store_size(&cfg), cfg.init_weight));
    let store_dyn: Arc<dyn WeightStore> = store.clone();
    let mut master = Master::new(cfg.clone(), &e, store_dyn.clone()).unwrap();
    for _ in 0..5 {
        master.maybe_push_params().unwrap();
        master.train_one_step(&e).unwrap();
    }
    let path = std::env::temp_dir().join(format!("issgd-it-ckpt-{}", std::process::id()));
    master.save_checkpoint(&path).unwrap();

    // A fresh session restored from the checkpoint must agree exactly.
    let store2: Arc<MemStore> = Arc::new(MemStore::new(Master::store_size(&cfg), cfg.init_weight));
    let mut resumed = Master::new(cfg.clone(), &e, store2).unwrap();
    resumed.restore_checkpoint(&e, &path).unwrap();
    assert_eq!(resumed.step, master.step);
    assert_eq!(resumed.version, master.version);
    assert_eq!(resumed.params, master.params);

    // Wrong seed must be rejected (dataset would silently differ).
    let mut other_cfg = cfg.clone();
    other_cfg.seed = 777;
    let store3: Arc<MemStore> =
        Arc::new(MemStore::new(Master::store_size(&other_cfg), other_cfg.init_weight));
    let mut wrong = Master::new(other_cfg, &e, store3).unwrap();
    assert!(wrong.restore_checkpoint(&e, &path).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn asgd_peer_modes_train() {
    use issgd::coordinator::peer::run_asgd_sim;
    let e = engine();
    let mut cfg = base_cfg();
    cfg.steps = 60;
    cfg.n_workers = 3;
    cfg.param_push_every = 4;
    for trainer in [TrainerKind::UniformSgd, TrainerKind::Issgd] {
        cfg.trainer = trainer;
        let out = run_asgd_sim(&cfg, &e).unwrap();
        assert_eq!(out.total_peer_steps, 60);
        let losses = out.rec.get("train_loss");
        assert!(
            losses.last().unwrap().value < losses.first().unwrap().value * 0.6,
            "{trainer:?} peers failed to train: {} -> {}",
            losses.first().unwrap().value,
            losses.last().unwrap().value
        );
        assert!(out.store_stats.grad_applies == 60);
        if trainer == TrainerKind::Issgd {
            // §6: weights are pushed alongside gradients.
            assert!(out.store_stats.weight_pushes > 0);
        }
    }
}

#[test]
fn asgd_eval_gate_fires_on_unaligned_rounds() {
    // Rounds advance by n_workers steps, so with n_workers = 3 and
    // eval_every = 10 the old `total % eval_every == 0` gate never fired.
    // The boundary-crossing gate must evaluate once per crossed boundary.
    use issgd::coordinator::peer::run_asgd_sim;
    let e = engine();
    let mut cfg = base_cfg();
    cfg.trainer = TrainerKind::UniformSgd;
    cfg.steps = 30;
    cfg.n_workers = 3;
    cfg.eval_every = 10;
    let out = run_asgd_sim(&cfg, &e).unwrap();
    let steps: Vec<u64> = out.rec.get("eval_train_err").iter().map(|s| s.step).collect();
    assert_eq!(
        steps,
        vec![12, 21, 30],
        "evaluations must fire at the first round end past each boundary"
    );
}

#[test]
fn peer_weight_pushes_are_coalesced() {
    // Every sampled example's weight still lands, but sorted contiguous
    // runs share one push call / write-sequence bump.
    use issgd::coordinator::peer::run_asgd_sim;
    let e = engine();
    let mut cfg = base_cfg();
    cfg.trainer = TrainerKind::Issgd;
    cfg.steps = 40;
    cfg.n_workers = 2;
    cfg.param_push_every = 4;
    let out = run_asgd_sim(&cfg, &e).unwrap();
    let st = out.store_stats;
    assert!(st.push_calls_saved > 0, "no runs coalesced across 40 IS steps");
    // Conservation: calls made + calls saved == entries written.
    assert_eq!(st.weight_pushes + st.push_calls_saved, st.weights_written);
}

#[test]
fn peer_proposal_matches_scratch_rebuild() {
    // The shared delta-synced maintainer must hold exactly the proposal
    // the old peer code rebuilt from a full snapshot every step — and
    // PeerState::step must never fetch a snapshot to get there.
    use issgd::config::StalenessUnit;
    use issgd::coordinator::{PeerState, ProposalMaintainer};
    use issgd::sampler::Smoothing;
    use std::sync::Mutex;

    let e = engine();
    let mut cfg = base_cfg();
    cfg.trainer = TrainerKind::Issgd;
    cfg.n_workers = 2;
    let store: Arc<MemStore> = Arc::new(MemStore::new(Master::store_size(&cfg), cfg.init_weight));
    let store_dyn: Arc<dyn WeightStore> = store.clone();
    let master = Master::new(cfg.clone(), &e, store_dyn.clone()).unwrap();
    store_dyn.push_params(1, master.params.to_bytes()).unwrap();
    let snapshots_before = store.stats().unwrap().snapshot_fetches;

    let prop = Arc::new(Mutex::new(ProposalMaintainer::with_coverage_prior(
        Master::store_size(&cfg),
        cfg.smoothing,
        None,
        StalenessUnit::Versions,
    )));
    let mut peers: Vec<PeerState> = (0..cfg.n_workers)
        .map(|id| {
            PeerState::new(
                id,
                e.manifest(),
                Arc::clone(&master.data),
                Arc::new(master.train_idx.clone()),
                store_dyn.clone(),
                Some(Arc::clone(&prop)),
                cfg.lr,
                cfg.seed,
            )
        })
        .collect();
    for _ in 0..8 {
        for p in &mut peers {
            p.refresh_params(&e).unwrap();
            p.step(&e).unwrap();
        }
    }
    let st = store.stats().unwrap();
    assert_eq!(
        st.snapshot_fetches, snapshots_before,
        "peer steps must sync via deltas, never full snapshots"
    );
    assert!(st.delta_fetches > 0, "peers never fetched a delta");

    let mut p = prop.lock().unwrap();
    // Drain the writes of the final steps, then compare against the old
    // O(N) rebuild: smoothed scored weights, scored-mean prior elsewhere.
    let d = store.fetch_weights_since(p.cursor()).unwrap();
    p.absorb(&d, 0).unwrap();
    let snap = store.fetch_weights().unwrap();
    let smooth = Smoothing::new(cfg.smoothing);
    let scored: Vec<f64> = snap
        .param_versions
        .iter()
        .zip(&snap.weights)
        .filter(|(&v, _)| v > 0)
        .map(|(_, &w)| w)
        .collect();
    assert!(!scored.is_empty(), "peers never scored anything");
    let prior = scored.iter().sum::<f64>() / scored.len() as f64;
    for i in 0..snap.len() {
        let expect = smooth.apply(if snap.param_versions[i] > 0 {
            snap.weights[i]
        } else {
            prior
        });
        assert!(
            (p.effective_weight(i) - expect).abs() < 1e-6 * expect.max(1.0),
            "entry {i}: maintained {} vs scratch {expect}",
            p.effective_weight(i)
        );
    }
}

#[test]
fn adaptive_smoothing_tracks_entropy_target() {
    let e = engine();
    let mut cfg = base_cfg();
    cfg.steps = 30;
    cfg.adaptive_entropy = Some(0.9);
    let out = run_sim_with_engine(&cfg, &e).unwrap();
    let cs = out.rec.get("smoothing_c");
    assert!(!cs.is_empty(), "adaptive smoothing constant not recorded");
    // The solver must engage (c > 0) once weights become non-uniform.
    assert!(cs.iter().any(|s| s.value > 0.0));
    // And training still works.
    let losses = out.rec.get("train_loss");
    assert!(losses.last().unwrap().value < losses.first().unwrap().value);
}

/// Failure injection: a store that errors on a configurable fraction of
/// operations.  The master must keep training (fire-and-forget, §4.2),
/// degrading towards uniform sampling, never crashing.
struct FlakyStore {
    inner: MemStore,
    fail_every: u64,
    counter: std::sync::atomic::AtomicU64,
}

impl FlakyStore {
    fn new(inner: MemStore, fail_every: u64) -> Self {
        FlakyStore {
            inner,
            fail_every,
            counter: std::sync::atomic::AtomicU64::new(0),
        }
    }

    fn maybe_fail(&self) -> anyhow::Result<()> {
        let c = self
            .counter
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if c % self.fail_every == self.fail_every - 1 {
            anyhow::bail!("injected store failure (op {c})");
        }
        Ok(())
    }
}

impl WeightStore for FlakyStore {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> anyhow::Result<()> {
        self.maybe_fail()?;
        self.inner.push_params(version, bytes)
    }
    fn fetch_params(&self, than: u64) -> anyhow::Result<Option<(u64, Vec<u8>)>> {
        self.maybe_fail()?;
        self.inner.fetch_params(than)
    }
    fn push_params_layers(
        &self,
        version: u64,
        full: bool,
        layers: &[(String, Vec<u8>)],
    ) -> anyhow::Result<()> {
        self.maybe_fail()?;
        self.inner.push_params_layers(version, full, layers)
    }
    fn fetch_params_since(
        &self,
        than: u64,
    ) -> anyhow::Result<Option<issgd::weightstore::ParamsDelta>> {
        self.maybe_fail()?;
        self.inner.fetch_params_since(than)
    }
    fn params_version(&self) -> anyhow::Result<u64> {
        self.inner.params_version()
    }
    fn push_weights(&self, start: usize, weights: &[f32], v: u64) -> anyhow::Result<()> {
        self.maybe_fail()?;
        self.inner.push_weights(start, weights, v)
    }
    fn fetch_weights(&self) -> anyhow::Result<issgd::weightstore::WeightSnapshot> {
        self.maybe_fail()?;
        self.inner.fetch_weights()
    }
    fn fetch_weights_since(&self, seq: u64) -> anyhow::Result<issgd::weightstore::WeightDelta> {
        self.maybe_fail()?;
        self.inner.fetch_weights_since(seq)
    }
    fn apply_grad(&self, scale: f32, grad: &[f32]) -> anyhow::Result<u64> {
        self.maybe_fail()?;
        self.inner.apply_grad(scale, grad)
    }
    fn save_cursor(&self, name: &str, seq: u64) -> anyhow::Result<()> {
        self.maybe_fail()?;
        self.inner.save_cursor(name, seq)
    }
    fn load_cursor(&self, name: &str) -> anyhow::Result<Option<u64>> {
        self.maybe_fail()?;
        self.inner.load_cursor(name)
    }
    fn drop_cursor(&self, name: &str) -> anyhow::Result<()> {
        self.maybe_fail()?;
        self.inner.drop_cursor(name)
    }
    fn now(&self) -> anyhow::Result<u64> {
        self.inner.now()
    }
    fn stats(&self) -> anyhow::Result<issgd::weightstore::StoreStats> {
        self.inner.stats()
    }
}

#[test]
fn master_survives_flaky_store() {
    let e = engine();
    let mut cfg = base_cfg();
    cfg.steps = 40;
    let flaky: Arc<dyn WeightStore> = Arc::new(FlakyStore::new(
        MemStore::new(Master::store_size(&cfg), cfg.init_weight),
        3, // every third store op fails
    ));
    let mut master = Master::new(cfg.clone(), &e, flaky).unwrap();
    for _ in 0..cfg.steps {
        master.maybe_push_params().unwrap(); // must swallow failures
        master.train_one_step(&e).unwrap(); // must fall back to uniform
    }
    assert!(master.store_errors > 0, "injection never fired");
    let losses = master.rec.get("train_loss");
    assert!(
        losses.last().unwrap().value < losses.first().unwrap().value * 0.5,
        "training did not survive the flaky store"
    );
}

#[test]
fn evaluate_handles_partial_final_batch_exactly() {
    use issgd::coordinator::EvalSplit;
    use issgd::data::{split_indices, BatchBuilder, SplitSpec};

    let e = engine();
    let eb = e.manifest().batch_eval;
    let mut cfg = base_cfg();
    cfg.eval_max_batches = 0; // whole split
    // Pick an example count whose valid split has a partial final batch —
    // the configuration where the old wrapping path double-counted.
    cfg.n_examples = (cfg.n_examples..cfg.n_examples + 64 * eb)
        .find(|&n| {
            let (_, va, _) = split_indices(n, SplitSpec::default());
            va.len() > eb && va.len() % eb != 0
        })
        .expect("no split size with a partial eval batch in range");
    let store: Arc<MemStore> = Arc::new(MemStore::new(Master::store_size(&cfg), cfg.init_weight));
    let mut master = Master::new(cfg, &e, store).unwrap();
    assert!(master.valid_idx.len() % eb != 0);

    let (loss, err) = master.evaluate(&e, EvalSplit::Valid).unwrap();

    // Ground truth: per-example metrics via all-duplicate batches (every
    // slot the same row, so sum/e isolates that row's exact contribution).
    let manifest = e.manifest();
    let mut bb = BatchBuilder::new(eb, manifest.input_dim, manifest.n_classes);
    let (mut tl, mut tc) = (0f64, 0f64);
    for &g in &master.valid_idx {
        bb.fill(master.data.as_ref(), &vec![g; eb]);
        let out = e.eval_step(&master.params, &bb.x, &bb.y).unwrap();
        tl += out.sum_loss as f64 / eb as f64;
        tc += out.n_correct as f64 / eb as f64;
    }
    let n = master.valid_idx.len() as f64;
    let true_loss = tl / n;
    let true_err = 1.0 - tc / n;
    assert!(
        (loss - true_loss).abs() < 1e-3 * true_loss.abs().max(1.0),
        "mean loss {loss} vs exact {true_loss}"
    );
    assert!(
        (err - true_err).abs() < 1e-6,
        "prediction error {err} vs exact {true_err}"
    );
}

// -- live peer mode + fault injection ---------------------------------------

fn peer_cfg(steps: u64) -> RunConfig {
    let mut cfg = base_cfg();
    cfg.trainer = TrainerKind::Issgd;
    cfg.steps = steps;
    cfg.n_workers = 3;
    cfg.param_push_every = 4;
    // Driver-side evals are wall-clock racy; keep them out of
    // reproducibility-sensitive runs.
    cfg.eval_every = 0;
    cfg
}

#[test]
fn peer_live_lockstep_is_deterministic_under_faults() {
    // Fixed seed + FaultClock + lockstep op order ⇒ the whole chaos run,
    // injected schedule included, is bit-reproducible.
    use issgd::coordinator::{run_peer_live, PeerLiveOptions};
    use issgd::weightstore::faulty::{FaultSpec, FaultyStore};

    let run = || {
        let cfg = peer_cfg(18);
        let inner = Arc::new(MemStore::new(Master::store_size(&cfg), cfg.init_weight));
        let store = Arc::new(FaultyStore::new(
            inner as Arc<dyn WeightStore>,
            // Delivery faults only: withheld/partial deltas exercise the
            // stale-proposal path without error branches in driver setup.
            FaultSpec::quiet(77).with_withholding(0.3).with_partial_deltas(0.3),
        ));
        let out = run_peer_live(
            &cfg,
            &PeerLiveOptions {
                store: Some(store.clone() as Arc<dyn WeightStore>),
                lockstep: true,
                deadline: Some(std::time::Duration::from_secs(120)),
                ..PeerLiveOptions::default()
            },
        )
        .unwrap();
        let losses: Vec<f64> = out.rec.get("train_loss").iter().map(|s| s.value).collect();
        let faults = store.fault_stats();
        (losses, out.final_err, out.final_weights, out.final_ess, faults)
    };
    let (la, ea, wa, essa, fa) = run();
    let (lb, eb, wb, essb, fb) = run();
    assert!(fa.withheld_deltas + fa.partial_deltas > 0, "injection never fired");
    assert_eq!(fa, fb, "fault schedules diverged across identical runs");
    assert_eq!(la, lb, "loss traces diverged");
    assert_eq!(ea, eb);
    assert_eq!(wa, wb, "final proposals diverged");
    assert_eq!(essa, essb);
    assert_eq!(la.len(), 18);
}

#[test]
fn peer_live_lockstep_matches_sim_without_faults() {
    // Live-vs-sim equivalence: same seed, same round-robin op order, no
    // faults — per-peer maintainers must land on the same final proposal
    // as the sim's shared maintainer (both mirror the same store).
    use issgd::coordinator::{run_peer_live, PeerLiveOptions};

    let e = engine();
    let cfg = peer_cfg(18);
    let sim = issgd::coordinator::run_asgd_sim(&cfg, &e).unwrap();
    let live = run_peer_live(
        &cfg,
        &PeerLiveOptions {
            lockstep: true,
            deadline: Some(std::time::Duration::from_secs(120)),
            ..PeerLiveOptions::default()
        },
    )
    .unwrap();
    assert_eq!(live.total_peer_steps, 18);
    assert_eq!(sim.final_weights.len(), live.final_weights.len());
    assert!(!sim.final_weights.is_empty());
    for (i, (a, b)) in live.final_weights.iter().zip(&sim.final_weights).enumerate() {
        assert!(
            (a - b).abs() < 1e-6 * b.abs().max(1.0),
            "proposal entry {i}: live {a} vs sim {b}"
        );
    }
    assert!(
        (live.final_ess - sim.final_ess).abs() < 1e-6,
        "ESS diverged: live {} vs sim {}",
        live.final_ess,
        sim.final_ess
    );
    // Same schedule ⇒ same parameter-server trajectory ⇒ same training
    // quality (exact-comparison of losses is done via the proposal above;
    // final errors ride the same params).
    assert!((live.final_err.0 - sim.final_err.0).abs() < 1e-6);
}

#[test]
fn peer_live_chaos_converges_within_tolerance() {
    // The acceptance check: a mid-run store outage (transient errors +
    // withheld deltas) must leave every peer's cursor converged to the
    // store's write sequence, with final ESS within 5% of the fault-free
    // run.  Lockstep pins the schedule so the comparison isolates fault
    // effects from scheduler noise.
    use issgd::coordinator::{run_peer_live, PeerLiveOptions};
    use issgd::weightstore::faulty::{FaultSpec, FaultyStore};

    let cfg = peer_cfg(18);
    let clean = run_peer_live(
        &cfg,
        &PeerLiveOptions {
            lockstep: true,
            deadline: Some(std::time::Duration::from_secs(120)),
            ..PeerLiveOptions::default()
        },
    )
    .unwrap();

    let inner = Arc::new(MemStore::new(Master::store_size(&cfg), cfg.init_weight));
    let faulty = Arc::new(FaultyStore::new(
        inner.clone() as Arc<dyn WeightStore>,
        // ~10 ns/op: the outage spans roughly ops 15..70 of the run —
        // after driver setup, over well before shutdown and drain.
        FaultSpec::quiet(13)
            .with_errors(0.2)
            .with_withholding(0.4)
            .with_latency(10, 0)
            .with_fault_window(150, 700),
    ));
    let out = run_peer_live(
        &cfg,
        &PeerLiveOptions {
            store: Some(faulty.clone() as Arc<dyn WeightStore>),
            lockstep: true,
            deadline: Some(std::time::Duration::from_secs(180)),
            ..PeerLiveOptions::default()
        },
    )
    .unwrap();
    let faults = faulty.fault_stats();
    assert!(
        faults.injected_errors + faults.withheld_deltas > 0,
        "chaos schedule never fired: {faults:?}"
    );
    assert_eq!(out.total_peer_steps, 18, "peers lost budget to the outage");
    // Every peer's drained cursor reached the store's write sequence.
    for p in &out.peers {
        assert_eq!(
            p.final_cursor,
            inner.write_seq(),
            "peer {} cursor stuck at {} (write_seq {})",
            p.id,
            p.final_cursor,
            inner.write_seq()
        );
    }
    // Survived errors are visible in the stats.
    let total_errors: u64 = out.peers.iter().map(|p| p.store_errors).sum();
    assert!(faults.injected_errors == 0 || total_errors > 0);
    // Variance-reduction quality degraded at most marginally.
    assert!(
        (out.final_ess - clean.final_ess).abs() <= 0.05 * clean.final_ess,
        "ESS under chaos {} vs fault-free {}",
        out.final_ess,
        clean.final_ess
    );
}

#[test]
fn peer_live_free_running_trains_and_syncs() {
    // Free-running mode: genuinely concurrent peers (no turn token), real
    // cursor divergence, and still a converged drain at shutdown.
    use issgd::coordinator::{run_peer_live, PeerLiveOptions};

    let cfg = peer_cfg(30);
    let mem = Arc::new(MemStore::new(Master::store_size(&cfg), cfg.init_weight));
    let out = run_peer_live(
        &cfg,
        &PeerLiveOptions {
            store: Some(mem.clone() as Arc<dyn WeightStore>),
            deadline: Some(std::time::Duration::from_secs(120)),
            ..PeerLiveOptions::default()
        },
    )
    .unwrap();
    // In-flight contributions may overshoot the budget by < n_workers.
    assert!(out.total_peer_steps >= 30);
    assert!(out.total_peer_steps < 30 + cfg.n_workers as u64);
    assert_eq!(out.rec.get("train_loss").len() as u64, out.total_peer_steps);
    assert!(out.store_stats.grad_applies >= 30);
    for p in &out.peers {
        assert_eq!(p.final_cursor, mem.write_seq(), "peer {} never caught up", p.id);
    }
    // Minibatch losses are noisy; compare head vs tail means.
    let losses = out.rec.get("train_loss");
    let head: f64 = losses[..5].iter().map(|s| s.value).sum::<f64>() / 5.0;
    let tail: f64 = losses[losses.len() - 5..].iter().map(|s| s.value).sum::<f64>() / 5.0;
    assert!(tail < head, "live peers failed to train: {head} -> {tail}");
}

#[test]
fn peer_push_retries_lose_nothing_under_faults() {
    // Write-back coalescing under injected transient push failures: the
    // pending-retry queue must advance `push_calls_saved` and
    // `store_errors` while landing exactly the newest value per position —
    // nothing lost, nothing double-applied (shadow-table oracle).
    use issgd::coordinator::PeerState;
    use issgd::weightstore::faulty::{FaultSpec, FaultyStore};

    let e = engine();
    let cfg = peer_cfg(1);
    let n = Master::store_size(&cfg);
    let inner = Arc::new(MemStore::new(n, cfg.init_weight));
    let faulty = Arc::new(FaultyStore::new(
        inner.clone() as Arc<dyn WeightStore>,
        FaultSpec::quiet(31).with_errors(0.35),
    ));
    let master = Master::new(cfg.clone(), &e, inner.clone() as Arc<dyn WeightStore>).unwrap();
    let mut peer = PeerState::new(
        0,
        e.manifest(),
        Arc::clone(&master.data),
        Arc::new(master.train_idx.clone()),
        faulty.clone() as Arc<dyn WeightStore>,
        None,
        cfg.lr,
        cfg.seed,
    );

    // Shadow oracle: the newest value this peer ever emitted per position.
    let mut shadow: std::collections::BTreeMap<usize, f32> = std::collections::BTreeMap::new();
    let mut rng = issgd::util::rng::Pcg64::seeded(5);
    for round in 0..60u64 {
        // Mix contiguous runs (coalescable) and scattered singles.
        let mut entries: Vec<(usize, f32)> = Vec::new();
        let start = rng.next_below((n - 8) as u64) as usize;
        for k in 0..4 {
            entries.push((start + k, (round * 100 + k as u64) as f32 + 0.5));
        }
        for _ in 0..3 {
            let pos = rng.next_below(n as u64) as usize;
            entries.push((pos, (round * 100 + 50) as f32 + 0.25));
        }
        // The shadow applies entries the way flush does: sorted stable,
        // last-inserted wins per position.
        let mut sorted = entries.clone();
        sorted.sort_by_key(|e| e.0);
        for &(pos, w) in &sorted {
            shadow.insert(pos, w);
        }
        peer.flush_weight_pushes(&entries);
    }
    assert!(peer.store_errors > 0, "push-failure injection never fired");
    assert!(peer.push_calls_saved > 0, "no runs were coalesced");

    // Outage over: drain the retry queue.
    faulty.set_enabled(false);
    for _ in 0..8 {
        if peer.pending_pushes() == 0 {
            break;
        }
        peer.flush_weight_pushes(&[]);
    }
    assert_eq!(peer.pending_pushes(), 0, "pending queue failed to drain");

    // Every position holds exactly the newest emitted value; untouched
    // positions keep the init weight.
    let snap = inner.fetch_weights().unwrap();
    for i in 0..n {
        let expect = shadow.get(&i).copied().map(f64::from).unwrap_or(cfg.init_weight);
        assert_eq!(
            snap.weights[i], expect,
            "position {i}: store holds {} but newest write was {expect}",
            snap.weights[i]
        );
    }
    // Conservation: every successful call of a k-run wrote k entries.
    let st = inner.stats().unwrap();
    assert_eq!(st.weight_pushes + peer.push_calls_saved, st.weights_written);
}

#[test]
fn worker_death_does_not_stop_live_master() {
    use issgd::coordinator::{run_live, LiveOptions};
    // Workers share one shard-set; killing the store connection of workers
    // is equivalent to them dying.  run_live already reaps worker errors
    // without failing the run — emulate by steps >> worker lifetime with a
    // throttle so workers barely contribute, then assert the master
    // finished all steps regardless of the workers' scoring volume.
    let mut cfg = base_cfg();
    cfg.steps = 12;
    let out = run_live(
        &cfg,
        &LiveOptions {
            store: None,
            store_addr: None,
            worker_throttle: Some(std::time::Duration::from_millis(250)),
            wait_for_first_scores: false,
        },
    )
    .unwrap();
    assert_eq!(out.rec.get("train_loss").len(), 12);
}
