//! Helpers shared across the integration/property test binaries (each
//! test target compiles this module independently via `mod common;`).

use std::path::PathBuf;

/// Self-cleaning scratch directory for durable-store tests: unique per
/// (process, counter) so concurrent test binaries never collide, removed
/// on drop.
pub struct TempDir(pub PathBuf);

impl TempDir {
    pub fn new(tag: &str) -> TempDir {
        use std::sync::atomic::{AtomicU64, Ordering};
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let k = NEXT.fetch_add(1, Ordering::Relaxed);
        let path =
            std::env::temp_dir().join(format!("issgd-test-{tag}-{}-{k}", std::process::id()));
        let _ = std::fs::remove_dir_all(&path);
        TempDir(path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}
