//! Integration: rust PJRT runtime ⇄ AOT artifacts produced by
//! `python/compile/aot.py` (requires `make artifacts` for the `tiny`
//! config).  Exercises every entry point end-to-end and checks the
//! numerics that matter: training reduces loss, per-example gradient
//! norms behave like norms, eval counts are consistent.

use issgd::data::{BatchBuilder, Dataset, SynthDataset, SynthSpec};
use issgd::model::ParamSet;
use issgd::runtime::{artifacts_dir, Engine};
use issgd::util::rng::Pcg64;

fn engine() -> Engine {
    let dir = artifacts_dir("tiny");
    assert!(
        dir.join("manifest.json").exists(),
        "missing artifacts: run `make artifacts` first (looked in {})",
        dir.display()
    );
    Engine::load(&dir).expect("engine load")
}

fn setup(engine: &Engine) -> (SynthDataset, ParamSet, Pcg64) {
    let m = engine.manifest();
    let data = SynthDataset::generate(42, SynthSpec::tiny(256));
    assert_eq!(data.dim(), m.input_dim);
    let mut rng = Pcg64::seeded(7);
    let params = ParamSet::init_he(m, &mut rng);
    (data, params, rng)
}

#[test]
fn train_step_reduces_loss_and_updates_params() {
    let e = engine();
    let m = e.manifest().clone();
    let (data, mut params, mut rng) = setup(&e);
    let before = params.clone();
    let mut batch = BatchBuilder::new(m.batch_train, m.input_dim, m.n_classes);
    let coef = vec![1.0f32; m.batch_train];

    let mut losses = Vec::new();
    for _ in 0..60 {
        let idx = rng.sample_with_replacement(data.len(), m.batch_train);
        batch.fill(&data, &idx);
        let out = e
            .train_step(&mut params, &batch.x, &batch.y, &coef, 0.05)
            .expect("train_step");
        assert!(out.loss.is_finite());
        losses.push(out.loss);
    }
    assert_ne!(params, before, "parameters did not change");
    let head: f32 = losses[..10].iter().sum::<f32>() / 10.0;
    let tail: f32 = losses[losses.len() - 10..].iter().sum::<f32>() / 10.0;
    assert!(
        tail < head * 0.8,
        "loss did not go down: head {head}, tail {tail} ({losses:?})"
    );
}

#[test]
fn grad_norms_are_positive_and_scale_sensitive() {
    let e = engine();
    let m = e.manifest().clone();
    let (data, params, _) = setup(&e);
    let mut batch = BatchBuilder::new(m.batch_score, m.input_dim, m.n_classes);
    let idx: Vec<usize> = (0..m.batch_score).collect();
    batch.fill(&data, &idx);
    let out = e.grad_norms(&params, &batch.x, &batch.y).expect("grad_norms");
    assert_eq!(out.sqnorms.len(), m.batch_score);
    assert_eq!(out.losses.len(), m.batch_score);
    for (&sq, &l) in out.sqnorms.iter().zip(&out.losses) {
        assert!(sq.is_finite() && sq >= 0.0, "sqnorm {sq}");
        assert!(l.is_finite() && l >= 0.0, "loss {l}");
    }
    // A freshly-initialised net on tiered data: norms must not all be
    // identical (the heavy tail is the entire point).
    let min = out.sqnorms.iter().cloned().fold(f32::INFINITY, f32::min);
    let max = out.sqnorms.iter().cloned().fold(0f32, f32::max);
    assert!(max > min * 1.5, "gradient norms suspiciously uniform: {min}..{max}");
}

#[test]
fn grad_norms_identical_rows_get_identical_scores() {
    let e = engine();
    let m = e.manifest().clone();
    let (data, params, _) = setup(&e);
    let mut batch = BatchBuilder::new(m.batch_score, m.input_dim, m.n_classes);
    // Fill the whole batch with copies of example 3.
    batch.fill(&data, &[3]);
    let out = e.grad_norms(&params, &batch.x, &batch.y).unwrap();
    let first = out.sqnorms[0];
    for &s in &out.sqnorms {
        assert!((s - first).abs() <= 1e-4 * first.abs().max(1e-6), "{s} vs {first}");
    }
}

#[test]
fn eval_step_counts_are_consistent() {
    let e = engine();
    let m = e.manifest().clone();
    let (data, params, _) = setup(&e);
    let mut batch = BatchBuilder::new(m.batch_eval, m.input_dim, m.n_classes);
    let idx: Vec<usize> = (0..m.batch_eval).collect();
    batch.fill(&data, &idx);
    let out = e.eval_step(&params, &batch.x, &batch.y).expect("eval_step");
    assert!(out.sum_loss.is_finite() && out.sum_loss > 0.0);
    assert!(out.n_correct >= 0.0 && out.n_correct <= m.batch_eval as f32);
    assert_eq!(out.n_correct.fract(), 0.0, "correct count must be integral");
}

#[test]
fn grad_mean_sqnorm_matches_scored_scale() {
    let e = engine();
    let m = e.manifest().clone();
    let (data, params, mut rng) = setup(&e);
    let mut batch = BatchBuilder::new(m.batch_train, m.input_dim, m.n_classes);
    let idx = rng.sample_with_replacement(data.len(), m.batch_train);
    batch.fill(&data, &idx);
    let sq = e.grad_mean_sqnorm(&params, &batch.x, &batch.y).expect("grad_mean_sqnorm");
    assert!(sq.is_finite() && sq > 0.0);
    // ||mean of per-example grads|| <= mean of per-example norms (Jensen) —
    // cross-entry-point consistency check on the same index multiset
    // (batch_score is a multiple of batch_train for tiny, and padding
    // cycles the same index list).
    let mut sbatch = BatchBuilder::new(m.batch_score, m.input_dim, m.n_classes);
    sbatch.fill(&data, &idx);
    let scored = e.grad_norms(&params, &sbatch.x, &sbatch.y).unwrap();
    let mean_norm = scored.sqnorms.iter().map(|&s| (s as f64).sqrt()).sum::<f64>()
        / scored.sqnorms.len() as f64;
    assert!(
        (sq as f64).sqrt() <= mean_norm * (1.0 + 1e-3),
        "||g_mean|| {} > mean ||g_n|| {}",
        (sq as f64).sqrt(),
        mean_norm
    );
}

#[test]
fn missing_entry_point_errors_cleanly() {
    let dir = artifacts_dir("tiny");
    let e = Engine::load_entries(&dir, &["grad_norms"]).unwrap();
    let m = e.manifest().clone();
    let (data, mut params, _) = setup(&e);
    let mut batch = BatchBuilder::new(m.batch_train, m.input_dim, m.n_classes);
    batch.fill(&data, &[0]);
    let coef = vec![1.0f32; m.batch_train];
    let err = e.train_step(&mut params, &batch.x, &batch.y, &coef, 0.1);
    assert!(err.is_err(), "train_step should be unavailable");
}

#[test]
fn execute_path_does_not_leak_memory() {
    // Regression test for the xla-rs 0.1.6 `execute()` input-buffer leak
    // (see runtime/engine.rs): 500 train steps must not grow RSS by more
    // than a few MB.  With the literal path this grew ~25 KB/step on tiny
    // and ~8 MB/step on `small`, OOM-killing long experiment runs.
    fn rss_bytes() -> usize {
        let s = std::fs::read_to_string("/proc/self/statm").unwrap();
        let pages: usize = s.split_whitespace().nth(1).unwrap().parse().unwrap();
        pages * 4096
    }
    let e = engine();
    let m = e.manifest().clone();
    let (data, mut params, mut rng) = setup(&e);
    let mut batch = BatchBuilder::new(m.batch_train, m.input_dim, m.n_classes);
    let coef = vec![1.0f32; m.batch_train];
    let idx = rng.sample_with_replacement(data.len(), m.batch_train);
    batch.fill(&data, &idx);
    // Warm up allocator pools before measuring.
    for _ in 0..50 {
        e.train_step(&mut params, &batch.x, &batch.y, &coef, 1e-3).unwrap();
    }
    let before = rss_bytes();
    for _ in 0..500 {
        e.train_step(&mut params, &batch.x, &batch.y, &coef, 1e-3).unwrap();
    }
    let grown = rss_bytes().saturating_sub(before);
    assert!(
        grown < 8 << 20,
        "RSS grew {:.1} MB over 500 steps — execute path is leaking again",
        grown as f64 / 1e6
    );
}

#[test]
fn peer_step_entry_subset_loads_alone() {
    // Live peer threads compile only the `peer_step` entry point (the
    // worker analogue of loading just `grad_norms`): the subset must load
    // and execute, and unloaded entries must error, not panic.
    let dir = artifacts_dir("tiny");
    assert!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    let e = Engine::load_entries(&dir, &["peer_step"]).expect("peer_step-only engine");
    let m = e.manifest().clone();
    let data = SynthDataset::generate(42, SynthSpec::tiny(256));
    let mut rng = Pcg64::seeded(7);
    let params = ParamSet::init_he(&m, &mut rng);
    let mut batch = BatchBuilder::new(m.batch_train, m.input_dim, m.n_classes);
    let idx = rng.sample_with_replacement(data.len(), m.batch_train);
    batch.fill(&data, &idx);
    let coef = vec![1.0f32; m.batch_train];
    let out = e.peer_step(&params, &batch.x, &batch.y, &coef).expect("peer_step");
    assert!(out.loss.is_finite());
    assert_eq!(out.grad_flat.len(), m.n_params);
    assert!(out.sqnorms.iter().all(|s| s.is_finite()));
    // Entries outside the subset are absent, reported as errors (same
    // batch shape, so the failure is "not loaded", not a size mismatch).
    assert!(e.grad_mean_sqnorm(&params, &batch.x, &batch.y).is_err());
}
