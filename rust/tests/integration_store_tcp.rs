//! Integration: the TCP weight store — server/client round-trips,
//! concurrent clients, error propagation, shutdown, and a full
//! master+worker session running over TCP instead of shared memory.

use std::sync::Arc;

use issgd::weightstore::client::Client;
use issgd::weightstore::server::Server;
use issgd::weightstore::{MemStore, WeightStore};

fn spawn_store(n: usize) -> (String, std::thread::JoinHandle<()>) {
    let store = Arc::new(MemStore::new(n, 1.0));
    let server = Server::bind("127.0.0.1:0", store).unwrap();
    let (addr, handle) = server.serve_in_background().unwrap();
    (addr.to_string(), handle)
}

#[test]
fn params_roundtrip_over_tcp() {
    let (addr, handle) = spawn_store(8);
    {
        let c = Client::connect(&addr).unwrap();
        assert_eq!(c.params_version().unwrap(), 0);
        assert!(c.fetch_params(0).unwrap().is_none());
        let blob: Vec<u8> = (0..=255).collect();
        c.push_params(3, blob.clone()).unwrap();
        let (v, b) = c.fetch_params(0).unwrap().unwrap();
        assert_eq!(v, 3);
        assert_eq!(b, blob);
        assert!(c.fetch_params(3).unwrap().is_none());
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn weights_roundtrip_over_tcp() {
    let (addr, handle) = spawn_store(10);
    {
        let c = Client::connect(&addr).unwrap();
        c.push_weights(2, &[0.5, 1.5, 2.5], 7).unwrap();
        let snap = c.fetch_weights().unwrap();
        assert_eq!(snap.weights.len(), 10);
        assert_eq!(&snap.weights[2..5], &[0.5, 1.5, 2.5]);
        assert_eq!(snap.param_versions[3], 7);
        assert_eq!(snap.param_versions[0], 0);
        assert!(snap.stamps[2] > 0);
        let now = c.now().unwrap();
        assert!(now >= snap.stamps[2]);
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn delta_fetch_over_tcp_tracks_snapshot() {
    let (addr, handle) = spawn_store(64);
    {
        let c = Client::connect(&addr).unwrap();
        // Fresh consumer: seq 0 returns the full table.
        let d = c.fetch_weights_since(0).unwrap();
        assert!(d.full);
        assert_eq!(d.n, 64);
        assert_eq!(d.len(), 64);
        let mut mirror = d.to_snapshot().unwrap();
        let mut cursor = d.seq;
        assert_eq!(mirror, c.fetch_weights().unwrap());
        // Incremental: only the changed rows travel.
        c.push_weights(5, &[2.5, 3.5], 4).unwrap();
        c.push_weights(40, &[9.0], 5).unwrap();
        let d = c.fetch_weights_since(cursor).unwrap();
        assert!(!d.full);
        assert_eq!(d.indices, vec![5, 6, 40]);
        assert_eq!(d.weights, vec![2.5, 3.5, 9.0]);
        assert_eq!(d.param_versions, vec![4, 4, 5]);
        d.apply_to(&mut mirror).unwrap();
        cursor = d.seq;
        assert_eq!(mirror, c.fetch_weights().unwrap());
        // Idle: empty delta, stable cursor.
        let d = c.fetch_weights_since(cursor).unwrap();
        assert!(d.is_empty());
        assert_eq!(d.seq, cursor);
        let stats = c.stats().unwrap();
        assert_eq!(stats.delta_fetches, 3);
        assert_eq!(stats.delta_entries, 64 + 3);
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn two_tcp_consumers_with_independent_cursors_converge() {
    // The cursor is client-side state (see WeightStore::fetch_weights_since):
    // two connections advancing private cursors at different cadences must
    // each reconstruct the same table.
    use issgd::weightstore::WeightSnapshot;
    let (addr, handle) = spawn_store(50);
    {
        let c1 = Client::connect(&addr).unwrap();
        let c2 = Client::connect(&addr).unwrap();
        let mut m1 = WeightSnapshot::default();
        let mut m2 = WeightSnapshot::default();
        let (mut s1, mut s2) = (0u64, 0u64);
        for round in 0..12u64 {
            c1.push_weights((round as usize * 3) % 40, &[round as f32, 1.0], round + 1)
                .unwrap();
            if round % 2 == 0 {
                let d = c1.fetch_weights_since(s1).unwrap();
                d.apply_to(&mut m1).unwrap();
                s1 = d.seq;
            }
            if round % 3 == 0 {
                let d = c2.fetch_weights_since(s2).unwrap();
                d.apply_to(&mut m2).unwrap();
                s2 = d.seq;
            }
        }
        let d = c1.fetch_weights_since(s1).unwrap();
        d.apply_to(&mut m1).unwrap();
        let d = c2.fetch_weights_since(s2).unwrap();
        d.apply_to(&mut m2).unwrap();
        let truth = c1.fetch_weights().unwrap();
        assert_eq!(m1, truth);
        assert_eq!(m2, truth);
        c1.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn params_layers_roundtrip_over_tcp() {
    // The params-delta opcodes (0x0C/0x0D/0x89) end to end: full layout
    // publish, partial layer update, incremental fetch, fallbacks.
    let (addr, handle) = spawn_store(8);
    {
        let c = Client::connect(&addr).unwrap();
        assert!(c.fetch_params_since(0).unwrap().is_none());
        c.push_params_layers(
            1,
            true,
            &[("layer0".into(), vec![1, 1, 1, 1]), ("layer1".into(), vec![2, 2, 2, 2])],
        )
        .unwrap();
        let d = c.fetch_params_since(0).unwrap().unwrap();
        assert!(d.full);
        assert_eq!(d.version, 1);
        assert_eq!(d.len(), 2);
        // Partial update: only the dirty layer travels.
        c.push_params_layers(2, false, &[("layer1".into(), vec![9, 9, 9, 9])])
            .unwrap();
        let d = c.fetch_params_since(1).unwrap().unwrap();
        assert!(!d.full);
        assert_eq!(d.len(), 1);
        assert_eq!(d.layers[0].name, "layer1");
        assert_eq!(d.layers[0].bytes, vec![9, 9, 9, 9]);
        assert!(c.fetch_params_since(2).unwrap().is_none());
        // The blob view agrees.
        let (v, blob) = c.fetch_params(0).unwrap().unwrap();
        assert_eq!((v, blob), (2, vec![1, 1, 1, 1, 9, 9, 9, 9]));
        // Errors propagate as responses, connection stays usable.
        assert!(c
            .push_params_layers(3, false, &[("nope".into(), vec![0, 0, 0, 0])])
            .is_err());
        assert_eq!(c.params_version().unwrap(), 2);
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn drop_cursor_over_tcp_unpins_compaction() {
    let (addr, handle) = spawn_store(8);
    {
        let c = Client::connect(&addr).unwrap();
        let d = c.fetch_weights_since(0).unwrap();
        c.save_cursor("dead", d.seq).unwrap();
        assert_eq!(c.load_cursor("dead").unwrap(), Some(d.seq));
        c.drop_cursor("dead").unwrap();
        assert_eq!(c.load_cursor("dead").unwrap(), None);
        // Idempotent over the wire too.
        c.drop_cursor("dead").unwrap();
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn server_side_errors_propagate() {
    let (addr, handle) = spawn_store(4);
    {
        let c = Client::connect(&addr).unwrap();
        // Out-of-bounds write must come back as an error, not a hang.
        let err = c.push_weights(3, &[1.0, 1.0], 1).unwrap_err();
        assert!(err.to_string().contains("out of bounds"), "{err}");
        // Version must increase.
        c.push_params(2, vec![1]).unwrap();
        assert!(c.push_params(2, vec![2]).is_err());
        // Connection still usable after an error response.
        assert_eq!(c.params_version().unwrap(), 2);
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn concurrent_clients_share_state() {
    let (addr, handle) = spawn_store(100);
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let c = Client::connect(&addr).unwrap();
            for i in 0..25usize {
                let idx = t as usize * 25 + i;
                c.push_weights(idx, &[(idx + 1) as f32], 1).unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let c = Client::connect(&addr).unwrap();
    let snap = c.fetch_weights().unwrap();
    for (i, &w) in snap.weights.iter().enumerate() {
        assert_eq!(w, (i + 1) as f64, "lost write at {i}");
    }
    let stats = c.stats().unwrap();
    assert_eq!(stats.weight_pushes, 100);
    assert_eq!(stats.weights_written, 100);
    c.shutdown_server().unwrap();
    handle.join().unwrap();
}

#[test]
fn full_training_session_over_tcp() {
    use issgd::config::RunConfig;
    use issgd::coordinator::{run_live, LiveOptions, Master};

    let mut cfg = RunConfig::tiny_test();
    cfg.steps = 10;
    let n_weights = Master::store_size(&cfg);
    let store = Arc::new(MemStore::new(n_weights, cfg.init_weight));
    let server = Server::bind("127.0.0.1:0", store).unwrap();
    let (addr, handle) = server.serve_in_background().unwrap();

    let out = run_live(
        &cfg,
        &LiveOptions {
            store: None,
            store_addr: Some(addr.to_string()),
            worker_throttle: Some(std::time::Duration::from_millis(1)),
            wait_for_first_scores: true,
        },
    )
    .unwrap();
    assert_eq!(out.rec.get("train_loss").len(), 10);
    assert!(out.scored > 0);
    assert!(out.store_stats.weight_pushes > 0);

    Client::connect(&addr.to_string())
        .unwrap()
        .shutdown_server()
        .unwrap();
    handle.join().unwrap();
}

#[test]
fn faulty_decorator_over_tcp_client_converges() {
    // FaultyStore wraps ANY WeightStore — here a TCP client — so chaos
    // schedules compose with the real transport.  A cursor-replaying
    // consumer behind the decorator must converge once the outage ends.
    use issgd::weightstore::faulty::{FaultSpec, FaultyStore};

    let (addr, handle) = spawn_store(64);
    {
        let oracle = Client::connect(&addr).unwrap();
        let client: Arc<dyn WeightStore> = Arc::new(Client::connect(&addr).unwrap());
        let store = FaultyStore::new(
            client,
            FaultSpec::quiet(17)
                .with_errors(0.3)
                .with_withholding(0.4)
                .with_partial_deltas(0.4),
        );
        let d0 = store.fetch_weights_since(0).unwrap();
        let mut mirror = d0.to_snapshot().unwrap();
        let mut cursor = d0.seq;
        for round in 0..40u64 {
            oracle
                .push_weights((round % 60) as usize, &[round as f32 + 1.0, 2.0], round + 1)
                .unwrap();
            if let Ok(d) = store.fetch_weights_since(cursor) {
                d.apply_to(&mut mirror).unwrap();
                cursor = d.seq;
            }
        }
        store.set_enabled(false);
        let d = store.fetch_weights_since(cursor).unwrap();
        d.apply_to(&mut mirror).unwrap();
        assert_eq!(mirror, oracle.fetch_weights().unwrap());
        oracle.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn cursors_roundtrip_over_tcp() {
    let (addr, handle) = spawn_store(8);
    {
        let c = Client::connect(&addr).unwrap();
        assert_eq!(c.load_cursor("master").unwrap(), None);
        let d = c.fetch_weights_since(0).unwrap();
        c.save_cursor("master", d.seq).unwrap();
        assert_eq!(c.load_cursor("master").unwrap(), Some(d.seq));
        // Empty names are a server-side error, not a dropped connection.
        assert!(c.save_cursor("", 1).is_err());
        assert_eq!(c.load_cursor("master").unwrap(), Some(d.seq));
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn durable_store_over_tcp_resumes_across_server_restarts() {
    // The `issgd db-server --store-path` shape: the TCP server is generic
    // over its backend, so a durable store serves remote clients and a
    // server restart (process crash) loses neither the table nor the
    // consumers' saved cursors — the remote master resumes incrementally.
    use issgd::weightstore::durable::{DurableOptions, DurableStore};

    let dir = std::env::temp_dir().join(format!("issgd-tcp-durable-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = DurableOptions {
        segment_bytes: 1 << 14,
        compact_after_bytes: 0,
        ..DurableOptions::default()
    };

    // Serve cycle 1: create, write, persist a cursor.
    let (cursor, table) = {
        let store = Arc::new(DurableStore::create(&dir, 32, 1.0, opts.clone()).unwrap());
        let server = Server::bind("127.0.0.1:0", store).unwrap();
        let (addr, handle) = server.serve_in_background().unwrap();
        let c = Client::connect(&addr.to_string()).unwrap();
        c.push_weights(3, &[5.0, 6.0], 2).unwrap();
        c.push_weights(20, &[9.0], 3).unwrap();
        let d = c.fetch_weights_since(0).unwrap();
        c.save_cursor("master", d.seq).unwrap();
        let table = c.fetch_weights().unwrap();
        c.shutdown_server().unwrap();
        handle.join().unwrap();
        (d.seq, table)
    };
    // serve() joins every handler thread before returning, so once the
    // join above came back no connection still holds the old store — the
    // directory can be reopened immediately without racing a late write.

    // Serve cycle 2: recover from disk, the remote consumer continues.
    {
        let store = Arc::new(DurableStore::open(&dir, opts).unwrap());
        let server = Server::bind("127.0.0.1:0", store).unwrap();
        let (addr, handle) = server.serve_in_background().unwrap();
        let c = Client::connect(&addr.to_string()).unwrap();
        assert_eq!(c.fetch_weights().unwrap(), table);
        assert_eq!(c.load_cursor("master").unwrap(), Some(cursor));
        let d = c.fetch_weights_since(cursor).unwrap();
        assert!(!d.full, "remote master demoted to full resync after restart");
        assert!(d.is_empty());
        c.push_weights(0, &[7.0], 9).unwrap();
        let d = c.fetch_weights_since(cursor).unwrap();
        assert_eq!(d.indices, vec![0]);
        c.shutdown_server().unwrap();
        handle.join().unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shutdown_releases_idle_and_hung_connections() {
    // The handler-leak fix: connection reads poll the stop flag, so after
    // Shutdown a client that is idle — or hung mid-frame, the worst case —
    // no longer pins its handler thread; the handler exits and the socket
    // closes underneath the client.
    use std::io::{Read, Write};

    let (addr, handle) = spawn_store(4);
    // An idle connection (no bytes sent) and a hung one (half a frame
    // header, then silence).
    let mut idle = std::net::TcpStream::connect(&addr).unwrap();
    let mut hung = std::net::TcpStream::connect(&addr).unwrap();
    hung.write_all(&[5, 0]).unwrap();
    // Let both handlers enter their read loops, then shut down.
    std::thread::sleep(std::time::Duration::from_millis(100));
    let c = Client::connect(&addr).unwrap();
    c.shutdown_server().unwrap();
    handle.join().unwrap();
    for (name, stream) in [("idle", &mut idle), ("hung", &mut hung)] {
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        let mut buf = [0u8; 1];
        match stream.read(&mut buf) {
            Ok(0) => {} // EOF: the handler thread released us
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                panic!("{name} connection still pinned a handler thread after shutdown")
            }
            Err(_) => {} // reset is also a release
            Ok(n) => panic!("unexpected {n} bytes on the {name} connection"),
        }
    }
}
