//! Integration: the TCP weight store — server/client round-trips,
//! concurrent clients, error propagation, shutdown, and a full
//! master+worker session running over TCP instead of shared memory.

use std::sync::Arc;

use issgd::weightstore::client::Client;
use issgd::weightstore::server::Server;
use issgd::weightstore::{MemStore, WeightStore};

fn spawn_store(n: usize) -> (String, std::thread::JoinHandle<()>) {
    let store = Arc::new(MemStore::new(n, 1.0));
    let server = Server::bind("127.0.0.1:0", store).unwrap();
    let (addr, handle) = server.serve_in_background().unwrap();
    (addr.to_string(), handle)
}

#[test]
fn params_roundtrip_over_tcp() {
    let (addr, handle) = spawn_store(8);
    {
        let c = Client::connect(&addr).unwrap();
        assert_eq!(c.params_version().unwrap(), 0);
        assert!(c.fetch_params(0).unwrap().is_none());
        let blob: Vec<u8> = (0..=255).collect();
        c.push_params(3, blob.clone()).unwrap();
        let (v, b) = c.fetch_params(0).unwrap().unwrap();
        assert_eq!(v, 3);
        assert_eq!(b, blob);
        assert!(c.fetch_params(3).unwrap().is_none());
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn weights_roundtrip_over_tcp() {
    let (addr, handle) = spawn_store(10);
    {
        let c = Client::connect(&addr).unwrap();
        c.push_weights(2, &[0.5, 1.5, 2.5], 7).unwrap();
        let snap = c.fetch_weights().unwrap();
        assert_eq!(snap.weights.len(), 10);
        assert_eq!(&snap.weights[2..5], &[0.5, 1.5, 2.5]);
        assert_eq!(snap.param_versions[3], 7);
        assert_eq!(snap.param_versions[0], 0);
        assert!(snap.stamps[2] > 0);
        let now = c.now().unwrap();
        assert!(now >= snap.stamps[2]);
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn delta_fetch_over_tcp_tracks_snapshot() {
    let (addr, handle) = spawn_store(64);
    {
        let c = Client::connect(&addr).unwrap();
        // Fresh consumer: seq 0 returns the full table.
        let d = c.fetch_weights_since(0).unwrap();
        assert!(d.full);
        assert_eq!(d.n, 64);
        assert_eq!(d.len(), 64);
        let mut mirror = d.to_snapshot().unwrap();
        let mut cursor = d.seq;
        assert_eq!(mirror, c.fetch_weights().unwrap());
        // Incremental: only the changed rows travel.
        c.push_weights(5, &[2.5, 3.5], 4).unwrap();
        c.push_weights(40, &[9.0], 5).unwrap();
        let d = c.fetch_weights_since(cursor).unwrap();
        assert!(!d.full);
        assert_eq!(d.indices, vec![5, 6, 40]);
        assert_eq!(d.weights, vec![2.5, 3.5, 9.0]);
        assert_eq!(d.param_versions, vec![4, 4, 5]);
        d.apply_to(&mut mirror).unwrap();
        cursor = d.seq;
        assert_eq!(mirror, c.fetch_weights().unwrap());
        // Idle: empty delta, stable cursor.
        let d = c.fetch_weights_since(cursor).unwrap();
        assert!(d.is_empty());
        assert_eq!(d.seq, cursor);
        let stats = c.stats().unwrap();
        assert_eq!(stats.delta_fetches, 3);
        assert_eq!(stats.delta_entries, 64 + 3);
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn two_tcp_consumers_with_independent_cursors_converge() {
    // The cursor is client-side state (see WeightStore::fetch_weights_since):
    // two connections advancing private cursors at different cadences must
    // each reconstruct the same table.
    use issgd::weightstore::WeightSnapshot;
    let (addr, handle) = spawn_store(50);
    {
        let c1 = Client::connect(&addr).unwrap();
        let c2 = Client::connect(&addr).unwrap();
        let mut m1 = WeightSnapshot::default();
        let mut m2 = WeightSnapshot::default();
        let (mut s1, mut s2) = (0u64, 0u64);
        for round in 0..12u64 {
            c1.push_weights((round as usize * 3) % 40, &[round as f32, 1.0], round + 1)
                .unwrap();
            if round % 2 == 0 {
                let d = c1.fetch_weights_since(s1).unwrap();
                d.apply_to(&mut m1).unwrap();
                s1 = d.seq;
            }
            if round % 3 == 0 {
                let d = c2.fetch_weights_since(s2).unwrap();
                d.apply_to(&mut m2).unwrap();
                s2 = d.seq;
            }
        }
        let d = c1.fetch_weights_since(s1).unwrap();
        d.apply_to(&mut m1).unwrap();
        let d = c2.fetch_weights_since(s2).unwrap();
        d.apply_to(&mut m2).unwrap();
        let truth = c1.fetch_weights().unwrap();
        assert_eq!(m1, truth);
        assert_eq!(m2, truth);
        c1.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn params_layers_roundtrip_over_tcp() {
    // The params-delta opcodes (0x0C/0x0D/0x89) end to end: full layout
    // publish, partial layer update, incremental fetch, fallbacks.
    let (addr, handle) = spawn_store(8);
    {
        let c = Client::connect(&addr).unwrap();
        assert!(c.fetch_params_since(0).unwrap().is_none());
        c.push_params_layers(
            1,
            true,
            &[("layer0".into(), vec![1, 1, 1, 1]), ("layer1".into(), vec![2, 2, 2, 2])],
        )
        .unwrap();
        let d = c.fetch_params_since(0).unwrap().unwrap();
        assert!(d.full);
        assert_eq!(d.version, 1);
        assert_eq!(d.len(), 2);
        // Partial update: only the dirty layer travels.
        c.push_params_layers(2, false, &[("layer1".into(), vec![9, 9, 9, 9])])
            .unwrap();
        let d = c.fetch_params_since(1).unwrap().unwrap();
        assert!(!d.full);
        assert_eq!(d.len(), 1);
        assert_eq!(d.layers[0].name, "layer1");
        assert_eq!(d.layers[0].bytes, vec![9, 9, 9, 9]);
        assert!(c.fetch_params_since(2).unwrap().is_none());
        // The blob view agrees.
        let (v, blob) = c.fetch_params(0).unwrap().unwrap();
        assert_eq!((v, blob), (2, vec![1, 1, 1, 1, 9, 9, 9, 9]));
        // Errors propagate as responses, connection stays usable.
        assert!(c
            .push_params_layers(3, false, &[("nope".into(), vec![0, 0, 0, 0])])
            .is_err());
        assert_eq!(c.params_version().unwrap(), 2);
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn drop_cursor_over_tcp_unpins_compaction() {
    let (addr, handle) = spawn_store(8);
    {
        let c = Client::connect(&addr).unwrap();
        let d = c.fetch_weights_since(0).unwrap();
        c.save_cursor("dead", d.seq).unwrap();
        assert_eq!(c.load_cursor("dead").unwrap(), Some(d.seq));
        c.drop_cursor("dead").unwrap();
        assert_eq!(c.load_cursor("dead").unwrap(), None);
        // Idempotent over the wire too.
        c.drop_cursor("dead").unwrap();
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn server_side_errors_propagate() {
    let (addr, handle) = spawn_store(4);
    {
        let c = Client::connect(&addr).unwrap();
        // Out-of-bounds write must come back as an error, not a hang.
        let err = c.push_weights(3, &[1.0, 1.0], 1).unwrap_err();
        assert!(err.to_string().contains("out of bounds"), "{err}");
        // Version must increase.
        c.push_params(2, vec![1]).unwrap();
        assert!(c.push_params(2, vec![2]).is_err());
        // Connection still usable after an error response.
        assert_eq!(c.params_version().unwrap(), 2);
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn concurrent_clients_share_state() {
    let (addr, handle) = spawn_store(100);
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let c = Client::connect(&addr).unwrap();
            for i in 0..25usize {
                let idx = t as usize * 25 + i;
                c.push_weights(idx, &[(idx + 1) as f32], 1).unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let c = Client::connect(&addr).unwrap();
    let snap = c.fetch_weights().unwrap();
    for (i, &w) in snap.weights.iter().enumerate() {
        assert_eq!(w, (i + 1) as f64, "lost write at {i}");
    }
    let stats = c.stats().unwrap();
    assert_eq!(stats.weight_pushes, 100);
    assert_eq!(stats.weights_written, 100);
    c.shutdown_server().unwrap();
    handle.join().unwrap();
}

#[test]
fn full_training_session_over_tcp() {
    use issgd::config::RunConfig;
    use issgd::coordinator::{run_live, LiveOptions, Master};

    let mut cfg = RunConfig::tiny_test();
    cfg.steps = 10;
    let n_weights = Master::store_size(&cfg);
    let store = Arc::new(MemStore::new(n_weights, cfg.init_weight));
    let server = Server::bind("127.0.0.1:0", store).unwrap();
    let (addr, handle) = server.serve_in_background().unwrap();

    let out = run_live(
        &cfg,
        &LiveOptions {
            store: None,
            store_addr: Some(addr.to_string()),
            worker_throttle: Some(std::time::Duration::from_millis(1)),
            wait_for_first_scores: true,
        },
    )
    .unwrap();
    assert_eq!(out.rec.get("train_loss").len(), 10);
    assert!(out.scored > 0);
    assert!(out.store_stats.weight_pushes > 0);

    Client::connect(&addr.to_string())
        .unwrap()
        .shutdown_server()
        .unwrap();
    handle.join().unwrap();
}

#[test]
fn faulty_decorator_over_tcp_client_converges() {
    // FaultyStore wraps ANY WeightStore — here a TCP client — so chaos
    // schedules compose with the real transport.  A cursor-replaying
    // consumer behind the decorator must converge once the outage ends.
    use issgd::weightstore::faulty::{FaultSpec, FaultyStore};

    let (addr, handle) = spawn_store(64);
    {
        let oracle = Client::connect(&addr).unwrap();
        let client: Arc<dyn WeightStore> = Arc::new(Client::connect(&addr).unwrap());
        let store = FaultyStore::new(
            client,
            FaultSpec::quiet(17)
                .with_errors(0.3)
                .with_withholding(0.4)
                .with_partial_deltas(0.4),
        );
        let d0 = store.fetch_weights_since(0).unwrap();
        let mut mirror = d0.to_snapshot().unwrap();
        let mut cursor = d0.seq;
        for round in 0..40u64 {
            oracle
                .push_weights((round % 60) as usize, &[round as f32 + 1.0, 2.0], round + 1)
                .unwrap();
            if let Ok(d) = store.fetch_weights_since(cursor) {
                d.apply_to(&mut mirror).unwrap();
                cursor = d.seq;
            }
        }
        store.set_enabled(false);
        let d = store.fetch_weights_since(cursor).unwrap();
        d.apply_to(&mut mirror).unwrap();
        assert_eq!(mirror, oracle.fetch_weights().unwrap());
        oracle.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn cursors_roundtrip_over_tcp() {
    let (addr, handle) = spawn_store(8);
    {
        let c = Client::connect(&addr).unwrap();
        assert_eq!(c.load_cursor("master").unwrap(), None);
        let d = c.fetch_weights_since(0).unwrap();
        c.save_cursor("master", d.seq).unwrap();
        assert_eq!(c.load_cursor("master").unwrap(), Some(d.seq));
        // Empty names are a server-side error, not a dropped connection.
        assert!(c.save_cursor("", 1).is_err());
        assert_eq!(c.load_cursor("master").unwrap(), Some(d.seq));
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn durable_store_over_tcp_resumes_across_server_restarts() {
    // The `issgd db-server --store-path` shape: the TCP server is generic
    // over its backend, so a durable store serves remote clients and a
    // server restart (process crash) loses neither the table nor the
    // consumers' saved cursors — the remote master resumes incrementally.
    use issgd::weightstore::durable::{DurableOptions, DurableStore};

    let dir = std::env::temp_dir().join(format!("issgd-tcp-durable-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = DurableOptions {
        segment_bytes: 1 << 14,
        compact_after_bytes: 0,
        ..DurableOptions::default()
    };

    // Serve cycle 1: create, write, persist a cursor.
    let (cursor, table) = {
        let store = Arc::new(DurableStore::create(&dir, 32, 1.0, opts.clone()).unwrap());
        let server = Server::bind("127.0.0.1:0", store).unwrap();
        let (addr, handle) = server.serve_in_background().unwrap();
        let c = Client::connect(&addr.to_string()).unwrap();
        c.push_weights(3, &[5.0, 6.0], 2).unwrap();
        c.push_weights(20, &[9.0], 3).unwrap();
        let d = c.fetch_weights_since(0).unwrap();
        c.save_cursor("master", d.seq).unwrap();
        let table = c.fetch_weights().unwrap();
        c.shutdown_server().unwrap();
        handle.join().unwrap();
        (d.seq, table)
    };
    // serve() joins every handler thread before returning, so once the
    // join above came back no connection still holds the old store — the
    // directory can be reopened immediately without racing a late write.

    // Serve cycle 2: recover from disk, the remote consumer continues.
    {
        let store = Arc::new(DurableStore::open(&dir, opts).unwrap());
        let server = Server::bind("127.0.0.1:0", store).unwrap();
        let (addr, handle) = server.serve_in_background().unwrap();
        let c = Client::connect(&addr.to_string()).unwrap();
        assert_eq!(c.fetch_weights().unwrap(), table);
        assert_eq!(c.load_cursor("master").unwrap(), Some(cursor));
        let d = c.fetch_weights_since(cursor).unwrap();
        assert!(!d.full, "remote master demoted to full resync after restart");
        assert!(d.is_empty());
        c.push_weights(0, &[7.0], 9).unwrap();
        let d = c.fetch_weights_since(cursor).unwrap();
        assert_eq!(d.indices, vec![0]);
        c.shutdown_server().unwrap();
        handle.join().unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Event-loop regressions: desync poisoning, timeouts, malformed frames,
// pipelining, connection-scale soak, slow-reader eviction.
// ---------------------------------------------------------------------------

#[test]
fn client_poisons_desynced_connection_and_reconnects() {
    // The desync bug: a mid-call i/o error used to leave the shared stream
    // with half a response in flight; the next call would pair its request
    // with the stale bytes and return another call's answer.  The client
    // must poison the connection instead and reconnect.
    use issgd::weightstore::client::ClientOptions;
    use issgd::weightstore::protocol::{read_frame, write_frame, Response};
    use std::io::Write;
    use std::time::Duration;

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    let fake = std::thread::spawn(move || {
        // Connection 1: read the request, answer with HALF a frame
        // carrying a stale cursor Some(7), then stall.
        let (mut s1, _) = listener.accept().unwrap();
        let _req = read_frame(&mut s1).unwrap();
        let payload = Response::Cursor(Some(7)).encode();
        let mut frame = (payload.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&payload);
        let half = frame.len() / 2;
        s1.write_all(&frame[..half]).unwrap();
        s1.flush().unwrap();
        // Once the client has timed out, complete the stale frame: a
        // non-poisoning client would read it as the answer to its NEXT
        // request and report Some(7).
        rx.recv().unwrap();
        let _ = s1.write_all(&frame[half..]);
        // Connection 2: a well-behaved responder with the true value.
        let (mut s2, _) = listener.accept().unwrap();
        while let Ok(_req) = read_frame(&mut s2) {
            write_frame(&mut s2, &Response::Cursor(Some(42)).encode()).unwrap();
        }
    });

    let opts = ClientOptions {
        io_timeout: Duration::from_millis(200),
        connect_attempts: 1,
        ..ClientOptions::default()
    };
    let c = Client::connect_with(&addr, opts).unwrap();
    // Mid-frame stall: the call errors out instead of hanging, and the
    // connection is poisoned.
    let err = c.load_cursor("x").unwrap_err();
    assert!(format!("{err:#}").contains("poisoned"), "{err:#}");
    tx.send(()).unwrap();
    // The next call transparently reconnects and gets the *correct*
    // answer — not the stale Some(7) now sitting in the first stream.
    assert_eq!(c.load_cursor("x").unwrap(), Some(42));
    drop(c);
    fake.join().unwrap();
}

#[test]
fn hung_server_times_out_instead_of_blocking_forever() {
    // The no-timeout bug: a server that accepts but never responds used to
    // block the calling actor forever on a bare `read`.
    use issgd::weightstore::client::ClientOptions;
    use std::time::Duration;

    // Accepts via the kernel backlog, never answers.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ClientOptions {
        connect_timeout: Duration::from_secs(2),
        io_timeout: Duration::from_millis(200),
        connect_attempts: 1,
        ..ClientOptions::default()
    };
    let c = Client::connect_with(&addr, opts).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        tx.send(c.now().map(|_| ())).unwrap();
    });
    let outcome = rx
        .recv_timeout(Duration::from_secs(5))
        .expect("client call hung: io timeout never kicked in");
    assert!(outcome.is_err());
    drop(listener);
}

#[test]
fn malformed_frame_gets_err_response_and_keeps_connection() {
    use issgd::weightstore::protocol::{read_frame, write_frame, Request, Response};
    use std::io::{Read, Write};

    let (addr, handle) = spawn_store(4);
    let mut s = std::net::TcpStream::connect(&addr).unwrap();
    // Well-framed but undecodable payload (no such opcode): answered
    // in-band, connection kept.
    write_frame(&mut s, &[0x7f]).unwrap();
    match Response::decode(&read_frame(&mut s).unwrap()).unwrap() {
        Response::Err(msg) => assert!(msg.contains("protocol error"), "{msg}"),
        other => panic!("expected Response::Err, got {other:?}"),
    }
    // Same connection still serves valid requests.
    write_frame(&mut s, &Request::Now.encode()).unwrap();
    let resp = Response::decode(&read_frame(&mut s).unwrap()).unwrap();
    assert!(matches!(resp, Response::Now(_)), "{resp:?}");
    // The transport folds its error count into Stats.
    let c = Client::connect(&addr).unwrap();
    assert_eq!(c.stats().unwrap().protocol_errors, 1);
    // Framing-level corruption (length beyond MAX_FRAME) is different:
    // the stream offset can't be trusted, so the connection is dropped.
    let mut bad = std::net::TcpStream::connect(&addr).unwrap();
    bad.write_all(&u32::MAX.to_le_bytes()).unwrap();
    bad.set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    let mut buf = [0u8; 1];
    match bad.read(&mut buf) {
        Ok(0) => {} // EOF: dropped as required
        Err(e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
        {
            panic!("connection survived framing corruption")
        }
        Err(_) => {} // reset is also a drop
        Ok(n) => panic!("expected drop, got {n} bytes"),
    }
    c.shutdown_server().unwrap();
    handle.join().unwrap();
}

#[test]
fn pipelined_requests_get_in_order_responses() {
    use issgd::weightstore::protocol::{read_frame, Request, Response};
    use std::io::Write;

    let (addr, handle) = spawn_store(8);
    let mut s = std::net::TcpStream::connect(&addr).unwrap();
    let reqs = [
        Request::SaveCursor {
            name: "pipe".into(),
            seq: 5,
        },
        Request::Now,
        Request::LoadCursor { name: "pipe".into() },
    ];
    let mut batch = Vec::new();
    for req in &reqs {
        let p = req.encode();
        batch.extend_from_slice(&(p.len() as u32).to_le_bytes());
        batch.extend_from_slice(&p);
    }
    // One write, three frames: the server must decode all of them in this
    // tick and answer the k-th response to the k-th request.
    s.write_all(&batch).unwrap();
    let r = Response::decode(&read_frame(&mut s).unwrap()).unwrap();
    assert!(matches!(r, Response::Ok), "{r:?}");
    let r = Response::decode(&read_frame(&mut s).unwrap()).unwrap();
    assert!(matches!(r, Response::Now(_)), "{r:?}");
    match Response::decode(&read_frame(&mut s).unwrap()).unwrap() {
        Response::Cursor(cur) => assert_eq!(cur, Some(5)),
        other => panic!("out-of-order response: {other:?}"),
    }
    Client::connect(&addr).unwrap().shutdown_server().unwrap();
    handle.join().unwrap();
}

/// 256 pipelined connections hammering one event loop with mixed traffic;
/// every client asserts the in-order response contract and that it reads
/// back its *own* cursor, never a neighbour's.
fn soak_event_loop(store: Arc<dyn WeightStore>) {
    use issgd::weightstore::protocol::{read_frame, Request, Response};
    use std::io::Write;

    const CLIENTS: usize = 256;
    const THREADS: usize = 16;
    const PER: usize = CLIENTS / THREADS;
    const ROUNDS: usize = 3;

    let server = Server::bind("127.0.0.1:0", store).unwrap();
    let (addr, handle) = server.serve_in_background().unwrap();
    let addr = addr.to_string();
    let mut joins = Vec::new();
    for t in 0..THREADS {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut socks: Vec<std::net::TcpStream> = (0..PER)
                .map(|_| {
                    let s = std::net::TcpStream::connect(&addr).unwrap();
                    s.set_nodelay(true).ok();
                    s
                })
                .collect();
            for round in 0..ROUNDS {
                for (j, s) in socks.iter_mut().enumerate() {
                    let id = t * PER + j;
                    let name = format!("client-{id}");
                    let seq = (round as u64 + 1) * 1_000 + id as u64;
                    let val = (id * 8 + round) as f32;
                    let reqs = [
                        Request::PushWeights {
                            start: (id * 4) as u64,
                            param_version: round as u64 + 1,
                            weights: vec![val; 4],
                        },
                        Request::FetchWeightsSince { seq: 0 },
                        Request::SaveCursor {
                            name: name.clone(),
                            seq,
                        },
                        Request::LoadCursor { name: name.clone() },
                        Request::Now,
                    ];
                    let mut batch = Vec::new();
                    for req in &reqs {
                        let p = req.encode();
                        batch.extend_from_slice(&(p.len() as u32).to_le_bytes());
                        batch.extend_from_slice(&p);
                    }
                    s.write_all(&batch).unwrap();
                    let r = Response::decode(&read_frame(s).unwrap()).unwrap();
                    assert!(matches!(r, Response::Ok), "client {id}: push ack, got {r:?}");
                    match Response::decode(&read_frame(s).unwrap()).unwrap() {
                        Response::WeightsDelta(d) => {
                            assert!(d.full, "client {id}: seq-0 fetch must be full")
                        }
                        other => panic!("client {id}: fetch, got {other:?}"),
                    }
                    let r = Response::decode(&read_frame(s).unwrap()).unwrap();
                    assert!(matches!(r, Response::Ok), "client {id}: cursor ack, got {r:?}");
                    match Response::decode(&read_frame(s).unwrap()).unwrap() {
                        Response::Cursor(cur) => {
                            assert_eq!(cur, Some(seq), "client {id}: read a foreign cursor")
                        }
                        other => panic!("client {id}: load_cursor, got {other:?}"),
                    }
                    let r = Response::decode(&read_frame(s).unwrap()).unwrap();
                    assert!(matches!(r, Response::Now(_)), "client {id}: now, got {r:?}");
                }
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let c = Client::connect(&addr).unwrap();
    let snap = c.fetch_weights().unwrap();
    for id in 0..CLIENTS {
        let expect = (id * 8 + ROUNDS - 1) as f64;
        for k in 0..4 {
            assert_eq!(snap.weights[id * 4 + k], expect, "client {id} lost its final write");
        }
    }
    let stats = c.stats().unwrap();
    assert_eq!(stats.weight_pushes, (CLIENTS * ROUNDS) as u64);
    assert_eq!(stats.protocol_errors, 0);
    c.shutdown_server().unwrap();
    handle.join().unwrap();
}

#[test]
fn soak_256_clients_over_memstore() {
    soak_event_loop(Arc::new(MemStore::new(1024, 0.0)));
}

#[test]
fn soak_256_clients_over_durable_store() {
    use issgd::weightstore::durable::{DurableOptions, DurableStore};
    let dir = std::env::temp_dir().join(format!("issgd-tcp-soak-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    soak_event_loop(Arc::new(
        DurableStore::create(&dir, 1024, 0.0, DurableOptions::default()).unwrap(),
    ));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn slow_reader_is_evicted_but_prompt_clients_survive() {
    use issgd::weightstore::protocol::{Request, Response};
    use issgd::weightstore::server::ServerOptions;
    use std::io::{Read, Write};

    let n = 64_000usize;
    let server = Server::bind_with_options(
        "127.0.0.1:0",
        Arc::new(MemStore::new(n, 1.0)),
        ServerOptions {
            max_write_queue: 256 << 10,
        },
    )
    .unwrap();
    let (addr, handle) = server.serve_in_background().unwrap();
    let addr = addr.to_string();

    // One full-snapshot response is ~24 B/weight — far over the cap.
    let frame_len = 4 + Response::Weights(MemStore::new(n, 1.0).fetch_weights().unwrap())
        .encode()
        .len();

    let mut slow = std::net::TcpStream::connect(&addr).unwrap();
    let req = Request::FetchWeights.encode();
    let mut batch = Vec::new();
    for _ in 0..10 {
        batch.extend_from_slice(&(req.len() as u32).to_le_bytes());
        batch.extend_from_slice(&req);
    }
    slow.write_all(&batch).unwrap();
    // Never read.  The queue blows past the cap, the server evicts, and
    // draining afterwards yields only what the kernel had already
    // buffered — far less than the 10 snapshots a live connection owes.
    slow.set_read_timeout(Some(std::time::Duration::from_secs(30)))
        .unwrap();
    let mut total = 0usize;
    let mut buf = vec![0u8; 64 << 10];
    loop {
        match slow.read(&mut buf) {
            Ok(0) => break,
            Ok(k) => total += k,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                panic!("slow reader was never evicted ({total} bytes read so far)")
            }
            Err(_) => break, // reset is eviction too
        }
    }
    assert!(
        total < 5 * frame_len,
        "evicted connection still received {total} of {} queued bytes",
        10 * frame_len
    );

    // Eviction killed one connection, not the loop: prompt clients are
    // still served.
    let c = Client::connect(&addr).unwrap();
    c.now().unwrap();
    assert_eq!(c.fetch_weights().unwrap().weights.len(), n);
    c.shutdown_server().unwrap();
    handle.join().unwrap();
}

#[test]
fn client_pool_shares_connections_across_threads() {
    use issgd::weightstore::client::ClientPool;

    let (addr, handle) = spawn_store(32);
    let pool = Arc::new(ClientPool::new(&addr, 3));
    // More threads than pooled connections: every op checks a connection
    // out, runs exactly one request/response, and checks it back in.
    let mut joins = Vec::new();
    for t in 0..8usize {
        let pool = Arc::clone(&pool);
        joins.push(std::thread::spawn(move || {
            for i in 0..10usize {
                pool.push_weights(t * 4, &[t as f32 + 1.0], (i + 1) as u64)
                    .unwrap();
                let d = pool.fetch_weights_since(0).unwrap();
                assert!(d.full);
                assert_eq!(pool.load_cursor("missing").unwrap(), None);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let stats = pool.stats().unwrap();
    assert_eq!(stats.weight_pushes, 80);
    // Same-cursor fetches may coalesce into shared round-trips, so the
    // server-side count can be below the 80 issued — never above.
    assert!(
        (1..=80u64).contains(&stats.delta_fetches),
        "delta_fetches = {}",
        stats.delta_fetches
    );
    Client::connect(&addr).unwrap().shutdown_server().unwrap();
    handle.join().unwrap();
}

#[test]
fn shutdown_releases_idle_and_hung_connections() {
    // The handler-leak fix: connection reads poll the stop flag, so after
    // Shutdown a client that is idle — or hung mid-frame, the worst case —
    // no longer pins its handler thread; the handler exits and the socket
    // closes underneath the client.
    use std::io::{Read, Write};

    let (addr, handle) = spawn_store(4);
    // An idle connection (no bytes sent) and a hung one (half a frame
    // header, then silence).
    let mut idle = std::net::TcpStream::connect(&addr).unwrap();
    let mut hung = std::net::TcpStream::connect(&addr).unwrap();
    hung.write_all(&[5, 0]).unwrap();
    // Let both handlers enter their read loops, then shut down.
    std::thread::sleep(std::time::Duration::from_millis(100));
    let c = Client::connect(&addr).unwrap();
    c.shutdown_server().unwrap();
    handle.join().unwrap();
    for (name, stream) in [("idle", &mut idle), ("hung", &mut hung)] {
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        let mut buf = [0u8; 1];
        match stream.read(&mut buf) {
            Ok(0) => {} // EOF: the handler thread released us
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                panic!("{name} connection still pinned a handler thread after shutdown")
            }
            Err(_) => {} // reset is also a release
            Ok(n) => panic!("unexpected {n} bytes on the {name} connection"),
        }
    }
}

/// `FetchMetrics` round-trip against a MemStore-backed server: the scrape
/// must parse as a telemetry snapshot, carry the pre-registered canonical
/// schema, and show the event loop's tick histogram actually populated.
#[test]
fn metrics_scrape_over_tcp() {
    let (addr, handle) = spawn_store(8);
    {
        let c = Client::connect(&addr).unwrap();
        // Some traffic first, so the scrape reflects served requests.
        c.push_weights(0, &[1.5, 2.5], 1).unwrap();
        let _ = c.fetch_weights().unwrap();
        let text = c.fetch_metrics().unwrap();
        let snap = issgd::telemetry::Snapshot::from_json_str(&text).unwrap();
        // Ticks that served the requests above were recorded before the
        // scrape's own tick, so the histogram cannot be empty.
        let ticks = &snap.histograms["server.tick_ns"];
        assert!(ticks.count > 0, "event loop recorded no ticks");
        assert!(ticks.p50() <= ticks.p99());
        assert!(ticks.max >= ticks.p99());
        // The full canonical schema is pre-registered at serve() start —
        // including metrics owned by other subsystems, still at zero here.
        assert!(snap.counters.contains_key("server.evictions"));
        assert!(snap.counters.contains_key("client.reconnects"));
        assert!(snap.counters.contains_key("client.protocol_errors"));
        assert!(snap.histograms.contains_key("journal.fsync_ns"));
        assert!(snap.histograms.contains_key("compact.duration_ns"));
        assert!(snap.gauges.contains_key("proposal.ess"));
        assert!(snap.gauges.contains_key("peer.cursor_lag"));
        // And the Prometheus rendering of the same snapshot is well-formed.
        let prom = snap.to_prometheus();
        assert!(prom.contains("# TYPE issgd_server_tick_ns summary"));
        assert!(prom.contains("issgd_server_tick_ns{quantile=\"0.99\"}"));
        assert!(prom.contains("issgd_server_evictions"));
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}
