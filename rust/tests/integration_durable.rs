//! Integration: the durable weight store subsystem end-to-end — kill /
//! reopen / resume with live consumer cursors, compaction + GC bounding
//! the on-disk footprint across snapshot cycles, and torn-tail recovery.
//!
//! The consumers here are the real coordinator state machines
//! (`ProposalMaintainer` in master mode and peer/coverage-prior mode),
//! driven directly so no AOT artifacts are needed: what is under test is
//! the store's half of the §4.2 topology, not the model.

use std::sync::Arc;

use issgd::config::StalenessUnit;
use issgd::coordinator::ProposalMaintainer;
use issgd::util::rng::Pcg64;
use issgd::weightstore::durable::{DurableOptions, DurableStore};
use issgd::weightstore::WeightStore;

mod common;
use common::TempDir;

fn small_opts() -> DurableOptions {
    DurableOptions {
        segment_bytes: 1 << 14,
        compact_after_bytes: 1 << 15,
        ..DurableOptions::default()
    }
}

/// The acceptance scenario: a master-mode and a peer-mode consumer keep
/// their proposals synced against a durable store that crashes (drop +
/// reopen) every cycle, with enough write traffic that the compactor runs
/// several snapshot cycles.  Both consumers must resume *incrementally*
/// from their persisted cursors after every crash, and the on-disk
/// footprint must stay bounded instead of growing with history.
#[test]
fn master_and_peer_resume_from_persisted_cursors_with_bounded_disk() {
    let dir = TempDir::new("resume");
    let n = 512usize;
    let mut master = ProposalMaintainer::new(n, 0.5, None, StalenessUnit::Versions);
    let mut peer = ProposalMaintainer::with_coverage_prior(n, 0.5, None, StalenessUnit::Versions);
    let mut rng = Pcg64::seeded(0xD04_AB1E);

    let mut store = Arc::new(DurableStore::create(&dir.0, n, 1.0, small_opts()).unwrap());
    // Bootstrap both consumers (full fetch) and persist their cursors.
    let d = store.fetch_weights_since(master.cursor()).unwrap();
    master.absorb(&d, 0).unwrap();
    store.save_cursor("master", master.cursor()).unwrap();
    let d = store.fetch_weights_since(peer.cursor()).unwrap();
    peer.absorb(&d, 0).unwrap();
    store.save_cursor("peer-0", peer.cursor()).unwrap();

    let mut compactions_total = 0u64;
    let mut disk_per_cycle: Vec<u64> = Vec::new();
    for cycle in 0..4 {
        for round in 0..200u64 {
            let start = rng.next_below((n - 8) as u64) as usize;
            let vals: Vec<f32> = (0..8).map(|_| rng.next_f32().abs() + 0.01).collect();
            store.push_weights(start, &vals, cycle as u64 * 200 + round + 1).unwrap();
            if round % 3 == 0 {
                let d = store.fetch_weights_since(master.cursor()).unwrap();
                assert!(!d.full, "master demoted to full mid-cycle {cycle}");
                master.absorb(&d, 0).unwrap();
                store.save_cursor("master", master.cursor()).unwrap();
            }
            if round % 5 == 0 {
                let d = store.fetch_weights_since(peer.cursor()).unwrap();
                assert!(!d.full, "peer demoted to full mid-cycle {cycle}");
                peer.absorb(&d, 0).unwrap();
                store.save_cursor("peer-0", peer.cursor()).unwrap();
            }
        }
        // Compaction is a background thread now: let any signalled cycle
        // finish before reading its counters and the disk footprint.
        store.quiesce_compactor();
        compactions_total += store.compactions();
        disk_per_cycle.push(store.disk_bytes().unwrap());

        // Crash: drop the only handle, reopen from disk.
        let seq_before = store.write_seq();
        let table_before = store.fetch_weights().unwrap();
        drop(store);
        store = Arc::new(DurableStore::open(&dir.0, small_opts()).unwrap());

        // The store came back bit-exact (stamps included: the journal is
        // exact) and remembers both consumers.
        assert_eq!(store.write_seq(), seq_before, "write sequence lost in crash {cycle}");
        assert_eq!(store.fetch_weights().unwrap(), table_before);
        assert_eq!(store.load_cursor("master").unwrap(), Some(master.cursor()));
        assert_eq!(store.load_cursor("peer-0").unwrap(), Some(peer.cursor()));

        // THE acceptance point: both consumers continue incrementally from
        // their persisted cursors — no O(N) re-score after the restart.
        let d = store.fetch_weights_since(master.cursor()).unwrap();
        assert!(!d.full, "master demoted to full resync after crash {cycle}");
        master.absorb(&d, 0).unwrap();
        store.save_cursor("master", master.cursor()).unwrap();
        let d = store.fetch_weights_since(peer.cursor()).unwrap();
        assert!(!d.full, "peer demoted to full resync after crash {cycle}");
        peer.absorb(&d, 0).unwrap();
        store.save_cursor("peer-0", peer.cursor()).unwrap();
    }

    // ≥3 snapshot cycles actually happened (the acceptance bar), and disk
    // stayed bounded: the last cycle's footprint is within a small factor
    // of the first's and under an absolute ceiling, instead of growing
    // with ~800 rounds of history.
    assert!(
        compactions_total >= 3,
        "only {compactions_total} snapshot cycles ran"
    );
    let first = *disk_per_cycle.first().unwrap();
    let last = *disk_per_cycle.last().unwrap();
    assert!(
        last <= first.saturating_mul(3).max(256 << 10),
        "disk grew unboundedly: first cycle {first} B, last cycle {last} B"
    );
    assert!(last < (1 << 20), "disk footprint {last} B exceeds 1 MiB at n=512");

    // Final convergence: both mirrors equal the store's table exactly.
    let truth = store.fetch_weights().unwrap();
    assert_eq!(*master.raw(), truth);
    assert_eq!(*peer.raw(), truth);

    // GC hygiene: the directory holds the latest snapshot + live segments,
    // not 4 cycles of history.
    let files = std::fs::read_dir(&dir.0).unwrap().count();
    assert!(files <= 8, "GC left {files} files behind");
}

/// A consumer that never saves a cursor is still correct after a crash —
/// it just pays the documented full-table fallback once compaction has
/// folded history past its private cursor.
#[test]
fn unpinned_consumer_degrades_to_full_fallback_not_corruption() {
    let dir = TempDir::new("unpinned");
    let n = 64usize;
    let store = DurableStore::create(&dir.0, n, 1.0, small_opts()).unwrap();
    let d = store.fetch_weights_since(0).unwrap();
    let mut mirror = d.to_snapshot().unwrap();
    let mut cursor = d.seq;
    for round in 0..50u64 {
        store.push_weights((round as usize * 7) % 56, &[round as f32 + 1.0], round + 1).unwrap();
    }
    // No pins anywhere: the compactor may fold everything.
    store.compact().unwrap();
    let d = store.fetch_weights_since(cursor).unwrap();
    assert!(d.full, "history below the fold should no longer be servable");
    d.apply_to(&mut mirror).unwrap();
    cursor = d.seq;
    assert_eq!(mirror, store.fetch_weights().unwrap());
    // Incremental service resumes from the post-fold cursor.
    store.push_weights(0, &[99.0], 77).unwrap();
    let d = store.fetch_weights_since(cursor).unwrap();
    assert!(!d.full);
    assert_eq!(d.indices, vec![0]);
}

/// Crash mid-append: garbage after the last complete frame is truncated on
/// reopen and the store keeps serving + journaling.
#[test]
fn torn_tail_recovery_is_repeatable() {
    let dir = TempDir::new("torn");
    let n = 16usize;
    let store = DurableStore::create(&dir.0, n, 1.0, small_opts()).unwrap();
    for i in 0..5 {
        store.push_weights(i, &[i as f32 + 1.0], 1).unwrap();
    }
    let want = store.fetch_weights().unwrap();
    drop(store);
    for garbage in [vec![0x7Fu8], vec![0xFF; 6], vec![0xAB; 13]] {
        // Damage the newest segment's tail...
        let segs =
            issgd::weightstore::segment::list_numbered(&dir.0, "seg-", ".log").unwrap();
        let (_, newest) = segs.last().unwrap();
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(newest).unwrap();
        f.write_all(&garbage).unwrap();
        drop(f);
        // ...and recover: the table is intact every time.
        let back = DurableStore::open(&dir.0, small_opts()).unwrap();
        assert_eq!(back.fetch_weights().unwrap(), want);
        drop(back);
    }
}

/// The layer-wise params acceptance point: a partial layer publish
/// journals only the layers it carried — the durable journal no longer
/// grows by the whole blob per publish — while crash recovery reproduces
/// the blob, the per-layer versions, and a consumer's incremental
/// position bit-exactly.
#[test]
fn params_journal_is_layerwise_and_recovery_stays_bit_exact() {
    let dir = TempDir::new("params-journal");
    // Explicit-compaction-only options: every byte written between the
    // measurements below is journal growth from the pushes themselves.
    let opts = DurableOptions {
        segment_bytes: u64::MAX,
        compact_after_bytes: 0,
        ..DurableOptions::default()
    };
    let n_layers = 8usize;
    let layer_bytes = 4096usize;
    let store = DurableStore::create(&dir.0, 4, 1.0, opts.clone()).unwrap();
    let full: Vec<(String, Vec<u8>)> = (0..n_layers)
        .map(|i| (format!("L{i}"), vec![i as u8; layer_bytes]))
        .collect();
    store.push_params_layers(1, true, &full).unwrap();

    // 100 single-layer updates.  Whole-blob journaling would cost
    // ~100 × 8 × 4 KiB = 3.2 MiB; layer-wise is ~100 × 4 KiB.
    let before = store.disk_bytes().unwrap();
    let mut rng = Pcg64::seeded(0x1A7E5);
    let mut version = 1u64;
    for round in 0..100u64 {
        let i = rng.next_below(n_layers as u64) as usize;
        version += 1;
        let payload = vec![(round % 251) as u8; layer_bytes];
        store
            .push_params_layers(version, false, &[(format!("L{i}"), payload)])
            .unwrap();
    }
    let growth = store.disk_bytes().unwrap() - before;
    let blob_cost = 100 * n_layers as u64 * layer_bytes as u64;
    assert!(
        growth < blob_cost / 4,
        "params journal grew {growth} B over 100 partial pushes — \
         whole-blob records would cost ~{blob_cost} B; layer records should be ~1/8 of that"
    );

    // A consumer absorbed everything up to the head; another sits mid-way.
    let head = store.fetch_params_since(0).unwrap().unwrap().version;
    assert_eq!(head, version);
    let mid = version - 10;
    let want_blob = store.fetch_params(0).unwrap().unwrap();
    let want_mid_delta = store.fetch_params_since(mid).unwrap().unwrap();
    assert!(!want_mid_delta.full, "mid-stream cursor demoted to full");

    // Crash (journal replay only), then again after a checkpoint: both
    // recovery paths must reproduce the same params state bit-exactly.
    drop(store);
    let back = DurableStore::open(&dir.0, opts.clone()).unwrap();
    assert_eq!(back.fetch_params(0).unwrap().unwrap(), want_blob);
    assert_eq!(back.fetch_params_since(mid).unwrap().unwrap(), want_mid_delta);
    assert!(back.fetch_params_since(version).unwrap().is_none());
    back.compact().unwrap(); // snapshot now holds the layer patches
    drop(back);
    let again = DurableStore::open(&dir.0, opts).unwrap();
    assert_eq!(again.fetch_params(0).unwrap().unwrap(), want_blob);
    assert_eq!(again.fetch_params_since(mid).unwrap().unwrap(), want_mid_delta);
    assert!(again.fetch_params_since(version).unwrap().is_none());
}

/// Satellite regression: a dead peer's saved cursor no longer pins the
/// compaction floor forever.  Kill the peer, drop (or expire) its pin,
/// and the floor advances past it while the live master stays
/// incremental.
#[test]
fn dead_peer_pin_is_dropped_and_the_floor_advances() {
    let dir = TempDir::new("dead-peer");
    let n = 64usize;
    let store = DurableStore::create(&dir.0, n, 1.0, small_opts()).unwrap();
    let mut master = ProposalMaintainer::new(n, 0.5, None, StalenessUnit::Versions);
    let mut peer = ProposalMaintainer::with_coverage_prior(n, 0.5, None, StalenessUnit::Versions);
    let d = store.fetch_weights_since(master.cursor()).unwrap();
    master.absorb(&d, 0).unwrap();
    store.save_cursor("master", master.cursor()).unwrap();
    let d = store.fetch_weights_since(peer.cursor()).unwrap();
    peer.absorb(&d, 0).unwrap();
    store.save_cursor("peer-0", peer.cursor()).unwrap();
    let dead_pin = peer.cursor();
    // The peer dies here: no more fetches, no more saves.  The master
    // keeps working.
    for round in 0..200u64 {
        store.push_weights((round as usize * 3) % 56, &[round as f32 + 1.0], round + 1).unwrap();
        if round % 3 == 0 {
            let d = store.fetch_weights_since(master.cursor()).unwrap();
            master.absorb(&d, 0).unwrap();
            store.save_cursor("master", master.cursor()).unwrap();
        }
    }
    store.quiesce_compactor();
    // However many cycles ran, the dead pin clamps the floor.
    assert!(
        store.compact_floor() <= dead_pin,
        "floor {} moved past a live pin at {dead_pin}",
        store.compact_floor()
    );
    // Reap the dead peer and compact: the floor advances to the master.
    store.drop_cursor("peer-0").unwrap();
    store.compact().unwrap();
    assert!(
        store.compact_floor() > dead_pin,
        "floor {} still stuck at the dead peer's pin {dead_pin}",
        store.compact_floor()
    );
    assert_eq!(store.compact_floor(), master.cursor());
    // The live master is still served incrementally...
    let d = store.fetch_weights_since(master.cursor()).unwrap();
    assert!(!d.full, "live master demoted to full by the reap");
    master.absorb(&d, 0).unwrap();
    assert_eq!(*master.raw(), store.fetch_weights().unwrap());
    // ...and the returned-from-the-dead peer degrades to the documented
    // full fallback instead of corrupting.
    let d = store.fetch_weights_since(peer.cursor()).unwrap();
    assert!(d.full);
    peer.absorb(&d, 0).unwrap();
    assert_eq!(*peer.raw(), store.fetch_weights().unwrap());
}

/// `FetchMetrics` against a DurableStore-backed server: the journal's
/// fsync-latency histogram and appended-bytes counter must reflect the
/// writes served between two scrapes.  (The telemetry registry is
/// process-global, so assertions are deltas between scrapes — other
/// tests' journals only push the deltas higher, never lower.)
#[test]
fn metrics_scrape_reflects_journal_activity() {
    use issgd::telemetry::Snapshot;
    use issgd::weightstore::client::Client;
    use issgd::weightstore::server::Server;

    let dir = TempDir::new("metrics");
    let store = DurableStore::create(&dir.0, 32, 1.0, small_opts()).unwrap();
    let server = Server::bind("127.0.0.1:0", Arc::new(store)).unwrap();
    let (addr, handle) = server.serve_in_background().unwrap();
    {
        let c = Client::connect(&addr.to_string()).unwrap();
        let before = Snapshot::from_json_str(&c.fetch_metrics().unwrap()).unwrap();
        for i in 0..16u64 {
            c.push_weights((i % 32) as usize, &[i as f32 + 0.5], i).unwrap();
        }
        // Request/response is synchronous, so by this scrape all 16
        // appends have hit the journal.
        let after = Snapshot::from_json_str(&c.fetch_metrics().unwrap()).unwrap();
        let fsyncs = after.histograms["journal.fsync_ns"].count
            - before.histograms["journal.fsync_ns"].count;
        assert!(fsyncs >= 16, "expected >= 16 timed journal appends, saw {fsyncs}");
        let bytes = after.counters["journal.bytes"] - before.counters["journal.bytes"];
        assert!(bytes > 0, "journal byte counter did not move");
        c.shutdown_server().unwrap();
    }
    handle.join().unwrap();
}
