//! Variance monitors: the paper's Tr(Σ(q)) estimators for the three
//! proposals compared in §4/§5 and Figure 4.
//!
//! Given per-example gradient norms ‖g(x_n)‖ under the *current* parameters
//! and the (possibly stale, possibly smoothed) probability weights ω̃_n the
//! master actually samples with:
//!
//!   Tr(Σ(q_IDEAL)) = (mean_n ‖g_n‖)²                    − ‖g_TRUE‖²   (eq 7)
//!   Tr(Σ(q_UNIF))  =  mean_n ‖g_n‖²                     − ‖g_TRUE‖²   (eq 8)
//!   Tr(Σ(q_STALE)) = (mean_n ω̃_n)(mean_n ‖g_n‖²/ω̃_n)   − ‖g_TRUE‖²   (eq 9)
//!
//! ‖g_TRUE‖² is common to all three, so the *ordering* is insensitive to
//! how it is approximated (§B.2) — we expose both the raw second moments
//! and the ‖g_TRUE‖²-corrected values.

/// One Tr(Σ) measurement for the three proposals.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VarianceReport {
    /// Raw second-moment term of eq. 7 (before subtracting ‖g_TRUE‖²).
    pub ideal_raw: f64,
    /// Raw term of eq. 9.
    pub stale_raw: f64,
    /// Raw term of eq. 8.
    pub unif_raw: f64,
    /// The ‖g_TRUE‖² estimate used for the corrected values.
    pub g_true_sq: f64,
    /// Fraction of examples with usable (positive) stale weights.
    pub kept_frac: f64,
}

impl VarianceReport {
    pub fn ideal(&self) -> f64 {
        (self.ideal_raw - self.g_true_sq).max(0.0)
    }
    pub fn stale(&self) -> f64 {
        (self.stale_raw - self.g_true_sq).max(0.0)
    }
    pub fn unif(&self) -> f64 {
        (self.unif_raw - self.g_true_sq).max(0.0)
    }

    /// The §4.2 sanity ordering on the raw terms (always true
    /// mathematically for ideal ≤ stale by Cauchy-Schwarz; stale ≤ unif
    /// only when the weights still carry signal).
    pub fn ordering_holds(&self) -> bool {
        self.ideal_raw <= self.stale_raw * (1.0 + 1e-9) + 1e-12
    }
}

/// Compute the three Tr(Σ) raw terms from current squared gradient norms
/// `sqnorms[n] = ‖g(x_n)‖²` and the sampling weights `stale_weights` the
/// master is actually using (post smoothing/staleness-filter).
///
/// Indices whose stale weight is zero (filtered out, §B.1) are excluded
/// from all three averages, mirroring the paper's practice of restricting
/// the proposal to the kept subset.
pub fn trace_sigma(sqnorms: &[f64], stale_weights: &[f64], g_true_sq: f64) -> VarianceReport {
    assert_eq!(sqnorms.len(), stale_weights.len());
    let mut n_kept = 0usize;
    let (mut sum_norm, mut sum_sq, mut sum_w, mut sum_ratio) = (0.0, 0.0, 0.0, 0.0);
    for (&sq, &w) in sqnorms.iter().zip(stale_weights) {
        if w <= 0.0 {
            continue;
        }
        n_kept += 1;
        let norm = sq.max(0.0).sqrt();
        sum_norm += norm;
        sum_sq += sq.max(0.0);
        sum_w += w;
        sum_ratio += sq.max(0.0) / w;
    }
    if n_kept == 0 {
        return VarianceReport {
            ideal_raw: 0.0,
            stale_raw: 0.0,
            unif_raw: 0.0,
            g_true_sq,
            kept_frac: 0.0,
        };
    }
    let n = n_kept as f64;
    VarianceReport {
        ideal_raw: (sum_norm / n).powi(2),
        stale_raw: (sum_w / n) * (sum_ratio / n),
        unif_raw: sum_sq / n,
        g_true_sq,
        kept_frac: n / sqnorms.len() as f64,
    }
}

/// Running §B.2 estimator of ‖g_TRUE‖²: averages per-minibatch
/// ‖mean-gradient‖² values, which upper-bounds the true value and decays
/// to it as training converges.
#[derive(Debug, Clone, Default)]
pub struct GTrueEstimator {
    sum: f64,
    count: u64,
}

impl GTrueEstimator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, minibatch_sqnorm: f64) {
        self.sum += minibatch_sqnorm.max(0.0);
        self.count += 1;
    }

    /// Current estimate (0 before any observation — the conservative
    /// choice: raw terms are then reported uncorrected).
    pub fn estimate(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Forget history (call when parameters changed enough that old
    /// minibatch gradients are no longer representative).
    pub fn reset(&mut self) {
        self.sum = 0.0;
        self.count = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_equals_stale_when_weights_are_norms() {
        // If ω̃_n = ‖g_n‖ exactly, eq 9 reduces to eq 7.
        let sqnorms = vec![1.0, 4.0, 9.0, 16.0];
        let weights: Vec<f64> = sqnorms.iter().map(|s: &f64| s.sqrt()).collect();
        let r = trace_sigma(&sqnorms, &weights, 0.0);
        assert!((r.ideal_raw - r.stale_raw).abs() < 1e-12);
        assert_eq!(r.kept_frac, 1.0);
    }

    #[test]
    fn uniform_weights_reduce_stale_to_unif() {
        // If ω̃_n = const, eq 9 reduces to eq 8.
        let sqnorms = vec![1.0, 4.0, 9.0, 16.0];
        let r = trace_sigma(&sqnorms, &[7.0; 4], 0.0);
        assert!((r.stale_raw - r.unif_raw).abs() < 1e-12);
    }

    #[test]
    fn ordering_ideal_le_stale_le_unif_for_reasonable_weights() {
        // Stale-but-correlated weights: ideal ≤ stale ≤ unif (§4.2).
        let sqnorms = vec![0.25, 1.0, 4.0, 25.0, 100.0];
        let stale: Vec<f64> = sqnorms.iter().map(|s: &f64| s.sqrt() * 1.3 + 0.1).collect();
        let r = trace_sigma(&sqnorms, &stale, 0.0);
        assert!(r.ideal_raw <= r.stale_raw + 1e-12);
        assert!(r.stale_raw <= r.unif_raw + 1e-12);
        assert!(r.ordering_holds());
    }

    #[test]
    fn adversarial_weights_break_upper_ordering() {
        // Paper §4.2: random/anti-correlated weights CAN exceed uniform.
        let sqnorms = vec![100.0, 0.01];
        let stale = vec![0.01, 100.0]; // exactly wrong
        let r = trace_sigma(&sqnorms, &stale, 0.0);
        assert!(r.stale_raw > r.unif_raw);
        // ...but ideal ≤ stale always holds (Cauchy-Schwarz).
        assert!(r.ideal_raw <= r.stale_raw);
    }

    #[test]
    fn filtered_indices_are_excluded() {
        let sqnorms = vec![1.0, 4.0, 9.0, 16.0];
        let stale = vec![1.0, 0.0, 3.0, 0.0];
        let r = trace_sigma(&sqnorms, &stale, 0.0);
        assert_eq!(r.kept_frac, 0.5);
        // unif over kept subset {0, 2}: (1 + 9)/2
        assert!((r.unif_raw - 5.0).abs() < 1e-12);
    }

    #[test]
    fn correction_subtracts_g_true() {
        let r = trace_sigma(&[4.0, 4.0], &[2.0, 2.0], 1.5);
        assert!((r.unif() - 2.5).abs() < 1e-12);
        assert!((r.unif_raw - 4.0).abs() < 1e-12);
    }

    #[test]
    fn correction_clamps_at_zero() {
        let r = trace_sigma(&[1.0], &[1.0], 100.0);
        assert_eq!(r.unif(), 0.0);
    }

    #[test]
    fn g_true_estimator_averages() {
        let mut e = GTrueEstimator::new();
        assert_eq!(e.estimate(), 0.0);
        e.push(2.0);
        e.push(4.0);
        assert!((e.estimate() - 3.0).abs() < 1e-12);
        e.reset();
        assert_eq!(e.estimate(), 0.0);
    }

    #[test]
    fn empty_kept_set_is_all_zero() {
        let r = trace_sigma(&[1.0, 2.0], &[0.0, 0.0], 0.5);
        assert_eq!(r.kept_frac, 0.0);
        assert_eq!(r.ideal_raw, 0.0);
    }
}
