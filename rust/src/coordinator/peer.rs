//! Peer mode: ASGD, and the paper's §6 recommended ISSGD+ASGD combination.
//!
//! The paper's future-work section sketches how importance sampling should
//! be married to Asynchronous SGD: "get rid of the master/workers
//! distinction and have only workers (or *peers*) along with a parameter
//! server...  Whenever a gradient contribution is computed, the importance
//! weights can be obtained at the same time.  These can be shared in the
//! same way that the gradients are shared, so that all the workers are
//! able to use the importance weights to run ISSGD steps."
//!
//! We implement exactly that topology:
//! * the *parameter server* is the weight store's `apply_grad` op
//!   (`params -= lr * grad`, version bump per contribution);
//! * each *peer* loops: fetch latest params (stale between fetches),
//!   draw a minibatch — uniformly (plain ASGD) or by importance sampling
//!   from the shared weights (ISSGD+ASGD) — run the `peer_step` artifact,
//!   push the gradient, and push the per-example norms that came for free.
//!
//! # Incremental proposal maintenance
//!
//! ISSGD+ASGD peers keep their proposal synced the same way the master
//! does: a [`ProposalMaintainer`] in coverage-prior mode mirrors the store
//! through `fetch_weights_since(cursor)` deltas, so one peer step costs
//! O(changes · log N) Fenwick point updates instead of the old full
//! `fetch_weights()` snapshot + `FenwickSampler::new` rebuild (O(N) bytes
//! and work per step — the overhead that 1803.00942 identifies as the
//! reason importance sampling rarely pays off).  The coverage-correction
//! prior (never-scored entries priced at the mean of scored weights) is
//! folded into the maintainer as two running sums, so it moves with every
//! delta at no extra cost.
//!
//! Each `PeerState` holds an `Arc<Mutex<ProposalMaintainer>>`: the
//! in-process `run_asgd_sim` hands every peer the *same* maintainer (one
//! mirror, one cursor, lock-guarded — all peers observe the same store so
//! sharing is both correct and memory-frugal), while a distributed
//! deployment gives each peer its own maintainer whose private cursor
//! advances independently — the store's cursor contract is per-consumer
//! (see `WeightStore::fetch_weights_since`).
//!
//! Weight write-back is *coalesced*: the sampled positions are sorted,
//! de-duplicated (last slot wins, matching sequential push order) and
//! contiguous runs are pushed as single `push_weights` calls — one store
//! round-trip, one write-sequence bump, and one delta entry per run
//! instead of per example.
//!
//! `run_asgd_sim` drives the peers in a deterministic round-robin with a
//! configurable fetch cadence, so gradients are genuinely stale (a peer
//! computes on params that other peers have since updated) while runs
//! remain reproducible.  The same [`PeerState`] also powers the live
//! threaded topology (`super::peer_live::run_peer_live`), where every peer
//! is a real OS thread with its *own* maintainer and delta cursor.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::{RunConfig, StalenessUnit, TrainerKind};
use crate::data::{BatchBuilder, SynthDataset};
use crate::metrics::RunRecorder;
use crate::model::ParamSet;
use crate::runtime::{Engine, Manifest};
use crate::sampler::strategy::ScoreKind;
use crate::util::rng::Pcg64;
use crate::weightstore::{MemStore, WeightStore};

use super::master::{EvalSplit, Master};
use super::proposal::ProposalMaintainer;

/// One ASGD peer.
pub struct PeerState {
    pub id: usize,
    data: Arc<SynthDataset>,
    train_idx: Arc<Vec<usize>>,
    store: Arc<dyn WeightStore>,
    params: Option<ParamSet>,
    pub version: u64,
    /// Delta-synced proposal (ISSGD+ASGD); `None` = uniform minibatches
    /// (plain ASGD).  Shared between in-process peers, per-peer when
    /// distributed — the store cursor lives inside the maintainer.
    proposal: Option<Arc<Mutex<ProposalMaintainer>>>,
    lr: f32,
    rng: Pcg64,
    batch: BatchBuilder,
    coef_buf: Vec<f32>,
    /// Scratch for sorting/coalescing weight write-backs
    /// (position, weight, param version at emission).
    push_buf: Vec<(usize, f32, u64)>,
    run_buf: Vec<f32>,
    /// Scratch for staging a minibatch's weight entries (reused so the
    /// steady-state step allocates nothing).
    entry_buf: Vec<(usize, f32)>,
    /// Weight entries whose push failed transiently, queued for retry on
    /// the next step (merged newest-wins, so a stale retry can never
    /// overwrite a fresher value).  Each entry keeps the param version it
    /// was *measured* under, so a late retry never masquerades as fresh
    /// to the §B.1 staleness filter.  Bounded by the table size: the
    /// merge dedups positions every step.
    pending: Vec<(usize, f32, u64)>,
    pub steps_done: u64,
    /// `push_weights` round-trips avoided by run coalescing.
    pub push_calls_saved: u64,
    /// Transient store failures survived (monitoring, mirrors
    /// `WorkerState::store_errors`).
    pub store_errors: u64,
    /// This peer's saved-cursor name (`peer-{id}`): compaction pin +
    /// crash-resume handle, mirroring [`super::master::MASTER_CURSOR`].
    cursor_name: String,
    /// Last cursor successfully persisted (skip the round trip / journal
    /// frame when nothing advanced).
    saved_cursor: u64,
}

impl PeerState {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        manifest: &crate::runtime::Manifest,
        data: Arc<SynthDataset>,
        train_idx: Arc<Vec<usize>>,
        store: Arc<dyn WeightStore>,
        proposal: Option<Arc<Mutex<ProposalMaintainer>>>,
        lr: f32,
        seed: u64,
    ) -> PeerState {
        PeerState {
            id,
            data,
            train_idx,
            store,
            params: None,
            version: 0,
            proposal,
            lr,
            rng: Pcg64::new(seed, 0x9EE5 + id as u64),
            batch: BatchBuilder::new(manifest.batch_train, manifest.input_dim, manifest.n_classes),
            coef_buf: Vec::new(),
            push_buf: Vec::new(),
            run_buf: Vec::new(),
            entry_buf: Vec::new(),
            pending: Vec::new(),
            steps_done: 0,
            push_calls_saved: 0,
            store_errors: 0,
            cursor_name: format!("peer-{id}"),
            saved_cursor: 0,
        }
    }

    /// Weight entries queued for retry after transient push failures.
    pub fn pending_pushes(&self) -> usize {
        self.pending.len()
    }

    /// Whether this peer importance-samples (ISSGD+ASGD) or draws
    /// uniformly (plain ASGD).
    pub fn use_is(&self) -> bool {
        self.proposal.is_some()
    }

    /// Pull newer parameters if available — layer-wise: a full delta
    /// (bootstrap / fallback) rebuilds the local copy, an incremental one
    /// patches only the dirty layers in place.
    pub fn refresh_params(&mut self, engine: &Engine) -> Result<bool> {
        match self.store.fetch_params_since(self.version)? {
            None => Ok(false),
            Some(delta) => {
                match &mut self.params {
                    Some(p) if !delta.full => p.apply_delta(engine.manifest(), &delta)?,
                    _ => {
                        anyhow::ensure!(
                            delta.full,
                            "incremental params delta before any full sync"
                        );
                        self.params = Some(ParamSet::from_delta(engine.manifest(), &delta)?);
                    }
                }
                self.version = delta.version;
                Ok(true)
            }
        }
    }

    /// One peer contribution: sample, compute gradient + norms, push both.
    /// Returns the minibatch loss (None before params are available).
    pub fn step(&mut self, engine: &Engine) -> Result<Option<f32>> {
        let params = match &self.params {
            None => return Ok(None),
            Some(p) => p,
        };
        let m = self.batch.batch();
        let n = self.train_idx.len();
        let (positions, coefs) = match &self.proposal {
            Some(shared) => {
                // Advance the maintainer's cursor and absorb only the
                // entries written since — O(changes · log N), no snapshot.
                let mut prop = shared.lock().unwrap();
                let now = match prop.unit() {
                    StalenessUnit::Nanos => self.store.now()?,
                    StalenessUnit::Versions => self.version,
                };
                let delta = self.store.fetch_weights_since(prop.cursor())?;
                prop.absorb(&delta, now)?;
                // Persist the advanced cursor (compaction pin + resume
                // point) — fire-and-forget like every other store op
                // here, saved on the master's coarse cadence (a lagging
                // pin is never a correctness problem) and only when it
                // actually moved.
                let cursor = prop.cursor();
                if cursor != self.saved_cursor
                    && (self.saved_cursor == 0
                        || self.steps_done % super::master::CURSOR_SAVE_EVERY == 0)
                {
                    match self.store.save_cursor(&self.cursor_name, cursor) {
                        Ok(()) => self.saved_cursor = cursor,
                        Err(e) => {
                            self.store_errors += 1;
                            crate::telemetry::counter("peer.store_errors").inc();
                            crate::log_warn!(
                                "peer",
                                "peer-{} cursor save failed (continuing): {e}",
                                self.id
                            );
                        }
                    }
                }
                let (pos, coefs, _) = prop.draw_minibatch(&mut self.rng, m);
                (pos, coefs)
            }
            None => (self.rng.sample_with_replacement(n, m), vec![1.0f32; m]),
        };
        let global: Vec<usize> = positions.iter().map(|&p| self.train_idx[p]).collect();
        self.batch.fill(self.data.as_ref(), &global);
        self.coef_buf.clear();
        self.coef_buf.extend_from_slice(&coefs);
        let out = engine.peer_step(params, &self.batch.x, &self.batch.y, &self.coef_buf)?;
        // Parameter-server update (asynchronous: our params copy is stale).
        self.store.apply_grad(self.lr, &out.grad_flat)?;
        // Share the importance weights that came for free (§6) — only for
        // the examples this minibatch touched, like the worker scoring path
        // but with zero extra compute.  `entry_buf` is moved out and back
        // so the borrow checker allows the `&mut self` flush call without
        // a per-step allocation.
        let mut entries = std::mem::take(&mut self.entry_buf);
        entries.clear();
        for (slot, &pos) in positions.iter().enumerate() {
            let sq = out.sqnorms[slot].max(0.0);
            if sq > 0.0 {
                entries.push((pos, sq.sqrt()));
            }
        }
        self.flush_weight_pushes(&entries);
        self.entry_buf = entries;
        self.steps_done += 1;
        Ok(Some(out.loss))
    }

    /// Coalesced, fault-tolerant weight write-back.  Retry-queued entries
    /// from earlier failed pushes are merged in first (newest value wins on
    /// a position conflict), then runs of contiguous positions are pushed
    /// as single `push_weights` calls: a minibatch used to cost m
    /// round-trips and m write-sequence bumps; coalescing pays one per run.
    ///
    /// A transient push failure (§4.2 fire-and-forget) is counted in
    /// `store_errors` and the whole run re-queued in `pending` — values are
    /// absolute, so a late retry is idempotent, and the newest-wins merge
    /// guarantees a stale retry can never clobber a fresher write from
    /// this peer.  No weight update is lost or double-applied.  Retried
    /// entries keep the param version they were *measured* under (runs
    /// split on version boundaries), so the §B.1 staleness filter sees a
    /// late delivery as exactly as old as it is.
    pub fn flush_weight_pushes(&mut self, entries: &[(usize, f32)]) {
        let version = self.version;
        self.push_buf.clear();
        // Pending (older) first, fresh entries after: the stable sort below
        // keeps that order within a position, so dedup keeps the freshest.
        self.push_buf.append(&mut self.pending);
        self.push_buf
            .extend(entries.iter().map(|&(pos, w)| (pos, w, version)));
        // Stable sort keeps insertion order within a position, so after
        // dedup the surviving value is the last-inserted — the same value
        // the old one-push-per-example loop left behind.
        self.push_buf.sort_by_key(|e| e.0);
        self.push_buf.dedup_by(|next, kept| {
            if next.0 == kept.0 {
                kept.1 = next.1;
                kept.2 = next.2;
                true
            } else {
                false
            }
        });
        let total = self.push_buf.len();
        let mut i = 0;
        while i < total {
            let (start, first_w, run_version) = self.push_buf[i];
            self.run_buf.clear();
            self.run_buf.push(first_w);
            let mut j = i + 1;
            while j < total
                && self.push_buf[j].0 == self.push_buf[j - 1].0 + 1
                && self.push_buf[j].2 == run_version
            {
                self.run_buf.push(self.push_buf[j].1);
                j += 1;
            }
            match self.store.push_weights(start, &self.run_buf, run_version) {
                Ok(()) => {
                    // One call covered the whole run.
                    self.push_calls_saved += self.run_buf.len() as u64 - 1;
                    crate::telemetry::counter("peer.push_calls_saved")
                        .add(self.run_buf.len() as u64 - 1);
                }
                Err(e) => {
                    self.store_errors += 1;
                    crate::telemetry::counter("peer.store_errors").inc();
                    crate::log_warn!(
                        "peer",
                        "peer-{} weight push failed (run queued for retry): {e}",
                        self.id
                    );
                    for (k, &w) in self.run_buf.iter().enumerate() {
                        self.pending.push((start + k, w, run_version));
                    }
                }
            }
            i = j;
        }
    }
}

/// Refresh an eval master's parameters from the server through a params
/// version cursor (shared by the sim and the live driver): an unchanged
/// model skips the download entirely, an incremental delta patches only
/// the dirty layers, and the advanced version is threaded back through
/// `eval_version` at *every* call site — the final eval included — so a
/// later refresh never re-downloads a model it already holds.
pub(crate) fn refresh_eval_params(
    master: &mut Master,
    manifest: &Manifest,
    store: &Arc<dyn WeightStore>,
    eval_version: &mut u64,
) -> Result<()> {
    if let Some(delta) = store.fetch_params_since(*eval_version)? {
        *eval_version = apply_eval_params_delta(master, manifest, &delta)?;
    }
    Ok(())
}

/// Apply half of an eval refresh, returning the new version cursor.
/// Split out so callers that retry transient *fetch* failures can still
/// propagate a failing *apply* — a delta that does not apply means
/// publisher and store disagree on the model config, which is
/// deterministic and must not be retried or swallowed.
pub(crate) fn apply_eval_params_delta(
    master: &mut Master,
    manifest: &Manifest,
    delta: &crate::weightstore::ParamsDelta,
) -> Result<u64> {
    if delta.full {
        master.params = ParamSet::from_delta(manifest, delta)?;
    } else {
        master.params.apply_delta(manifest, delta)?;
    }
    Ok(delta.version)
}

/// Peers publish the ‖g‖-derived scores their `peer_step` artifact
/// co-computes (§6 — `PeerOutput` has no per-example losses), so a
/// strategy whose [`crate::sampler::strategy::ScoreSource`] wants a
/// different statistic still prices grad-norm scores in the peer
/// topology.  Warn rather than fail: the strategy's mass transform and
/// draw policy still apply, only the raw score substitutes.
pub(crate) fn warn_if_peer_scores_diverge(cfg: &RunConfig) {
    if cfg.strategy.score_source().kind() != ScoreKind::GradNorm {
        crate::log_warn!(
            "peer",
            "strategy {} scores by {:?}, but peers co-compute grad norms only; \
             sampling mass will be priced from grad-norm scores",
            cfg.strategy.name(),
            cfg.strategy.score_source().kind()
        );
    }
}

/// Per-peer shutdown counters (shared by the sim and the live threaded
/// topology — `coordinator::peer_live`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PeerStats {
    pub id: usize,
    /// Gradient contributions this peer made.
    pub steps: u64,
    /// `push_weights` round-trips avoided by run coalescing.
    pub push_calls_saved: u64,
    /// Transient store failures survived.
    pub store_errors: u64,
    /// Delta cursor of the peer's (or shared) maintainer after the final
    /// drain (0 = uniform peer, no maintainer).
    pub final_cursor: u64,
    /// How far the cursor trailed the store's write sequence when the peer
    /// stopped stepping (0 = fully synced; the cursor-divergence stat).
    pub cursor_lag: u64,
}

/// Outcome of an ASGD/peer run (mirrors `SimOutcome`; produced by both
/// [`run_asgd_sim`] and `peer_live::run_peer_live`).
pub struct AsgdOutcome {
    pub rec: RunRecorder,
    pub final_err: (f64, f64, f64),
    pub total_peer_steps: u64,
    pub store_stats: crate::weightstore::StoreStats,
    /// Per-peer counters at shutdown.
    pub peers: Vec<PeerStats>,
    /// ESS/N of the final drained proposal (1.0 for uniform peers).
    pub final_ess: f64,
    /// Effective sampling weight of every entry in the final drained
    /// proposal (empty for uniform peers) — the live-vs-sim equivalence
    /// probe.
    pub final_weights: Vec<f64>,
}

/// Deterministic ASGD / ISSGD+ASGD simulation.
///
/// `cfg.n_workers` peers contribute gradients round-robin; each peer
/// re-fetches parameters every `cfg.param_push_every` of its own steps
/// (the staleness knob: contributions in between are computed on old
/// params).  `cfg.trainer` picks plain ASGD (`UniformSgd`) or the §6
/// combination (`Issgd`).  `cfg.steps` counts *total* gradient
/// contributions across peers, making loss-vs-gradient-budget comparable
/// with the master/worker topology.
///
/// ISSGD peers share one lock-guarded [`ProposalMaintainer`] (one store
/// mirror, one delta cursor).  Evaluation triggers whenever a round of
/// peer steps *crosses* an `eval_every` boundary — rounds advance by
/// `n_workers` steps, so the old `total % eval_every == 0` gate silently
/// skipped every evaluation when the two weren't aligned — and fetches
/// server parameters through a version cursor, so an unchanged blob is
/// neither re-downloaded nor re-decoded.
pub fn run_asgd_sim(cfg: &RunConfig, engine: &Engine) -> Result<AsgdOutcome> {
    cfg.validate()?;
    let store: Arc<MemStore> = Arc::new(MemStore::new(Master::store_size(cfg), cfg.init_weight));
    let store_dyn: Arc<dyn WeightStore> = store.clone();
    // Reuse Master for data/split/init/eval plumbing; it never trains here.
    let mut eval_master = Master::new(cfg.clone(), engine, store_dyn.clone())?;
    // Publish initial parameters (version 1) for the peers — the full
    // manifest-keyed layout, so later fetches are layer-precise.
    store_dyn.push_params_layers(1, true, &eval_master.params.to_layer_chunks())?;

    let manifest = engine.manifest();
    let use_is = cfg.trainer == TrainerKind::Issgd;
    // One shared maintainer for all in-process peers.  The staleness
    // threshold composes with the coverage prior (filtered-out stale
    // entries fall back to the prior mass — see `proposal`'s module docs);
    // `None` keeps the original prior-only semantics.
    let proposal = if use_is {
        warn_if_peer_scores_diverge(cfg);
        Some(Arc::new(Mutex::new(
            ProposalMaintainer::with_coverage_prior_strategy(
                Master::store_size(cfg),
                cfg.smoothing,
                cfg.staleness_threshold,
                cfg.staleness_unit,
                cfg.strategy.strategy(),
            ),
        )))
    } else {
        None
    };
    let mut peers: Vec<PeerState> = (0..cfg.n_workers)
        .map(|id| {
            PeerState::new(
                id,
                manifest,
                Arc::clone(&eval_master.data),
                Arc::new(eval_master.train_idx.clone()),
                store_dyn.clone(),
                proposal.clone(),
                cfg.lr,
                cfg.seed,
            )
        })
        .collect();

    let mut rec = RunRecorder::new();
    let mut total_steps = 0u64;
    // Version cursor for evaluation parameter fetches: unchanged server
    // params skip the blob download + decode (mirrors `refresh_params`).
    let mut eval_version = 0u64;
    while total_steps < cfg.steps {
        let round_start = total_steps;
        for peer in &mut peers {
            if total_steps >= cfg.steps {
                break;
            }
            // Fetch cadence: stale in between (the ASGD staleness source).
            if peer.steps_done % cfg.param_push_every == 0 {
                peer.refresh_params(engine)?;
            }
            if let Some(loss) = peer.step(engine)? {
                rec.record("train_loss", total_steps, loss as f64);
                total_steps += 1;
            }
        }
        // Evaluate with the *server's* current parameters whenever this
        // round crossed an eval boundary (rounds advance by n_workers
        // steps, so exact `% eval_every == 0` hits can't be relied on).
        if cfg.eval_every > 0 && round_start / cfg.eval_every != total_steps / cfg.eval_every {
            refresh_eval_params(&mut eval_master, manifest, &store_dyn, &mut eval_version)?;
            let (l, e) = eval_master.evaluate(engine, EvalSplit::Train)?;
            let (_tl, te) = eval_master.evaluate(engine, EvalSplit::Test)?;
            rec.record("eval_train_loss", total_steps, l);
            rec.record("eval_train_err", total_steps, e);
            rec.record("eval_test_err", total_steps, te);
        }
    }

    // Final evaluation with server params — same cursor-threading helper
    // as the in-round path, so the version advances here too and a later
    // reader of `eval_version` never re-downloads a model already held
    // (the old code discarded the returned version at exactly this site).
    refresh_eval_params(&mut eval_master, manifest, &store_dyn, &mut eval_version)?;
    let final_err = (
        eval_master.evaluate(engine, EvalSplit::Train)?.1,
        eval_master.evaluate(engine, EvalSplit::Valid)?.1,
        eval_master.evaluate(engine, EvalSplit::Test)?.1,
    );
    // Drain the shared maintainer so the reported proposal reflects every
    // write (the live-vs-sim equivalence probe reads this).
    let mut final_ess = 1.0;
    let mut final_weights = Vec::new();
    let mut final_cursor = 0u64;
    let mut cursor_lag = 0u64;
    if let Some(shared) = &proposal {
        let mut prop = shared.lock().unwrap();
        let now = match prop.unit() {
            StalenessUnit::Nanos => store_dyn.now()?,
            StalenessUnit::Versions => store_dyn.params_version()?,
        };
        let before = prop.cursor();
        let delta = store_dyn.fetch_weights_since(before)?;
        cursor_lag = delta.seq.saturating_sub(before);
        prop.absorb(&delta, now)?;
        final_cursor = prop.cursor();
        final_ess = prop.ess_ratio();
        final_weights = (0..prop.len()).map(|i| prop.effective_weight(i)).collect();
    }
    let peers_stats: Vec<PeerStats> = peers
        .iter()
        .map(|p| PeerStats {
            id: p.id,
            steps: p.steps_done,
            push_calls_saved: p.push_calls_saved,
            store_errors: p.store_errors,
            // The sim shares one maintainer, so every peer reports the
            // shared drained cursor.
            final_cursor,
            cursor_lag,
        })
        .collect();
    let mut store_stats = store.stats()?;
    store_stats.push_calls_saved = peers.iter().map(|p| p.push_calls_saved).sum();
    Ok(AsgdOutcome {
        rec,
        final_err,
        total_peer_steps: total_steps,
        store_stats,
        peers: peers_stats,
        final_ess,
        final_weights,
    })
}
