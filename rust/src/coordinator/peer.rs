//! Peer mode: ASGD, and the paper's §6 recommended ISSGD+ASGD combination.
//!
//! The paper's future-work section sketches how importance sampling should
//! be married to Asynchronous SGD: "get rid of the master/workers
//! distinction and have only workers (or *peers*) along with a parameter
//! server...  Whenever a gradient contribution is computed, the importance
//! weights can be obtained at the same time.  These can be shared in the
//! same way that the gradients are shared, so that all the workers are
//! able to use the importance weights to run ISSGD steps."
//!
//! We implement exactly that topology:
//! * the *parameter server* is the weight store's `apply_grad` op
//!   (`params -= lr * grad`, version bump per contribution);
//! * each *peer* loops: fetch latest params (stale between fetches),
//!   draw a minibatch — uniformly (plain ASGD) or by importance sampling
//!   from the shared weights (ISSGD+ASGD) — run the `peer_step` artifact,
//!   push the gradient, and push the per-example norms that came for free.
//!
//! `run_asgd_sim` drives the peers in a deterministic round-robin with a
//! configurable fetch cadence, so gradients are genuinely stale (a peer
//! computes on params that other peers have since updated) while runs
//! remain reproducible.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{RunConfig, TrainerKind};
use crate::data::{BatchBuilder, SynthDataset};
use crate::metrics::RunRecorder;
use crate::model::ParamSet;
use crate::runtime::Engine;
use crate::sampler::{draw_minibatch, FenwickSampler, Smoothing};
use crate::util::rng::Pcg64;
use crate::weightstore::{MemStore, WeightStore};

use super::master::{EvalSplit, Master};

/// One ASGD peer.
pub struct PeerState {
    pub id: usize,
    data: Arc<SynthDataset>,
    train_idx: Arc<Vec<usize>>,
    store: Arc<dyn WeightStore>,
    params: Option<ParamSet>,
    pub version: u64,
    /// Use importance sampling from the shared weights (ISSGD+ASGD) or
    /// uniform minibatches (plain ASGD).
    pub use_is: bool,
    smoothing: f64,
    lr: f32,
    rng: Pcg64,
    batch: BatchBuilder,
    coef_buf: Vec<f32>,
    pub steps_done: u64,
}

impl PeerState {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        manifest: &crate::runtime::Manifest,
        data: Arc<SynthDataset>,
        train_idx: Arc<Vec<usize>>,
        store: Arc<dyn WeightStore>,
        use_is: bool,
        smoothing: f64,
        lr: f32,
        seed: u64,
    ) -> PeerState {
        PeerState {
            id,
            data,
            train_idx,
            store,
            params: None,
            version: 0,
            use_is,
            smoothing,
            lr,
            rng: Pcg64::new(seed, 0x9EE5 + id as u64),
            batch: BatchBuilder::new(manifest.batch_train, manifest.input_dim, manifest.n_classes),
            coef_buf: Vec::new(),
            steps_done: 0,
        }
    }

    /// Pull newer parameters if available.
    pub fn refresh_params(&mut self, engine: &Engine) -> Result<bool> {
        match self.store.fetch_params(self.version)? {
            None => Ok(false),
            Some((version, bytes)) => {
                self.params = Some(ParamSet::from_bytes(engine.manifest(), &bytes)?);
                self.version = version;
                Ok(true)
            }
        }
    }

    /// One peer contribution: sample, compute gradient + norms, push both.
    /// Returns the minibatch loss (None before params are available).
    pub fn step(&mut self, engine: &Engine) -> Result<Option<f32>> {
        let params = match &self.params {
            None => return Ok(None),
            Some(p) => p,
        };
        let m = self.batch.batch();
        let n = self.train_idx.len();
        let (positions, coefs) = if self.use_is {
            let snap = self.store.fetch_weights()?;
            let smooth = Smoothing::new(self.smoothing);
            // Coverage correction: unlike the master/worker topology, peers
            // only score the examples they happen to sample, so early on
            // most weights are still the placeholder init value — which is
            // NOT a gradient norm, and treating it as one mis-calibrates
            // the importance correction badly enough to diverge.  Examples
            // never scored (param_version == 0) get the *mean of scored
            // weights* as their prior: they are sampled at an average rate
            // and their coefficient stays ~1 until real information about
            // them exists.
            let scored: Vec<f64> = snap
                .param_versions
                .iter()
                .zip(&snap.weights)
                .filter(|(&v, _)| v > 0)
                .map(|(_, &w)| w)
                .collect();
            let prior = if scored.is_empty() {
                1.0
            } else {
                scored.iter().sum::<f64>() / scored.len() as f64
            };
            let weights: Vec<f64> = snap
                .weights
                .iter()
                .zip(&snap.param_versions)
                .map(|(&w, &v)| smooth.apply(if v > 0 { w } else { prior }))
                .collect();
            let sampler = FenwickSampler::new(&weights);
            let (pos, coefs, _) = draw_minibatch(&sampler, &mut self.rng, m);
            (pos, coefs)
        } else {
            (self.rng.sample_with_replacement(n, m), vec![1.0f32; m])
        };
        let global: Vec<usize> = positions.iter().map(|&p| self.train_idx[p]).collect();
        self.batch.fill(self.data.as_ref(), &global);
        self.coef_buf.clear();
        self.coef_buf.extend_from_slice(&coefs);
        let out = engine.peer_step(params, &self.batch.x, &self.batch.y, &self.coef_buf)?;
        // Parameter-server update (asynchronous: our params copy is stale).
        self.store.apply_grad(self.lr, &out.grad_flat)?;
        // Share the importance weights that came for free (§6) — only for
        // the examples this minibatch touched, like the worker scoring path
        // but with zero extra compute.
        for (slot, &pos) in positions.iter().enumerate() {
            let sq = out.sqnorms[slot].max(0.0);
            if sq > 0.0 {
                self.store.push_weights(pos, &[sq.sqrt()], self.version)?;
            }
        }
        self.steps_done += 1;
        Ok(Some(out.loss))
    }
}

/// Outcome of an ASGD/peer simulation (mirrors `SimOutcome`).
pub struct AsgdOutcome {
    pub rec: RunRecorder,
    pub final_err: (f64, f64, f64),
    pub total_peer_steps: u64,
    pub store_stats: crate::weightstore::StoreStats,
}

/// Deterministic ASGD / ISSGD+ASGD simulation.
///
/// `cfg.n_workers` peers contribute gradients round-robin; each peer
/// re-fetches parameters every `cfg.param_push_every` of its own steps
/// (the staleness knob: contributions in between are computed on old
/// params).  `cfg.trainer` picks plain ASGD (`UniformSgd`) or the §6
/// combination (`Issgd`).  `cfg.steps` counts *total* gradient
/// contributions across peers, making loss-vs-gradient-budget comparable
/// with the master/worker topology.
pub fn run_asgd_sim(cfg: &RunConfig, engine: &Engine) -> Result<AsgdOutcome> {
    cfg.validate()?;
    let store: Arc<MemStore> = Arc::new(MemStore::new(Master::store_size(cfg), cfg.init_weight));
    let store_dyn: Arc<dyn WeightStore> = store.clone();
    // Reuse Master for data/split/init/eval plumbing; it never trains here.
    let mut eval_master = Master::new(cfg.clone(), engine, store_dyn.clone())?;
    // Publish initial parameters (version 1) for the peers.
    store_dyn.push_params(1, eval_master.params.to_bytes())?;

    let manifest = engine.manifest();
    let use_is = cfg.trainer == TrainerKind::Issgd;
    let mut peers: Vec<PeerState> = (0..cfg.n_workers)
        .map(|id| {
            PeerState::new(
                id,
                manifest,
                Arc::clone(&eval_master.data),
                Arc::new(eval_master.train_idx.clone()),
                store_dyn.clone(),
                use_is,
                cfg.smoothing,
                cfg.lr,
                cfg.seed,
            )
        })
        .collect();

    let mut rec = RunRecorder::new();
    let mut total_steps = 0u64;
    while total_steps < cfg.steps {
        for peer in &mut peers {
            if total_steps >= cfg.steps {
                break;
            }
            // Fetch cadence: stale in between (the ASGD staleness source).
            if peer.steps_done % cfg.param_push_every == 0 {
                peer.refresh_params(engine)?;
            }
            if let Some(loss) = peer.step(engine)? {
                rec.record("train_loss", total_steps, loss as f64);
                total_steps += 1;
            }
        }
        // Evaluate with the *server's* current parameters.
        if cfg.eval_every > 0 && total_steps % cfg.eval_every == 0 {
            if let Some((_v, bytes)) = store_dyn.fetch_params(0)? {
                eval_master.params = ParamSet::from_bytes(manifest, &bytes)?;
                let (l, e) = eval_master.evaluate(engine, EvalSplit::Train)?;
                let (_tl, te) = eval_master.evaluate(engine, EvalSplit::Test)?;
                rec.record("eval_train_loss", total_steps, l);
                rec.record("eval_train_err", total_steps, e);
                rec.record("eval_test_err", total_steps, te);
            }
        }
    }

    // Final evaluation with server params.
    if let Some((_v, bytes)) = store_dyn.fetch_params(0)? {
        eval_master.params = ParamSet::from_bytes(manifest, &bytes)?;
    }
    let final_err = (
        eval_master.evaluate(engine, EvalSplit::Train)?.1,
        eval_master.evaluate(engine, EvalSplit::Valid)?.1,
        eval_master.evaluate(engine, EvalSplit::Test)?.1,
    );
    Ok(AsgdOutcome {
        rec,
        final_err,
        total_peer_steps: total_steps,
        store_stats: store.stats()?,
    })
}
