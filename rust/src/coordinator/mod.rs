//! The paper's distributed coordination layer (§4): master, workers and
//! their orchestration.
//!
//! Actors:
//! * [`master::Master`] — runs ISSGD / uniform SGD against a weight store.
//! * [`worker::WorkerState`] — scores per-example gradient norms and keeps
//!   the store fresh.
//! * the *database* actor lives in [`crate::weightstore`].
//!
//! Orchestration modes:
//! * [`sim::run_sim`] — deterministic single-thread interleave (the
//!   experiment drivers' workhorse; bit-reproducible staleness).
//! * [`live::run_live`] — real threads, real clocks, optional TCP store
//!   (the paper's deployment shape).

pub mod live;
pub mod master;
pub mod peer;
pub mod proposal;
pub mod sim;
pub mod worker;

pub use live::{run_live, LiveOptions};
pub use peer::{run_asgd_sim, AsgdOutcome, PeerState};
pub use master::{EvalSplit, Master};
pub use proposal::ProposalMaintainer;
pub use sim::{run_sim, run_sim_with_engine, SimOutcome};
pub use worker::WorkerState;
