//! The paper's distributed coordination layer (§4): master, workers and
//! their orchestration.
//!
//! Actors:
//! * [`master::Master`] — runs ISSGD / uniform SGD against a weight store.
//! * [`worker::WorkerState`] — scores per-example gradient norms and keeps
//!   the store fresh.
//! * [`peer::PeerState`] — a §6 peer: gradient contributions + co-computed
//!   importance weights against a parameter server (no master/worker
//!   split).
//! * the *database* actor lives in [`crate::weightstore`]
//!   ([`crate::weightstore::faulty::FaultyStore`] is its sanctioned
//!   chaos decorator).
//!
//! Orchestration modes — master/worker topology:
//! * [`sim::run_sim`] — deterministic single-thread interleave (the
//!   experiment drivers' workhorse; bit-reproducible staleness).
//! * [`live::run_live`] — real threads, real clocks, optional TCP store
//!   (the paper's deployment shape).
//!
//! Orchestration modes — peer/ASGD topology (the same triad):
//! * [`peer::run_asgd_sim`] — deterministic round-robin, one shared
//!   proposal maintainer.
//! * [`peer_live::run_peer_live`] — one OS thread per peer, per-peer
//!   maintainers and delta cursors (real cursor divergence); its
//!   `lockstep` option pins the store-op order for bit-reproducible
//!   chaos runs and live-vs-sim equivalence checks.

pub mod live;
pub mod master;
pub mod peer;
pub mod peer_live;
pub mod proposal;
pub mod sim;
pub mod worker;

pub use live::{run_live, LiveOptions};
pub use peer::{run_asgd_sim, AsgdOutcome, PeerState, PeerStats};
pub use peer_live::{run_peer_live, PeerLiveOptions};
pub use master::{EvalSplit, Master, MASTER_CURSOR};
pub use proposal::ProposalMaintainer;
pub use sim::{run_sim, run_sim_with_engine, run_sim_with_store, SimOutcome};
pub use worker::WorkerState;
