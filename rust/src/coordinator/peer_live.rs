//! Live peer/ASGD cluster: every peer is a real OS thread — the §6
//! topology under genuine concurrency, mirroring [`super::live::run_live`]
//! for the master/worker topology.
//!
//! Differences from [`super::peer::run_asgd_sim`]:
//!
//! * Each peer owns its engine (PJRT client handles are not `Send`), its
//!   **own** coverage-prior [`ProposalMaintainer`], and its **own** delta
//!   cursor against the shared [`WeightStore`] — the store's cursor
//!   contract is per-consumer, so N peers mean N independently-advancing
//!   cursors that genuinely diverge under load.  (The sim shares one
//!   lock-guarded maintainer; here sharing would serialize the threads and
//!   hide exactly the divergence this mode exists to exercise.)
//! * Transient store failures never kill a peer thread (§4.2
//!   fire-and-forget): gradient pushes are retried next loop after an
//!   exponential backoff, weight pushes ride `PeerState`'s pending-retry
//!   queue, and everything is counted in the per-peer
//!   [`PeerStats`] of the returned [`AsgdOutcome`].
//! * Shutdown is stop-flag + reap: the driver joins every thread, logs
//!   panics/errors without failing the run, then *drains* each surviving
//!   maintainer's cursor so the outcome reports true cursor lag and a
//!   fully-synced final proposal.
//!
//! # Determinism: lockstep mode
//!
//! [`PeerLiveOptions::lockstep`] serializes the peers on a rotating turn
//! token (threads and their store connections stay real — only the store
//! *op order* is pinned to round-robin).  Given a fixed seed, a run is
//! then bit-reproducible — including any injected fault schedule from a
//! [`crate::weightstore::faulty::FaultyStore`], whose seeded decisions
//! depend only on op order — and its final proposal matches
//! `run_asgd_sim`'s, which is the live-vs-sim equivalence check in the
//! integration tests.  Free-running mode (the default) is the production
//! shape: wall-clock staleness, racy cursors, nondeterministic schedules.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::config::{RunConfig, StalenessUnit, TrainerKind};
use crate::metrics::RunRecorder;
use crate::runtime::{artifacts_dir, Engine};
use crate::weightstore::{MemStore, WeightStore};
use crate::{log_info, log_warn};

use super::master::{EvalSplit, Master};
use super::peer::{AsgdOutcome, PeerState, PeerStats};
use super::proposal::ProposalMaintainer;

/// Options specific to live peer execution.
#[derive(Clone, Default)]
pub struct PeerLiveOptions {
    /// Inject a pre-built store (tests wrap a [`MemStore`] in a
    /// `FaultyStore`); it must track `Master::store_size(cfg)` weights.
    pub store: Option<Arc<dyn WeightStore>>,
    /// Connect every peer to a remote TCP store instead (mutually
    /// exclusive with `store`).
    pub store_addr: Option<String>,
    /// Serialize peers on a rotating turn token: threads stay real, store
    /// op order becomes deterministic round-robin (see module docs).
    pub lockstep: bool,
    /// Pause between free-running peer steps (keeps small hosts
    /// responsive; ignored in lockstep mode).
    pub throttle: Option<std::time::Duration>,
    /// Abort the run (stop flag + reap) after this much wall time — a
    /// liveness backstop for chaos tests against misbehaving stores.
    pub deadline: Option<std::time::Duration>,
}

const BACKOFF_MIN: std::time::Duration = std::time::Duration::from_millis(1);
const BACKOFF_MAX: std::time::Duration = std::time::Duration::from_millis(500);
/// Driver-side drain attempts per peer (each retry re-rolls any injected
/// fault, so persistent failure means a genuinely dead store).
const DRAIN_RETRIES: usize = 64;

/// What a peer thread hands back to the driver.
struct PeerReport {
    stats: PeerStats,
    /// (global step index, minibatch loss) — merged into the recorder in
    /// index order, so lockstep traces are comparable to the sim's.
    losses: Vec<(u64, f64)>,
    /// The peer's maintainer, for the driver-side final drain (None for
    /// uniform/plain-ASGD peers).
    proposal: Option<ProposalMaintainer>,
}

/// Rotating turn token for lockstep mode.
struct Turn {
    state: Mutex<u64>,
    cv: Condvar,
}

impl Turn {
    fn new() -> Arc<Turn> {
        Arc::new(Turn {
            state: Mutex::new(0),
            cv: Condvar::new(),
        })
    }

    /// Block until it is `id`'s turn (of `n`) or `stop` flips.  Returns
    /// false when stopping.
    fn acquire(&self, id: usize, n: usize, stop: &AtomicBool) -> bool {
        let mut cur = self.state.lock().unwrap();
        loop {
            if stop.load(Ordering::Relaxed) {
                return false;
            }
            if (*cur % n as u64) as usize == id {
                return true;
            }
            // Timed wait so a stop request is honoured even if a notify
            // was missed.
            let (guard, _) = self
                .cv
                .wait_timeout(cur, std::time::Duration::from_millis(10))
                .unwrap();
            cur = guard;
        }
    }

    /// Pass the token to the next peer.
    fn advance(&self) {
        let mut cur = self.state.lock().unwrap();
        *cur += 1;
        drop(cur);
        self.cv.notify_all();
    }
}

/// Run a live threaded peer/ASGD cluster for `cfg`.
///
/// `cfg.steps` counts total gradient contributions across peers (matching
/// [`super::peer::run_asgd_sim`]); in free-running mode the total may
/// overshoot by up to `n_workers − 1` contributions that were already in
/// flight when the budget filled.  Periodic evaluation (`cfg.eval_every`)
/// runs on the driver thread against the server's current parameters;
/// its sample values are wall-clock racy in free-running mode — set
/// `eval_every = 0` for bit-reproducible lockstep runs.
pub fn run_peer_live(cfg: &RunConfig, opts: &PeerLiveOptions) -> Result<AsgdOutcome> {
    cfg.validate()?;
    anyhow::ensure!(
        opts.store.is_none() || opts.store_addr.is_none(),
        "pass either an injected store or a store address, not both"
    );
    let n_weights = Master::store_size(cfg);
    let mem: Option<Arc<MemStore>> = if opts.store.is_none() && opts.store_addr.is_none() {
        Some(Arc::new(MemStore::new(n_weights, cfg.init_weight)))
    } else {
        None
    };
    // One shared connection pool for every role in TCP mode: peers +
    // driver multiplex over at most `n_workers + 2` sockets, and peers
    // polling the same delta cursor coalesce into one fetch.
    let pool: Option<Arc<crate::weightstore::client::ClientPool>> =
        opts.store_addr.as_ref().map(|addr| {
            Arc::new(crate::weightstore::client::ClientPool::new(
                addr,
                cfg.n_workers + 2,
            ))
        });
    if let Some(pool) = &pool {
        // The pool dials lazily; ping once so a bad address still fails
        // fast here rather than from inside a peer thread.
        pool.now()?;
    }
    let connect = |role: &str| -> Result<Arc<dyn WeightStore>> {
        Ok(match (&pool, &opts.store, &mem) {
            (Some(pool), _, _) => {
                log_info!(
                    role,
                    "sharing store pool at {} ({} conns max)",
                    opts.store_addr.as_deref().unwrap_or("?"),
                    cfg.n_workers + 2
                );
                Arc::clone(pool) as Arc<dyn WeightStore>
            }
            (None, Some(store), _) => Arc::clone(store),
            (None, None, Some(mem)) => mem.clone() as Arc<dyn WeightStore>,
            _ => unreachable!(),
        })
    };

    let dims_dir = artifacts_dir(&cfg.model);
    // Driver engine first — fail fast before spawning anything.  The
    // driver's Master never trains; it provides data/split/eval plumbing.
    let driver_engine = Engine::load(&dims_dir)?;
    let driver_store = connect("peer-driver")?;
    let mut eval_master = Master::new(cfg.clone(), &driver_engine, driver_store.clone())?;
    // Publish initial parameters so peers can start — only on a fresh
    // store (version 0), as the full manifest-keyed layout so every later
    // fetch is layer-precise.  A recovered durable store already holds the
    // model `Master::new` just adopted: republishing it would re-journal
    // the whole blob and raise the params floor, demoting every resumed
    // consumer to the full-blob fallback for nothing.
    if driver_store.params_version()? == 0 {
        driver_store.push_params_layers(1, true, &eval_master.params.to_layer_chunks())?;
    }

    let use_is = cfg.trainer == TrainerKind::Issgd;
    if use_is {
        super::peer::warn_if_peer_scores_diverge(cfg);
    }
    let n_peers = cfg.n_workers;
    let stop = Arc::new(AtomicBool::new(false));
    let total = Arc::new(AtomicU64::new(0));
    let turn = Turn::new();

    let mut handles = Vec::new();
    for id in 0..n_peers {
        let stop = Arc::clone(&stop);
        let total = Arc::clone(&total);
        let turn = Arc::clone(&turn);
        let data = Arc::clone(&eval_master.data);
        let train_idx = Arc::new(eval_master.train_idx.clone());
        let store = connect(&format!("peer-{id}"))?;
        let dir = dims_dir.clone();
        let cfg = cfg.clone();
        let lockstep = opts.lockstep;
        let throttle = opts.throttle;
        handles.push(std::thread::spawn(move || -> Result<PeerReport> {
            let engine = Engine::load_entries(&dir, &["peer_step"])?;
            // Per-peer maintainer + per-peer cursor: cursor divergence
            // under real concurrency is the point of this mode.
            let proposal = if use_is {
                Some(Arc::new(Mutex::new(
                    ProposalMaintainer::with_coverage_prior_strategy(
                        n_weights,
                        cfg.smoothing,
                        cfg.staleness_threshold,
                        cfg.staleness_unit,
                        cfg.strategy.strategy(),
                    ),
                )))
            } else {
                None
            };
            let mut peer = PeerState::new(
                id,
                engine.manifest(),
                data,
                train_idx,
                Arc::clone(&store),
                proposal.clone(),
                cfg.lr,
                cfg.seed,
            );
            let mut losses = Vec::new();
            let mut backoff = BACKOFF_MIN;
            loop {
                if lockstep {
                    if !turn.acquire(id, n_peers, &stop) {
                        break;
                    }
                    if total.load(Ordering::SeqCst) >= cfg.steps {
                        // Pass the token so every waiter gets its exit turn.
                        turn.advance();
                        break;
                    }
                } else if stop.load(Ordering::Relaxed)
                    || total.load(Ordering::SeqCst) >= cfg.steps
                {
                    break;
                }
                // Fetch cadence: stale in between (the ASGD staleness
                // source), exactly as in the sim.
                let step_result = (|| -> Result<Option<f32>> {
                    if peer.steps_done % cfg.param_push_every == 0 {
                        peer.refresh_params(&engine)?;
                    }
                    peer.step(&engine)
                })();
                match step_result {
                    Ok(Some(loss)) => {
                        let idx = total.fetch_add(1, Ordering::SeqCst);
                        losses.push((idx, loss as f64));
                        backoff = BACKOFF_MIN;
                        if !lockstep {
                            if let Some(d) = throttle {
                                std::thread::sleep(d);
                            }
                        }
                    }
                    Ok(None) => {
                        // No parameters yet (a transient fetch failure ate
                        // the initial publish) — retry next turn/loop.
                        if !lockstep {
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                    }
                    Err(e) => {
                        // Transient store failure: §4.2 fire-and-forget —
                        // degrade, count, back off, never die.  Engine
                        // errors inside `peer_step` are deterministic and
                        // would loop forever, but they can only originate
                        // from the store-fed inputs here, so the blanket
                        // retry stays safe: the next attempt re-fetches.
                        peer.store_errors += 1;
                        log_warn!("peer", "peer-{id} step failed (retrying): {e}");
                        if !lockstep {
                            let mut waited = std::time::Duration::ZERO;
                            while waited < backoff && !stop.load(Ordering::Relaxed) {
                                let slice =
                                    (backoff - waited).min(std::time::Duration::from_millis(10));
                                std::thread::sleep(slice);
                                waited += slice;
                            }
                            backoff = (backoff * 2).min(BACKOFF_MAX);
                        }
                    }
                }
                if lockstep {
                    turn.advance();
                }
            }
            let stats = PeerStats {
                id,
                steps: peer.steps_done,
                push_calls_saved: peer.push_calls_saved,
                store_errors: peer.store_errors,
                final_cursor: 0,
                cursor_lag: 0,
            };
            drop(peer);
            let proposal = proposal.and_then(|shared| {
                Arc::try_unwrap(shared).ok().map(|m| m.into_inner().unwrap())
            });
            Ok(PeerReport {
                stats,
                losses,
                proposal,
            })
        }));
    }
    log_info!(
        "peer-driver",
        "live peer cluster up: {} peers, {} total steps{}",
        n_peers,
        cfg.steps,
        if opts.lockstep { " (lockstep)" } else { "" }
    );

    // Driver loop: watch progress, run boundary-crossing evaluations, and
    // enforce the deadline.  Stamps use the eval boundary (k·eval_every),
    // not the racing counter.
    // analyze: allow(wallclock): the run deadline is wall time by definition
    let started = std::time::Instant::now();
    let mut rec = RunRecorder::new();
    let mut eval_version = 0u64;
    let mut evals_done = 0u64;
    let mut deadline_hit = false;
    loop {
        let t = total.load(Ordering::SeqCst);
        if t >= cfg.steps || handles.iter().all(|h| h.is_finished()) {
            break;
        }
        if opts.lockstep && handles.iter().any(|h| h.is_finished()) {
            // A dead peer would wedge the turn token forever; reap early.
            log_warn!("peer-driver", "a lockstep peer exited early at {t}/{} steps", cfg.steps);
            break;
        }
        if let Some(d) = opts.deadline {
            if started.elapsed() > d {
                deadline_hit = true;
                log_warn!("peer-driver", "deadline {d:?} hit at {t}/{} steps; stopping", cfg.steps);
                break;
            }
        }
        if cfg.eval_every > 0 && t / cfg.eval_every > evals_done {
            evals_done = t / cfg.eval_every;
            let step = evals_done * cfg.eval_every;
            match eval_at(&mut eval_master, &driver_engine, &driver_store, &mut eval_version) {
                Ok((l, e, te)) => {
                    rec.record("eval_train_loss", step, l);
                    rec.record("eval_train_err", step, e);
                    rec.record("eval_test_err", step, te);
                }
                Err(e) => log_warn!("peer-driver", "evaluation failed (skipping): {e}"),
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);
    turn.cv.notify_all();

    // Reap every peer thread; failures degrade the outcome, never the run.
    // After a deadline hit, a peer can be wedged inside a store call that
    // never returns (the TCP client sets no socket timeouts), and an
    // unconditional join would hang forever — defeating the deadline.
    // Give such peers a grace period to observe the stop flag, then
    // detach the stuck ones instead of joining them.
    if deadline_hit {
        // analyze: allow(wallclock): reap grace period for wedged live peers
        let grace = std::time::Instant::now() + std::time::Duration::from_secs(10);
        // analyze: allow(wallclock): reap grace period for wedged live peers
        while std::time::Instant::now() < grace && !handles.iter().all(|h| h.is_finished()) {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
    }
    let mut reports: Vec<PeerReport> = Vec::new();
    for h in handles {
        if deadline_hit && !h.is_finished() {
            log_warn!("peer-driver", "peer thread wedged in a store call; detaching it");
            continue;
        }
        match h.join() {
            Ok(Ok(report)) => reports.push(report),
            Ok(Err(e)) => log_warn!("peer-driver", "peer thread failed: {e}"),
            Err(_) => log_warn!("peer-driver", "peer thread panicked"),
        }
    }
    anyhow::ensure!(!reports.is_empty(), "every peer thread failed");
    anyhow::ensure!(
        !deadline_hit || total.load(Ordering::SeqCst) > 0,
        "deadline hit before any peer contributed a step"
    );

    // Drain each surviving maintainer: record how far its cursor trailed
    // the store (the divergence stat), then catch it up so the reported
    // proposal reflects every write.  Retries ride out injected faults.
    let mut final_ess = 1.0;
    let mut final_weights: Vec<f64> = Vec::new();
    // Whether the published final proposal came from a settled drain (a
    // still-faulting store can leave a maintainer stuck mid-sync; prefer
    // any peer whose drain settled over one that didn't).
    let mut final_settled = false;
    for report in reports.iter_mut() {
        let Some(prop) = report.proposal.as_mut() else {
            continue;
        };
        let before = prop.cursor();
        // Highest store cursor observed across attempts: `top − before` is
        // how far this peer had fallen behind by shutdown.
        let mut top_seq = before;
        // A fault-injected fetch can return "no progress" (empty delta,
        // cursor unchanged) and look exactly like an idle store, so one
        // quiet fetch proves nothing; two consecutive quiet fetches is the
        // convergence signal (residual injection makes that a coin flip
        // squared — and the chaos tests schedule their outages to end
        // before shutdown anyway).
        let mut quiet = 0;
        let mut drained = false;
        for _ in 0..DRAIN_RETRIES {
            let at = prop.cursor();
            let attempt = (|| -> Result<(u64, usize)> {
                let now = match prop.unit() {
                    StalenessUnit::Nanos => driver_store.now()?,
                    StalenessUnit::Versions => driver_store.params_version()?,
                };
                let delta = driver_store.fetch_weights_since(at)?;
                let out = (delta.seq, delta.len());
                prop.absorb(&delta, now)?;
                Ok(out)
            })();
            match attempt {
                Ok((seq, len)) => {
                    top_seq = top_seq.max(seq);
                    if len == 0 && seq == at {
                        quiet += 1;
                    } else {
                        quiet = 0;
                    }
                    if quiet >= 2 {
                        drained = true;
                        break;
                    }
                }
                Err(_) => quiet = 0,
            }
        }
        report.stats.final_cursor = prop.cursor();
        report.stats.cursor_lag = top_seq.saturating_sub(before);
        crate::telemetry::gauge("peer.cursor_lag").set(report.stats.cursor_lag as f64);
        if !drained {
            log_warn!(
                "peer-driver",
                "peer-{} cursor drain did not settle (cursor {})",
                report.stats.id,
                prop.cursor()
            );
        }
        if final_weights.is_empty() || (drained && !final_settled) {
            final_settled = drained;
            final_ess = prop.ess_ratio();
            final_weights = (0..prop.len()).map(|i| prop.effective_weight(i)).collect();
        }
    }

    // Merge per-peer loss samples in global step order.
    let mut samples: Vec<(u64, f64)> = reports
        .iter()
        .flat_map(|r| r.losses.iter().copied())
        .collect();
    samples.sort_by_key(|s| s.0);
    for (idx, loss) in &samples {
        rec.record("train_loss", *idx, *loss);
    }

    // Final evaluation with the server's current parameters.  The store
    // may still be injecting faults at shutdown: retry the *fetch*, and
    // on persistent failure evaluate with the last successfully applied
    // params instead of discarding the whole run.  A delta that fails to
    // *apply* is deterministic (publisher/store config mismatch) and
    // still propagates — only transport failures are retried.
    let mut final_delta = None;
    for attempt in 0..DRAIN_RETRIES {
        match driver_store.fetch_params_since(eval_version) {
            Ok(d) => {
                final_delta = d;
                break;
            }
            Err(e) => log_warn!(
                "peer-driver",
                "final param fetch failed (attempt {attempt}, retrying): {e}"
            ),
        }
    }
    if let Some(delta) = final_delta {
        eval_version = super::peer::apply_eval_params_delta(
            &mut eval_master,
            driver_engine.manifest(),
            &delta,
        )?;
    }
    let _ = eval_version; // the cursor stays threaded through the last refresh too
    let final_err = (
        eval_master.evaluate(&driver_engine, EvalSplit::Train)?.1,
        eval_master.evaluate(&driver_engine, EvalSplit::Valid)?.1,
        eval_master.evaluate(&driver_engine, EvalSplit::Test)?.1,
    );
    let mut store_stats = match driver_store.stats() {
        Ok(s) => s,
        Err(e) => {
            log_warn!("peer-driver", "final stats fetch failed (reporting zeros): {e}");
            crate::weightstore::StoreStats::default()
        }
    };
    store_stats.push_calls_saved = reports.iter().map(|r| r.stats.push_calls_saved).sum();
    Ok(AsgdOutcome {
        rec,
        final_err,
        total_peer_steps: total.load(Ordering::SeqCst),
        store_stats,
        peers: reports.into_iter().map(|r| r.stats).collect(),
        final_ess,
        final_weights,
    })
}

/// One driver-side evaluation round against the server's current
/// parameters (version cursor: an unchanged model skips the download, a
/// changed one ships only its dirty layers).
fn eval_at(
    eval_master: &mut Master,
    engine: &Engine,
    store: &Arc<dyn WeightStore>,
    eval_version: &mut u64,
) -> Result<(f64, f64, f64)> {
    super::peer::refresh_eval_params(eval_master, engine.manifest(), store, eval_version)?;
    let (l, e) = eval_master.evaluate(engine, EvalSplit::Train)?;
    let (_tl, te) = eval_master.evaluate(engine, EvalSplit::Test)?;
    Ok((l, e, te))
}
