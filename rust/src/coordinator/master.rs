//! The master actor: runs ISSGD (or the uniform-SGD baseline) against a
//! weight store, per paper §4.
//!
//! Per step the master: (1) periodically publishes its parameters to the
//! store ("fire and forget"), (2) pulls the *delta* of probability weights
//! written since its cursor and folds it into a persistent
//! [`ProposalMaintainer`] — staleness filter (§B.1) and smoothing (§B.3)
//! maintained incrementally, O(changes · log N) instead of an O(N)
//! snapshot clone + sampler rebuild — (3) draws a minibatch from the
//! multinomial proposal, (4) executes the AOT `train_step` with the
//! importance coefficients, and (5) on configured cadences evaluates
//! prediction error and the Figure-4 variance monitors.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{RunConfig, StalenessUnit, TrainerKind};
use crate::data::{split_indices, BatchBuilder, Dataset, SplitSpec, SynthDataset, SynthSpec};
use crate::metrics::RunRecorder;
use crate::model::ParamSet;
use crate::runtime::Engine;
use crate::sampler::{smoothing_for_entropy, StalenessFilter};
use crate::util::rng::Pcg64;
use crate::variance::{trace_sigma, GTrueEstimator, VarianceReport};
use crate::weightstore::WeightStore;

use super::proposal::ProposalMaintainer;

/// Adaptive-entropy drift band: the O(N) smoothing re-solve fires when the
/// maintained (O(1)) entropy falls this far below the target...
const ADAPTIVE_ENTROPY_LOW_TOL: f64 = 5e-3;
/// ...or rises this far above it while a positive constant is active.
const ADAPTIVE_ENTROPY_HIGH_TOL: f64 = 2e-2;

/// The master's default saved-cursor name ([`WeightStore::save_cursor`]):
/// pins the store's compaction at the proposal's cursor and, on a durable
/// backend, survives store restarts so a resumed master can be found by
/// name.  The name is deliberately stable (not per-process) so a
/// restarted master reclaims its own pin; a **multi-master** deployment
/// sharing one store must give each master a distinct name via
/// [`Master::set_cursor_name`], or the fastest master drags the shared
/// pin forward and compaction demotes the slower ones to full-table
/// fetches.
pub const MASTER_CURSOR: &str = "master";

/// Steps between cursor persists (master steps / peer contributions).  The
/// pin needs only coarse granularity — a lagging pin costs at worst a
/// slightly larger delta after compaction, never correctness — so the sync
/// hot path must not pay a store round trip (or grow a durable journal)
/// every step.  Shared with `PeerState` so both consumer kinds pin at the
/// same cadence.
pub(crate) const CURSOR_SAVE_EVERY: u64 = 16;

/// Which split to evaluate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalSplit {
    Train,
    Valid,
    Test,
}

/// Master-side training session: parameters, data, splits, metrics.
pub struct Master {
    pub cfg: RunConfig,
    pub data: Arc<SynthDataset>,
    pub train_idx: Vec<usize>,
    pub valid_idx: Vec<usize>,
    pub test_idx: Vec<usize>,
    pub store: Arc<dyn WeightStore>,
    pub params: ParamSet,
    /// Last parameter version published to the store.
    pub version: u64,
    /// Master step counter.
    pub step: u64,
    pub rec: RunRecorder,
    rng: Pcg64,
    batch: BatchBuilder,
    gtrue: GTrueEstimator,
    /// Persistent proposal state: mirrors the store via deltas and keeps
    /// the Fenwick sampler maintained with point updates.
    proposal: ProposalMaintainer,
    /// Per-layer chunk bytes of the last successful publish: the dirty
    /// tracker behind layer-wise parameter pushes.  Empty = no layout
    /// published by us yet (the next publish ships the full layout).
    last_pushed: Vec<Vec<u8>>,
    /// Saved-cursor name ([`MASTER_CURSOR`] by default; multi-master
    /// deployments set distinct names — see the constant's docs).
    cursor_name: String,
    /// Last cursor successfully persisted via [`WeightStore::save_cursor`]
    /// (skip the round trip / journal frame when nothing advanced).
    saved_cursor: u64,
    /// Count of swallowed store failures (fire-and-forget resilience).
    pub store_errors: u64,
}

impl Master {
    /// Build a session: synthesise the dataset, split it, init parameters.
    pub fn new(cfg: RunConfig, engine: &Engine, store: Arc<dyn WeightStore>) -> Result<Master> {
        cfg.validate()?;
        let manifest = engine.manifest();
        let spec = if manifest.input_dim == 64 {
            SynthSpec::tiny(cfg.n_examples)
        } else {
            SynthSpec {
                dim: manifest.input_dim,
                ..SynthSpec::svhn_like(cfg.n_examples)
            }
        };
        anyhow::ensure!(
            spec.n_classes == manifest.n_classes,
            "dataset classes {} != model classes {}",
            spec.n_classes,
            manifest.n_classes
        );
        let data = Arc::new(SynthDataset::generate(cfg.seed, spec));
        let (train_idx, valid_idx, test_idx) = split_indices(data.len(), SplitSpec::default());
        anyhow::ensure!(
            store.fetch_weights()?.len() == train_idx.len(),
            "store tracks {} weights but the train split has {} examples",
            store.fetch_weights()?.len(),
            train_idx.len()
        );
        let mut rng = Pcg64::new(cfg.seed, 0x3A57E5);
        // Resume from the store when it already holds a published model
        // (a recovered durable store, or joining a live cluster): adopt
        // both the blob and its version so our first publish lands above
        // the persisted head instead of clobbering trained parameters
        // with a fresh init.  A fresh store (version 0) starts from He
        // init as before.  A *failed* probe is a hard error — not
        // fire-and-forget: guessing version 0 against a store that
        // actually holds v ≥ 1 would wedge every future publish behind
        // the monotonicity check, and guessing fresh params would clobber
        // a resumed run's model.  Construction has nothing safe to
        // degrade to; the caller retries or aborts.
        // Fetched layer-wise (cursor 0 ⇒ a full delta) so an adopted
        // store whose layout matches ours can seed the dirty tracker:
        // the first publish after a resume then ships only what actually
        // changed instead of re-uploading (and re-journaling) the whole
        // model under a fresh layout.
        let (version, params, last_pushed) = match store.fetch_params_since(0)? {
            Some(delta) => {
                crate::log_info!(
                    "master",
                    "resuming persisted parameters at version {}",
                    delta.version
                );
                let params = ParamSet::from_delta(manifest, &delta)?;
                let ours: Vec<String> = (0..params.layers.len())
                    .map(crate::model::layer_chunk_name)
                    .collect();
                let theirs: Vec<&str> = delta.layers.iter().map(|l| l.name.as_str()).collect();
                let seeded = if ours.iter().map(String::as_str).eq(theirs) {
                    delta.layers.into_iter().map(|l| l.bytes).collect()
                } else {
                    Vec::new() // blob-layout store: next publish re-layers it
                };
                (delta.version, params, seeded)
            }
            None => (0, ParamSet::init_he(manifest, &mut rng), Vec::new()),
        };
        let batch = BatchBuilder::new(manifest.batch_train, manifest.input_dim, manifest.n_classes);
        let proposal = ProposalMaintainer::new_with_strategy(
            train_idx.len(),
            cfg.smoothing,
            cfg.staleness_threshold,
            cfg.staleness_unit,
            cfg.strategy.strategy(),
        );
        Ok(Master {
            cfg,
            data,
            train_idx,
            valid_idx,
            test_idx,
            store,
            params,
            version,
            step: 0,
            rec: RunRecorder::new(),
            rng,
            batch,
            gtrue: GTrueEstimator::new(),
            proposal,
            last_pushed,
            cursor_name: MASTER_CURSOR.to_string(),
            saved_cursor: 0,
            store_errors: 0,
        })
    }

    /// Rename this master's compaction pin / resume handle (required when
    /// several masters share one store — see [`MASTER_CURSOR`]).
    pub fn set_cursor_name(&mut self, name: impl Into<String>) {
        self.cursor_name = name.into();
        // Force a re-save under the new name on the next sync.
        self.saved_cursor = 0;
    }

    /// Number of weights the store must track for this session's config —
    /// use before `Master::new` to size the store.
    pub fn store_size(cfg: &RunConfig) -> usize {
        let (train, _, _) = split_indices(cfg.n_examples, SplitSpec::default());
        train.len()
    }

    /// Publish current parameters if the cadence says so (always publishes
    /// at step 0 so workers can start scoring immediately) — **layer-wise**:
    /// the first publish ships the full manifest-keyed layout, every later
    /// one diffs each layer's bytes against the last successful publish
    /// and ships only the layers the optimizer actually changed (frozen or
    /// converged layers cost nothing on the wire or in the durable
    /// journal).  A cadence step where nothing changed skips the store
    /// round trip entirely.
    ///
    /// Store failures are logged and swallowed: the paper's master is
    /// "fire and forget" (§4.2) — a flaky database must degrade ISSGD
    /// towards plain SGD, never crash training.  The dirty tracker only
    /// advances on success, so a failed push's layers are retried whole.
    pub fn maybe_push_params(&mut self) -> Result<bool> {
        if self.step % self.cfg.param_push_every != 0 {
            return Ok(false);
        }
        let mut chunks = self.params.to_layer_chunks();
        let full = self.last_pushed.len() != chunks.len();
        let dirty: Vec<usize> = if full {
            (0..chunks.len()).collect()
        } else {
            (0..chunks.len())
                .filter(|&i| self.last_pushed[i] != chunks[i].1)
                .collect()
        };
        if dirty.is_empty() {
            return Ok(false); // nothing changed since the last publish
        }
        // Move (never copy) the dirty chunks into the payload: a full
        // publish of the `paper` config is ~76 MB, and on success the
        // same buffers become the dirty tracker's new baseline.  On
        // failure the payload is simply dropped — the next cadence
        // re-serializes from `self.params`, whose layers a failed store
        // call cannot have consumed.
        let payload: Vec<(String, Vec<u8>)> = dirty
            .iter()
            .map(|&i| (std::mem::take(&mut chunks[i].0), std::mem::take(&mut chunks[i].1)))
            .collect();
        match self.store.push_params_layers(self.version + 1, full, &payload) {
            Ok(()) => {
                self.version += 1;
                if full {
                    self.last_pushed = payload.into_iter().map(|(_, b)| b).collect();
                } else {
                    for (&i, (_, b)) in dirty.iter().zip(payload) {
                        self.last_pushed[i] = b;
                    }
                }
                Ok(true)
            }
            Err(e) => {
                self.store_errors += 1;
                crate::log_warn!("master", "param push failed (continuing): {e}");
                Ok(false)
            }
        }
    }

    /// Staleness-filter a raw weight snapshot.  Returns the raw (unsmoothed)
    /// weights with filtered-out entries marked `None`, plus the kept
    /// fraction.
    fn raw_filtered_weights(&self) -> Result<(Vec<Option<f64>>, f64)> {
        let snap = self.store.fetch_weights()?;
        let (stamps, now): (&[u64], u64) = match self.cfg.staleness_unit {
            StalenessUnit::Nanos => (&snap.stamps, self.store.now()?),
            StalenessUnit::Versions => (&snap.param_versions, self.version),
        };
        let filter = match self.cfg.staleness_threshold {
            None => StalenessFilter::disabled(),
            Some(t) => StalenessFilter::with_threshold(t),
        };
        let mut weights = vec![None; snap.len()];
        let mut kept = 0usize;
        for i in 0..snap.len() {
            if filter.keep(stamps[i], now) {
                weights[i] = Some(snap.weights[i]);
                kept += 1;
            }
        }
        let kept_frac = if snap.is_empty() {
            1.0
        } else {
            kept as f64 / snap.len() as f64
        };
        Ok((weights, kept_frac))
    }

    /// Staleness-filter + price a raw weight snapshot into the sampling
    /// weights actually used (the configured strategy's `mass`, which for
    /// the default grad-norm strategy is exactly the §B.3 `w + c`).
    /// Returns `(weights, kept_fraction)` — filtered-out entries get
    /// weight 0 (excluded from the proposal).
    pub fn effective_weights(&self, smoothing: f64) -> Result<(Vec<f64>, f64)> {
        let (raw, kept_frac) = self.raw_filtered_weights()?;
        let strategy = self.cfg.strategy.strategy();
        let weights = raw
            .iter()
            .map(|w| w.map(|w| strategy.mass(w, smoothing)).unwrap_or(0.0))
            .collect();
        Ok((weights, kept_frac))
    }

    /// Pull the weight delta written since our cursor and fold it into the
    /// persistent proposal — the O(changes · log N) replacement for the old
    /// per-step snapshot clone + sampler rebuild.
    ///
    /// Store failures are swallowed ("fire and forget", §4.2): the master
    /// keeps sampling from the last synced proposal, which stays a valid
    /// (merely staler) importance distribution; before the first successful
    /// sync the proposal is empty and `draw_minibatch` degrades to uniform
    /// SGD.
    fn sync_proposal(&mut self) {
        let synced = (|| -> Result<()> {
            let now = match self.cfg.staleness_unit {
                StalenessUnit::Nanos => self.store.now()?,
                StalenessUnit::Versions => self.version,
            };
            let delta = self.store.fetch_weights_since(self.proposal.cursor())?;
            self.proposal.absorb(&delta, now)
        })();
        match synced {
            Ok(()) => {
                // Persist the advanced cursor: a compaction pin while we
                // live, a resume point if the store (or we) restart.  As
                // fire-and-forget as the fetch itself — the worst a lost
                // save costs is one full-table resync later.  Saved on the
                // [`CURSOR_SAVE_EVERY`] cadence (plus once up front to
                // register the pin) and only when it actually moved.
                let cursor = self.proposal.cursor();
                if cursor != self.saved_cursor
                    && (self.saved_cursor == 0 || self.step % CURSOR_SAVE_EVERY == 0)
                {
                    match self.store.save_cursor(&self.cursor_name, cursor) {
                        Ok(()) => self.saved_cursor = cursor,
                        Err(e) => {
                            self.store_errors += 1;
                            crate::log_warn!("master", "cursor save failed (continuing): {e}");
                        }
                    }
                }
            }
            Err(e) => {
                self.store_errors += 1;
                crate::log_warn!("master", "weight delta fetch failed (keeping last proposal): {e}");
            }
        }
    }

    /// One master training step.  Returns the minibatch loss.
    pub fn train_one_step(&mut self, engine: &Engine) -> Result<f32> {
        let m = self.batch.batch();
        let (positions, coefs) = match self.cfg.trainer {
            TrainerKind::Issgd => {
                self.sync_proposal();
                self.rec
                    .record("kept_frac", self.step, self.proposal.kept_fraction());
                if let Some(target) = self.cfg.adaptive_entropy {
                    // Adaptive entropy: the maintainer tracks Σ v ln v
                    // incrementally, so the current normalised entropy is
                    // O(1).  Only when it drifts off target do we pay the
                    // O(N) re-solve + re-smooth — the fast path survives a
                    // moving constant.  The band is asymmetric: dropping
                    // below target (the §B.3 "time bomb" direction) triggers
                    // almost immediately, while an over-smoothed proposal
                    // (merely conservative) is allowed more slack.
                    let h = self.proposal.normalized_entropy();
                    let drifted = h + ADAPTIVE_ENTROPY_LOW_TOL < target
                        || (self.proposal.smoothing() > 0.0
                            && h > target + ADAPTIVE_ENTROPY_HIGH_TOL);
                    if drifted {
                        let c = smoothing_for_entropy(&self.proposal.kept_raw(), target, 1e-4);
                        self.proposal.set_smoothing(c);
                    }
                    self.rec
                        .record("smoothing_c", self.step, self.proposal.smoothing());
                }
                if self.step % 10 == 0 {
                    self.rec.record("ess", self.step, self.proposal.ess_ratio());
                    self.rec.record(
                        "proposal_changes",
                        self.step,
                        self.proposal.last_changes() as f64,
                    );
                }
                let (positions, coefs, _) = self.proposal.draw_minibatch(&mut self.rng, m);
                (positions, coefs)
            }
            TrainerKind::UniformSgd => {
                let positions = self.rng.sample_with_replacement(self.train_idx.len(), m);
                (positions, vec![1.0f32; m])
            }
        };
        // Staleness diagnostics: how old (in versions) are the weights of
        // the sampled examples?  Reads the proposal's raw mirror — the old
        // code cloned a *second* full snapshot from the store for this.
        if self.cfg.trainer == TrainerKind::Issgd && self.step % 10 == 0 {
            // cursor > 0 ⇔ at least one successful sync: before that the
            // mirror is all zeros and the lag would be fabricated (the old
            // code likewise skipped the metric when its fetch failed).
            if self.proposal.cursor() > 0 {
                let raw = self.proposal.raw();
                let lag: f64 = positions
                    .iter()
                    .map(|&p| (self.version.saturating_sub(raw.param_versions[p])) as f64)
                    .sum::<f64>()
                    / positions.len().max(1) as f64;
                self.rec.record("sampled_version_lag", self.step, lag);
            }
        }
        let global: Vec<usize> = positions.iter().map(|&p| self.train_idx[p]).collect();
        self.batch.fill(self.data.as_ref(), &global);
        let out = engine.train_step(&mut self.params, &self.batch.x, &self.batch.y, &coefs, self.cfg.lr)?;
        self.rec.record("train_loss", self.step, out.loss as f64);
        self.step += 1;
        Ok(out.loss)
    }

    /// Mean loss + prediction error over (a capped number of batches of) a
    /// split.  Exact: the final partial batch is padded (the AOT artifact's
    /// batch shape is fixed) but padding is measured and subtracted, so no
    /// example is double-counted and the divisor is the true example count.
    pub fn evaluate(&mut self, engine: &Engine, split: EvalSplit) -> Result<(f64, f64)> {
        let idx: &[usize] = match split {
            EvalSplit::Train => &self.train_idx,
            EvalSplit::Valid => &self.valid_idx,
            EvalSplit::Test => &self.test_idx,
        };
        let manifest = engine.manifest();
        let e = manifest.batch_eval;
        let mut batch = BatchBuilder::new(e, manifest.input_dim, manifest.n_classes);
        let (mut sum_loss, mut sum_correct, mut count) = (0f64, 0f64, 0usize);
        for (start, c) in eval_batch_plan(idx.len(), e, self.cfg.eval_max_batches) {
            let chunk = &idx[start..start + c];
            if c == e {
                batch.fill(self.data.as_ref(), chunk);
                let out = engine.eval_step(&self.params, &batch.x, &batch.y)?;
                sum_loss += out.sum_loss as f64;
                sum_correct += out.n_correct as f64;
            } else {
                // Partial tail: pad every free slot with one row and
                // measure that row's exact per-example contribution with a
                // batch made only of it, then subtract the padding.
                let pad = chunk[0];
                batch.fill(self.data.as_ref(), &vec![pad; e]);
                let pout = engine.eval_step(&self.params, &batch.x, &batch.y)?;
                let pad_loss = pout.sum_loss as f64 / e as f64;
                let pad_correct = pout.n_correct as f64 / e as f64;
                let mut slots = chunk.to_vec();
                slots.resize(e, pad);
                batch.fill(self.data.as_ref(), &slots);
                let out = engine.eval_step(&self.params, &batch.x, &batch.y)?;
                let extra = (e - c) as f64;
                sum_loss += out.sum_loss as f64 - extra * pad_loss;
                sum_correct += out.n_correct as f64 - extra * pad_correct;
            }
            count += c;
        }
        anyhow::ensure!(count > 0, "evaluation split is empty");
        let mean_loss = sum_loss / count as f64;
        let err = 1.0 - sum_correct / count as f64;
        Ok((mean_loss, err))
    }

    /// Record the standard evaluation metrics on the configured cadence.
    pub fn maybe_evaluate(&mut self, engine: &Engine) -> Result<()> {
        if self.cfg.eval_every == 0 || self.step % self.cfg.eval_every != 0 {
            return Ok(());
        }
        let (train_loss, train_err) = self.evaluate(engine, EvalSplit::Train)?;
        let (test_loss, test_err) = self.evaluate(engine, EvalSplit::Test)?;
        let step = self.step;
        self.rec.record("eval_train_loss", step, train_loss);
        self.rec.record("eval_train_err", step, train_err);
        self.rec.record("eval_test_loss", step, test_loss);
        self.rec.record("eval_test_err", step, test_err);
        Ok(())
    }

    /// Current per-example squared gradient norms over the whole training
    /// split (the variance monitor's ground truth; O(N/B) scoring calls).
    pub fn score_train_set(&self, engine: &Engine) -> Result<Vec<f64>> {
        let manifest = engine.manifest();
        let b = manifest.batch_score;
        let mut batch = BatchBuilder::new(b, manifest.input_dim, manifest.n_classes);
        let n = self.train_idx.len();
        let mut sqnorms = vec![0f64; n];
        let mut start = 0;
        while start < n {
            let count = (n - start).min(b);
            let chunk: Vec<usize> = (0..count).map(|i| self.train_idx[start + i]).collect();
            batch.fill(self.data.as_ref(), &chunk);
            let out = engine.grad_norms(&self.params, &batch.x, &batch.y)?;
            for i in 0..count {
                sqnorms[start + i] = out.sqnorms[i] as f64;
            }
            start += count;
        }
        Ok(sqnorms)
    }

    /// §B.2 ‖g_TRUE‖² estimate: average ‖minibatch mean grad‖² over
    /// `n_batches` uniform minibatches under the current parameters.
    pub fn estimate_g_true_sq(&mut self, engine: &Engine, n_batches: usize) -> Result<f64> {
        self.gtrue.reset();
        let m = self.batch.batch();
        for _ in 0..n_batches {
            let pos = self.rng.sample_with_replacement(self.train_idx.len(), m);
            let global: Vec<usize> = pos.iter().map(|&p| self.train_idx[p]).collect();
            self.batch.fill(self.data.as_ref(), &global);
            let sq = engine.grad_mean_sqnorm(&self.params, &self.batch.x, &self.batch.y)?;
            self.gtrue.push(sq as f64);
        }
        Ok(self.gtrue.estimate())
    }

    /// The Figure-4 variance monitor: Tr(Σ) for q_IDEAL / q_STALE (actual
    /// smoothing) / q_STALE (alternate smoothing) / q_UNIF under the
    /// *current* parameters.  Expensive — gated by `cfg.monitor_every`.
    pub fn monitor_variance(&mut self, engine: &Engine) -> Result<(VarianceReport, VarianceReport)> {
        let sqnorms = self.score_train_set(engine)?;
        let g_true_sq = self.estimate_g_true_sq(engine, 4)?;
        let (stale_actual, kept) = self.effective_weights(self.cfg.smoothing)?;
        let (stale_alt, _) = self.effective_weights(self.cfg.monitor_alt_smoothing)?;
        let actual = trace_sigma(&sqnorms, &stale_actual, g_true_sq);
        let alt = trace_sigma(&sqnorms, &stale_alt, g_true_sq);
        let step = self.step;
        self.rec.record("var_ideal_sqrt", step, actual.ideal().sqrt());
        self.rec.record("var_unif_sqrt", step, actual.unif().sqrt());
        self.rec.record("var_stale_sqrt", step, actual.stale().sqrt());
        self.rec.record("var_stale_alt_sqrt", step, alt.stale().sqrt());
        self.rec.record("g_true_sq", step, g_true_sq);
        self.rec.record("monitor_kept_frac", step, kept);
        Ok((actual, alt))
    }

    /// Persist a resumable checkpoint of this session.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        crate::model::Checkpoint {
            model: self.cfg.model.clone(),
            step: self.step,
            version: self.version,
            seed: self.cfg.seed,
            params: self.params.clone(),
        }
        .save(path)
    }

    /// Restore parameters/step/version from a checkpoint (validated
    /// against the engine's manifest; the config seed must match so the
    /// dataset regenerates identically).
    pub fn restore_checkpoint(&mut self, engine: &Engine, path: &std::path::Path) -> Result<()> {
        let ckpt = crate::model::Checkpoint::load(path, engine.manifest())?;
        anyhow::ensure!(
            ckpt.seed == self.cfg.seed,
            "checkpoint seed {} != config seed {} (dataset would differ)",
            ckpt.seed,
            self.cfg.seed
        );
        self.params = ckpt.params;
        self.step = ckpt.step;
        self.version = ckpt.version;
        Ok(())
    }

    pub fn maybe_monitor(&mut self, engine: &Engine) -> Result<()> {
        if self.cfg.monitor_every == 0 || self.step % self.cfg.monitor_every != 0 {
            return Ok(());
        }
        self.monitor_variance(engine)?;
        Ok(())
    }
}

/// Exact, non-wrapping evaluation batches: `(start, count)` chunks of up
/// to `batch` covering `[0, n)` in order, capped at `max_batches`
/// (0 = no cap).  Only the final chunk may be short — the old plan wrapped
/// indices modulo the split and double-counted whenever `n % batch != 0`.
pub fn eval_batch_plan(n: usize, batch: usize, max_batches: usize) -> Vec<(usize, usize)> {
    if n == 0 || batch == 0 {
        return Vec::new();
    }
    let total = n.div_ceil(batch);
    let take = if max_batches == 0 {
        total
    } else {
        total.min(max_batches)
    };
    (0..take)
        .map(|b| {
            let start = b * batch;
            (start, batch.min(n - start))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_plan_covers_divisible_split_exactly() {
        let plan = eval_batch_plan(12, 4, 0);
        assert_eq!(plan, vec![(0, 4), (4, 4), (8, 4)]);
        assert_eq!(plan.iter().map(|&(_, c)| c).sum::<usize>(), 12);
    }

    #[test]
    fn eval_plan_handles_partial_tail_without_wrapping() {
        let plan = eval_batch_plan(10, 4, 0);
        assert_eq!(plan, vec![(0, 4), (4, 4), (8, 2)]);
        // Every index covered exactly once.
        let mut seen = vec![0usize; 10];
        for (start, c) in plan {
            for i in start..start + c {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&k| k == 1));
    }

    #[test]
    fn eval_plan_small_split_is_one_short_batch() {
        assert_eq!(eval_batch_plan(3, 8, 0), vec![(0, 3)]);
    }

    #[test]
    fn eval_plan_respects_cap() {
        assert_eq!(eval_batch_plan(100, 10, 3), vec![(0, 10), (10, 10), (20, 10)]);
        // The cap can include the partial tail.
        assert_eq!(eval_batch_plan(15, 10, 2), vec![(0, 10), (10, 5)]);
    }

    #[test]
    fn eval_plan_degenerate_inputs() {
        assert!(eval_batch_plan(0, 8, 0).is_empty());
        assert!(eval_batch_plan(8, 0, 0).is_empty());
    }
}
