//! The master actor: runs ISSGD (or the uniform-SGD baseline) against a
//! weight store, per paper §4.
//!
//! Per step the master: (1) periodically publishes its parameters to the
//! store ("fire and forget"), (2) pulls the probability-weight snapshot,
//! applies the §B.1 staleness filter and §B.3 smoothing, (3) draws a
//! minibatch from the multinomial proposal, (4) executes the AOT
//! `train_step` with the importance coefficients, and (5) on configured
//! cadences evaluates prediction error and the Figure-4 variance monitors.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{RunConfig, StalenessUnit, TrainerKind};
use crate::data::{split_indices, BatchBuilder, Dataset, SplitSpec, SynthDataset, SynthSpec};
use crate::metrics::RunRecorder;
use crate::model::ParamSet;
use crate::runtime::Engine;
use crate::sampler::{
    draw_minibatch, effective_sample_size_ratio, smoothing_for_entropy, FenwickSampler,
    Smoothing, StalenessFilter,
};
use crate::util::rng::Pcg64;
use crate::variance::{trace_sigma, GTrueEstimator, VarianceReport};
use crate::weightstore::WeightStore;

/// Which split to evaluate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalSplit {
    Train,
    Valid,
    Test,
}

/// Master-side training session: parameters, data, splits, metrics.
pub struct Master {
    pub cfg: RunConfig,
    pub data: Arc<SynthDataset>,
    pub train_idx: Vec<usize>,
    pub valid_idx: Vec<usize>,
    pub test_idx: Vec<usize>,
    pub store: Arc<dyn WeightStore>,
    pub params: ParamSet,
    /// Last parameter version published to the store.
    pub version: u64,
    /// Master step counter.
    pub step: u64,
    pub rec: RunRecorder,
    rng: Pcg64,
    batch: BatchBuilder,
    gtrue: GTrueEstimator,
    /// Count of swallowed store failures (fire-and-forget resilience).
    pub store_errors: u64,
}

impl Master {
    /// Build a session: synthesise the dataset, split it, init parameters.
    pub fn new(cfg: RunConfig, engine: &Engine, store: Arc<dyn WeightStore>) -> Result<Master> {
        cfg.validate()?;
        let manifest = engine.manifest();
        let spec = if manifest.input_dim == 64 {
            SynthSpec::tiny(cfg.n_examples)
        } else {
            SynthSpec {
                dim: manifest.input_dim,
                ..SynthSpec::svhn_like(cfg.n_examples)
            }
        };
        anyhow::ensure!(
            spec.n_classes == manifest.n_classes,
            "dataset classes {} != model classes {}",
            spec.n_classes,
            manifest.n_classes
        );
        let data = Arc::new(SynthDataset::generate(cfg.seed, spec));
        let (train_idx, valid_idx, test_idx) = split_indices(data.len(), SplitSpec::default());
        anyhow::ensure!(
            store.fetch_weights()?.len() == train_idx.len(),
            "store tracks {} weights but the train split has {} examples",
            store.fetch_weights()?.len(),
            train_idx.len()
        );
        let mut rng = Pcg64::new(cfg.seed, 0x3A57E5);
        let params = ParamSet::init_he(manifest, &mut rng);
        let batch = BatchBuilder::new(manifest.batch_train, manifest.input_dim, manifest.n_classes);
        Ok(Master {
            cfg,
            data,
            train_idx,
            valid_idx,
            test_idx,
            store,
            params,
            version: 0,
            step: 0,
            rec: RunRecorder::new(),
            rng,
            batch,
            gtrue: GTrueEstimator::new(),
            store_errors: 0,
        })
    }

    /// Number of weights the store must track for this session's config —
    /// use before `Master::new` to size the store.
    pub fn store_size(cfg: &RunConfig) -> usize {
        let (train, _, _) = split_indices(cfg.n_examples, SplitSpec::default());
        train.len()
    }

    /// Publish current parameters if the cadence says so (always publishes
    /// at step 0 so workers can start scoring immediately).
    ///
    /// Store failures are logged and swallowed: the paper's master is
    /// "fire and forget" (§4.2) — a flaky database must degrade ISSGD
    /// towards plain SGD, never crash training.
    pub fn maybe_push_params(&mut self) -> Result<bool> {
        if self.step % self.cfg.param_push_every != 0 {
            return Ok(false);
        }
        match self
            .store
            .push_params(self.version + 1, self.params.to_bytes())
        {
            Ok(()) => {
                self.version += 1;
                Ok(true)
            }
            Err(e) => {
                self.store_errors += 1;
                crate::log_warn!("master", "param push failed (continuing): {e}");
                Ok(false)
            }
        }
    }

    /// Staleness-filter a raw weight snapshot.  Returns the raw (unsmoothed)
    /// weights with filtered-out entries marked `None`, plus the kept
    /// fraction.
    fn raw_filtered_weights(&self) -> Result<(Vec<Option<f64>>, f64)> {
        let snap = self.store.fetch_weights()?;
        let (stamps, now): (&[u64], u64) = match self.cfg.staleness_unit {
            StalenessUnit::Nanos => (&snap.stamps, self.store.now()?),
            StalenessUnit::Versions => (&snap.param_versions, self.version),
        };
        let filter = match self.cfg.staleness_threshold {
            None => StalenessFilter::disabled(),
            Some(t) => StalenessFilter::with_threshold(t),
        };
        let mut weights = vec![None; snap.len()];
        let mut kept = 0usize;
        for i in 0..snap.len() {
            if filter.keep(stamps[i], now) {
                weights[i] = Some(snap.weights[i]);
                kept += 1;
            }
        }
        let kept_frac = if snap.is_empty() {
            1.0
        } else {
            kept as f64 / snap.len() as f64
        };
        Ok((weights, kept_frac))
    }

    /// Staleness-filter + smooth a raw weight snapshot into the sampling
    /// weights actually used.  Returns `(weights, kept_fraction)` —
    /// filtered-out entries get weight 0 (excluded from the proposal).
    pub fn effective_weights(&self, smoothing: f64) -> Result<(Vec<f64>, f64)> {
        let (raw, kept_frac) = self.raw_filtered_weights()?;
        let smooth = Smoothing::new(smoothing);
        let weights = raw
            .iter()
            .map(|w| w.map(|w| smooth.apply(w)).unwrap_or(0.0))
            .collect();
        Ok((weights, kept_frac))
    }

    /// The smoothing constant for this step: the fixed §B.3 constant, or
    /// the entropy-targeted adaptive constant (§B.3's suggested extension)
    /// solved on the kept weights.
    fn smoothing_for_step(&self, raw: &[Option<f64>]) -> f64 {
        match self.cfg.adaptive_entropy {
            None => self.cfg.smoothing,
            Some(target) => {
                let kept: Vec<f64> = raw.iter().filter_map(|w| *w).collect();
                smoothing_for_entropy(&kept, target, 1e-4)
            }
        }
    }

    /// One master training step.  Returns the minibatch loss.
    pub fn train_one_step(&mut self, engine: &Engine) -> Result<f32> {
        let m = self.batch.batch();
        let (positions, coefs) = match self.cfg.trainer {
            TrainerKind::Issgd => {
                // Degrade to uniform sampling if the store is unreachable —
                // an unbiased fallback (it is exactly regular SGD).
                let (raw, kept) = match self.raw_filtered_weights() {
                    Ok(v) => v,
                    Err(e) => {
                        self.store_errors += 1;
                        crate::log_warn!("master", "weight fetch failed (uniform fallback): {e}");
                        (vec![Some(1.0); self.train_idx.len()], 1.0)
                    }
                };
                self.rec.record("kept_frac", self.step, kept);
                let c = self.smoothing_for_step(&raw);
                if self.cfg.adaptive_entropy.is_some() {
                    self.rec.record("smoothing_c", self.step, c);
                }
                let smooth = Smoothing::new(c);
                let weights: Vec<f64> = raw
                    .iter()
                    .map(|w| w.map(|w| smooth.apply(w)).unwrap_or(0.0))
                    .collect();
                if self.step % 10 == 0 {
                    self.rec
                        .record("ess", self.step, effective_sample_size_ratio(&weights));
                }
                let sampler = FenwickSampler::new(&weights);
                let (positions, coefs, _) = draw_minibatch(&sampler, &mut self.rng, m);
                (positions, coefs)
            }
            TrainerKind::UniformSgd => {
                let positions = self.rng.sample_with_replacement(self.train_idx.len(), m);
                (positions, vec![1.0f32; m])
            }
        };
        // Staleness diagnostics: how old (in versions) are the weights of
        // the sampled examples?
        if self.cfg.trainer == TrainerKind::Issgd && self.step % 10 == 0 {
            if let Ok(snap) = self.store.fetch_weights() {
            let lag: f64 = positions
                .iter()
                .map(|&p| (self.version.saturating_sub(snap.param_versions[p])) as f64)
                .sum::<f64>()
                / positions.len().max(1) as f64;
            self.rec.record("sampled_version_lag", self.step, lag);
            }
        }
        let global: Vec<usize> = positions.iter().map(|&p| self.train_idx[p]).collect();
        self.batch.fill(self.data.as_ref(), &global);
        let out = engine.train_step(&mut self.params, &self.batch.x, &self.batch.y, &coefs, self.cfg.lr)?;
        self.rec.record("train_loss", self.step, out.loss as f64);
        self.step += 1;
        Ok(out.loss)
    }

    /// Mean loss + prediction error over (a capped number of full batches
    /// of) a split.
    pub fn evaluate(&mut self, engine: &Engine, split: EvalSplit) -> Result<(f64, f64)> {
        let idx: &[usize] = match split {
            EvalSplit::Train => &self.train_idx,
            EvalSplit::Valid => &self.valid_idx,
            EvalSplit::Test => &self.test_idx,
        };
        let manifest = engine.manifest();
        let e = manifest.batch_eval;
        let mut batch = BatchBuilder::new(e, manifest.input_dim, manifest.n_classes);
        let n_full = (idx.len() / e).max(1);
        let n_batches = if self.cfg.eval_max_batches == 0 {
            n_full
        } else {
            n_full.min(self.cfg.eval_max_batches)
        };
        let (mut sum_loss, mut sum_correct, mut count) = (0f64, 0f64, 0usize);
        for b in 0..n_batches {
            let start = b * e;
            let chunk: Vec<usize> = (0..e).map(|i| idx[(start + i) % idx.len()]).collect();
            batch.fill(self.data.as_ref(), &chunk);
            let out = engine.eval_step(&self.params, &batch.x, &batch.y)?;
            sum_loss += out.sum_loss as f64;
            sum_correct += out.n_correct as f64;
            count += e;
        }
        let mean_loss = sum_loss / count as f64;
        let err = 1.0 - sum_correct / count as f64;
        Ok((mean_loss, err))
    }

    /// Record the standard evaluation metrics on the configured cadence.
    pub fn maybe_evaluate(&mut self, engine: &Engine) -> Result<()> {
        if self.cfg.eval_every == 0 || self.step % self.cfg.eval_every != 0 {
            return Ok(());
        }
        let (train_loss, train_err) = self.evaluate(engine, EvalSplit::Train)?;
        let (test_loss, test_err) = self.evaluate(engine, EvalSplit::Test)?;
        let step = self.step;
        self.rec.record("eval_train_loss", step, train_loss);
        self.rec.record("eval_train_err", step, train_err);
        self.rec.record("eval_test_loss", step, test_loss);
        self.rec.record("eval_test_err", step, test_err);
        Ok(())
    }

    /// Current per-example squared gradient norms over the whole training
    /// split (the variance monitor's ground truth; O(N/B) scoring calls).
    pub fn score_train_set(&self, engine: &Engine) -> Result<Vec<f64>> {
        let manifest = engine.manifest();
        let b = manifest.batch_score;
        let mut batch = BatchBuilder::new(b, manifest.input_dim, manifest.n_classes);
        let n = self.train_idx.len();
        let mut sqnorms = vec![0f64; n];
        let mut start = 0;
        while start < n {
            let count = (n - start).min(b);
            let chunk: Vec<usize> = (0..count).map(|i| self.train_idx[start + i]).collect();
            batch.fill(self.data.as_ref(), &chunk);
            let out = engine.grad_norms(&self.params, &batch.x, &batch.y)?;
            for i in 0..count {
                sqnorms[start + i] = out.sqnorms[i] as f64;
            }
            start += count;
        }
        Ok(sqnorms)
    }

    /// §B.2 ‖g_TRUE‖² estimate: average ‖minibatch mean grad‖² over
    /// `n_batches` uniform minibatches under the current parameters.
    pub fn estimate_g_true_sq(&mut self, engine: &Engine, n_batches: usize) -> Result<f64> {
        self.gtrue.reset();
        let m = self.batch.batch();
        for _ in 0..n_batches {
            let pos = self.rng.sample_with_replacement(self.train_idx.len(), m);
            let global: Vec<usize> = pos.iter().map(|&p| self.train_idx[p]).collect();
            self.batch.fill(self.data.as_ref(), &global);
            let sq = engine.grad_mean_sqnorm(&self.params, &self.batch.x, &self.batch.y)?;
            self.gtrue.push(sq as f64);
        }
        Ok(self.gtrue.estimate())
    }

    /// The Figure-4 variance monitor: Tr(Σ) for q_IDEAL / q_STALE (actual
    /// smoothing) / q_STALE (alternate smoothing) / q_UNIF under the
    /// *current* parameters.  Expensive — gated by `cfg.monitor_every`.
    pub fn monitor_variance(&mut self, engine: &Engine) -> Result<(VarianceReport, VarianceReport)> {
        let sqnorms = self.score_train_set(engine)?;
        let g_true_sq = self.estimate_g_true_sq(engine, 4)?;
        let (stale_actual, kept) = self.effective_weights(self.cfg.smoothing)?;
        let (stale_alt, _) = self.effective_weights(self.cfg.monitor_alt_smoothing)?;
        let actual = trace_sigma(&sqnorms, &stale_actual, g_true_sq);
        let alt = trace_sigma(&sqnorms, &stale_alt, g_true_sq);
        let step = self.step;
        self.rec.record("var_ideal_sqrt", step, actual.ideal().sqrt());
        self.rec.record("var_unif_sqrt", step, actual.unif().sqrt());
        self.rec.record("var_stale_sqrt", step, actual.stale().sqrt());
        self.rec.record("var_stale_alt_sqrt", step, alt.stale().sqrt());
        self.rec.record("g_true_sq", step, g_true_sq);
        self.rec.record("monitor_kept_frac", step, kept);
        Ok((actual, alt))
    }

    /// Persist a resumable checkpoint of this session.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        crate::model::Checkpoint {
            model: self.cfg.model.clone(),
            step: self.step,
            version: self.version,
            seed: self.cfg.seed,
            params: self.params.clone(),
        }
        .save(path)
    }

    /// Restore parameters/step/version from a checkpoint (validated
    /// against the engine's manifest; the config seed must match so the
    /// dataset regenerates identically).
    pub fn restore_checkpoint(&mut self, engine: &Engine, path: &std::path::Path) -> Result<()> {
        let ckpt = crate::model::Checkpoint::load(path, engine.manifest())?;
        anyhow::ensure!(
            ckpt.seed == self.cfg.seed,
            "checkpoint seed {} != config seed {} (dataset would differ)",
            ckpt.seed,
            self.cfg.seed
        );
        self.params = ckpt.params;
        self.step = ckpt.step;
        self.version = ckpt.version;
        Ok(())
    }

    pub fn maybe_monitor(&mut self, engine: &Engine) -> Result<()> {
        if self.cfg.monitor_every == 0 || self.step % self.cfg.monitor_every != 0 {
            return Ok(());
        }
        self.monitor_variance(engine)?;
        Ok(())
    }
}
