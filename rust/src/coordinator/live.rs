//! Live cluster: master + workers as real OS threads (optionally against a
//! remote TCP weight store), with genuine wall-clock staleness — the
//! paper's actual deployment shape.
//!
//! Every thread compiles its own [`Engine`] (PJRT client handles are not
//! `Send`), mirroring the paper's one-GPU-per-actor topology.  The master
//! never waits on workers ("fire and forget", §4.2) — relaxed mode only;
//! exact mode is a simulation-side tool (`sim.rs`).  The peer/ASGD
//! counterpart of this mode lives in [`super::peer_live`].

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::config::{RunConfig, SyncMode};
use crate::data::shards;
use crate::runtime::{artifacts_dir, Engine};
use crate::weightstore::{MemStore, WeightStore};
use crate::{log_info, log_warn};

use super::master::Master;
use super::sim::SimOutcome;
use super::worker::WorkerState;

/// Options specific to live execution.
#[derive(Clone, Default)]
pub struct LiveOptions {
    /// Inject a pre-built store (the CLI passes a durable backend; tests
    /// wrap one in a `FaultyStore`); it must track `Master::store_size(cfg)`
    /// weights.  Mutually exclusive with `store_addr`.
    pub store: Option<Arc<dyn WeightStore>>,
    /// Connect to a remote TCP store instead of an in-process one.
    pub store_addr: Option<String>,
    /// Pause between worker scoring batches (keeps a small host responsive
    /// and emulates slower scoring hardware).
    pub worker_throttle: Option<std::time::Duration>,
    /// Before the first master step, wait until every worker has pushed at
    /// least one weight batch.  Strictly speaking a synchronisation
    /// barrier (the paper's master never waits), but useful on small hosts
    /// where the master otherwise finishes before workers even compile.
    pub wait_for_first_scores: bool,
}

/// Run a live threaded cluster for `cfg`.
pub fn run_live(cfg: &RunConfig, opts: &LiveOptions) -> Result<SimOutcome> {
    anyhow::ensure!(
        cfg.sync == SyncMode::Relaxed,
        "live mode is fire-and-forget; use sim mode for exact-sync runs"
    );
    anyhow::ensure!(
        opts.store.is_none() || opts.store_addr.is_none(),
        "pass either an injected store or a store address, not both"
    );
    let n_weights = Master::store_size(cfg);
    let mem: Option<Arc<MemStore>> = if opts.store.is_none() && opts.store_addr.is_none() {
        Some(Arc::new(MemStore::new(n_weights, cfg.init_weight)))
    } else {
        None
    };
    // One shared connection pool for every role in TCP mode: master +
    // workers multiplex over at most `n_workers + 2` sockets (workers,
    // master, one spare for eval/stats bursts) instead of opening a
    // socket per role, and same-cursor delta fetches coalesce.
    let pool: Option<Arc<crate::weightstore::client::ClientPool>> =
        opts.store_addr.as_ref().map(|addr| {
            Arc::new(crate::weightstore::client::ClientPool::new(
                addr,
                cfg.n_workers + 2,
            ))
        });
    if let Some(pool) = &pool {
        // The pool dials lazily; ping once so a bad address still fails
        // fast here rather than from inside a worker thread.
        pool.now()?;
    }
    let connect = |role: &str| -> Result<Arc<dyn WeightStore>> {
        Ok(match (&pool, &opts.store, &mem) {
            (Some(pool), _, _) => {
                log_info!(
                    role,
                    "sharing store pool at {} ({} conns max)",
                    opts.store_addr.as_deref().unwrap_or("?"),
                    cfg.n_workers + 2
                );
                Arc::clone(pool) as Arc<dyn WeightStore>
            }
            (None, Some(store), _) => Arc::clone(store),
            (None, None, Some(mem)) => mem.clone() as Arc<dyn WeightStore>,
            _ => unreachable!(),
        })
    };

    let stop = Arc::new(AtomicBool::new(false));
    let dims_dir = artifacts_dir(&cfg.model);

    // Master engine first — fail fast before spawning anything.
    let master_engine = Engine::load(&dims_dir)?;
    // Strategy negotiation: the manifest must export the scoring entry
    // the configured strategy's workers publish through.
    cfg.strategy.validate_manifest(master_engine.manifest())?;
    let score = cfg.strategy.score_source();
    let master_store = connect("master")?;
    let mut master = Master::new(cfg.clone(), &master_engine, master_store.clone())?;

    // Workers: each thread owns engine + store connection + shard.
    let mut handles = Vec::new();
    for (id, shard) in shards(master.train_idx.len(), cfg.n_workers)
        .into_iter()
        .enumerate()
    {
        let stop = Arc::clone(&stop);
        let data = Arc::clone(&master.data);
        let train_idx = Arc::new(master.train_idx.clone());
        let dir = dims_dir.clone();
        let store = connect(&format!("worker-{id}"))?;
        let throttle = opts.worker_throttle;
        handles.push(std::thread::spawn(move || -> Result<u64> {
            let engine = Engine::load_entries(&dir, &[score.required_entry()])?;
            let mut w = WorkerState::new_with_score(
                id,
                shard,
                engine.manifest(),
                data,
                train_idx,
                store,
                score,
            );
            w.run_live(&engine, &stop, throttle)?;
            Ok(w.examples_scored)
        }));
    }
    log_info!("master", "live cluster up: {} workers, {} steps", cfg.n_workers, cfg.steps);

    let run = (|| -> Result<()> {
        if opts.wait_for_first_scores {
            // Publish params so workers can start, then poll the store.
            master.maybe_push_params()?;
            // analyze: allow(wallclock): live mode waits on real worker processes
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
            while master_store.stats()?.weight_pushes < cfg.n_workers as u64 {
                anyhow::ensure!(
                    // analyze: allow(wallclock): live mode waits on real worker processes
                    std::time::Instant::now() < deadline,
                    "workers produced no scores within 60s"
                );
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            log_info!("master", "all {} workers have scored; starting", cfg.n_workers);
        }
        for _ in 0..cfg.steps {
            master.maybe_push_params()?;
            master.train_one_step(&master_engine)?;
            master.maybe_evaluate(&master_engine)?;
            master.maybe_monitor(&master_engine)?;
        }
        Ok(())
    })();
    stop.store(true, Ordering::Relaxed);

    let mut scored_examples = 0u64;
    for h in handles {
        match h.join() {
            Ok(Ok(examples)) => scored_examples += examples,
            Ok(Err(e)) => log_warn!("master", "worker failed: {e}"),
            Err(_) => log_warn!("master", "worker panicked"),
        }
    }
    run?;

    let final_err = (
        master.evaluate(&master_engine, super::master::EvalSplit::Train)?.1,
        master.evaluate(&master_engine, super::master::EvalSplit::Valid)?.1,
        master.evaluate(&master_engine, super::master::EvalSplit::Test)?.1,
    );
    let store_stats = master_store.stats()?;
    Ok(SimOutcome {
        rec: master.rec,
        final_err,
        scored: scored_examples,
        store_stats,
    })
}
