//! Incremental proposal maintenance for the master (the hot path the
//! delta-aware store exists for).
//!
//! The old master cloned the store's full `WeightSnapshot` (3×N vectors)
//! and rebuilt a [`FenwickSampler`] from scratch on *every* training step —
//! O(N) bytes and O(N) work per step, which §4.2's "synchronization is not
//! free" argument says is exactly the cost that must stay below the compute
//! importance sampling saves.  [`ProposalMaintainer`] instead owns a
//! persistent sampler and mirrors the store through
//! [`WeightDelta`]s: each step applies O(k) changed entries as O(k log N)
//! Fenwick point updates.
//!
//! Staleness (§B.1) is also incremental: every kept entry schedules an
//! expiry tick (`stamp + threshold`) on a min-heap; advancing the clock
//! pops only the entries that actually crossed the threshold and zeroes
//! them in the sampler.  Heap records are lazily invalidated — a refreshed
//! entry simply has a newer record, and stale records are skipped when
//! popped — so the amortised cost per step is O(changes · log N), never
//! O(N).
//!
//! Smoothing (§B.3) is folded into the stored sampler weights
//! (`raw + c` for kept entries, `0` for filtered ones).  Changing the
//! constant (the adaptive-entropy extension) rebuilds the proposal in
//! O(N) — that mode trades the incremental win for entropy control and is
//! documented as such in `Master::train_one_step`.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::Result;

use crate::config::StalenessUnit;
use crate::sampler::{FenwickSampler, Smoothing, StalenessFilter};
use crate::weightstore::{WeightDelta, WeightSnapshot};

pub struct ProposalMaintainer {
    /// Mirror of the store's raw table (weights, stamps, param versions).
    raw: WeightSnapshot,
    /// Smoothed + staleness-filtered sampling weights.
    sampler: FenwickSampler,
    /// Store write-sequence this mirror reflects (next fetch cursor).
    cursor: u64,
    smoothing: f64,
    threshold: Option<u64>,
    unit: StalenessUnit,
    /// Min-heap of `(expiry_tick, index)`; lazily invalidated on refresh.
    expiry: BinaryHeap<Reverse<(u64, usize)>>,
    /// Whether each entry currently passes the staleness filter.
    kept: Vec<bool>,
    n_kept: usize,
    /// Running Σw² of the sampler weights (ESS diagnostic in O(1)).
    sum_sq: f64,
    /// Latest staleness clock observed (never moves backwards).
    now: u64,
    /// Point updates applied by the last `absorb` (delta entries plus
    /// expiries) — the per-step maintenance cost, exposed for benches.
    last_changes: usize,
}

impl ProposalMaintainer {
    pub fn new(
        n: usize,
        smoothing: f64,
        threshold: Option<u64>,
        unit: StalenessUnit,
    ) -> ProposalMaintainer {
        ProposalMaintainer {
            raw: WeightSnapshot {
                weights: vec![0.0; n],
                stamps: vec![0; n],
                param_versions: vec![0; n],
            },
            // All-zero until the first absorb: draw_minibatch falls back to
            // uniform, which is plain SGD — the unbiased degradation mode.
            sampler: FenwickSampler::new(&vec![0.0; n]),
            cursor: 0,
            smoothing,
            threshold,
            unit,
            expiry: BinaryHeap::new(),
            kept: vec![false; n],
            n_kept: 0,
            sum_sq: 0.0,
            now: 0,
            last_changes: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.raw.len()
    }

    pub fn is_empty(&self) -> bool {
        self.raw.is_empty()
    }

    /// Cursor to pass to the next `fetch_weights_since` call.
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    pub fn sampler(&self) -> &FenwickSampler {
        &self.sampler
    }

    /// The mirrored raw table (staleness diagnostics read this instead of
    /// re-fetching a snapshot from the store).
    pub fn raw(&self) -> &WeightSnapshot {
        &self.raw
    }

    pub fn smoothing(&self) -> f64 {
        self.smoothing
    }

    /// Fraction of entries currently passing the staleness filter.
    pub fn kept_fraction(&self) -> f64 {
        if self.raw.is_empty() {
            1.0
        } else {
            self.n_kept as f64 / self.raw.len() as f64
        }
    }

    /// Point updates applied by the last `absorb` (cost diagnostic).
    pub fn last_changes(&self) -> usize {
        self.last_changes
    }

    /// `ESS/N = (Σw)² / (N Σw²)` of the current proposal, maintained
    /// incrementally (mirrors `sampler::effective_sample_size_ratio`).
    pub fn ess_ratio(&self) -> f64 {
        let n = self.raw.len();
        if n == 0 {
            return 1.0;
        }
        let sum_sq = self.sum_sq.max(0.0);
        if sum_sq <= 0.0 {
            return 1.0;
        }
        let total = self.sampler.total();
        (total * total) / (n as f64 * sum_sq)
    }

    /// Raw weights of the currently-kept entries (input to the
    /// adaptive-entropy smoothing solver).
    pub fn kept_raw(&self) -> Vec<f64> {
        (0..self.raw.len())
            .filter(|&i| self.kept[i])
            .map(|i| self.raw.weights[i])
            .collect()
    }

    /// The staleness tick of entry `i` in the configured unit.
    fn tick(&self, i: usize) -> u64 {
        match self.unit {
            StalenessUnit::Nanos => self.raw.stamps[i],
            StalenessUnit::Versions => self.raw.param_versions[i],
        }
    }

    /// The §B.1 filter — the same abstraction `Master::effective_weights`
    /// uses, so the live proposal and the variance monitors can't drift.
    fn filter(&self) -> StalenessFilter {
        match self.threshold {
            None => StalenessFilter::disabled(),
            Some(t) => StalenessFilter::with_threshold(t),
        }
    }

    /// The §B.3 smoothing under the current constant.
    fn smooth(&self) -> Smoothing {
        Smoothing::new(self.smoothing)
    }

    /// Set entry `i`'s sampling weight, maintaining Σw² and the kept count.
    fn set_sampler_weight(&mut self, i: usize, v: f64, keep: bool) {
        let old = self.sampler.weight(i);
        self.sum_sq += v * v - old * old;
        if keep != self.kept[i] {
            self.kept[i] = keep;
            if keep {
                self.n_kept += 1;
            } else {
                self.n_kept -= 1;
            }
        }
        self.sampler.update(i, v);
    }

    /// Install one freshly-written entry: update the raw mirror, apply the
    /// filter + smoothing to the sampler, and schedule its expiry.
    fn apply_entry(&mut self, i: usize, w: f64, stamp: u64, param_version: u64) {
        self.raw.weights[i] = w;
        self.raw.stamps[i] = stamp;
        self.raw.param_versions[i] = param_version;
        let tick = self.tick(i);
        if self.filter().keep(tick, self.now) {
            let smoothed = self.smooth().apply(w);
            self.set_sampler_weight(i, smoothed, true);
            if let Some(t) = self.threshold {
                self.expiry.push(Reverse((tick.saturating_add(t), i)));
            }
        } else {
            self.set_sampler_weight(i, 0.0, false);
        }
    }

    /// Evict entries whose staleness crossed the threshold.  Pops only
    /// records at or past their expiry — O(evicted · log N), not O(N).
    fn expire(&mut self) -> usize {
        if self.threshold.is_none() {
            return 0;
        }
        let mut evicted = 0;
        while let Some(&Reverse((e, i))) = self.expiry.peek() {
            if e >= self.now {
                break;
            }
            self.expiry.pop();
            if !self.kept[i] {
                continue;
            }
            if self.filter().keep(self.tick(i), self.now) {
                // Refreshed since this record was queued; its newer record
                // (at `tick + t >= now`) is still in the heap.
                continue;
            }
            self.set_sampler_weight(i, 0.0, false);
            evicted += 1;
        }
        evicted
    }

    /// Recompute filter + smoothing + sampler wholesale from the raw
    /// mirror — O(N); used for full deltas and smoothing changes (also
    /// resets accumulated fp drift in Σw²).
    fn rebuild_from_raw(&mut self) {
        let n = self.raw.len();
        let filter = self.filter();
        let smooth = self.smooth();
        let mut weights = vec![0.0; n];
        self.n_kept = 0;
        self.expiry.clear();
        for i in 0..n {
            let tick = self.tick(i);
            let keep = filter.keep(tick, self.now);
            self.kept[i] = keep;
            if keep {
                weights[i] = smooth.apply(self.raw.weights[i]);
                self.n_kept += 1;
                if let Some(t) = self.threshold {
                    self.expiry.push(Reverse((tick.saturating_add(t), i)));
                }
            }
        }
        self.sum_sq = weights.iter().map(|w| w * w).sum();
        self.sampler = FenwickSampler::new(&weights);
    }

    /// Fold a store delta into the proposal and advance the staleness
    /// clock to `now`.  Incremental deltas cost
    /// O((entries + expiries) · log N); full deltas rebuild in O(N).
    pub fn absorb(&mut self, delta: &WeightDelta, now: u64) -> Result<()> {
        anyhow::ensure!(
            delta.n as usize == self.raw.len(),
            "delta tracks {} entries but proposal holds {}",
            delta.n,
            self.raw.len()
        );
        anyhow::ensure!(
            delta.indices.len() == delta.weights.len()
                && delta.weights.len() == delta.stamps.len()
                && delta.stamps.len() == delta.param_versions.len(),
            "delta columns disagree on length"
        );
        self.now = self.now.max(now);
        if delta.full {
            // Reuse the canonical delta application (it re-validates and
            // bounds-checks), then recompute filter + sampler wholesale.
            delta.apply_to(&mut self.raw)?;
            self.rebuild_from_raw();
            self.last_changes = delta.len();
        } else {
            for &idx in &delta.indices {
                anyhow::ensure!(
                    (idx as usize) < self.raw.len(),
                    "delta index {idx} out of bounds (n = {})",
                    self.raw.len()
                );
            }
            for (k, &idx) in delta.indices.iter().enumerate() {
                self.apply_entry(
                    idx as usize,
                    delta.weights[k],
                    delta.stamps[k],
                    delta.param_versions[k],
                );
            }
            let evicted = self.expire();
            self.last_changes = delta.len() + evicted;
        }
        self.cursor = delta.seq;
        Ok(())
    }

    /// Change the §B.3 smoothing constant.  No-op when unchanged; a real
    /// change re-smooths every kept entry (O(N)) — the price of the
    /// adaptive-entropy mode.
    pub fn set_smoothing(&mut self, c: f64) {
        if c == self.smoothing {
            return;
        }
        self.smoothing = c;
        self.rebuild_from_raw();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn full_delta(seq: u64, weights: &[f64], stamps: &[u64], versions: &[u64]) -> WeightDelta {
        WeightDelta {
            seq,
            n: weights.len() as u64,
            full: true,
            indices: (0..weights.len() as u64).collect(),
            weights: weights.to_vec(),
            stamps: stamps.to_vec(),
            param_versions: versions.to_vec(),
        }
    }

    fn sparse_delta(
        seq: u64,
        n: usize,
        entries: &[(usize, f64, u64, u64)],
    ) -> WeightDelta {
        WeightDelta {
            seq,
            n: n as u64,
            full: false,
            indices: entries.iter().map(|e| e.0 as u64).collect(),
            weights: entries.iter().map(|e| e.1).collect(),
            stamps: entries.iter().map(|e| e.2).collect(),
            param_versions: entries.iter().map(|e| e.3).collect(),
        }
    }

    /// Ground truth: what the old per-step full recomputation produced.
    fn expected_weights(
        raw: &[f64],
        ticks: &[u64],
        now: u64,
        threshold: Option<u64>,
        c: f64,
    ) -> Vec<f64> {
        raw.iter()
            .zip(ticks)
            .map(|(&w, &s)| match threshold {
                Some(t) if now.saturating_sub(s) > t => 0.0,
                _ => w + c,
            })
            .collect()
    }

    fn assert_matches(p: &ProposalMaintainer, expect: &[f64]) {
        assert_eq!(p.sampler().len(), expect.len());
        for (i, &e) in expect.iter().enumerate() {
            assert!(
                (p.sampler().weight(i) - e).abs() < 1e-9,
                "weight {i}: {} vs {e}",
                p.sampler().weight(i)
            );
        }
        let kept = expect.iter().filter(|&&w| w > 0.0).count();
        // kept tracks the filter, not positivity — with c = 0 a kept entry
        // can have weight 0, so only check when smoothing is positive.
        if p.smoothing() > 0.0 {
            assert_eq!((p.kept_fraction() * expect.len() as f64).round() as usize, kept);
        }
    }

    #[test]
    fn starts_empty_and_uniform_safe() {
        let p = ProposalMaintainer::new(8, 1.0, None, StalenessUnit::Versions);
        assert_eq!(p.cursor(), 0);
        assert_eq!(p.sampler().total(), 0.0);
        assert_eq!(p.kept_fraction(), 0.0);
        assert_eq!(p.ess_ratio(), 1.0);
    }

    #[test]
    fn full_delta_installs_smoothed_weights() {
        let mut p = ProposalMaintainer::new(4, 2.0, None, StalenessUnit::Versions);
        let d = full_delta(5, &[1.0, 0.0, 3.0, 2.0], &[0; 4], &[0; 4]);
        p.absorb(&d, 0).unwrap();
        assert_eq!(p.cursor(), 5);
        assert_matches(&p, &[3.0, 2.0, 5.0, 4.0]);
        assert!((p.kept_fraction() - 1.0).abs() < 1e-12);
        assert_eq!(p.last_changes(), 4);
    }

    #[test]
    fn sparse_delta_applies_point_updates() {
        let mut p = ProposalMaintainer::new(5, 0.5, None, StalenessUnit::Versions);
        p.absorb(&full_delta(1, &[1.0; 5], &[0; 5], &[0; 5]), 0).unwrap();
        p.absorb(&sparse_delta(2, 5, &[(1, 4.0, 0, 1), (3, 0.0, 0, 1)]), 0)
            .unwrap();
        assert_eq!(p.cursor(), 2);
        assert_matches(&p, &[1.5, 4.5, 1.5, 0.5, 1.5]);
        assert_eq!(p.last_changes(), 2);
    }

    #[test]
    fn staleness_expires_entries_without_deltas() {
        // Threshold 10 in version units; entries stamped at version 0.
        let mut p = ProposalMaintainer::new(3, 1.0, Some(10), StalenessUnit::Versions);
        p.absorb(&full_delta(1, &[2.0; 3], &[0; 3], &[0; 3]), 0).unwrap();
        assert!((p.kept_fraction() - 1.0).abs() < 1e-12);
        // now = 10: age 10 <= threshold, everything still kept.
        p.absorb(&sparse_delta(1, 3, &[]), 10).unwrap();
        assert_matches(&p, &[3.0, 3.0, 3.0]);
        // now = 11: age 11 > threshold, all evicted by the expiry heap.
        p.absorb(&sparse_delta(1, 3, &[]), 11).unwrap();
        assert_matches(&p, &[0.0, 0.0, 0.0]);
        assert_eq!(p.kept_fraction(), 0.0);
        assert_eq!(p.last_changes(), 3); // three expiries
    }

    #[test]
    fn refresh_reinstates_evicted_entries() {
        let mut p = ProposalMaintainer::new(2, 1.0, Some(5), StalenessUnit::Versions);
        p.absorb(&full_delta(1, &[1.0, 1.0], &[0; 2], &[0; 2]), 0).unwrap();
        p.absorb(&sparse_delta(1, 2, &[]), 20).unwrap();
        assert_eq!(p.kept_fraction(), 0.0);
        // A new push stamped at version 18 (age 2) brings entry 0 back.
        p.absorb(&sparse_delta(2, 2, &[(0, 7.0, 0, 18)]), 20).unwrap();
        assert_matches(&p, &[8.0, 0.0]);
        assert!((p.kept_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn refreshed_entry_survives_its_stale_heap_record() {
        let mut p = ProposalMaintainer::new(1, 0.0, Some(5), StalenessUnit::Versions);
        p.absorb(&full_delta(1, &[1.0], &[0], &[0]), 0).unwrap();
        // Refresh at version 8 before the first record (expiry 5) fires.
        p.absorb(&sparse_delta(2, 1, &[(0, 2.0, 0, 8)]), 8).unwrap();
        // now = 10 pops the stale (expiry 5) record; the entry must stay
        // (age 2, new record expires at 13).
        p.absorb(&sparse_delta(2, 1, &[]), 10).unwrap();
        assert_matches(&p, &[2.0]);
        // now = 14 pops the live record and evicts for real.
        p.absorb(&sparse_delta(2, 1, &[]), 14).unwrap();
        assert_matches(&p, &[0.0]);
    }

    #[test]
    fn incremental_matches_scratch_recomputation() {
        // Random deltas + advancing clock: the maintained sampler must equal
        // the old full recomputation at every step.
        let n = 64;
        let threshold = Some(30u64);
        let c = 0.25;
        let mut p = ProposalMaintainer::new(n, c, threshold, StalenessUnit::Nanos);
        let mut raw = vec![0.0f64; n];
        let mut stamps = vec![0u64; n];
        let mut rng = Pcg64::seeded(42);
        p.absorb(&full_delta(1, &raw, &stamps, &vec![0; n]), 0).unwrap();
        let mut now = 0u64;
        for round in 0..200u64 {
            now += rng.next_below(8);
            let k = rng.next_below(6) as usize;
            let entries: Vec<(usize, f64, u64, u64)> = (0..k)
                .map(|_| {
                    let i = rng.next_below(n as u64) as usize;
                    let w = rng.next_f64() * 10.0;
                    let stamp = now.saturating_sub(rng.next_below(40));
                    (i, w, stamp, round)
                })
                .collect();
            for &(i, w, stamp, _) in &entries {
                raw[i] = w;
                stamps[i] = stamp;
            }
            p.absorb(&sparse_delta(round + 2, n, &entries), now).unwrap();
            let expect = expected_weights(&raw, &stamps, now, threshold, c);
            assert_matches(&p, &expect);
            // ESS must agree with the from-scratch diagnostic.
            let scratch = crate::sampler::effective_sample_size_ratio(&expect);
            assert!(
                (p.ess_ratio() - scratch).abs() < 1e-6,
                "round {round}: ess {} vs {scratch}",
                p.ess_ratio()
            );
        }
    }

    #[test]
    fn set_smoothing_resmooths_everything() {
        let mut p = ProposalMaintainer::new(3, 1.0, None, StalenessUnit::Versions);
        p.absorb(&full_delta(1, &[1.0, 2.0, 3.0], &[0; 3], &[0; 3]), 0).unwrap();
        p.set_smoothing(10.0);
        assert_matches(&p, &[11.0, 12.0, 13.0]);
        p.set_smoothing(0.0);
        assert_matches(&p, &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn rejects_size_mismatch_and_bad_indices() {
        let mut p = ProposalMaintainer::new(3, 1.0, None, StalenessUnit::Versions);
        assert!(p.absorb(&full_delta(1, &[1.0; 4], &[0; 4], &[0; 4]), 0).is_err());
        assert!(p
            .absorb(&sparse_delta(1, 3, &[(3, 1.0, 0, 0)]), 0)
            .is_err());
        let mut bad = sparse_delta(1, 3, &[(0, 1.0, 0, 0)]);
        bad.stamps.pop();
        assert!(p.absorb(&bad, 0).is_err());
    }

    #[test]
    fn empty_proposal_is_safe() {
        let mut p = ProposalMaintainer::new(0, 1.0, None, StalenessUnit::Versions);
        assert_eq!(p.kept_fraction(), 1.0);
        assert_eq!(p.ess_ratio(), 1.0);
        p.absorb(
            &WeightDelta {
                seq: 1,
                full: true,
                ..WeightDelta::default()
            },
            0,
        )
        .unwrap();
        assert_eq!(p.cursor(), 1);
    }
}
