//! Incremental proposal maintenance — the hot path the delta-aware store
//! exists for, shared by *both* training topologies.
//!
//! The old master cloned the store's full `WeightSnapshot` (3×N vectors)
//! and rebuilt a [`FenwickSampler`] from scratch on *every* training step —
//! O(N) bytes and O(N) work per step, which §4.2's "synchronization is not
//! free" argument says is exactly the cost that must stay below the compute
//! importance sampling saves.  [`ProposalMaintainer`] instead owns a
//! persistent sampler and mirrors the store through
//! [`WeightDelta`]s: each step applies O(k) changed entries as O(k log N)
//! Fenwick point updates.
//!
//! Staleness (§B.1) is also incremental: every kept entry schedules an
//! expiry tick (`stamp + threshold`) on a min-heap; advancing the clock
//! pops only the entries that actually crossed the threshold and zeroes
//! them in the sampler.  Heap records are lazily invalidated — a refreshed
//! entry simply has a newer record, and stale records are skipped when
//! popped — so the amortised cost per step is O(changes · log N), never
//! O(N).
//!
//! Smoothing (§B.3) is folded into the stored sampler weights
//! (`raw + c` for kept entries, `0` for filtered ones).  Changing the
//! constant (the adaptive-entropy extension) rebuilds the proposal in
//! O(N), but the maintainer also tracks `Σ v ln v` of the sampler weights
//! incrementally, so [`ProposalMaintainer::normalized_entropy`] is O(1)
//! and the master only pays the O(N) re-solve when the entropy actually
//! drifts off target (see `Master::train_one_step`).
//!
//! # Coverage-prior mode (peer/ASGD topology, §6)
//!
//! Peers only score the examples they happen to sample, so early in
//! training most store entries still hold the placeholder init value —
//! which is *not* a gradient norm.  [`ProposalMaintainer::with_coverage_prior`]
//! gives every never-scored entry (`param_version == 0`) the **mean of the
//! scored weights** as its prior, so unscored examples are sampled at an
//! average rate with coefficient ~1 until real information exists.  The
//! prior is maintained as two running sums (scored count + scored weight
//! total), and the unscored entries live in a second indicator Fenwick
//! tree, so a moving prior re-prices the whole unscored mass in O(1) —
//! the old peer implementation recomputed it with two O(N) passes per
//! step.  [`ProposalMaintainer::draw_minibatch`] samples the resulting
//! mixture exactly.
//!
//! §B.1 staleness *composes* with the coverage prior: in prior mode a
//! scored entry whose weight crosses the threshold is not zeroed out of
//! the proposal (that would un-sample it and re-introduce the coverage
//! hole the prior exists to close) — it falls back to the prior-priced
//! unscored mass, i.e. "this measurement is too old to trust" degrades to
//! "treat it like an unmeasured example".  The prior itself averages only
//! the *fresh* scored weights.  Every example therefore stays samplable
//! at all times, which is what keeps the estimator unbiased (§2).
//!
//! # Strategy parameterization
//!
//! The transform from raw mirrored scores to sampler mass is owned by a
//! [`ProposalStrategy`] (see `sampler::strategy` for the contracts and
//! the cross-reference table into the follow-on literature).  The default
//! [`ProposalMaintainer::new`] / [`ProposalMaintainer::with_coverage_prior`]
//! constructors use the paper's grad-norm exact-IS strategy, whose
//! `mass(raw, c) = raw + c` is bit-identical to the old hard-wired §B.3
//! smoothing — existing trajectories are unchanged.  The `*_with_strategy`
//! constructors swap in any registered strategy.  The §B.1 filter and the
//! coverage prior compose with every strategy because they decide *which
//! raw value* is priced (the fresh score, the prior, or nothing), while
//! the strategy alone decides *how* a raw value is priced; `mass` is a
//! pure function, so incremental `apply_entry` updates and wholesale
//! `rebuild_from_raw` land on identical trees.
//! [`ProposalMaintainer::draw_minibatch`] enforces the strategy's
//! unbiasedness declaration: biased strategies draw with the identical
//! RNG consumption but run with coefficients pinned to 1, and
//! presample/reject strategies draw `factor · m` candidates keeping the
//! `m` with the largest effective mass.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::Result;

use crate::config::StalenessUnit;
use crate::sampler::strategy::{DrawPolicy, ProposalStrategy, StrategyKind};
use crate::sampler::{FenwickSampler, StalenessFilter};
use crate::util::rng::Pcg64;
use crate::weightstore::{WeightDelta, WeightSnapshot};

/// `v · ln v`, continuously extended to 0 at `v = 0` (entropy summand).
#[inline]
fn wlogw(v: f64) -> f64 {
    if v > 0.0 {
        v * v.ln()
    } else {
        0.0
    }
}

pub struct ProposalMaintainer {
    /// Mirror of the store's raw table (weights, stamps, param versions).
    raw: WeightSnapshot,
    /// Smoothed + staleness-filtered sampling weights.  In coverage-prior
    /// mode this tree holds only the *scored* entries; unscored mass lives
    /// in `unscored_kept`.
    sampler: FenwickSampler,
    /// Store write-sequence this mirror reflects (next fetch cursor).
    cursor: u64,
    smoothing: f64,
    threshold: Option<u64>,
    unit: StalenessUnit,
    /// How raw scores are priced into sampler mass (module docs).  `mass`
    /// is pure, so incremental and wholesale application agree.
    strategy: &'static dyn ProposalStrategy,
    /// Min-heap of `(expiry_tick, index)`; lazily invalidated on refresh.
    expiry: BinaryHeap<Reverse<(u64, usize)>>,
    /// Whether each entry currently passes the staleness filter.
    kept: Vec<bool>,
    n_kept: usize,
    /// Running Σw² of the sampler weights (ESS diagnostic in O(1)).
    sum_sq: f64,
    /// Running Σ v·ln v of the sampler weights (entropy in O(1)).
    sum_wlogw: f64,
    /// Count of strictly-positive sampler weights (entropy support size).
    n_pos: usize,
    /// Latest staleness clock observed (never moves backwards).
    now: u64,
    /// Point updates applied by the last `absorb` (delta entries plus
    /// expiries) — the per-step maintenance cost, exposed for benches.
    last_changes: usize,
    /// Coverage-prior mode: count of entries that are scored
    /// (`param_version > 0`) *and* currently pass the staleness filter,
    /// and the sum of their raw weights (stale measurements don't feed
    /// the prior).
    scored_count: usize,
    scored_total: f64,
    /// Indicator tree (weight 1) over prior-priced entries — never-scored
    /// *or* scored-but-stale — `Some` iff coverage-prior mode is on.
    /// Sampling it uniformly picks a prior-priced entry in O(log N).
    unscored_kept: Option<FenwickSampler>,
}

impl ProposalMaintainer {
    pub fn new(
        n: usize,
        smoothing: f64,
        threshold: Option<u64>,
        unit: StalenessUnit,
    ) -> ProposalMaintainer {
        Self::build(
            n,
            smoothing,
            threshold,
            unit,
            false,
            StrategyKind::GradNormIs.strategy(),
        )
    }

    /// A master-mode maintainer pricing mass with a non-default
    /// [`ProposalStrategy`].  `new` is exactly this with the paper's
    /// grad-norm exact-IS strategy.
    pub fn new_with_strategy(
        n: usize,
        smoothing: f64,
        threshold: Option<u64>,
        unit: StalenessUnit,
        strategy: &'static dyn ProposalStrategy,
    ) -> ProposalMaintainer {
        Self::build(n, smoothing, threshold, unit, false, strategy)
    }

    /// A maintainer for the peer/ASGD topology: never-scored entries
    /// (`param_version == 0`) get the mean of the scored raw weights as
    /// their prior (1.0 before anything is scored), maintained in O(1).
    /// With a staleness `threshold`, scored entries whose age crosses it
    /// also fall back to the prior mass (see the module docs) — §B.1
    /// filtering composed with the coverage prior.
    pub fn with_coverage_prior(
        n: usize,
        smoothing: f64,
        threshold: Option<u64>,
        unit: StalenessUnit,
    ) -> ProposalMaintainer {
        Self::build(
            n,
            smoothing,
            threshold,
            unit,
            true,
            StrategyKind::GradNormIs.strategy(),
        )
    }

    /// Coverage-prior mode with a non-default [`ProposalStrategy`] (the
    /// peer topology's strategy threading point).
    pub fn with_coverage_prior_strategy(
        n: usize,
        smoothing: f64,
        threshold: Option<u64>,
        unit: StalenessUnit,
        strategy: &'static dyn ProposalStrategy,
    ) -> ProposalMaintainer {
        Self::build(n, smoothing, threshold, unit, true, strategy)
    }

    fn build(
        n: usize,
        smoothing: f64,
        threshold: Option<u64>,
        unit: StalenessUnit,
        coverage_prior: bool,
        strategy: &'static dyn ProposalStrategy,
    ) -> ProposalMaintainer {
        ProposalMaintainer {
            raw: WeightSnapshot {
                weights: vec![0.0; n],
                stamps: vec![0; n],
                param_versions: vec![0; n],
            },
            // All-zero until the first absorb: draw_minibatch falls back to
            // uniform, which is plain SGD — the unbiased degradation mode.
            sampler: FenwickSampler::new(&vec![0.0; n]),
            cursor: 0,
            smoothing,
            threshold,
            unit,
            strategy,
            expiry: BinaryHeap::new(),
            kept: vec![false; n],
            n_kept: 0,
            sum_sq: 0.0,
            sum_wlogw: 0.0,
            n_pos: 0,
            now: 0,
            last_changes: 0,
            scored_count: 0,
            scored_total: 0.0,
            unscored_kept: if coverage_prior {
                Some(FenwickSampler::new(&vec![0.0; n]))
            } else {
                None
            },
        }
    }

    pub fn len(&self) -> usize {
        self.raw.len()
    }

    pub fn is_empty(&self) -> bool {
        self.raw.is_empty()
    }

    /// Cursor to pass to the next `fetch_weights_since` call.
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    pub fn sampler(&self) -> &FenwickSampler {
        &self.sampler
    }

    /// The mirrored raw table (staleness diagnostics read this instead of
    /// re-fetching a snapshot from the store).
    pub fn raw(&self) -> &WeightSnapshot {
        &self.raw
    }

    pub fn smoothing(&self) -> f64 {
        self.smoothing
    }

    /// The proposal strategy pricing this maintainer's mass.
    pub fn strategy(&self) -> &'static dyn ProposalStrategy {
        self.strategy
    }

    /// The staleness unit this maintainer's clock advances in (consumers
    /// use it to decide what `now` value to pass to `absorb`).
    pub fn unit(&self) -> StalenessUnit {
        self.unit
    }

    /// Whether coverage-prior mode is on.
    pub fn has_coverage_prior(&self) -> bool {
        self.unscored_kept.is_some()
    }

    /// Fraction of entries currently passing the staleness filter.
    pub fn kept_fraction(&self) -> f64 {
        if self.raw.is_empty() {
            1.0
        } else {
            self.n_kept as f64 / self.raw.len() as f64
        }
    }

    /// Point updates applied by the last `absorb` (cost diagnostic).
    pub fn last_changes(&self) -> usize {
        self.last_changes
    }

    /// Coverage prior: mean raw weight of the fresh scored entries, 1.0
    /// while nothing (unexpired) has been scored yet (coefficient ~1
    /// territory).
    pub fn prior(&self) -> f64 {
        if self.scored_count == 0 {
            1.0
        } else {
            // max(0): incremental ± updates can drift a hair below zero.
            self.scored_total.max(0.0) / self.scored_count as f64
        }
    }

    /// `(count, per-entry weight)` of the unscored-but-kept mass.
    fn unscored_terms(&self) -> (f64, f64) {
        match &self.unscored_kept {
            None => (0.0, 0.0),
            Some(tree) => {
                let u = tree.total();
                if u <= 0.0 {
                    (0.0, 0.0)
                } else {
                    (u, self.strategy.mass(self.prior(), self.smoothing))
                }
            }
        }
    }

    /// Total proposal mass, including the prior-priced unscored entries.
    pub fn total_mass(&self) -> f64 {
        let (u, p) = self.unscored_terms();
        self.sampler.total() + u * p
    }

    /// The sampling weight entry `i` is currently drawn with.  Master
    /// mode: 0 if filtered out, the smoothed raw weight otherwise.
    /// Coverage-prior mode: the smoothed raw weight when fresh-scored,
    /// the prior-priced value otherwise (unscored *or* stale — never 0).
    pub fn effective_weight(&self, i: usize) -> f64 {
        if self.unscored_kept.is_some() {
            if self.kept[i] && self.raw.param_versions[i] > 0 {
                self.sampler.weight(i)
            } else {
                self.strategy.mass(self.prior(), self.smoothing)
            }
        } else if self.kept[i] {
            self.sampler.weight(i)
        } else {
            0.0
        }
    }

    /// `ESS/N = (Σw)² / (N Σw²)` of the current proposal, maintained
    /// incrementally (mirrors `sampler::effective_sample_size_ratio`).
    pub fn ess_ratio(&self) -> f64 {
        let n = self.raw.len();
        if n == 0 {
            return 1.0;
        }
        let (u, p) = self.unscored_terms();
        let sum_sq = (self.sum_sq + u * p * p).max(0.0);
        if sum_sq <= 0.0 {
            return 1.0;
        }
        let total = self.sampler.total() + u * p;
        (total * total) / (n as f64 * sum_sq)
    }

    /// Normalised entropy of the current proposal in O(1), maintained
    /// alongside the sampler (mirrors `sampler::normalized_entropy` on the
    /// effective weights): `H = ln S − (Σ v ln v)/S`, divided by the log
    /// of the positive-support size.
    pub fn normalized_entropy(&self) -> f64 {
        let (u, p) = self.unscored_terms();
        let total = self.sampler.total() + u * p;
        if total <= 0.0 {
            return 1.0;
        }
        let mut e = self.sum_wlogw;
        let mut n_pos = self.n_pos as f64;
        if u > 0.0 && p > 0.0 {
            e += u * wlogw(p);
            n_pos += u;
        }
        if n_pos <= 1.0 {
            return 1.0;
        }
        ((total.ln() - e / total) / n_pos.ln()).max(0.0)
    }

    /// Raw weights of the currently-kept entries (input to the
    /// adaptive-entropy smoothing solver).
    pub fn kept_raw(&self) -> Vec<f64> {
        (0..self.raw.len())
            .filter(|&i| self.kept[i])
            .map(|i| self.raw.weights[i])
            .collect()
    }

    /// Draw a minibatch from the maintained proposal, enforcing the
    /// strategy's declarations.
    ///
    /// Unbiased + direct (the default) is exactly the pre-refactor draw:
    /// same RNG consumption, same indices, same `mean(w)/w_i`
    /// coefficients.  A biased strategy draws with *identical* RNG
    /// consumption but runs with coefficients pinned to 1 — no
    /// coefficient recovers exactness once the mass transform is
    /// non-linear or the draw is truncated, so none is applied.  A
    /// presample/reject strategy draws `factor · m` candidates and keeps
    /// the `m` with the largest effective mass (ties resolve in draw
    /// order, so the selection is deterministic under a fixed seed).
    pub fn draw_minibatch(&self, rng: &mut Pcg64, m: usize) -> (Vec<usize>, Vec<f32>, f64) {
        match self.strategy.draw_policy() {
            DrawPolicy::Direct => {
                let (indices, mut coefs, mean_w) = self.draw_direct(rng, m);
                if !self.strategy.unbiased() {
                    coefs.iter_mut().for_each(|c| *c = 1.0);
                }
                (indices, coefs, mean_w)
            }
            DrawPolicy::PresampleTopK { factor } => {
                let (cand, _, mean_w) = self.draw_direct(rng, m * factor.max(1));
                let mut order: Vec<usize> = (0..cand.len()).collect();
                order.sort_by(|&a, &b| {
                    self.effective_weight(cand[b])
                        .total_cmp(&self.effective_weight(cand[a]))
                        .then(a.cmp(&b))
                });
                order.truncate(m);
                order.sort_unstable(); // survivors keep their draw order
                let indices: Vec<usize> = order.iter().map(|&k| cand[k]).collect();
                let coefs = vec![1.0; indices.len()];
                (indices, coefs, mean_w)
            }
        }
    }

    /// The exact multinomial draw shared by every policy.  Without
    /// coverage-prior mode this is exactly
    /// [`crate::sampler::draw_minibatch`] on the maintained sampler (same
    /// RNG consumption, so master traces are unchanged).  With it, the
    /// proposal is the exact mixture of the scored tree and the uniform
    /// prior-priced unscored mass; coefficients use the effective weight
    /// of whichever component the index came from.
    fn draw_direct(&self, rng: &mut Pcg64, m: usize) -> (Vec<usize>, Vec<f32>, f64) {
        let Some(unscored) = &self.unscored_kept else {
            return crate::sampler::draw_minibatch(&self.sampler, rng, m);
        };
        let n = self.raw.len();
        let (u, p) = self.unscored_terms();
        let scored_mass = self.sampler.total();
        let total = scored_mass + u * p;
        if total <= 0.0 {
            let indices = rng.sample_with_replacement(n, m);
            return (indices, vec![1.0; m], 0.0);
        }
        let mean_w = total / n as f64;
        let mut indices = Vec::with_capacity(m);
        let mut coefs = Vec::with_capacity(m);
        for _ in 0..m {
            let r = rng.next_f64() * total;
            let (i, w) = if r < scored_mass {
                let i = self
                    .sampler
                    .sample(rng)
                    .expect("scored mass positive but sample failed");
                (i, self.sampler.weight(i))
            } else {
                let i = unscored
                    .sample(rng)
                    .expect("unscored mass positive but sample failed");
                (i, p)
            };
            indices.push(i);
            coefs.push((mean_w / w) as f32);
        }
        (indices, coefs, mean_w)
    }

    /// The staleness tick of entry `i` in the configured unit.
    fn tick(&self, i: usize) -> u64 {
        match self.unit {
            StalenessUnit::Nanos => self.raw.stamps[i],
            StalenessUnit::Versions => self.raw.param_versions[i],
        }
    }

    /// The §B.1 filter — the same abstraction `Master::effective_weights`
    /// uses, so the live proposal and the variance monitors can't drift.
    fn filter(&self) -> StalenessFilter {
        match self.threshold {
            None => StalenessFilter::disabled(),
            Some(t) => StalenessFilter::with_threshold(t),
        }
    }

    /// Flip entry `i`'s kept flag, maintaining the count.
    fn set_kept(&mut self, i: usize, keep: bool) {
        if keep != self.kept[i] {
            self.kept[i] = keep;
            if keep {
                self.n_kept += 1;
            } else {
                self.n_kept -= 1;
            }
        }
    }

    /// Set entry `i`'s weight in the scored tree, maintaining Σw²,
    /// Σ v ln v, and the positive-support count.
    fn set_scored_weight(&mut self, i: usize, v: f64) {
        let old = self.sampler.weight(i);
        if old == v {
            return;
        }
        self.sum_sq += v * v - old * old;
        self.sum_wlogw += wlogw(v) - wlogw(old);
        match (old > 0.0, v > 0.0) {
            (false, true) => self.n_pos += 1,
            (true, false) => self.n_pos -= 1,
            _ => {}
        }
        self.sampler.update(i, v);
    }

    /// Whether entry `i` currently contributes to the prior sums
    /// (coverage-prior mode invariant: scored *and* passing the filter).
    fn counts_as_scored(&self, i: usize) -> bool {
        self.kept[i] && self.raw.param_versions[i] > 0
    }

    /// Install one freshly-written entry: update the raw mirror and the
    /// scored sums, apply the filter + smoothing to the right tree, and
    /// schedule its expiry.
    fn apply_entry(&mut self, i: usize, w: f64, stamp: u64, param_version: u64) {
        let prior_mode = self.unscored_kept.is_some();
        // Retract the old contribution to the prior sums, then re-add the
        // new one below — simpler than a transition table now that both
        // scoring *and* freshness can flip in one update.
        if prior_mode && self.counts_as_scored(i) {
            self.scored_count -= 1;
            self.scored_total -= self.raw.weights[i];
        }
        self.raw.weights[i] = w;
        self.raw.stamps[i] = stamp;
        self.raw.param_versions[i] = param_version;
        let tick = self.tick(i);
        let keep = self.filter().keep(tick, self.now);
        self.set_kept(i, keep);
        if keep {
            if let Some(t) = self.threshold {
                self.expiry.push(Reverse((tick.saturating_add(t), i)));
            }
        }
        if prior_mode {
            let in_sampler = keep && param_version > 0;
            if in_sampler {
                self.scored_count += 1;
                self.scored_total += w;
            }
            let v = if in_sampler {
                self.strategy.mass(w, self.smoothing)
            } else {
                0.0
            };
            self.set_scored_weight(i, v);
            if let Some(tree) = self.unscored_kept.as_mut() {
                // Not fresh-scored ⇒ prior-priced, never dropped: §B.1
                // composed with the coverage prior (module docs).
                tree.update(i, if in_sampler { 0.0 } else { 1.0 });
            }
        } else {
            let v = if keep {
                self.strategy.mass(w, self.smoothing)
            } else {
                0.0
            };
            self.set_scored_weight(i, v);
        }
    }

    /// Evict entries whose staleness crossed the threshold.  Pops only
    /// records at or past their expiry — O(evicted · log N), not O(N).
    fn expire(&mut self) -> usize {
        if self.threshold.is_none() {
            return 0;
        }
        let mut evicted = 0;
        while let Some(&Reverse((e, i))) = self.expiry.peek() {
            if e >= self.now {
                break;
            }
            self.expiry.pop();
            if !self.kept[i] {
                continue;
            }
            if self.filter().keep(self.tick(i), self.now) {
                // Refreshed since this record was queued; its newer record
                // (at `tick + t >= now`) is still in the heap.
                continue;
            }
            if self.unscored_kept.is_some() {
                // Coverage-prior mode: the expired measurement degrades to
                // the prior mass — the entry stays samplable (module docs).
                if self.counts_as_scored(i) {
                    self.scored_count -= 1;
                    self.scored_total -= self.raw.weights[i];
                }
                self.set_kept(i, false);
                self.set_scored_weight(i, 0.0);
                if let Some(tree) = self.unscored_kept.as_mut() {
                    tree.update(i, 1.0);
                }
            } else {
                self.set_kept(i, false);
                self.set_scored_weight(i, 0.0);
            }
            evicted += 1;
        }
        evicted
    }

    /// Recompute filter + smoothing + trees wholesale from the raw
    /// mirror — O(N); used for full deltas and smoothing changes (also
    /// resets accumulated fp drift in the running sums).
    fn rebuild_from_raw(&mut self) {
        let n = self.raw.len();
        let filter = self.filter();
        let strategy = self.strategy;
        let c = self.smoothing;
        let prior_mode = self.unscored_kept.is_some();
        let mut weights = vec![0.0; n];
        let mut indicator = vec![0.0; n];
        self.n_kept = 0;
        self.scored_count = 0;
        self.scored_total = 0.0;
        self.expiry.clear();
        for i in 0..n {
            let tick = self.tick(i);
            let keep = filter.keep(tick, self.now);
            self.kept[i] = keep;
            if keep {
                self.n_kept += 1;
                if let Some(t) = self.threshold {
                    self.expiry.push(Reverse((tick.saturating_add(t), i)));
                }
            }
            if prior_mode {
                if keep && self.raw.param_versions[i] > 0 {
                    self.scored_count += 1;
                    self.scored_total += self.raw.weights[i];
                    weights[i] = strategy.mass(self.raw.weights[i], c);
                } else {
                    // Unscored or stale: prior-priced, never dropped.
                    indicator[i] = 1.0;
                }
            } else if keep {
                weights[i] = strategy.mass(self.raw.weights[i], c);
            }
        }
        self.sum_sq = weights.iter().map(|w| w * w).sum();
        self.sum_wlogw = weights.iter().map(|&w| wlogw(w)).sum();
        self.n_pos = weights.iter().filter(|&&w| w > 0.0).count();
        self.sampler = FenwickSampler::new(&weights);
        if prior_mode {
            self.unscored_kept = Some(FenwickSampler::new(&indicator));
        }
    }

    /// Fold a store delta into the proposal and advance the staleness
    /// clock to `now`.  Incremental deltas cost
    /// O((entries + expiries) · log N); full deltas rebuild in O(N).
    pub fn absorb(&mut self, delta: &WeightDelta, now: u64) -> Result<()> {
        anyhow::ensure!(
            delta.n as usize == self.raw.len(),
            "delta tracks {} entries but proposal holds {}",
            delta.n,
            self.raw.len()
        );
        anyhow::ensure!(
            delta.indices.len() == delta.weights.len()
                && delta.weights.len() == delta.stamps.len()
                && delta.stamps.len() == delta.param_versions.len(),
            "delta columns disagree on length"
        );
        let absorb = crate::telemetry::start();
        self.now = self.now.max(now);
        if delta.full {
            // Reuse the canonical delta application (it re-validates and
            // bounds-checks), then recompute filter + sampler wholesale.
            delta.apply_to(&mut self.raw)?;
            self.rebuild_from_raw();
            self.last_changes = delta.len();
        } else {
            for &idx in &delta.indices {
                anyhow::ensure!(
                    (idx as usize) < self.raw.len(),
                    "delta index {idx} out of bounds (n = {})",
                    self.raw.len()
                );
            }
            for (k, &idx) in delta.indices.iter().enumerate() {
                self.apply_entry(
                    idx as usize,
                    delta.weights[k],
                    delta.stamps[k],
                    delta.param_versions[k],
                );
            }
            let evicted = self.expire();
            self.last_changes = delta.len() + evicted;
        }
        self.cursor = delta.seq;
        crate::telemetry::histogram("proposal.absorb_ns").record_elapsed(&absorb);
        crate::telemetry::gauge("proposal.ess").set(self.ess_ratio());
        Ok(())
    }

    /// Change the §B.3 smoothing constant.  No-op when unchanged; a real
    /// change re-smooths every kept entry (O(N)) — the price of the
    /// adaptive-entropy mode, paid only when the maintained entropy drifts
    /// off target.
    pub fn set_smoothing(&mut self, c: f64) {
        if c == self.smoothing {
            return;
        }
        self.smoothing = c;
        self.rebuild_from_raw();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn full_delta(seq: u64, weights: &[f64], stamps: &[u64], versions: &[u64]) -> WeightDelta {
        WeightDelta {
            seq,
            n: weights.len() as u64,
            full: true,
            indices: (0..weights.len() as u64).collect(),
            weights: weights.to_vec(),
            stamps: stamps.to_vec(),
            param_versions: versions.to_vec(),
        }
    }

    fn sparse_delta(
        seq: u64,
        n: usize,
        entries: &[(usize, f64, u64, u64)],
    ) -> WeightDelta {
        WeightDelta {
            seq,
            n: n as u64,
            full: false,
            indices: entries.iter().map(|e| e.0 as u64).collect(),
            weights: entries.iter().map(|e| e.1).collect(),
            stamps: entries.iter().map(|e| e.2).collect(),
            param_versions: entries.iter().map(|e| e.3).collect(),
        }
    }

    /// Ground truth: what the old per-step full recomputation produced.
    fn expected_weights(
        raw: &[f64],
        ticks: &[u64],
        now: u64,
        threshold: Option<u64>,
        c: f64,
    ) -> Vec<f64> {
        raw.iter()
            .zip(ticks)
            .map(|(&w, &s)| match threshold {
                Some(t) if now.saturating_sub(s) > t => 0.0,
                _ => w + c,
            })
            .collect()
    }

    /// Ground truth for coverage-prior mode: the old peer-step rebuild
    /// (prior = mean of scored raw weights, applied to unscored entries).
    fn expected_prior_weights(raw: &[f64], versions: &[u64], c: f64) -> Vec<f64> {
        let scored: Vec<f64> = versions
            .iter()
            .zip(raw)
            .filter(|(&v, _)| v > 0)
            .map(|(_, &w)| w)
            .collect();
        let prior = if scored.is_empty() {
            1.0
        } else {
            scored.iter().sum::<f64>() / scored.len() as f64
        };
        raw.iter()
            .zip(versions)
            .map(|(&w, &v)| if v > 0 { w + c } else { prior + c })
            .collect()
    }

    fn assert_matches(p: &ProposalMaintainer, expect: &[f64]) {
        assert_eq!(p.sampler().len(), expect.len());
        for (i, &e) in expect.iter().enumerate() {
            assert!(
                (p.sampler().weight(i) - e).abs() < 1e-9,
                "weight {i}: {} vs {e}",
                p.sampler().weight(i)
            );
        }
        let kept = expect.iter().filter(|&&w| w > 0.0).count();
        // kept tracks the filter, not positivity — with c = 0 a kept entry
        // can have weight 0, so only check when smoothing is positive.
        if p.smoothing() > 0.0 {
            assert_eq!((p.kept_fraction() * expect.len() as f64).round() as usize, kept);
        }
    }

    #[test]
    fn starts_empty_and_uniform_safe() {
        let p = ProposalMaintainer::new(8, 1.0, None, StalenessUnit::Versions);
        assert_eq!(p.cursor(), 0);
        assert_eq!(p.sampler().total(), 0.0);
        assert_eq!(p.kept_fraction(), 0.0);
        assert_eq!(p.ess_ratio(), 1.0);
        assert_eq!(p.normalized_entropy(), 1.0);
    }

    #[test]
    fn full_delta_installs_smoothed_weights() {
        let mut p = ProposalMaintainer::new(4, 2.0, None, StalenessUnit::Versions);
        let d = full_delta(5, &[1.0, 0.0, 3.0, 2.0], &[0; 4], &[0; 4]);
        p.absorb(&d, 0).unwrap();
        assert_eq!(p.cursor(), 5);
        assert_matches(&p, &[3.0, 2.0, 5.0, 4.0]);
        assert!((p.kept_fraction() - 1.0).abs() < 1e-12);
        assert_eq!(p.last_changes(), 4);
    }

    #[test]
    fn sparse_delta_applies_point_updates() {
        let mut p = ProposalMaintainer::new(5, 0.5, None, StalenessUnit::Versions);
        p.absorb(&full_delta(1, &[1.0; 5], &[0; 5], &[0; 5]), 0).unwrap();
        p.absorb(&sparse_delta(2, 5, &[(1, 4.0, 0, 1), (3, 0.0, 0, 1)]), 0)
            .unwrap();
        assert_eq!(p.cursor(), 2);
        assert_matches(&p, &[1.5, 4.5, 1.5, 0.5, 1.5]);
        assert_eq!(p.last_changes(), 2);
    }

    #[test]
    fn staleness_expires_entries_without_deltas() {
        // Threshold 10 in version units; entries stamped at version 0.
        let mut p = ProposalMaintainer::new(3, 1.0, Some(10), StalenessUnit::Versions);
        p.absorb(&full_delta(1, &[2.0; 3], &[0; 3], &[0; 3]), 0).unwrap();
        assert!((p.kept_fraction() - 1.0).abs() < 1e-12);
        // now = 10: age 10 <= threshold, everything still kept.
        p.absorb(&sparse_delta(1, 3, &[]), 10).unwrap();
        assert_matches(&p, &[3.0, 3.0, 3.0]);
        // now = 11: age 11 > threshold, all evicted by the expiry heap.
        p.absorb(&sparse_delta(1, 3, &[]), 11).unwrap();
        assert_matches(&p, &[0.0, 0.0, 0.0]);
        assert_eq!(p.kept_fraction(), 0.0);
        assert_eq!(p.last_changes(), 3); // three expiries
    }

    #[test]
    fn refresh_reinstates_evicted_entries() {
        let mut p = ProposalMaintainer::new(2, 1.0, Some(5), StalenessUnit::Versions);
        p.absorb(&full_delta(1, &[1.0, 1.0], &[0; 2], &[0; 2]), 0).unwrap();
        p.absorb(&sparse_delta(1, 2, &[]), 20).unwrap();
        assert_eq!(p.kept_fraction(), 0.0);
        // A new push stamped at version 18 (age 2) brings entry 0 back.
        p.absorb(&sparse_delta(2, 2, &[(0, 7.0, 0, 18)]), 20).unwrap();
        assert_matches(&p, &[8.0, 0.0]);
        assert!((p.kept_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn refreshed_entry_survives_its_stale_heap_record() {
        let mut p = ProposalMaintainer::new(1, 0.0, Some(5), StalenessUnit::Versions);
        p.absorb(&full_delta(1, &[1.0], &[0], &[0]), 0).unwrap();
        // Refresh at version 8 before the first record (expiry 5) fires.
        p.absorb(&sparse_delta(2, 1, &[(0, 2.0, 0, 8)]), 8).unwrap();
        // now = 10 pops the stale (expiry 5) record; the entry must stay
        // (age 2, new record expires at 13).
        p.absorb(&sparse_delta(2, 1, &[]), 10).unwrap();
        assert_matches(&p, &[2.0]);
        // now = 14 pops the live record and evicts for real.
        p.absorb(&sparse_delta(2, 1, &[]), 14).unwrap();
        assert_matches(&p, &[0.0]);
    }

    #[test]
    fn incremental_matches_scratch_recomputation() {
        // Random deltas + advancing clock: the maintained sampler must equal
        // the old full recomputation at every step.
        let n = 64;
        let threshold = Some(30u64);
        let c = 0.25;
        let mut p = ProposalMaintainer::new(n, c, threshold, StalenessUnit::Nanos);
        let mut raw = vec![0.0f64; n];
        let mut stamps = vec![0u64; n];
        let mut rng = Pcg64::seeded(42);
        p.absorb(&full_delta(1, &raw, &stamps, &vec![0; n]), 0).unwrap();
        let mut now = 0u64;
        for round in 0..200u64 {
            now += rng.next_below(8);
            let k = rng.next_below(6) as usize;
            let entries: Vec<(usize, f64, u64, u64)> = (0..k)
                .map(|_| {
                    let i = rng.next_below(n as u64) as usize;
                    let w = rng.next_f64() * 10.0;
                    let stamp = now.saturating_sub(rng.next_below(40));
                    (i, w, stamp, round)
                })
                .collect();
            for &(i, w, stamp, _) in &entries {
                raw[i] = w;
                stamps[i] = stamp;
            }
            p.absorb(&sparse_delta(round + 2, n, &entries), now).unwrap();
            let expect = expected_weights(&raw, &stamps, now, threshold, c);
            assert_matches(&p, &expect);
            // ESS and entropy must agree with the from-scratch diagnostics.
            let scratch = crate::sampler::effective_sample_size_ratio(&expect);
            assert!(
                (p.ess_ratio() - scratch).abs() < 1e-6,
                "round {round}: ess {} vs {scratch}",
                p.ess_ratio()
            );
            let scratch_h = crate::sampler::normalized_entropy(&expect);
            assert!(
                (p.normalized_entropy() - scratch_h).abs() < 1e-6,
                "round {round}: entropy {} vs {scratch_h}",
                p.normalized_entropy()
            );
        }
    }

    #[test]
    fn coverage_prior_matches_scratch_rebuild() {
        // The prior-mode maintainer must reproduce, at every step, exactly
        // what the old peer code computed with two O(N) passes per step.
        let n = 48;
        let c = 0.5;
        let mut p = ProposalMaintainer::with_coverage_prior(n, c, None, StalenessUnit::Versions);
        let mut raw = vec![1.0f64; n]; // store init_weight
        let mut versions = vec![0u64; n];
        let mut rng = Pcg64::seeded(7);
        p.absorb(&full_delta(1, &raw, &vec![0; n], &versions), 0).unwrap();
        for round in 0..150u64 {
            let k = rng.next_below(5) as usize;
            let entries: Vec<(usize, f64, u64, u64)> = (0..k)
                .map(|_| {
                    let i = rng.next_below(n as u64) as usize;
                    (i, rng.next_f64() * 4.0, 0, 1 + rng.next_below(9))
                })
                .collect();
            for &(i, w, _, v) in &entries {
                raw[i] = w;
                versions[i] = v;
            }
            p.absorb(&sparse_delta(round + 2, n, &entries), 0).unwrap();
            let expect = expected_prior_weights(&raw, &versions, c);
            let total: f64 = expect.iter().sum();
            assert!(
                (p.total_mass() - total).abs() < 1e-6 * total.max(1.0),
                "round {round}: mass {} vs {total}",
                p.total_mass()
            );
            for i in 0..n {
                assert!(
                    (p.effective_weight(i) - expect[i]).abs() < 1e-6,
                    "round {round} entry {i}: {} vs {}",
                    p.effective_weight(i),
                    expect[i]
                );
            }
            let scratch_ess = crate::sampler::effective_sample_size_ratio(&expect);
            assert!(
                (p.ess_ratio() - scratch_ess).abs() < 1e-6,
                "round {round}: ess {} vs {scratch_ess}",
                p.ess_ratio()
            );
            let scratch_h = crate::sampler::normalized_entropy(&expect);
            assert!(
                (p.normalized_entropy() - scratch_h).abs() < 1e-6,
                "round {round}: entropy {} vs {scratch_h}",
                p.normalized_entropy()
            );
        }
        // By now most entries are scored; the drawn coefficients must be
        // the IS scaling against the effective weights.
        let expect = expected_prior_weights(&raw, &versions, c);
        let mean_w = expect.iter().sum::<f64>() / n as f64;
        let (idx, coefs, got_mean) = p.draw_minibatch(&mut rng, 64);
        assert!((got_mean - mean_w).abs() < 1e-6 * mean_w);
        for (i, cf) in idx.iter().zip(&coefs) {
            assert!(
                (*cf as f64 - mean_w / expect[*i]).abs() < 1e-4,
                "coef for {i}: {cf} vs {}",
                mean_w / expect[*i]
            );
        }
    }

    #[test]
    fn coverage_prior_unscored_defaults_to_one() {
        // Nothing scored yet: every entry prices at prior 1.0 + c, so the
        // draw is uniform with coefficients exactly 1.
        let n = 16;
        let mut p = ProposalMaintainer::with_coverage_prior(n, 2.0, None, StalenessUnit::Versions);
        // Store init: weights 0.7 (placeholder — must be ignored), v = 0.
        p.absorb(&full_delta(1, &vec![0.7; n], &vec![0; n], &vec![0; n]), 0)
            .unwrap();
        assert!((p.prior() - 1.0).abs() < 1e-12);
        for i in 0..n {
            assert!((p.effective_weight(i) - 3.0).abs() < 1e-12);
        }
        let mut rng = Pcg64::seeded(3);
        let (_, coefs, _) = p.draw_minibatch(&mut rng, 32);
        assert!(coefs.iter().all(|&c| (c - 1.0).abs() < 1e-6));
        // Scoring one entry moves the prior to that entry's weight.
        p.absorb(&sparse_delta(2, n, &[(4, 5.0, 0, 3)]), 0).unwrap();
        assert!((p.prior() - 5.0).abs() < 1e-12);
        assert!((p.effective_weight(4) - 7.0).abs() < 1e-12);
        assert!((p.effective_weight(0) - 7.0).abs() < 1e-12); // prior-priced
    }

    #[test]
    fn coverage_prior_draw_samples_both_components() {
        // Half scored with large weights, half unscored: both kinds must
        // appear among draws, with frequencies favouring the heavy side.
        let n = 8;
        let mut p = ProposalMaintainer::with_coverage_prior(n, 0.0, None, StalenessUnit::Versions);
        p.absorb(&full_delta(1, &vec![1.0; n], &vec![0; n], &vec![0; n]), 0)
            .unwrap();
        let scored: Vec<(usize, f64, u64, u64)> =
            (0..4).map(|i| (i, 9.0, 0, 1)).collect();
        p.absorb(&sparse_delta(2, n, &scored), 0).unwrap();
        // prior = 9 ⇒ all effective weights 9: uniform across both trees.
        let mut rng = Pcg64::seeded(11);
        let (idx, coefs, _) = p.draw_minibatch(&mut rng, 4000);
        let unscored_hits = idx.iter().filter(|&&i| i >= 4).count();
        assert!(
            (1400..2600).contains(&unscored_hits),
            "mixture imbalance: {unscored_hits}/4000 unscored"
        );
        assert!(coefs.iter().all(|&c| (c - 1.0).abs() < 1e-6));
    }

    #[test]
    fn coverage_prior_staleness_falls_back_to_prior() {
        // §B.1 composed with the coverage prior: a scored entry whose
        // weight crosses the threshold is re-priced at the prior mass,
        // never zeroed — every example stays samplable.
        let n = 6;
        let c = 0.5;
        let mut p =
            ProposalMaintainer::with_coverage_prior(n, c, Some(5), StalenessUnit::Versions);
        p.absorb(&full_delta(1, &vec![1.0; n], &vec![0; n], &vec![0; n]), 0)
            .unwrap();
        // Score entry 0 at version 2 (already stale at now = 8) and entry
        // 1 at version 8 (fresh).  Only the fresh one feeds the prior.
        p.absorb(&sparse_delta(2, n, &[(0, 4.0, 0, 2), (1, 8.0, 0, 8)]), 8)
            .unwrap();
        assert!((p.prior() - 8.0).abs() < 1e-12);
        assert!((p.effective_weight(1) - 8.5).abs() < 1e-12); // fresh: raw + c
        assert!((p.effective_weight(0) - 8.5).abs() < 1e-12); // stale: prior + c
        assert!((p.effective_weight(3) - 8.5).abs() < 1e-12); // unscored: prior + c
        assert!((p.total_mass() - 6.0 * 8.5).abs() < 1e-9);
        // now = 14: the last fresh measurement expires too; the prior
        // falls back to 1.0 and the proposal stays strictly positive.
        p.absorb(&sparse_delta(2, n, &[]), 14).unwrap();
        assert!((p.prior() - 1.0).abs() < 1e-12);
        for i in 0..n {
            assert!(
                (p.effective_weight(i) - 1.5).abs() < 1e-12,
                "entry {i}: {} should be prior-priced, never zero",
                p.effective_weight(i)
            );
        }
        assert!((p.total_mass() - 6.0 * 1.5).abs() < 1e-9);
        // All-prior proposal is uniform: coefficients are exactly 1.
        let mut rng = Pcg64::seeded(9);
        let (_, coefs, _) = p.draw_minibatch(&mut rng, 16);
        assert!(coefs.iter().all(|&cf| (cf - 1.0).abs() < 1e-6));
    }

    /// Ground truth for coverage-prior mode WITH a staleness threshold:
    /// fresh-scored entries keep their smoothed weight, everything else
    /// (unscored or stale) is priced at the fresh-scored mean.
    fn expected_prior_staleness_weights(
        raw: &[f64],
        versions: &[u64],
        now: u64,
        t: u64,
        c: f64,
    ) -> Vec<f64> {
        let fresh = |v: u64| now.saturating_sub(v) <= t;
        let scored: Vec<f64> = versions
            .iter()
            .zip(raw)
            .filter(|(&v, _)| v > 0 && fresh(v))
            .map(|(_, &w)| w)
            .collect();
        let prior = if scored.is_empty() {
            1.0
        } else {
            scored.iter().sum::<f64>() / scored.len() as f64
        };
        raw.iter()
            .zip(versions)
            .map(|(&w, &v)| if v > 0 && fresh(v) { w + c } else { prior + c })
            .collect()
    }

    #[test]
    fn coverage_prior_with_staleness_matches_scratch_rebuild() {
        // Random deltas + advancing clock: the maintained mixture must
        // equal the from-scratch recomputation at every step.
        let n = 40;
        let t = 6u64;
        let c = 0.25;
        let mut p =
            ProposalMaintainer::with_coverage_prior(n, c, Some(t), StalenessUnit::Versions);
        let mut raw = vec![1.0f64; n];
        let mut versions = vec![0u64; n];
        let mut rng = Pcg64::seeded(21);
        p.absorb(&full_delta(1, &raw, &vec![0; n], &versions), 0).unwrap();
        let mut now = 0u64;
        for round in 0..200u64 {
            now += rng.next_below(3);
            let k = rng.next_below(5) as usize;
            let entries: Vec<(usize, f64, u64, u64)> = (0..k)
                .map(|_| {
                    let i = rng.next_below(n as u64) as usize;
                    let w = 0.1 + rng.next_f64() * 4.0;
                    // Stamp versions around `now`: some fresh, some stale.
                    let v = 1 + now.saturating_sub(rng.next_below(12));
                    (i, w, 0, v)
                })
                .collect();
            for &(i, w, _, v) in &entries {
                raw[i] = w;
                versions[i] = v;
            }
            p.absorb(&sparse_delta(round + 2, n, &entries), now).unwrap();
            let expect = expected_prior_staleness_weights(&raw, &versions, now, t, c);
            let total: f64 = expect.iter().sum();
            assert!(
                (p.total_mass() - total).abs() < 1e-6 * total.max(1.0),
                "round {round}: mass {} vs {total}",
                p.total_mass()
            );
            for i in 0..n {
                assert!(
                    (p.effective_weight(i) - expect[i]).abs() < 1e-6,
                    "round {round} entry {i}: {} vs {}",
                    p.effective_weight(i),
                    expect[i]
                );
                assert!(p.effective_weight(i) > 0.0, "entry {i} dropped to zero");
            }
            let scratch_ess = crate::sampler::effective_sample_size_ratio(&expect);
            assert!(
                (p.ess_ratio() - scratch_ess).abs() < 1e-6,
                "round {round}: ess {} vs {scratch_ess}",
                p.ess_ratio()
            );
        }
    }

    #[test]
    fn set_smoothing_resmooths_everything() {
        let mut p = ProposalMaintainer::new(3, 1.0, None, StalenessUnit::Versions);
        p.absorb(&full_delta(1, &[1.0, 2.0, 3.0], &[0; 3], &[0; 3]), 0).unwrap();
        p.set_smoothing(10.0);
        assert_matches(&p, &[11.0, 12.0, 13.0]);
        p.set_smoothing(0.0);
        assert_matches(&p, &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn rejects_size_mismatch_and_bad_indices() {
        let mut p = ProposalMaintainer::new(3, 1.0, None, StalenessUnit::Versions);
        assert!(p.absorb(&full_delta(1, &[1.0; 4], &[0; 4], &[0; 4]), 0).is_err());
        assert!(p
            .absorb(&sparse_delta(1, 3, &[(3, 1.0, 0, 0)]), 0)
            .is_err());
        let mut bad = sparse_delta(1, 3, &[(0, 1.0, 0, 0)]);
        bad.stamps.pop();
        assert!(p.absorb(&bad, 0).is_err());
    }

    #[test]
    fn empty_proposal_is_safe() {
        let mut p = ProposalMaintainer::new(0, 1.0, None, StalenessUnit::Versions);
        assert_eq!(p.kept_fraction(), 1.0);
        assert_eq!(p.ess_ratio(), 1.0);
        p.absorb(
            &WeightDelta {
                seq: 1,
                full: true,
                ..WeightDelta::default()
            },
            0,
        )
        .unwrap();
        assert_eq!(p.cursor(), 1);
    }

    #[test]
    fn default_strategy_constructors_are_bit_exact() {
        // `new` and `new_with_strategy(GradNormIs)` must be the same
        // maintainer: identical trees, identical draws, identical coefs.
        let d = full_delta(1, &[0.5, 2.0, 0.0, 7.0], &[0; 4], &[0; 4]);
        let mut a = ProposalMaintainer::new(4, 1.5, None, StalenessUnit::Versions);
        let mut b = ProposalMaintainer::new_with_strategy(
            4,
            1.5,
            None,
            StalenessUnit::Versions,
            StrategyKind::GradNormIs.strategy(),
        );
        a.absorb(&d, 0).unwrap();
        b.absorb(&d, 0).unwrap();
        for i in 0..4 {
            assert_eq!(a.sampler().weight(i), b.sampler().weight(i));
        }
        let mut ra = Pcg64::seeded(13);
        let mut rb = Pcg64::seeded(13);
        let (ia, ca, ma) = a.draw_minibatch(&mut ra, 32);
        let (ib, cb, mb) = b.draw_minibatch(&mut rb, 32);
        assert_eq!(ia, ib);
        assert_eq!(ca, cb);
        assert_eq!(ma, mb);
        assert_eq!(ra.next_u64(), rb.next_u64());
    }

    #[test]
    fn biased_strategy_pins_coefficients_without_touching_the_rng() {
        // PowerIs is biased + direct: same indices and RNG consumption as
        // an exact draw over its own mass, but coefficients pinned to 1.
        let mut p = ProposalMaintainer::new_with_strategy(
            6,
            0.5,
            None,
            StalenessUnit::Versions,
            StrategyKind::PowerIs.strategy(),
        );
        p.absorb(&full_delta(1, &[0.0, 1.0, 4.0, 9.0, 16.0, 25.0], &[0; 6], &[0; 6]), 0)
            .unwrap();
        // mass = (raw + c)^alpha — verify the tree holds the transform.
        let alpha = crate::sampler::strategy::POWER_IS_ALPHA;
        assert!((p.sampler().weight(3) - 9.5f64.powf(alpha)).abs() < 1e-12);
        let mut r1 = Pcg64::seeded(17);
        let mut r2 = Pcg64::seeded(17);
        let (idx, coefs, _) = p.draw_minibatch(&mut r1, 48);
        let (idx_exact, _, _) = crate::sampler::draw_minibatch(p.sampler(), &mut r2, 48);
        assert_eq!(idx, idx_exact);
        assert!(coefs.iter().all(|&c| c == 1.0));
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn presample_topk_matches_manual_truncation() {
        // LossReject draws factor·m candidates and keeps the m heaviest
        // (ties by draw order), surviving in draw order, coefs pinned to 1.
        let mut p = ProposalMaintainer::new_with_strategy(
            10,
            0.1,
            None,
            StalenessUnit::Versions,
            StrategyKind::LossReject.strategy(),
        );
        let raw: Vec<f64> = (0..10).map(|i| i as f64).collect();
        p.absorb(&full_delta(1, &raw, &[0; 10], &[0; 10]), 0).unwrap();
        let m = 4;
        let factor = match StrategyKind::LossReject.strategy().draw_policy() {
            DrawPolicy::PresampleTopK { factor } => factor,
            DrawPolicy::Direct => panic!("loss-reject must presample"),
        };
        let mut r1 = Pcg64::seeded(23);
        let mut r2 = Pcg64::seeded(23);
        let (idx, coefs, mean_w) = p.draw_minibatch(&mut r1, m);
        let (cand, _, mean_direct) = p.draw_direct(&mut r2, m * factor);
        let mut order: Vec<usize> = (0..cand.len()).collect();
        order.sort_by(|&a, &b| {
            p.effective_weight(cand[b])
                .total_cmp(&p.effective_weight(cand[a]))
                .then(a.cmp(&b))
        });
        order.truncate(m);
        order.sort_unstable();
        let expect: Vec<usize> = order.iter().map(|&k| cand[k]).collect();
        assert_eq!(idx, expect);
        assert_eq!(idx.len(), m);
        assert!(coefs.iter().all(|&c| c == 1.0));
        assert_eq!(mean_w, mean_direct);
        assert_eq!(r1.next_u64(), r2.next_u64());
        // Survivors skew heavy: their mean weight beats the candidate mean.
        let surv: f64 =
            idx.iter().map(|&i| p.effective_weight(i)).sum::<f64>() / idx.len() as f64;
        let cand_mean: f64 =
            cand.iter().map(|&i| p.effective_weight(i)).sum::<f64>() / cand.len() as f64;
        assert!(surv >= cand_mean, "top-k kept light examples: {surv} < {cand_mean}");
    }

    #[test]
    fn exp3_strategy_keeps_full_support_and_exact_coefs() {
        // Exp3 is unbiased: its γ floor keeps every mass positive even at
        // raw = 0 with c = 0, and coefficients stay exact mean(w)/w.
        let mut p = ProposalMaintainer::new_with_strategy(
            5,
            0.0,
            None,
            StalenessUnit::Versions,
            StrategyKind::Exp3.strategy(),
        );
        p.absorb(&full_delta(1, &[0.0, 0.3, 0.0, 1.2, 0.9], &[0; 5], &[0; 5]), 0)
            .unwrap();
        for i in 0..5 {
            assert!(p.sampler().weight(i) > 0.0, "entry {i} lost support");
        }
        let mean_w = p.sampler().total() / 5.0;
        let mut rng = Pcg64::seeded(29);
        let (idx, coefs, got_mean) = p.draw_minibatch(&mut rng, 40);
        assert_eq!(got_mean, mean_w);
        for (i, c) in idx.iter().zip(&coefs) {
            assert!(
                (*c as f64 - mean_w / p.sampler().weight(*i)).abs() < 1e-6,
                "coef for {i} not the exact IS scaling"
            );
        }
    }

    #[test]
    fn strategy_composes_with_coverage_prior_and_staleness() {
        // Prior + §B.1 decide *which raw value* is priced; the strategy
        // decides *how*.  With Exp3, fresh entries price mass(raw, c) and
        // stale/unscored entries price mass(prior, c) — never zero.
        let c = 0.25;
        let strat = StrategyKind::Exp3.strategy();
        let mut p = ProposalMaintainer::with_coverage_prior_strategy(
            6,
            c,
            Some(4),
            StalenessUnit::Versions,
            strat,
        );
        p.absorb(&full_delta(1, &vec![1.0; 6], &vec![0; 6], &vec![0; 6]), 0)
            .unwrap();
        // Fresh scores on 0 and 2 (version 8 at now 8); stale score on 1.
        p.absorb(
            &sparse_delta(2, 6, &[(0, 2.0, 0, 8), (2, 4.0, 0, 8), (1, 9.0, 0, 2)]),
            8,
        )
        .unwrap();
        assert!((p.prior() - 3.0).abs() < 1e-12); // mean of fresh {2, 4}
        assert_eq!(p.effective_weight(0), strat.mass(2.0, c));
        assert_eq!(p.effective_weight(2), strat.mass(4.0, c));
        for i in [1usize, 3, 4, 5] {
            assert_eq!(p.effective_weight(i), strat.mass(3.0, c), "entry {i}");
            assert!(p.effective_weight(i) > 0.0);
        }
        let expect_total = strat.mass(2.0, c) + strat.mass(4.0, c) + 4.0 * strat.mass(3.0, c);
        assert!((p.total_mass() - expect_total).abs() < 1e-9);
    }

    #[test]
    fn nonlinear_strategy_incremental_matches_rebuild() {
        // `mass` is pure, so sparse `apply_entry` updates and the O(N)
        // `rebuild_from_raw` (triggered by set_smoothing) must land on
        // bit-identical trees even for a non-linear transform.
        let n = 32;
        let mut p = ProposalMaintainer::new_with_strategy(
            n,
            0.5,
            None,
            StalenessUnit::Versions,
            StrategyKind::Exp3.strategy(),
        );
        let mut rng = Pcg64::seeded(31);
        p.absorb(&full_delta(1, &vec![0.0; n], &vec![0; n], &vec![0; n]), 0)
            .unwrap();
        for round in 0..40u64 {
            let entries: Vec<(usize, f64, u64, u64)> = (0..3)
                .map(|_| {
                    let i = rng.next_below(n as u64) as usize;
                    (i, rng.next_f64() * 3.0, 0, round + 1)
                })
                .collect();
            p.absorb(&sparse_delta(round + 2, n, &entries), 0).unwrap();
        }
        let incremental: Vec<f64> = (0..n).map(|i| p.sampler().weight(i)).collect();
        // Round-trip the smoothing constant: two full rebuilds from raw.
        p.set_smoothing(9.0);
        p.set_smoothing(0.5);
        for (i, &w) in incremental.iter().enumerate() {
            assert_eq!(p.sampler().weight(i), w, "entry {i} drifted");
        }
    }
}
