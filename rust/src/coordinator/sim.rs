//! Deterministic single-thread cluster simulation.
//!
//! Interleaves the master and workers on a fixed schedule: per master
//! step, each worker refreshes parameters and scores
//! `cfg.worker_batches_per_step` batches.  This reproduces the paper's
//! staleness phenomenology (weights lag parameters by a controlled
//! amount) while staying bit-reproducible across runs and machines —
//! which is what the multi-seed experiment drivers need.  The live
//! thread/TCP topology with real wall-clock staleness lives in
//! [`super::live`].
//!
//! In `SyncMode::Exact` the interleave becomes the paper's Figure-1
//! barrier diagram: every parameter publish is followed by a full
//! re-score of all shards before the master takes its next step.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{RunConfig, SyncMode};
use crate::data::shards;
use crate::metrics::RunRecorder;
use crate::runtime::{artifacts_dir, Engine};
use crate::weightstore::{MemStore, WeightStore};

use super::master::Master;
use super::worker::WorkerState;

/// Outcome of a simulated run.
pub struct SimOutcome {
    pub rec: RunRecorder,
    /// Final-parameters prediction error on (train, valid, test).
    pub final_err: (f64, f64, f64),
    /// Total examples scored by all workers.
    pub scored: u64,
    /// Store op counters.
    pub store_stats: crate::weightstore::StoreStats,
}

/// Run one full simulated experiment for `cfg`.
///
/// Engine is loaded from the artifacts directory of `cfg.model`
/// (`ISSGD_ARTIFACTS` env var overrides the base path).
pub fn run_sim(cfg: &RunConfig) -> Result<SimOutcome> {
    let engine = Engine::load(&artifacts_dir(&cfg.model))?;
    run_sim_with_engine(cfg, &engine)
}

/// Same as [`run_sim`] but reusing an already-compiled engine (the
/// experiment drivers run many seeds against one engine).
pub fn run_sim_with_engine(cfg: &RunConfig, engine: &Engine) -> Result<SimOutcome> {
    let store: Arc<dyn WeightStore> =
        Arc::new(MemStore::new(Master::store_size(cfg), cfg.init_weight));
    run_sim_with_store(cfg, engine, store)
}

/// Same as [`run_sim_with_engine`] but against a caller-supplied store —
/// the injection point for chaos runs (wrap a [`MemStore`] in
/// [`crate::weightstore::faulty::FaultyStore`]) or a durable backend.
/// The store must already be sized to [`Master::store_size`].
pub fn run_sim_with_store(
    cfg: &RunConfig,
    engine: &Engine,
    store_dyn: Arc<dyn WeightStore>,
) -> Result<SimOutcome> {
    let mut master = Master::new(cfg.clone(), engine, store_dyn.clone())?;

    let manifest = engine.manifest();
    // Workers publish the statistic the configured strategy samples by —
    // the manifest-negotiated score entry point feeds both.
    cfg.strategy.validate_manifest(manifest)?;
    let score = cfg.strategy.score_source();
    let mut workers: Vec<WorkerState> = shards(master.train_idx.len(), cfg.n_workers)
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            WorkerState::new_with_score(
                id,
                shard,
                manifest,
                Arc::clone(&master.data),
                Arc::new(master.train_idx.clone()),
                store_dyn.clone(),
                score,
            )
        })
        .collect();

    let mut scored = 0u64;
    for _ in 0..cfg.steps {
        let pushed = master.maybe_push_params()?;
        match cfg.sync {
            SyncMode::Exact => {
                if pushed {
                    // Barrier: every weight refreshed under the new params
                    // before the master continues (paper fig. 1 dotted lines).
                    for w in &mut workers {
                        scored += w.sweep_full(engine)? as u64;
                    }
                }
            }
            SyncMode::Relaxed => {
                for w in &mut workers {
                    scored += w.advance(engine, cfg.worker_batches_per_step)? as u64;
                }
            }
        }
        master.train_one_step(engine)?;
        master.maybe_evaluate(engine)?;
        master.maybe_monitor(engine)?;
    }

    let final_err = (
        master.evaluate(engine, super::master::EvalSplit::Train)?.1,
        master.evaluate(engine, super::master::EvalSplit::Valid)?.1,
        master.evaluate(engine, super::master::EvalSplit::Test)?.1,
    );
    Ok(SimOutcome {
        rec: master.rec,
        final_err,
        scored,
        store_stats: store_dyn.stats()?,
    })
}
