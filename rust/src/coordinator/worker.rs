//! The worker actor: keeps probability weights fresh (paper §4.2).
//!
//! A worker owns a contiguous shard of training-set *positions*, fetches
//! the newest parameters from the store when available, sweeps its shard
//! in scoring batches computing per-example statistics via the AOT
//! `grad_norms` entry point (Proposition 1 / Pallas kernel), and pushes
//! scores back to the store tagged with the parameter version they were
//! computed from.  *Which* statistic is pushed — ‖g(x_n)‖ (the paper) or
//! the loss (the reject/bandit strategies) — is the worker's
//! [`ScoreSource`], negotiated from the training strategy so master and
//! workers always agree on what the store's weight table means.
//!
//! The same `WorkerState` drives both execution modes:
//! * **sim** — `advance(k)` called by the deterministic interleaver.
//! * **live** — `run_live` loops in its own OS thread with its own engine
//!   until the stop flag flips.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::data::{BatchBuilder, Shard, SynthDataset};
use crate::model::ParamSet;
use crate::runtime::Engine;
use crate::sampler::strategy::{ScoreSource, StrategyKind};
use crate::weightstore::{ParamsDelta, WeightStore};

pub struct WorkerState {
    pub id: usize,
    /// Positions (train-split indices) this worker scores.
    pub shard: Shard,
    /// Global dataset ids for each train-split position.
    train_idx: Arc<Vec<usize>>,
    data: Arc<SynthDataset>,
    store: Arc<dyn WeightStore>,
    /// Local parameter copy + its version (0 = none yet).
    params: Option<ParamSet>,
    pub version: u64,
    /// Next position within the shard to score.
    cursor: usize,
    batch: BatchBuilder,
    /// Scoring batches completed (monitoring).
    pub batches_done: u64,
    /// Total examples scored (monitoring).
    pub examples_scored: u64,
    /// Transient store failures survived in live mode (monitoring).
    pub store_errors: u64,
    /// Reusable weight staging buffer.
    push_buf: Vec<f32>,
    /// Which per-example statistic this worker publishes as the score.
    score: &'static dyn ScoreSource,
}

impl WorkerState {
    /// A worker publishing the paper's grad-norm scores (the default
    /// strategy's [`ScoreSource`]).
    pub fn new(
        id: usize,
        shard: Shard,
        engine_manifest: &crate::runtime::Manifest,
        data: Arc<SynthDataset>,
        train_idx: Arc<Vec<usize>>,
        store: Arc<dyn WeightStore>,
    ) -> WorkerState {
        Self::new_with_score(
            id,
            shard,
            engine_manifest,
            data,
            train_idx,
            store,
            StrategyKind::GradNormIs.score_source(),
        )
    }

    /// A worker publishing an arbitrary [`ScoreSource`]'s statistic — the
    /// strategy negotiation point for the master/worker topology.
    pub fn new_with_score(
        id: usize,
        shard: Shard,
        engine_manifest: &crate::runtime::Manifest,
        data: Arc<SynthDataset>,
        train_idx: Arc<Vec<usize>>,
        store: Arc<dyn WeightStore>,
        score: &'static dyn ScoreSource,
    ) -> WorkerState {
        let batch = BatchBuilder::new(
            engine_manifest.batch_score,
            engine_manifest.input_dim,
            engine_manifest.n_classes,
        );
        WorkerState {
            id,
            shard,
            train_idx,
            data,
            store,
            params: None,
            version: 0,
            cursor: shard.start,
            batch,
            batches_done: 0,
            examples_scored: 0,
            store_errors: 0,
            push_buf: Vec::new(),
            score,
        }
    }

    /// The statistic this worker publishes.
    pub fn score_source(&self) -> &'static dyn ScoreSource {
        self.score
    }

    /// Store half of a parameter refresh: fetch the layers written since
    /// our version, if any.  Failures here are transport-transient.  The
    /// steady-state traffic is O(dirty layers), not the whole blob — the
    /// paper's latency-tolerant propagation made cheap.
    fn fetch_newer_params(&self) -> Result<Option<ParamsDelta>> {
        self.store.fetch_params_since(self.version)
    }

    /// Decode half of a parameter refresh.  A delta that does not apply is
    /// a deterministic failure (wrong model/config on the store) — callers
    /// must not retry it.  Full deltas (bootstrap / store fallback)
    /// rebuild the set; incremental ones patch the named layers in place.
    fn install_params(&mut self, engine: &Engine, delta: &ParamsDelta) -> Result<()> {
        match &mut self.params {
            Some(p) if !delta.full => p.apply_delta(engine.manifest(), delta)?,
            _ => {
                anyhow::ensure!(
                    delta.full,
                    "incremental params delta before any full sync"
                );
                self.params = Some(ParamSet::from_delta(engine.manifest(), delta)?);
            }
        }
        self.version = delta.version;
        Ok(())
    }

    /// Pull newer parameters if the store has them.  Returns true if the
    /// local copy changed.
    pub fn refresh_params(&mut self, engine: &Engine) -> Result<bool> {
        match self.fetch_newer_params()? {
            None => Ok(false),
            Some(delta) => {
                self.install_params(engine, &delta)?;
                Ok(true)
            }
        }
    }

    /// Engine half of a scoring round: compute ‖g‖ for the next batch of
    /// shard positions into the staging buffer.  Returns `(start, count)`
    /// for [`WorkerState::push_scores`], or `None` when there is nothing
    /// to score yet.  Engine failures propagate — they are deterministic.
    fn compute_scores(&mut self, engine: &Engine) -> Result<Option<(usize, usize)>> {
        let params = match &self.params {
            None => return Ok(None),
            Some(p) => p,
        };
        if self.shard.is_empty() {
            return Ok(None);
        }
        let b = self.batch.batch();
        let count = (self.shard.end - self.cursor).min(b);
        let positions: Vec<usize> = (0..count).map(|i| self.cursor + i).collect();
        let global: Vec<usize> = positions.iter().map(|&p| self.train_idx[p]).collect();
        self.batch.fill(self.data.as_ref(), &global);
        let out = engine.grad_norms(params, &self.batch.x, &self.batch.y)?;
        // The ScoreSource picks the published statistic: ‖g(x_n)‖ — the
        // *norm*, not the squared norm (Theorem 1) — for the paper's
        // strategy, the per-example loss for the reject/bandit family.
        self.push_buf.clear();
        self.push_buf.extend(
            out.sqnorms[..count]
                .iter()
                .zip(&out.losses[..count])
                .map(|(&sq, &l)| self.score.score(sq, l)),
        );
        Ok(Some((self.cursor, count)))
    }

    /// Store half of a scoring round: push the staged weights and advance
    /// the cursor.  On failure the cursor does not move, so the same batch
    /// is re-scored on retry.
    fn push_scores(&mut self, start: usize, count: usize) -> Result<()> {
        self.store
            .push_weights(start, &self.push_buf, self.version)?;
        self.cursor = start + count;
        if self.cursor >= self.shard.end {
            self.cursor = self.shard.start;
        }
        self.batches_done += 1;
        self.examples_scored += count as u64;
        Ok(())
    }

    /// Score the next batch of shard positions and push the score-source
    /// weights.  No-op (returns 0) until parameters have been published.
    pub fn score_next_batch(&mut self, engine: &Engine) -> Result<usize> {
        match self.compute_scores(engine)? {
            None => Ok(0),
            Some((start, count)) => {
                self.push_scores(start, count)?;
                Ok(count)
            }
        }
    }

    /// Sim-mode driver: refresh params once, then score `k` batches.
    pub fn advance(&mut self, engine: &Engine, k: usize) -> Result<usize> {
        self.refresh_params(engine)?;
        let mut scored = 0;
        for _ in 0..k {
            scored += self.score_next_batch(engine)?;
        }
        Ok(scored)
    }

    /// Exact-mode sweep: score the entire shard under the current params
    /// (refreshing first).  Returns examples scored.
    pub fn sweep_full(&mut self, engine: &Engine) -> Result<usize> {
        self.refresh_params(engine)?;
        if self.params.is_none() || self.shard.is_empty() {
            return Ok(0);
        }
        self.cursor = self.shard.start;
        let mut scored = 0;
        loop {
            scored += self.score_next_batch(engine)?;
            if self.cursor == self.shard.start {
                break; // wrapped: full sweep done
            }
        }
        Ok(scored)
    }

    /// Live-mode loop: poll for parameters and keep sweeping until `stop`.
    /// `throttle` inserts a pause between batches to emulate slower
    /// workers (and to keep a single-core host responsive).
    ///
    /// The topology is fire-and-forget (§4.2): a transient *store* failure
    /// must degrade freshness, never kill the scoring thread.  Store-op
    /// errors (param fetch, weight push) are counted in `store_errors` and
    /// retried after an exponential backoff that resets on the next
    /// successful round.  Engine failures are deterministic — retrying
    /// would spin forever on the same batch — so they still propagate and
    /// end the thread (reaped by `run_live`'s caller).
    pub fn run_live(
        &mut self,
        engine: &Engine,
        stop: &AtomicBool,
        throttle: Option<std::time::Duration>,
    ) -> Result<()> {
        const BACKOFF_MIN: std::time::Duration = std::time::Duration::from_millis(1);
        const BACKOFF_MAX: std::time::Duration = std::time::Duration::from_millis(500);
        let mut backoff = BACKOFF_MIN;
        while !stop.load(Ordering::Relaxed) {
            let store_err: Option<(&str, anyhow::Error)> = match self.fetch_newer_params() {
                Err(e) => Some(("param fetch", e)),
                Ok(delta) => {
                    if let Some(delta) = delta {
                        // A non-applying delta is deterministic — propagate.
                        self.install_params(engine, &delta)?;
                    }
                    match self.compute_scores(engine)? {
                        None => {
                            // No parameters published yet — wait for the master.
                            backoff = BACKOFF_MIN;
                            std::thread::sleep(std::time::Duration::from_millis(1));
                            None
                        }
                        Some((start, count)) => match self.push_scores(start, count) {
                            Ok(()) => {
                                backoff = BACKOFF_MIN;
                                if let Some(d) = throttle {
                                    std::thread::sleep(d);
                                }
                                None
                            }
                            Err(e) => Some(("weight push", e)),
                        },
                    }
                }
            };
            if let Some((stage, e)) = store_err {
                self.store_errors += 1;
                crate::log_warn!(
                    "worker",
                    "worker-{} {stage} failed (retry in {:?}): {e}",
                    self.id,
                    backoff
                );
                // Sleep in slices so a stop request is honoured promptly
                // even mid-backoff.
                let mut waited = std::time::Duration::ZERO;
                while waited < backoff && !stop.load(Ordering::Relaxed) {
                    let slice = (backoff - waited).min(std::time::Duration::from_millis(10));
                    std::thread::sleep(slice);
                    waited += slice;
                }
                backoff = (backoff * 2).min(BACKOFF_MAX);
            }
        }
        Ok(())
    }
}
