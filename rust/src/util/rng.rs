//! Deterministic pseudo-random numbers (no `rand` crate offline).
//!
//! `Pcg64` is the PCG-XSL-RR 128/64 generator — small state, excellent
//! statistical quality, and `split`-able so every actor (master, worker i,
//! data shard j) derives an independent stream from one experiment seed.
//! Determinism matters here: the synthetic dataset is *regenerated
//! identically on every node* from the seed instead of being shipped over
//! the wire (DESIGN.md §3).

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with an arbitrary 64-bit value; `stream` selects one of 2^63
    /// independent sequences (used by [`Pcg64::split`]).
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Seed from a single value (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent generator for a sub-component (worker id,
    /// shard id, ...).  Streams with different `tag`s never collide.
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64();
        Pcg64::new(seed, tag.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(tag) | 1)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of mantissa entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased method.
    pub fn next_below(&mut self, n: u64) -> u64 {
        // analyze: allow(panics): n == 0 is a caller bug, not reachable from wire input — store-path callers pass constant+1 bounds
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box-Muller (we always consume pairs; the spare
    /// is cached).
    pub fn next_gaussian(&mut self) -> f64 {
        // Cache-free two-sample Box-Muller keeps the struct Copy-cheap;
        // the trig cost is irrelevant next to PJRT execution.
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Fill a slice with N(0, std^2) f32 samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = (self.next_gaussian() as f32) * std;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` indices in `[0, n)` uniformly *with* replacement.
    pub fn sample_with_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.next_below(n as u64) as usize).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg64::seeded(7);
        let mut w0 = root.split(0);
        let mut w1 = root.split(1);
        let same = (0..64).filter(|_| w0.next_u64() == w1.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg64::seeded(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::seeded(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(6);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
