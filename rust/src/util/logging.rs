//! Leveled, timestamped stderr logger shared by all actors.
//!
//! Each log line carries the elapsed wall-clock since process start and an
//! actor tag (`master`, `worker-2`, `db`), which makes interleaved
//! multi-thread traces readable.  Level comes from CLI `--log-level` when
//! given; otherwise the `ISSGD_LOG` environment variable (same names:
//! `error`/`warn`/`info`/`debug`/`trace`), so spawned test/CI processes
//! can enable debug logs without CLI plumbing.  Default: `info`.
//!
//! analyze: allow-module(wallclock): log timestamps are wall time by design

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

/// Sentinel for "no level chosen yet": the first `enabled()` check
/// resolves `ISSGD_LOG` (falling back to `Info`) and caches the result,
/// so the env read happens at most once.
const LEVEL_UNSET: u8 = u8::MAX;

static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current effective level, resolving the `ISSGD_LOG` fallback on first
/// use.  A concurrent `set_level` wins over the env resolution.
fn effective_level() -> u8 {
    let cur = LEVEL.load(Ordering::Relaxed);
    if cur != LEVEL_UNSET {
        return cur;
    }
    let from_env = std::env::var("ISSGD_LOG")
        .ok()
        .as_deref()
        .and_then(level_from_str)
        .unwrap_or(Level::Info) as u8;
    // compare_exchange so an explicit set_level racing this resolution is
    // never overwritten by the env default.
    match LEVEL.compare_exchange(LEVEL_UNSET, from_env, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => from_env,
        Err(set_meanwhile) => set_meanwhile,
    }
}

pub fn level_from_str(s: &str) -> Option<Level> {
    Some(match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "info" => Level::Info,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => return None,
    })
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= effective_level()
}

pub fn log(level: Level, actor: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:9.3}s {} {}] {}", t.as_secs_f64(), tag, actor, msg);
}

#[macro_export]
macro_rules! log_info {
    ($actor:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $actor, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($actor:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $actor, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($actor:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $actor, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($actor:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $actor, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(level_from_str("debug"), Some(Level::Debug));
        assert_eq!(level_from_str("WARN"), Some(Level::Warn));
        assert_eq!(level_from_str("nope"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
