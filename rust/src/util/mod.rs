//! Infrastructure substrates that would normally come from crates.io but
//! are rebuilt in-tree for this offline, self-contained reproduction:
//! RNG (`rand` substitute), JSON (`serde_json` substitute), CLI parsing
//! (`clap` substitute), logging (`env_logger` substitute) and timing/stat
//! helpers (part of the `criterion` substitute in `crate::bench`).

pub mod cli;
pub mod csv;
pub mod json;
pub mod logging;
pub mod plot;
pub mod rng;
pub mod timer;
