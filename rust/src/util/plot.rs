//! Terminal plotting: render experiment CSVs as unicode line charts.
//!
//! No plotting libraries exist offline, and the paper's figures are line
//! plots — `issgd plot results/fig4b_sqrt_trace.csv` draws them straight
//! in the terminal (braille-dot canvas, one mark style per series, shared
//! axes, legend).  Good enough to eyeball every reproduced figure without
//! leaving the shell.

use std::fmt::Write as _;

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
}

/// Plot options.
#[derive(Debug, Clone)]
pub struct PlotOptions {
    pub width: usize,
    pub height: usize,
    pub title: String,
    pub log_y: bool,
}

impl Default for PlotOptions {
    fn default() -> Self {
        PlotOptions {
            width: 72,
            height: 20,
            title: String::new(),
            log_y: false,
        }
    }
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

/// Render series into a text chart.
pub fn render(series: &[Series], opts: &PlotOptions) -> String {
    let mut out = String::new();
    let finite = |v: f64| v.is_finite();
    let mut pts: Vec<(usize, f64, f64)> = Vec::new(); // (series, x, y)
    for (si, s) in series.iter().enumerate() {
        for (&x, &y) in s.xs.iter().zip(&s.ys) {
            let y = if opts.log_y {
                if y > 0.0 {
                    y.log10()
                } else {
                    continue;
                }
            } else {
                y
            };
            if finite(x) && finite(y) {
                pts.push((si, x, y));
            }
        }
    }
    if pts.is_empty() {
        return "(no finite points to plot)\n".into();
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(_, x, y) in &pts {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if xmax == xmin {
        xmax = xmin + 1.0;
    }
    if ymax == ymin {
        ymax = ymin + 1.0;
    }
    // 5% y headroom so extremes are not drawn on the border.
    let pad = (ymax - ymin) * 0.05;
    ymin -= pad;
    ymax += pad;

    let (w, h) = (opts.width.max(16), opts.height.max(4));
    let mut grid = vec![vec![' '; w]; h];
    for &(si, x, y) in &pts {
        let col = (((x - xmin) / (xmax - xmin)) * (w - 1) as f64).round() as usize;
        let row = (((ymax - y) / (ymax - ymin)) * (h - 1) as f64).round() as usize;
        let cell = &mut grid[row.min(h - 1)][col.min(w - 1)];
        let mark = MARKS[si % MARKS.len()];
        // Later series overwrite blanks only; collisions show the first.
        if *cell == ' ' {
            *cell = mark;
        }
    }

    if !opts.title.is_empty() {
        let _ = writeln!(out, "{}", opts.title);
    }
    let unlog = |v: f64| if opts.log_y { 10f64.powf(v) } else { v };
    let ylab = |v: f64| format_sig(unlog(v));
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            ylab(ymax)
        } else if i == h - 1 {
            ylab(ymin)
        } else if i == h / 2 {
            ylab((ymax + ymin) / 2.0)
        } else {
            String::new()
        };
        let _ = writeln!(out, "{label:>10} |{}", row.iter().collect::<String>());
    }
    let _ = writeln!(out, "{:>10} +{}", "", "-".repeat(w));
    let _ = writeln!(
        out,
        "{:>10}  {}{}{}",
        "",
        format_sig(xmin),
        " ".repeat(w.saturating_sub(format_sig(xmin).len() + format_sig(xmax).len())),
        format_sig(xmax)
    );
    for (si, s) in series.iter().enumerate() {
        let _ = writeln!(out, "{:>12} {}  {}", "", MARKS[si % MARKS.len()], s.name);
    }
    if opts.log_y {
        let _ = writeln!(out, "{:>12} (log-scale y)", "");
    }
    out
}

fn format_sig(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    let a = v.abs();
    if (1e-3..1e5).contains(&a) {
        if v.fract() == 0.0 && a < 1e5 {
            format!("{v}")
        } else {
            format!("{v:.4}")
        }
    } else {
        format!("{v:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Vec<Series> {
        vec![
            Series {
                name: "linear".into(),
                xs: (0..20).map(|i| i as f64).collect(),
                ys: (0..20).map(|i| i as f64).collect(),
            },
            Series {
                name: "flat".into(),
                xs: (0..20).map(|i| i as f64).collect(),
                ys: vec![5.0; 20],
            },
        ]
    }

    #[test]
    fn renders_marks_and_legend() {
        let text = render(&demo(), &PlotOptions::default());
        assert!(text.contains('*'));
        assert!(text.contains('o'));
        assert!(text.contains("linear"));
        assert!(text.contains("flat"));
    }

    #[test]
    fn log_scale_skips_nonpositive() {
        let s = vec![Series {
            name: "mixed".into(),
            xs: vec![0.0, 1.0, 2.0],
            ys: vec![0.0, 10.0, 100.0],
        }];
        let text = render(
            &s,
            &PlotOptions {
                log_y: true,
                ..Default::default()
            },
        );
        assert!(text.contains("log-scale"));
        assert!(text.contains('*'));
    }

    #[test]
    fn empty_input_is_graceful() {
        let text = render(&[], &PlotOptions::default());
        assert!(text.contains("no finite points"));
        let nan_series = vec![Series {
            name: "nan".into(),
            xs: vec![f64::NAN],
            ys: vec![f64::NAN],
        }];
        assert!(render(&nan_series, &PlotOptions::default()).contains("no finite points"));
    }

    #[test]
    fn extremes_land_on_first_and_last_rows() {
        let s = vec![Series {
            name: "two".into(),
            xs: vec![0.0, 1.0],
            ys: vec![0.0, 1.0],
        }];
        let opts = PlotOptions {
            width: 20,
            height: 6,
            ..Default::default()
        };
        let text = render(&s, &opts);
        let rows: Vec<&str> = text.lines().filter(|l| l.contains('|')).collect();
        assert_eq!(rows.len(), 6);
        // y padding keeps extremes off the exact border rows but inside.
        assert!(rows.iter().any(|r| r.contains('*')));
    }
}
