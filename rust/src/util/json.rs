//! Minimal JSON parser + emitter (serde is unavailable offline).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null) — enough for manifests, config files, and metric
//! dumps.  Numbers are kept as f64; integers round-trip exactly up to 2^53,
//! far beyond anything we store.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.  `Object` uses a BTreeMap so emission is
/// deterministic (stable key order) — handy for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ----- accessors ------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns None on any miss.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers used by manifest/config loading.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid usize field {key:?}"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field {key:?}"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field {key:?}"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field {key:?}"))
    }

    // ----- construction ---------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ----- parse ----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- emit -----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty print with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn keyword(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our files;
                            // map lone surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize().unwrap(), 2);
        assert_eq!(arr[2].get("b").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"dims":[64,32,10],"dtype":"f32","nested":{"x":1.5,"y":null,"z":[true,false]}}"#;
        let v = Json::parse(text).unwrap();
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::obj(vec![
            ("a", Json::arr_f64(&[1.0, 2.5])),
            ("b", Json::Str("hi \"there\"".into())),
        ]);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_roundtrip_exactly() {
        let v = Json::parse("9007199254740992").unwrap(); // 2^53
        assert_eq!(v.as_f64().unwrap(), 9007199254740992.0);
        let big = Json::Num(123456789012345.0);
        assert_eq!(big.to_string(), "123456789012345");
    }

    #[test]
    fn req_helpers() {
        let v = Json::parse(r#"{"n": 5, "s": "x", "f": 1.5, "a": [1]}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 5);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_f64("f").unwrap(), 1.5);
        assert_eq!(v.req_arr("a").unwrap().len(), 1);
        assert!(v.req_usize("missing").is_err());
        assert!(v.req_usize("f").is_err()); // 1.5 is not a usize
    }
}
