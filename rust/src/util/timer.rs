//! Wall-clock measurement helpers used by the coordinator's metrics and by
//! the bench harness (criterion is unavailable offline — see `crate::bench`).
//!
//! analyze: allow-module(wallclock): measuring wall time is this module's job

use std::time::{Duration, Instant};

/// A simple stopwatch accumulating named phase durations.
#[derive(Debug, Default)]
pub struct PhaseTimer {
    phases: Vec<(String, Duration)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and record it under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.phases.push((name.to_string(), t0.elapsed()));
        out
    }

    pub fn total(&self) -> Duration {
        self.phases.iter().map(|(_, d)| *d).sum()
    }

    /// Aggregate duration of all phases with this name.
    pub fn of(&self, name: &str) -> Duration {
        self.phases
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .sum()
    }

    pub fn report(&self) -> String {
        let total = self.total().as_secs_f64().max(1e-12);
        let mut names: Vec<&str> = self.phases.iter().map(|(n, _)| n.as_str()).collect();
        names.dedup();
        let mut uniq: Vec<&str> = Vec::new();
        for n in names {
            if !uniq.contains(&n) {
                uniq.push(n);
            }
        }
        let mut out = String::new();
        for name in uniq {
            let d = self.of(name).as_secs_f64();
            out.push_str(&format!(
                "{name:24} {:10.3} ms  {:5.1}%\n",
                d * 1e3,
                100.0 * d / total
            ));
        }
        out
    }
}

/// Online summary statistics (Welford) over a stream of samples.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Stats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_moments() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::new();
        t.time("a", || std::thread::sleep(Duration::from_millis(2)));
        t.time("b", || {});
        t.time("a", || {});
        assert!(t.of("a") >= Duration::from_millis(2));
        assert!(t.total() >= t.of("a"));
        assert!(t.report().contains("a"));
    }
}
