//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and subcommands (handled by the caller by peeking at the first
//! positional).  Typed getters parse on access and report readable errors.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("missing required option --{0}")]
    Missing(String),
    #[error("invalid value for --{0}: {1:?} ({2})")]
    Invalid(String, String, String),
}

/// Option names that take a value; anything else starting with `--` is a
/// boolean flag.  Keeping this explicit catches typos like `--seeds` vs
/// `--seed` at parse time instead of silently mis-grouping.
pub fn parse(argv: &[String], value_opts: &[&str]) -> Result<Args, String> {
    let mut args = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(body) = a.strip_prefix("--") {
            if let Some((k, v)) = body.split_once('=') {
                if !value_opts.contains(&k) {
                    return Err(format!("unknown option --{k}"));
                }
                args.opts.insert(k.to_string(), v.to_string());
            } else if value_opts.contains(&body) {
                i += 1;
                let v = argv
                    .get(i)
                    .ok_or_else(|| format!("option --{body} expects a value"))?;
                args.opts.insert(body.to_string(), v.clone());
            } else {
                args.flags.push(body.to_string());
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e: T::Err| CliError::Invalid(name.into(), v.into(), e.to_string())),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str, CliError> {
        self.get(name).ok_or_else(|| CliError::Missing(name.into()))
    }

    /// Comma-separated list option, e.g. `--workers 1,2,4`.
    pub fn get_list_parse<T: std::str::FromStr>(
        &self,
        name: &str,
        default: &[T],
    ) -> Result<Vec<T>, CliError>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|e: T::Err| {
                        CliError::Invalid(name.into(), p.into(), e.to_string())
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = parse(
            &argv("train --steps 100 --lr=0.01 --verbose extra"),
            &["steps", "lr"],
        )
        .unwrap();
        assert_eq!(a.positional(), &["train", "extra"]);
        assert_eq!(a.get_parse("steps", 0usize).unwrap(), 100);
        assert_eq!(a.get_parse("lr", 0.0f64).unwrap(), 0.01);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse(&argv("--steps nan-ish"), &["steps"]).unwrap();
        assert!(a.get_parse("steps", 5usize).is_err());
        let a = parse(&argv(""), &["steps"]).unwrap();
        assert_eq!(a.get_parse("steps", 5usize).unwrap(), 5);
        assert!(a.require("steps").is_err());
    }

    #[test]
    fn unknown_value_opt_with_equals_rejected() {
        assert!(parse(&argv("--nope=3"), &["steps"]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&argv("--steps"), &["steps"]).is_err());
    }

    #[test]
    fn list_parse() {
        let a = parse(&argv("--workers 1,2,8"), &["workers"]).unwrap();
        assert_eq!(a.get_list_parse("workers", &[3usize]).unwrap(), vec![1, 2, 8]);
        let b = parse(&argv(""), &["workers"]).unwrap();
        assert_eq!(b.get_list_parse("workers", &[3usize]).unwrap(), vec![3]);
    }
}
