//! Tiny CSV reader for the experiment result files (header + numeric
//! columns).  No quoting/escaping — our writers never emit any — but
//! malformed rows are reported with line numbers rather than silently
//! skipped.

use std::path::Path;

use anyhow::{Context, Result};

/// A parsed numeric CSV: named columns of equal length.
#[derive(Debug, Clone)]
pub struct Table {
    pub columns: Vec<String>,
    /// Column-major data.
    pub data: Vec<Vec<f64>>,
}

impl Table {
    pub fn parse(text: &str) -> Result<Table> {
        let mut lines = text.lines().enumerate();
        let (_, header) = lines.next().context("empty CSV")?;
        let columns: Vec<String> = header.split(',').map(|s| s.trim().to_string()).collect();
        anyhow::ensure!(!columns.is_empty(), "no columns");
        let mut data = vec![Vec::new(); columns.len()];
        for (lineno, line) in lines {
            if line.trim().is_empty() {
                continue;
            }
            let cells: Vec<&str> = line.split(',').collect();
            anyhow::ensure!(
                cells.len() == columns.len(),
                "line {}: {} cells, header has {}",
                lineno + 1,
                cells.len(),
                columns.len()
            );
            for (col, cell) in cells.iter().enumerate() {
                let v: f64 = cell
                    .trim()
                    .parse()
                    .with_context(|| format!("line {}, column {:?}", lineno + 1, columns[col]))?;
                data[col].push(v);
            }
        }
        Ok(Table { columns, data })
    }

    pub fn load(path: &Path) -> Result<Table> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| path.display().to_string())
    }

    pub fn n_rows(&self) -> usize {
        self.data.first().map(Vec::len).unwrap_or(0)
    }

    pub fn column(&self, name: &str) -> Option<&[f64]> {
        self.columns
            .iter()
            .position(|c| c == name)
            .map(|i| self.data[i].as_slice())
    }

    /// Column names ending in `suffix` (e.g. `_median`).
    pub fn columns_with_suffix(&self, suffix: &str) -> Vec<&str> {
        self.columns
            .iter()
            .filter(|c| c.ends_with(suffix))
            .map(String::as_str)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let t = Table::parse("step,a,b\n0,1.5,2\n10,2.5,4\n").unwrap();
        assert_eq!(t.columns, vec!["step", "a", "b"]);
        assert_eq!(t.n_rows(), 2);
        assert_eq!(t.column("a").unwrap(), &[1.5, 2.5]);
        assert_eq!(t.column("missing"), None);
    }

    #[test]
    fn skips_blank_lines_and_trims() {
        let t = Table::parse("x, y \n1, 2\n\n3, 4\n").unwrap();
        assert_eq!(t.columns, vec!["x", "y"]);
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    fn reports_bad_rows() {
        assert!(Table::parse("a,b\n1\n").is_err());
        assert!(Table::parse("a,b\n1,x\n").is_err());
        assert!(Table::parse("").is_err());
    }

    #[test]
    fn suffix_selection() {
        let t = Table::parse("step,a_median,a_q1,b_median\n0,1,2,3\n").unwrap();
        assert_eq!(t.columns_with_suffix("_median"), vec!["a_median", "b_median"]);
    }
}
