//! Fenwick (binary indexed) tree over non-negative weights, supporting
//! O(log N) point updates and O(log N) multinomial sampling by prefix-sum
//! descent.
//!
//! This is the master's default sampler: worker weight pushes arrive
//! continuously, so the proposal distribution changes between every
//! minibatch — an alias table (O(N) rebuild) would pay the full rebuild
//! cost per step, while the Fenwick tree absorbs point updates for free.
//! The crossover is measured in `benches/sampler.rs`.

use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct FenwickSampler {
    /// 1-based Fenwick array of partial sums (f64 to keep cumulative error
    /// harmless even for N ~ 10^6 weights).
    tree: Vec<f64>,
    /// Current raw weights (needed to compute deltas and to read back).
    weights: Vec<f64>,
    /// log2 ceiling of capacity, cached for the descent.
    log2: u32,
}

impl FenwickSampler {
    /// Build from initial weights (all must be finite and >= 0).
    ///
    /// O(N) bulk construction: seed each node with its own weight, then
    /// push every node's partial sum into its Fenwick parent once —
    /// instead of N point updates at O(log N) each.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        let mut tree = vec![0.0; n + 1];
        for (i, &w) in weights.iter().enumerate() {
            assert!(w.is_finite() && w >= 0.0, "weight {w} invalid");
            tree[i + 1] = w;
        }
        for i in 1..=n {
            let parent = i + (i & i.wrapping_neg());
            if parent <= n {
                tree[parent] += tree[i];
            }
        }
        FenwickSampler {
            tree,
            weights: weights.to_vec(),
            log2: usize::BITS - n.next_power_of_two().leading_zeros(),
        }
    }

    pub fn len(&self) -> usize {
        self.weights.len()
    }

    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    pub fn weight(&self, i: usize) -> f64 {
        self.weights[i]
    }

    /// Total weight mass.
    pub fn total(&self) -> f64 {
        self.prefix_sum(self.weights.len())
    }

    /// Sum of weights `[0, end)`.
    pub fn prefix_sum(&self, end: usize) -> f64 {
        let mut i = end;
        let mut acc = 0.0;
        while i > 0 {
            acc += self.tree[i];
            i &= i - 1;
        }
        acc
    }

    /// Set weight `i` to `w` in O(log N).
    pub fn update(&mut self, i: usize, w: f64) {
        assert!(w.is_finite() && w >= 0.0, "weight {w} invalid");
        let delta = w - self.weights[i];
        self.weights[i] = w;
        let mut j = i + 1;
        while j < self.tree.len() {
            self.tree[j] += delta;
            j += j & j.wrapping_neg();
        }
    }

    /// Sample one index with probability proportional to its weight.
    ///
    /// Uses the classic bit-descent: O(log N) with no division. Returns
    /// `None` if the total mass is zero.
    pub fn sample(&self, rng: &mut Pcg64) -> Option<usize> {
        // Explicit, not just a consequence of zero total: the descent
        // below would underflow at `weights.len() - 1` on an empty tree.
        if self.weights.is_empty() {
            return None;
        }
        let total = self.total();
        if total <= 0.0 {
            return None;
        }
        let mut target = rng.next_f64() * total;
        let mut pos = 0usize;
        let mut step = 1usize << self.log2;
        while step > 0 {
            let next = pos + step;
            if next < self.tree.len() && self.tree[next] < target {
                target -= self.tree[next];
                pos = next;
            }
            step >>= 1;
        }
        // pos is the count of elements strictly before the sampled one.
        // Cumulative fp error can land us on a zero-weight slot or one past
        // the end; walk to the nearest valid index.
        let mut idx = pos.min(self.weights.len() - 1);
        if self.weights[idx] == 0.0 {
            idx = (0..self.weights.len())
                .map(|d| (idx + d) % self.weights.len())
                .find(|&j| self.weights[j] > 0.0)?;
        }
        Some(idx)
    }

    /// Sample `k` indices with replacement.
    pub fn sample_many(&self, rng: &mut Pcg64, k: usize) -> Vec<usize> {
        (0..k).filter_map(|_| self.sample(rng)).collect()
    }

    /// Sample `k` indices with replacement via one coordinated descent.
    ///
    /// Element-wise identical to `k` sequential [`FenwickSampler::sample`]
    /// calls: the uniforms are drawn in the same RNG order up front, and
    /// each follows the exact comparison/subtraction chain of the
    /// per-draw walk — so the two paths are interchangeable under a fixed
    /// seed.  The win is coordination: targets are sorted once and walked
    /// top-down as groups, so each tree node is read once per *group*
    /// instead of once per draw (k draws share the O(log N) spine instead
    /// of repeating it).  Returns an empty vec when the mass is zero.
    pub fn sample_batch(&self, rng: &mut Pcg64, k: usize) -> Vec<usize> {
        if self.weights.is_empty() || k == 0 {
            return Vec::new();
        }
        let total = self.total();
        if total <= 0.0 {
            return Vec::new();
        }
        // Draw every uniform up front (same RNG order as k `sample`
        // calls), tagged with its slot so results land in draw order.
        let mut targets: Vec<(f64, usize)> =
            (0..k).map(|slot| (rng.next_f64() * total, slot)).collect();
        targets.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut out = vec![0usize; k];
        self.descend_batch(0, 1usize << self.log2, &mut targets, &mut out);
        out
    }

    /// Resolve a sorted slice of `(running target, slot)` pairs rooted at
    /// `pos` with descent width `step`, writing each slot's final index.
    fn descend_batch(
        &self,
        pos: usize,
        step: usize,
        targets: &mut [(f64, usize)],
        out: &mut [usize],
    ) {
        if targets.is_empty() {
            return;
        }
        if step == 0 {
            // Same fp-error repair as the per-draw path: clamp, then walk
            // forward to the nearest positive weight.
            for &(_, slot) in targets.iter() {
                let mut idx = pos.min(self.weights.len() - 1);
                if self.weights[idx] == 0.0 {
                    idx = (0..self.weights.len())
                        .map(|d| (idx + d) % self.weights.len())
                        .find(|&j| self.weights[j] > 0.0)
                        .expect("positive total mass but no positive weight");
                }
                out[slot] = idx;
            }
            return;
        }
        let next = pos + step;
        if next >= self.tree.len() {
            self.descend_batch(pos, step >> 1, targets, out);
            return;
        }
        let node = self.tree[next];
        // Sorted ⇒ the stay-left group (`!(node < target)`, mirroring the
        // per-draw comparison exactly) is a prefix of the slice.
        let split = targets.partition_point(|&(t, _)| !(node < t));
        let (left, right) = targets.split_at_mut(split);
        self.descend_batch(pos, step >> 1, left, out);
        for t in right.iter_mut() {
            t.0 -= node;
        }
        self.descend_batch(next, step >> 1, right, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_sums_match_naive() {
        let w = [1.0, 0.5, 2.0, 0.0, 3.25, 1.0, 0.0, 4.0, 0.125];
        let s = FenwickSampler::new(&w);
        let mut acc = 0.0;
        for i in 0..=w.len() {
            assert!((s.prefix_sum(i) - acc).abs() < 1e-12);
            if i < w.len() {
                acc += w[i];
            }
        }
        assert!((s.total() - acc).abs() < 1e-12);
    }

    #[test]
    fn updates_change_sums() {
        let mut s = FenwickSampler::new(&[1.0, 1.0, 1.0]);
        s.update(1, 5.0);
        assert_eq!(s.weight(1), 5.0);
        assert!((s.total() - 7.0).abs() < 1e-12);
        s.update(1, 0.0);
        assert!((s.total() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sampling_frequencies_match_weights() {
        let w = [1.0, 2.0, 4.0, 0.0, 8.0];
        let s = FenwickSampler::new(&w);
        let mut rng = Pcg64::seeded(1);
        let mut counts = [0usize; 5];
        let n = 60_000;
        for _ in 0..n {
            counts[s.sample(&mut rng).unwrap()] += 1;
        }
        assert_eq!(counts[3], 0);
        let total: f64 = w.iter().sum();
        for i in [0, 1, 2, 4] {
            let expect = w[i] / total;
            let got = counts[i] as f64 / n as f64;
            assert!(
                (got - expect).abs() < 0.01,
                "index {i}: got {got}, expect {expect}"
            );
        }
    }

    #[test]
    fn zero_mass_returns_none() {
        let s = FenwickSampler::new(&[0.0, 0.0]);
        let mut rng = Pcg64::seeded(2);
        assert_eq!(s.sample(&mut rng), None);
    }

    #[test]
    fn single_element() {
        let s = FenwickSampler::new(&[0.5]);
        let mut rng = Pcg64::seeded(3);
        for _ in 0..10 {
            assert_eq!(s.sample(&mut rng), Some(0));
        }
    }

    #[test]
    fn never_samples_zero_weight() {
        let mut w = vec![0.0; 257];
        w[0] = 1.0;
        w[256] = 1.0;
        let s = FenwickSampler::new(&w);
        let mut rng = Pcg64::seeded(4);
        for _ in 0..2000 {
            let i = s.sample(&mut rng).unwrap();
            assert!(i == 0 || i == 256, "sampled zero-weight index {i}");
        }
    }

    #[test]
    #[should_panic(expected = "invalid")]
    fn rejects_negative_weight() {
        FenwickSampler::new(&[1.0]).update(0, -1.0);
    }

    #[test]
    #[should_panic(expected = "invalid")]
    fn bulk_build_rejects_invalid_weight() {
        FenwickSampler::new(&[1.0, f64::NAN]);
    }

    #[test]
    fn empty_sampler_is_safe() {
        let s = FenwickSampler::new(&[]);
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
        assert_eq!(s.total(), 0.0);
        let mut rng = Pcg64::seeded(9);
        assert_eq!(s.sample(&mut rng), None);
        assert!(s.sample_many(&mut rng, 4).is_empty());
    }

    #[test]
    fn sample_batch_matches_sequential_draws() {
        // The ROADMAP-5 equivalence contract: under a fixed seed the
        // batched descent must return element-wise exactly what k
        // sequential `sample` calls return, and consume the same number
        // of RNG draws (so downstream streams stay aligned).
        for n in [1usize, 2, 3, 17, 64, 65, 200] {
            let mut wrng = Pcg64::seeded(n as u64);
            let mut w: Vec<f64> = (0..n)
                .map(|i| if i % 3 == 0 { 0.0 } else { wrng.next_f64() * 10.0 })
                .collect();
            if w.iter().sum::<f64>() <= 0.0 {
                w = vec![1.0; n];
            }
            let s = FenwickSampler::new(&w);
            for k in [0usize, 1, 5, 64] {
                let mut r_seq = Pcg64::new(99, n as u64);
                let mut r_batch = r_seq.clone();
                let seq: Vec<usize> = (0..k).map(|_| s.sample(&mut r_seq).unwrap()).collect();
                let batch = s.sample_batch(&mut r_batch, k);
                assert_eq!(batch, seq, "n={n} k={k}");
                assert_eq!(r_seq.next_u64(), r_batch.next_u64(), "n={n} k={k} rng drift");
            }
        }
    }

    #[test]
    fn sample_batch_zero_mass_and_empty_are_safe() {
        let mut rng = Pcg64::seeded(8);
        assert!(FenwickSampler::new(&[]).sample_batch(&mut rng, 4).is_empty());
        assert!(FenwickSampler::new(&[0.0; 5]).sample_batch(&mut rng, 4).is_empty());
        // Zero draws consume zero randomness.
        let s = FenwickSampler::new(&[1.0, 2.0]);
        let mut a = Pcg64::seeded(9);
        let b_next = Pcg64::seeded(9).next_u64();
        assert!(s.sample_batch(&mut a, 0).is_empty());
        assert_eq!(a.next_u64(), b_next);
    }

    #[test]
    fn bulk_build_matches_point_updates() {
        // The O(N) construction must produce the exact tree the O(N log N)
        // point-update path built — compare across sizes that exercise
        // power-of-two boundaries.
        for n in [1usize, 2, 3, 7, 8, 9, 63, 64, 65, 200] {
            let mut rng = Pcg64::seeded(n as u64);
            let w: Vec<f64> = (0..n)
                .map(|i| if i % 5 == 0 { 0.0 } else { rng.next_f64() * 10.0 })
                .collect();
            let bulk = FenwickSampler::new(&w);
            let mut incremental = FenwickSampler::new(&vec![0.0; n]);
            for (i, &v) in w.iter().enumerate() {
                incremental.update(i, v);
            }
            for end in 0..=n {
                assert!(
                    (bulk.prefix_sum(end) - incremental.prefix_sum(end)).abs() < 1e-9,
                    "n={n} end={end}"
                );
            }
        }
    }
}
