//! Importance-sampling machinery: multinomial samplers over the training
//! set, the paper's probability-weight smoothing (§B.3), and the staleness
//! filter (§B.1).
//!
//! The master composes these as: raw `ω̃_n` from the weight store →
//! staleness filter → `+c` smoothing → multinomial draw of a minibatch
//! (with replacement) → loss coefficients `coef_m = mean(ω̃) / ω̃_{i_m}`.

pub mod adaptive;
pub mod alias;
pub mod fenwick;
pub mod strategy;

pub use adaptive::{effective_sample_size_ratio, normalized_entropy, smoothing_for_entropy};
pub use alias::AliasSampler;
pub use fenwick::FenwickSampler;
pub use strategy::{DrawPolicy, ProposalStrategy, ScoreKind, ScoreSource, StrategyKind};

use crate::util::rng::Pcg64;

/// The paper's §B.3 additive smoothing: `ω̃_n ← ω̃_n + c`.
///
/// `c = 0` is pure ISSGD; `c → ∞` recovers uniform SGD.  Smoothing bounds
/// the loss coefficients (`coef ≤ mean(ω̃+c)/c`), defusing the "time bomb"
/// of a stale tiny weight meeting a now-large gradient.
#[derive(Debug, Clone, Copy)]
pub struct Smoothing {
    pub constant: f64,
}

impl Smoothing {
    pub fn new(constant: f64) -> Self {
        assert!(constant >= 0.0 && constant.is_finite());
        Smoothing { constant }
    }

    #[inline]
    pub fn apply(&self, w: f64) -> f64 {
        w + self.constant
    }

    pub fn apply_all(&self, ws: &mut [f64]) {
        for w in ws {
            *w += self.constant;
        }
    }
}

/// §B.1 staleness filter: keep only weights refreshed within `threshold`
/// of `now` (both in abstract "ticks" — wall-clock nanos in live runs,
/// master-step counts in simulated runs).  Filtered-out examples keep a
/// weight of 0 (never sampled) — the paper argues this is fair because
/// every index is equally likely to have been refreshed recently.
#[derive(Debug, Clone, Copy)]
pub struct StalenessFilter {
    /// Maximum allowed age; `None` disables filtering.
    pub threshold: Option<u64>,
}

impl StalenessFilter {
    pub fn disabled() -> Self {
        StalenessFilter { threshold: None }
    }

    pub fn with_threshold(threshold: u64) -> Self {
        StalenessFilter {
            threshold: Some(threshold),
        }
    }

    /// Whether a weight stamped at `stamp` is usable at time `now`.
    #[inline]
    pub fn keep(&self, stamp: u64, now: u64) -> bool {
        match self.threshold {
            None => true,
            Some(t) => now.saturating_sub(stamp) <= t,
        }
    }

    /// Apply in place: zero out weights older than the threshold.
    /// Returns the fraction kept.
    pub fn filter(&self, weights: &mut [f64], stamps: &[u64], now: u64) -> f64 {
        assert_eq!(weights.len(), stamps.len());
        if self.threshold.is_none() || weights.is_empty() {
            return 1.0;
        }
        let mut kept = 0usize;
        for (w, &s) in weights.iter_mut().zip(stamps) {
            if self.keep(s, now) {
                kept += 1;
            } else {
                *w = 0.0;
            }
        }
        kept as f64 / weights.len() as f64
    }
}

/// Draw an importance-sampled minibatch and its loss coefficients.
///
/// `weights` must already be smoothed/filtered.  Returns `(indices, coefs,
/// mean_weight)` where `coefs[m] = mean(weights)/weights[i_m]` — the §4.1
/// scaling with `Z = (1/N) Σ ω̃` folded in, so `train_step`'s
/// `mean(coef · CE)` is exactly the paper's minibatch loss.  Falls back to
/// uniform (all-ones coefs) if total mass is zero.
pub fn draw_minibatch(
    sampler: &FenwickSampler,
    rng: &mut Pcg64,
    m: usize,
) -> (Vec<usize>, Vec<f32>, f64) {
    let n = sampler.len();
    let total = sampler.total();
    if total <= 0.0 {
        let indices = rng.sample_with_replacement(n, m);
        return (indices, vec![1.0; m], 0.0);
    }
    let mean_w = total / n as f64;
    // One coordinated Fenwick descent for the whole minibatch — the k
    // uniforms are consumed in the same order (and mapped to the same
    // indices) as k sequential `sample` calls, so traces are unchanged.
    let indices = sampler.sample_batch(rng, m);
    debug_assert_eq!(indices.len(), m);
    let coefs = indices
        .iter()
        .map(|&i| (mean_w / sampler.weight(i)) as f32)
        .collect();
    (indices, coefs, mean_w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoothing_limits() {
        let s = Smoothing::new(10.0);
        assert_eq!(s.apply(0.0), 10.0);
        let mut ws = vec![0.0, 1.0, 5.0];
        s.apply_all(&mut ws);
        assert_eq!(ws, vec![10.0, 11.0, 15.0]);
    }

    #[test]
    #[should_panic]
    fn smoothing_rejects_negative() {
        Smoothing::new(-1.0);
    }

    #[test]
    fn staleness_keeps_fresh_only() {
        let f = StalenessFilter::with_threshold(4);
        let mut w = vec![1.0, 1.0, 1.0, 1.0];
        let stamps = vec![10, 5, 2, 8];
        let kept = f.filter(&mut w, &stamps, 10);
        assert_eq!(w, vec![1.0, 0.0, 0.0, 1.0]); // ages 0, 5, 8, 2
        assert!((kept - 0.5).abs() < 1e-12);
    }

    #[test]
    fn disabled_filter_keeps_all() {
        let f = StalenessFilter::disabled();
        let mut w = vec![1.0, 2.0];
        let kept = f.filter(&mut w, &[0, 0], u64::MAX);
        assert_eq!(kept, 1.0);
        assert_eq!(w, vec![1.0, 2.0]);
    }

    #[test]
    fn minibatch_coefs_are_is_scaling() {
        let weights = [1.0, 2.0, 3.0, 4.0];
        let s = FenwickSampler::new(&weights);
        let mut rng = Pcg64::seeded(5);
        let (idx, coefs, mean_w) = draw_minibatch(&s, &mut rng, 16);
        assert_eq!(idx.len(), 16);
        assert!((mean_w - 2.5).abs() < 1e-12);
        for (i, c) in idx.iter().zip(&coefs) {
            assert!((*c as f64 - 2.5 / weights[*i]).abs() < 1e-6);
        }
    }

    #[test]
    fn minibatch_estimator_is_unbiased_in_expectation() {
        // E[coef * f(i)] over the proposal == mean f — check empirically
        // with f(i) = i^2.
        let weights = [0.5, 1.0, 2.0, 4.0];
        let s = FenwickSampler::new(&weights);
        let mut rng = Pcg64::seeded(6);
        let f = |i: usize| (i * i) as f64;
        let truth: f64 = (0..4).map(f).sum::<f64>() / 4.0;
        let mut acc = 0.0;
        let rounds = 40_000;
        for _ in 0..rounds {
            let (idx, coefs, _) = draw_minibatch(&s, &mut rng, 1);
            acc += coefs[0] as f64 * f(idx[0]);
        }
        let est = acc / rounds as f64;
        assert!((est - truth).abs() < 0.08, "est {est} truth {truth}");
    }

    #[test]
    fn zero_mass_falls_back_to_uniform() {
        let s = FenwickSampler::new(&[0.0; 8]);
        let mut rng = Pcg64::seeded(7);
        let (idx, coefs, mean_w) = draw_minibatch(&s, &mut rng, 5);
        assert_eq!(idx.len(), 5);
        assert!(coefs.iter().all(|&c| c == 1.0));
        assert_eq!(mean_w, 0.0);
    }
}
