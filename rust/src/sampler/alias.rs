//! Walker's alias method: O(N) build, O(1) multinomial sampling.
//!
//! The alias table is the right sampler when the proposal is *frozen* for
//! many draws — e.g. exact-mode ISSGD, where all weights refresh at a
//! barrier and the master then draws a whole epoch of minibatches.  The
//! Fenwick tree (`fenwick.rs`) wins when weights mutate continuously; the
//! crossover is measured in `benches/sampler.rs`.

use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct AliasSampler {
    /// Acceptance probability of each slot's own index.
    prob: Vec<f64>,
    /// Fallback index taken when the acceptance test fails.
    alias: Vec<usize>,
    /// Slots with nonzero original weight (sampling must never return a
    /// zero-weight index even via fp slack in the split).
    nonzero: Vec<bool>,
}

impl AliasSampler {
    /// Build from non-negative weights.  Returns `None` if total mass is 0.
    pub fn new(weights: &[f64]) -> Option<Self> {
        let n = weights.len();
        let total: f64 = weights.iter().sum();
        if n == 0 || total <= 0.0 {
            return None;
        }
        for &w in weights {
            assert!(w.is_finite() && w >= 0.0, "weight {w} invalid");
        }
        // Scale to mean 1, then split into small (<1) and large (>=1).
        let mut scaled: Vec<f64> = weights.iter().map(|&w| w * n as f64 / total).collect();
        let mut prob = vec![0.0; n];
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s] = scaled[s];
            alias[s] = l;
            scaled[l] = (scaled[l] + scaled[s]) - 1.0;
            if scaled[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Leftovers (fp residue) get probability 1 of themselves.
        for &i in small.iter().chain(large.iter()) {
            prob[i] = 1.0;
            alias[i] = i;
        }
        Some(AliasSampler {
            prob,
            alias,
            nonzero: weights.iter().map(|&w| w > 0.0).collect(),
        })
    }

    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// O(1) draw.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        loop {
            let slot = rng.next_below(self.prob.len() as u64) as usize;
            let idx = if rng.next_f64() < self.prob[slot] {
                slot
            } else {
                self.alias[slot]
            };
            // Zero-weight indices can only be hit through fp residue in the
            // table build; rejecting them keeps the support exact.
            if self.nonzero[idx] {
                return idx;
            }
        }
    }

    pub fn sample_many(&self, rng: &mut Pcg64, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.sample(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequencies_match_weights() {
        let w = [1.0, 2.0, 4.0, 0.0, 8.0, 0.5];
        let s = AliasSampler::new(&w).unwrap();
        let mut rng = Pcg64::seeded(10);
        let n = 80_000;
        let mut counts = vec![0usize; w.len()];
        for _ in 0..n {
            counts[s.sample(&mut rng)] += 1;
        }
        assert_eq!(counts[3], 0);
        let total: f64 = w.iter().sum();
        for (i, &wi) in w.iter().enumerate() {
            let got = counts[i] as f64 / n as f64;
            assert!(
                (got - wi / total).abs() < 0.01,
                "index {i}: got {got} want {}",
                wi / total
            );
        }
    }

    #[test]
    fn zero_total_is_none() {
        assert!(AliasSampler::new(&[0.0, 0.0]).is_none());
        assert!(AliasSampler::new(&[]).is_none());
    }

    #[test]
    fn uniform_weights() {
        let s = AliasSampler::new(&[1.0; 7]).unwrap();
        let mut rng = Pcg64::seeded(11);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[s.sample(&mut rng)] += 1;
        }
        for &c in &counts {
            let got = c as f64 / 70_000.0;
            assert!((got - 1.0 / 7.0).abs() < 0.01);
        }
    }

    #[test]
    fn extreme_skew() {
        let mut w = vec![1e-9; 100];
        w[42] = 1e9;
        let s = AliasSampler::new(&w).unwrap();
        let mut rng = Pcg64::seeded(12);
        let hits = (0..1000).filter(|_| s.sample(&mut rng) == 42).count();
        assert!(hits > 990, "hits {hits}");
    }

    #[test]
    fn agrees_with_fenwick_distribution() {
        use crate::sampler::fenwick::FenwickSampler;
        let w = [0.3, 1.7, 0.0, 2.4, 0.6];
        let a = AliasSampler::new(&w).unwrap();
        let f = FenwickSampler::new(&w);
        let mut ra = Pcg64::seeded(13);
        let mut rf = Pcg64::seeded(14);
        let n = 50_000;
        let mut ca = vec![0f64; 5];
        let mut cf = vec![0f64; 5];
        for _ in 0..n {
            ca[a.sample(&mut ra)] += 1.0;
            cf[f.sample(&mut rf).unwrap()] += 1.0;
        }
        for i in 0..5 {
            let diff = (ca[i] - cf[i]).abs() / n as f64;
            assert!(diff < 0.01, "index {i}: alias {} vs fenwick {}", ca[i], cf[i]);
        }
    }
}
