//! Adaptive smoothing (§B.3, the paper's "not explored" suggestion).
//!
//! The paper's fixed additive constant `c` trades variance reduction for
//! stability, but the right `c` depends on the current weight distribution:
//! early in training the weights are heavy-tailed (small `c` is fine);
//! after convergence a few stragglers dominate and a larger `c` is needed.
//! The paper suggests choosing `c` to hit a target *entropy* of the
//! sampling distribution — "with a smoothing constant sufficiently large,
//! we can bring this entropy down to any target level".
//!
//! We implement exactly that: [`smoothing_for_entropy`] finds, by bisection
//! on `c`, the additive constant whose smoothed distribution has the
//! requested normalised entropy (1.0 = uniform = plain SGD, lower = sharper
//! = closer to raw ISSGD).  Entropy of the smoothed multinomial is
//! monotonically non-decreasing in `c`, which makes bisection exact.

/// Shannon entropy (nats) of the normalised weight vector.
pub fn entropy(weights: &[f64]) -> f64 {
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &w in weights {
        if w > 0.0 {
            let p = w / total;
            h -= p * p.ln();
        }
    }
    h
}

/// Entropy normalised to `[0, 1]` by the uniform maximum `ln(n)` over the
/// *positive-weight support*.
pub fn normalized_entropy(weights: &[f64]) -> f64 {
    let n = weights.iter().filter(|&&w| w > 0.0).count();
    if n <= 1 {
        return 1.0;
    }
    entropy(weights) / (n as f64).ln()
}

/// Find the additive smoothing constant that brings the normalised entropy
/// of `weights + c` up to `target` (in `[0, 1]`).
///
/// Returns 0.0 if the raw weights already meet the target.  Weights equal
/// to zero stay zero only if `c = 0`; with smoothing they re-enter the
/// support (matching the paper, where the constant is added to *all*
/// probability weights).
pub fn smoothing_for_entropy(weights: &[f64], target: f64, tol: f64) -> f64 {
    assert!((0.0..=1.0).contains(&target), "target entropy {target} not in [0,1]");
    assert!(tol > 0.0);
    if weights.len() <= 1 {
        return 0.0;
    }
    let h = |c: f64| {
        let smoothed: Vec<f64> = weights.iter().map(|&w| w + c).collect();
        normalized_entropy(&smoothed)
    };
    if h(0.0) >= target {
        return 0.0;
    }
    // Bracket: entropy(c→∞) → 1.  Grow the upper bound geometrically from
    // the mean weight scale.
    let mean = weights.iter().sum::<f64>() / weights.len() as f64;
    let mut lo = 0.0;
    let mut hi = mean.max(1e-12);
    let mut guard = 0;
    while h(hi) < target {
        hi *= 4.0;
        guard += 1;
        if guard > 200 {
            return hi; // target ~1.0 with adversarial weights; best effort
        }
    }
    // Bisection (entropy is monotone non-decreasing in c).
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if h(mid) < target {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo <= tol * hi.max(1e-12) {
            break;
        }
    }
    hi
}

/// Effective sample size ratio of an importance-sampling proposal — the
/// standard IS health diagnostic: `ESS/N = (Σw)² / (N Σw²)`, 1.0 for
/// uniform, → 1/N when one weight dominates.  The master logs this to
/// expose "time bomb" states (§B.3) before they bite.
pub fn effective_sample_size_ratio(weights: &[f64]) -> f64 {
    let n = weights.len();
    if n == 0 {
        return 1.0;
    }
    let sum: f64 = weights.iter().sum();
    let sumsq: f64 = weights.iter().map(|w| w * w).sum();
    if sumsq <= 0.0 {
        return 1.0;
    }
    (sum * sum) / (n as f64 * sumsq)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_of_uniform_is_ln_n() {
        let w = vec![2.0; 8];
        assert!((entropy(&w) - (8f64).ln()).abs() < 1e-12);
        assert!((normalized_entropy(&w) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_of_point_mass_is_zero() {
        let w = vec![0.0, 5.0, 0.0];
        assert_eq!(entropy(&w), 0.0);
    }

    #[test]
    fn smoothing_monotonically_raises_entropy() {
        let w = vec![0.01, 0.02, 10.0, 0.005];
        let h0 = normalized_entropy(&w);
        let h1 = normalized_entropy(&w.iter().map(|x| x + 1.0).collect::<Vec<_>>());
        let h2 = normalized_entropy(&w.iter().map(|x| x + 100.0).collect::<Vec<_>>());
        assert!(h0 < h1 && h1 < h2);
        assert!(h2 > 0.99);
    }

    #[test]
    fn solver_hits_target_entropy() {
        let w = vec![0.001, 0.01, 50.0, 0.1, 0.002, 3.0];
        for target in [0.5, 0.8, 0.95] {
            let c = smoothing_for_entropy(&w, target, 1e-6);
            let smoothed: Vec<f64> = w.iter().map(|x| x + c).collect();
            let got = normalized_entropy(&smoothed);
            assert!(
                (got - target).abs() < 0.01,
                "target {target}: c={c}, entropy {got}"
            );
        }
    }

    #[test]
    fn solver_returns_zero_if_already_above_target() {
        let w = vec![1.0, 1.1, 0.9, 1.05];
        assert_eq!(smoothing_for_entropy(&w, 0.5, 1e-6), 0.0);
    }

    #[test]
    fn ess_uniform_is_one_point_mass_is_tiny() {
        assert!((effective_sample_size_ratio(&[3.0; 10]) - 1.0).abs() < 1e-12);
        let mut w = vec![0.0; 100];
        w[7] = 1.0;
        assert!((effective_sample_size_ratio(&w) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn ess_degrades_with_skew() {
        let a = effective_sample_size_ratio(&[1.0, 1.0, 1.0, 1.0]);
        let b = effective_sample_size_ratio(&[1.0, 1.0, 1.0, 10.0]);
        let c = effective_sample_size_ratio(&[1.0, 1.0, 1.0, 1000.0]);
        assert!(a > b && b > c);
    }

    #[test]
    fn empty_and_singleton_edge_cases() {
        assert_eq!(effective_sample_size_ratio(&[]), 1.0);
        assert_eq!(smoothing_for_entropy(&[5.0], 0.9, 1e-6), 0.0);
        assert_eq!(normalized_entropy(&[5.0]), 1.0);
    }
}
