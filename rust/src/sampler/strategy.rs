//! Pluggable proposal strategies: how per-example scores become sampling
//! mass.
//!
//! The paper hard-wires one pipeline: workers compute ω̃_n = ‖g(x_n)‖, the
//! master smooths (`+c`, §B.3), filters (§B.1) and samples, and the update
//! scales each loss by `mean(ω̃)/ω̃_i` (exact importance sampling).  The
//! follow-on literature explores the same substrate with different score
//! sources and transforms, so two traits split that design space:
//!
//!  * [`ScoreSource`] — *what a worker computes per example* from a
//!    scoring pass.  [`crate::runtime::ScoreOutput`] carries both squared
//!    gradient norms and per-example losses from the one `grad_norms`
//!    entry point, so every registered source is served by the same AOT
//!    artifact; [`ScoreSource::required_entry`] is the
//!    manifest-negotiation hook ([`StrategyKind::validate_manifest`]).
//!  * [`ProposalStrategy`] — *how raw scores become sampling mass*
//!    ([`ProposalStrategy::mass`]), *how a minibatch is drawn* from that
//!    mass ([`ProposalStrategy::draw_policy`]), and — the correctness
//!    contract — *whether the resulting gradient estimate is unbiased*
//!    ([`ProposalStrategy::unbiased`]).
//!
//! # The unbiasedness declaration
//!
//! The importance-weight correction in the update path follows from the
//! declaration, enforced by `ProposalMaintainer::draw_minibatch`: unbiased
//! strategies get the exact `mean(w)/w_i` coefficients (the §4.1 scaling),
//! biased ones run with coefficients pinned to 1.  Scaling by `1/p` would
//! *not* recover an unbiased estimate once the draw is truncated
//! (presample/reject) or the mass transform deliberately flattens the
//! proposal (power transforms), so a biased strategy claiming the IS
//! correction would be wrong twice — the declaration makes the choice
//! explicit and testable.
//!
//! # Purity contract
//!
//! `mass(raw, c)` MUST be a pure function of its two arguments (no
//! interior state): [`crate::coordinator::ProposalMaintainer`] applies it
//! both incrementally (per delta entry, per expiry) and wholesale (full
//! rebuilds, smoothing changes), and the two paths must land on
//! bit-identical Fenwick trees.  Adaptive online state (the EXP3
//! exploration floor, the power exponent) therefore lives in constants or
//! in the raw scores themselves, never in the strategy object.
//!
//! # Registered strategies vs the literature (see PAPERS.md)
//!
//! | `StrategyKind` | score | mass(raw, c) | unbiased | draw |
//! |----------------|-------|--------------|----------|------|
//! | `GradNormIs` | ‖g‖ | `raw + c` | yes | direct |
//! | `LossReject` | loss | `raw + c` | no | presample ×4, keep top-m |
//! | `PowerIs` | ‖g‖ | `(raw + c)^α`, α = ½ | no | direct |
//! | `Exp3` | loss | `(1−γ)·e^min(η·raw, cap) + γ + c` | yes | direct |
//!
//! * `GradNormIs` — Alain et al. 2015, "Variance Reduction in SGD by
//!   Distributed Importance Sampling" (arXiv 1511.06481): this repo's
//!   source paper, the Theorem-1 minimum-variance proposal.  `mass` is
//!   exactly the §B.3 smoothing, so the default strategy reproduces the
//!   pre-refactor pipeline bit-exactly.
//! * `LossReject` — Katharopoulos & Fleuret 2018, "Not All Samples Are
//!   Created Equal: Deep Learning with Importance Sampling" (arXiv
//!   1803.00942): loss as a cheap upper-bound score, large-batch
//!   presampling, keep the top slice.  Deterministic truncation breaks IS
//!   exactness, hence the biased declaration.
//! * `PowerIs` — Katharopoulos & Fleuret 2017, "Biased Importance
//!   Sampling for Deep Neural Network Training" (arXiv 1706.00043):
//!   deliberately flattened proposal trading bias for variance.
//! * `Exp3` — Bouchard et al. 2015, "Online Learning to Sample" (arXiv
//!   1506.09016): bandit-style exponential reweighting of an online
//!   reward (the loss).  The exploration floor γ keeps every example's
//!   mass strictly positive, which is what lets it keep the unbiased
//!   declaration: full support + exact IS coefficients.
//!
//! # Topology caveat: peers always publish grad-norm scores
//!
//! The peer/ASGD topology (§6) co-computes scores with the training step;
//! [`crate::runtime::PeerOutput`] carries per-example squared norms but
//! only a *scalar* minibatch loss, so peers publish ‖g‖-derived scores
//! regardless of the configured source.  Score-kind negotiation applies
//! to the master/worker topology; a loss-scored strategy still runs under
//! peers, transforming ‖g‖ scores (`run_asgd_sim` logs the substitution).

use anyhow::{Context, Result};

use crate::runtime::Manifest;

/// What per-example statistic feeds the proposal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoreKind {
    /// ω̃_n = ‖g(x_n)‖ — the paper's minimum-variance score (Theorem 1).
    GradNorm,
    /// Per-example loss — the cheap upper-bound surrogate of the
    /// presample/reject literature.
    Loss,
}

/// What a worker computes per example (see the module docs).
pub trait ScoreSource: Send + Sync {
    fn name(&self) -> &'static str;
    fn kind(&self) -> ScoreKind;
    /// Engine entry point whose [`crate::runtime::ScoreOutput`] feeds
    /// [`ScoreSource::score`] — checked against the engine manifest by
    /// [`StrategyKind::validate_manifest`].
    fn required_entry(&self) -> &'static str;
    /// The published per-example score, from one `ScoreOutput` row.
    fn score(&self, sqnorm: f32, loss: f32) -> f32;
}

struct GradNormSource;

impl ScoreSource for GradNormSource {
    fn name(&self) -> &'static str {
        "grad-norm"
    }
    fn kind(&self) -> ScoreKind {
        ScoreKind::GradNorm
    }
    fn required_entry(&self) -> &'static str {
        "grad_norms"
    }
    fn score(&self, sqnorm: f32, _loss: f32) -> f32 {
        // ω̃_n = ‖g(x_n)‖ — the *norm*, not the squared norm (Theorem 1).
        sqnorm.max(0.0).sqrt()
    }
}

struct LossSource;

impl ScoreSource for LossSource {
    fn name(&self) -> &'static str {
        "loss"
    }
    fn kind(&self) -> ScoreKind {
        ScoreKind::Loss
    }
    fn required_entry(&self) -> &'static str {
        // Per-example losses are co-computed by the grad_norms pass, so
        // loss scoring needs no extra AOT artifact.
        "grad_norms"
    }
    fn score(&self, _sqnorm: f32, loss: f32) -> f32 {
        loss.max(0.0)
    }
}

/// How a strategy turns its sampling mass into a minibatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrawPolicy {
    /// One multinomial draw per minibatch slot (the paper's scheme).
    Direct,
    /// Draw `factor · m` candidates from the proposal, keep the `m` with
    /// the largest effective mass (presample-and-reject).
    PresampleTopK { factor: usize },
}

/// How raw scores become sampling mass (see the module docs).
pub trait ProposalStrategy: Send + Sync {
    fn name(&self) -> &'static str;
    /// Sampling mass of one raw score under smoothing constant `c`.
    /// MUST be pure, finite, non-negative for `raw >= 0, c >= 0`, and
    /// monotone non-decreasing in `raw` (purity contract: module docs).
    fn mass(&self, raw: f64, c: f64) -> f64;
    /// Whether the resulting gradient estimate is unbiased — decides the
    /// coefficient policy in `ProposalMaintainer::draw_minibatch`.
    fn unbiased(&self) -> bool;
    fn draw_policy(&self) -> DrawPolicy {
        DrawPolicy::Direct
    }
}

struct GradNormIsStrategy;

impl ProposalStrategy for GradNormIsStrategy {
    fn name(&self) -> &'static str {
        "grad-norm"
    }
    fn mass(&self, raw: f64, c: f64) -> f64 {
        // Exactly the §B.3 smoothing (`Smoothing::apply`) — keeping this
        // bit-identical is what makes the default strategy reproduce the
        // pre-refactor trajectory.
        raw + c
    }
    fn unbiased(&self) -> bool {
        true
    }
}

struct LossRejectStrategy;

impl ProposalStrategy for LossRejectStrategy {
    fn name(&self) -> &'static str {
        "loss-reject"
    }
    fn mass(&self, raw: f64, c: f64) -> f64 {
        raw + c
    }
    fn unbiased(&self) -> bool {
        // Deterministic top-m truncation of the candidate pool is not an
        // importance-sampling scheme; no coefficient recovers exactness.
        false
    }
    fn draw_policy(&self) -> DrawPolicy {
        DrawPolicy::PresampleTopK { factor: 4 }
    }
}

/// Flattening exponent of [`StrategyKind::PowerIs`].
pub const POWER_IS_ALPHA: f64 = 0.5;

struct PowerIsStrategy;

impl ProposalStrategy for PowerIsStrategy {
    fn name(&self) -> &'static str {
        "power"
    }
    fn mass(&self, raw: f64, c: f64) -> f64 {
        (raw + c).max(0.0).powf(POWER_IS_ALPHA)
    }
    fn unbiased(&self) -> bool {
        false
    }
}

/// EXP3 learning rate on the loss reward.
pub const EXP3_ETA: f64 = 1.0;
/// EXP3 exploration floor (also the full-support guarantee).
pub const EXP3_GAMMA: f64 = 0.1;
/// Cap on the exponent so a diverging loss cannot overflow the mass.
const EXP3_CAP: f64 = 30.0;

struct Exp3Strategy;

impl ProposalStrategy for Exp3Strategy {
    fn name(&self) -> &'static str {
        "exp3"
    }
    fn mass(&self, raw: f64, c: f64) -> f64 {
        (1.0 - EXP3_GAMMA) * (EXP3_ETA * raw).min(EXP3_CAP).exp() + EXP3_GAMMA + c
    }
    fn unbiased(&self) -> bool {
        true
    }
}

/// Registry of the pluggable strategies (the `--strategy` CLI surface).
/// Every strategy is a stateless singleton, so the kind is `Copy` and
/// threads through `RunConfig` without boxing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StrategyKind {
    /// The source paper's exact importance sampling on ‖g‖ (default).
    #[default]
    GradNormIs,
    /// Loss-scored presample-and-reject top-m (biased).
    LossReject,
    /// Biased power transform of the grad-norm score (α = ½).
    PowerIs,
    /// EXP3-style exponential loss reweighting with an exploration floor.
    Exp3,
}

impl StrategyKind {
    pub const ALL: [StrategyKind; 4] = [
        StrategyKind::GradNormIs,
        StrategyKind::LossReject,
        StrategyKind::PowerIs,
        StrategyKind::Exp3,
    ];

    /// Every registered strategy, in shoot-out order.
    pub fn all() -> &'static [StrategyKind] {
        &Self::ALL
    }

    /// The CLI/JSON name (round-trips through [`StrategyKind::parse`]).
    pub fn name(self) -> &'static str {
        self.strategy().name()
    }

    pub fn parse(s: &str) -> Result<StrategyKind> {
        Ok(match s {
            "grad-norm" | "gradnorm" | "is" => StrategyKind::GradNormIs,
            "loss-reject" | "reject" => StrategyKind::LossReject,
            "power" | "power-is" => StrategyKind::PowerIs,
            "exp3" | "bandit" => StrategyKind::Exp3,
            other => {
                anyhow::bail!("unknown strategy {other:?} (grad-norm|loss-reject|power|exp3)")
            }
        })
    }

    pub fn score_source(self) -> &'static dyn ScoreSource {
        match self {
            StrategyKind::GradNormIs | StrategyKind::PowerIs => &GradNormSource,
            StrategyKind::LossReject | StrategyKind::Exp3 => &LossSource,
        }
    }

    pub fn strategy(self) -> &'static dyn ProposalStrategy {
        match self {
            StrategyKind::GradNormIs => &GradNormIsStrategy,
            StrategyKind::LossReject => &LossRejectStrategy,
            StrategyKind::PowerIs => &PowerIsStrategy,
            StrategyKind::Exp3 => &Exp3Strategy,
        }
    }

    /// Score-kind negotiation: the engine manifest must export the entry
    /// point this strategy's score source reads.
    pub fn validate_manifest(self, manifest: &Manifest) -> Result<()> {
        let entry = self.score_source().required_entry();
        manifest.artifact_path(entry).map(|_| ()).with_context(|| {
            format!(
                "strategy {:?} needs the {entry:?} entry point, which model {:?} does not export",
                self.name(),
                manifest.config
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_parse_back_and_are_unique() {
        let mut seen = Vec::new();
        for &k in StrategyKind::all() {
            assert_eq!(StrategyKind::parse(k.name()).unwrap(), k);
            assert!(!seen.contains(&k.name()), "duplicate name {:?}", k.name());
            seen.push(k.name());
        }
        assert!(StrategyKind::parse("nope").is_err());
    }

    #[test]
    fn default_strategy_mass_is_exactly_the_smoothing() {
        // Bit-exactness contract: the grad-norm arm must reproduce the
        // pre-refactor `Smoothing::apply` arithmetic identically.
        let s = StrategyKind::GradNormIs.strategy();
        for &(w, c) in &[(0.0, 0.0), (1.5, 10.0), (3.25, 0.125), (1e-9, 1e3)] {
            assert_eq!(s.mass(w, c), crate::sampler::Smoothing::new(c).apply(w));
        }
        assert!(s.unbiased());
        assert_eq!(s.draw_policy(), DrawPolicy::Direct);
    }

    #[test]
    fn unbiased_strategies_have_full_support_mass() {
        // The declaration's precondition: an unbiased strategy must give
        // every example positive mass under a positive smoothing constant.
        for &k in StrategyKind::all() {
            let s = k.strategy();
            if s.unbiased() {
                for &raw in &[0.0, 1e-12, 0.5, 100.0, 1e9] {
                    assert!(s.mass(raw, 0.1) > 0.0, "{} lost support at {raw}", s.name());
                }
            }
        }
        // EXP3's floor holds even at c = 0.
        assert!(StrategyKind::Exp3.strategy().mass(0.0, 0.0) >= EXP3_GAMMA);
    }

    #[test]
    fn mass_is_finite_monotone_and_nonnegative() {
        for &k in StrategyKind::all() {
            let s = k.strategy();
            let mut prev = -1.0f64;
            for &raw in &[0.0, 0.1, 1.0, 10.0, 1e3, 1e9, 1e300] {
                let m = s.mass(raw, 0.5);
                assert!(m.is_finite() && m >= 0.0, "{}({raw}) = {m}", s.name());
                assert!(m >= prev, "{} not monotone at {raw}", s.name());
                prev = m;
            }
        }
    }

    #[test]
    fn score_sources_compute_the_declared_statistic() {
        let g = StrategyKind::GradNormIs.score_source();
        assert_eq!(g.kind(), ScoreKind::GradNorm);
        assert_eq!(g.score(4.0, 7.0), 2.0); // √sqnorm, loss ignored
        assert_eq!(g.score(-1.0, 7.0), 0.0); // negative sqnorm clamped
        let l = StrategyKind::LossReject.score_source();
        assert_eq!(l.kind(), ScoreKind::Loss);
        assert_eq!(l.score(4.0, 7.0), 7.0);
        assert_eq!(l.score(4.0, -3.0), 0.0);
        // Both sources are served by the one scoring entry point.
        for &k in StrategyKind::all() {
            assert_eq!(k.score_source().required_entry(), "grad_norms");
        }
    }

    #[test]
    fn manifest_negotiation_rejects_missing_entry() {
        use crate::runtime::{LayerSpec, Manifest};
        let mut m = Manifest::synthetic_for_tests(vec![LayerSpec { d_in: 4, d_out: 2 }]);
        for &k in StrategyKind::all() {
            assert!(k.validate_manifest(&m).is_err(), "{:?} accepted empty manifest", k);
        }
        m.artifacts.push(("grad_norms".into(), "grad_norms.bin".into()));
        for &k in StrategyKind::all() {
            k.validate_manifest(&m).unwrap();
        }
    }

    #[test]
    fn biased_declarations_match_the_literature() {
        assert!(!StrategyKind::LossReject.strategy().unbiased());
        assert!(!StrategyKind::PowerIs.strategy().unbiased());
        assert!(StrategyKind::Exp3.strategy().unbiased());
        assert_eq!(
            StrategyKind::LossReject.strategy().draw_policy(),
            DrawPolicy::PresampleTopK { factor: 4 }
        );
    }
}
