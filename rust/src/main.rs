//! `issgd` — CLI for the distributed importance-sampling SGD system.
//!
//! Subcommands:
//!   train        run one training session (sim or live topology)
//!   db-server    run the weight-store "database" actor on a TCP port
//!   worker       run a standalone scoring worker against a remote store
//!   experiment   regenerate a paper figure/table (fig2|fig3|fig4|table1|staleness|strategy-matrix|all)
//!   metrics      scrape a live db-server's telemetry registry
//!   info         print artifact/manifest information
//!
//! Examples:
//!   issgd train --model tiny --steps 50 --trainer issgd
//!   issgd db-server --addr 127.0.0.1:7070 --n-examples 4096
//!   issgd worker --store 127.0.0.1:7070 --worker-id 0 --workers 3
//!   issgd experiment fig4 --seeds 5 --steps 300
//!   ISSGD_RESULTS=results issgd experiment all

// Same clippy baseline as lib.rs (the binary is mostly arg plumbing, but
// the CI gate runs with `-D warnings` across targets).  Shrink, don't grow.
#![allow(clippy::collapsible_else_if)]
#![allow(clippy::collapsible_if)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::uninlined_format_args)]

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use issgd::config::RunConfig;
use issgd::coordinator::{run_live, run_sim, LiveOptions};
use issgd::experiments::{self, ExperimentScale};
use issgd::log_info;
use issgd::runtime::{artifacts_dir, Manifest};
use issgd::util::cli::{self, Args};
use issgd::util::logging;
use issgd::weightstore::durable::DurableStore;
use issgd::weightstore::{server::Server, MemStore, WeightStore};

const USAGE: &str = "\
issgd — Distributed Importance Sampling SGD (Alain et al., 2015)

USAGE: issgd <subcommand> [options]

SUBCOMMANDS
  train         one training session
                  --model tiny|small|paper  --trainer issgd|sgd  --sync exact|relaxed
                  --steps N --lr F --smoothing F --workers N --seed N
                  --strategy grad-norm|loss-reject|power|exp3
                                    proposal strategy (score + sampling-mass
                                    transform; grad-norm is the paper's)
                  --live            use real threads instead of the deterministic sim
                  --peer            peer/ASGD topology (§6) instead of master/worker;
                                    with --live every peer is its own OS thread
                  --lockstep        (peer --live) deterministic round-robin op order
                  --store ADDR      (live) connect to a remote db-server
                  --store-path DIR  (implies --live) durable on-disk weight store:
                                    append-only delta log + snapshot checkpoints,
                                    survives restarts (see db-server)
                  --throttle-ms N   (live) pause between worker/peer batches
                  --monitor-every N enable the variance monitor
  db-server     run the weight store
                  --addr HOST:PORT  --n-examples N  --init-weight F
                  --store-path DIR  serve a durable store (created on first run,
                                    recovered — snapshot + log replay — on later runs)
                  --write-queue-mb N  per-connection queued-response cap before a
                                    slow client is evicted (default 64)
                  --telemetry-dump PATH  append a JSONL telemetry snapshot
                                    to PATH about once a second (flight recorder)
  worker        standalone scoring worker against a remote store
                  --store ADDR --worker-id I --workers N --model NAME
                  --n-examples N --seed N
  experiment    regenerate paper artefacts:
                  fig2|fig3|fig4|table1|staleness|asgd|adaptive|strategy-matrix|all
                  --seeds N --steps N --n-examples N --model NAME
                  --live-peers      asgd arms run the live threaded peer mode
                  --store-path DIR  (with --live-peers) durable store per arm under DIR
  metrics       scrape a live db-server's telemetry (counters, gauges,
                latency histograms with p50/p99)
                  issgd metrics 127.0.0.1:7070 [--format prom|json]
  plot          render a result CSV as a terminal chart
                  issgd plot results/fig4b_sqrt_trace.csv [--log-y] [--width N] [--height N]
  info          print manifest info for --model
Global: --log-level error|warn|info|debug|trace  --results DIR";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        issgd::log_error!("cli", "{e:#}");
        std::process::exit(1);
    }
}

fn value_opts() -> Vec<&'static str> {
    let mut opts = RunConfig::CLI_OPTS.to_vec();
    opts.extend([
        "log-level", "addr", "store", "store-path", "worker-id", "seeds", "results",
        "throttle-ms", "width", "height", "write-queue-mb", "telemetry-dump", "format",
    ]);
    opts
}

fn dispatch(argv: &[String]) -> Result<()> {
    let args = cli::parse(argv, &value_opts()).map_err(|e| anyhow::anyhow!(e))?;
    if let Some(level) = args.get("log-level") {
        logging::set_level(
            logging::level_from_str(level).with_context(|| format!("bad log level {level:?}"))?,
        );
    }
    if let Some(dir) = args.get("results") {
        std::env::set_var("ISSGD_RESULTS", dir);
    }
    let sub = match args.positional().first() {
        Some(s) => s.as_str(),
        None => {
            println!("{USAGE}");
            return Ok(());
        }
    };
    match sub {
        "train" => cmd_train(&args),
        "db-server" => cmd_db_server(&args),
        "worker" => cmd_worker(&args),
        "experiment" => cmd_experiment(&args),
        "metrics" => cmd_metrics(&args),
        "plot" => cmd_plot(&args),
        "info" => cmd_info(&args),
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

/// Open (or create) the durable store named by `--store-path`, sized for
/// `cfg`'s train split.  `None` when the flag is absent.
fn durable_from_args(args: &Args, cfg: &RunConfig) -> Result<Option<Arc<dyn WeightStore>>> {
    let Some(path) = args.get("store-path") else {
        return Ok(None);
    };
    let n_weights = issgd::coordinator::Master::store_size(cfg);
    let store = DurableStore::open_or_create(
        std::path::Path::new(path),
        n_weights,
        cfg.init_weight,
        Default::default(),
    )?;
    log_info!(
        "cli",
        "durable weight store at {path}: {n_weights} weights, write seq {}",
        store.write_seq()
    );
    let store: Arc<dyn WeightStore> = Arc::new(store);
    Ok(Some(store))
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = RunConfig::default().apply_args(args)?;
    // A durable on-disk store only makes sense with real actors, so
    // --store-path implies --live (the sims build their own in-memory
    // store for determinism).
    let live =
        args.flag("live") || args.get("store").is_some() || args.get("store-path").is_some();
    let peer = args.flag("peer");
    log_info!(
        "cli",
        "training: model={} trainer={:?} sync={:?} steps={} workers={} ({}{})",
        cfg.model,
        cfg.trainer,
        cfg.sync,
        cfg.steps,
        cfg.n_workers,
        if peer { "peer " } else { "" },
        if live { "live" } else { "sim" }
    );
    if peer {
        return cmd_train_peer(args, &cfg, live);
    }
    let outcome = if live {
        let opts = LiveOptions {
            store: durable_from_args(args, &cfg)?,
            store_addr: args.get("store").map(String::from),
            worker_throttle: match args.get_parse("throttle-ms", 0u64)? {
                0 => None,
                ms => Some(std::time::Duration::from_millis(ms)),
            },
            wait_for_first_scores: args.flag("wait"),
        };
        run_live(&cfg, &opts)?
    } else {
        run_sim(&cfg)?
    };
    let losses = outcome.rec.get("train_loss");
    let last = losses.last().map(|s| s.value).unwrap_or(f64::NAN);
    println!("steps:            {}", losses.len());
    println!("final train loss: {last:.4}");
    println!(
        "final err (train/valid/test): {:.4} / {:.4} / {:.4}",
        outcome.final_err.0, outcome.final_err.1, outcome.final_err.2
    );
    println!("examples scored by workers:   {}", outcome.scored);
    println!(
        "store ops: {} param pushes, {} weight pushes ({} weights), {} snapshots",
        outcome.store_stats.param_pushes,
        outcome.store_stats.weight_pushes,
        outcome.store_stats.weights_written,
        outcome.store_stats.snapshot_fetches
    );
    Ok(())
}

/// `train --peer`: the §6 peer/ASGD topology — deterministic round-robin
/// sim, or one OS thread per peer with `--live`.
fn cmd_train_peer(args: &Args, cfg: &RunConfig, live: bool) -> Result<()> {
    use issgd::coordinator::{run_asgd_sim, run_peer_live, PeerLiveOptions};
    use issgd::runtime::Engine;

    let outcome = if live {
        let opts = PeerLiveOptions {
            store: durable_from_args(args, cfg)?,
            store_addr: args.get("store").map(String::from),
            lockstep: args.flag("lockstep"),
            throttle: match args.get_parse("throttle-ms", 0u64)? {
                0 => None,
                ms => Some(std::time::Duration::from_millis(ms)),
            },
            deadline: None,
        };
        run_peer_live(cfg, &opts)?
    } else {
        let engine = Engine::load(&artifacts_dir(&cfg.model))?;
        run_asgd_sim(cfg, &engine)?
    };
    let losses = outcome.rec.get("train_loss");
    let last = losses.last().map(|s| s.value).unwrap_or(f64::NAN);
    println!("peer steps:       {}", outcome.total_peer_steps);
    println!("final train loss: {last:.4}");
    println!(
        "final err (train/valid/test): {:.4} / {:.4} / {:.4}",
        outcome.final_err.0, outcome.final_err.1, outcome.final_err.2
    );
    println!("final proposal ESS/N:         {:.4}", outcome.final_ess);
    println!(
        "store ops: {} grad applies, {} weight pushes ({} saved by coalescing)",
        outcome.store_stats.grad_applies,
        outcome.store_stats.weight_pushes,
        outcome.store_stats.push_calls_saved
    );
    for p in &outcome.peers {
        println!(
            "  peer {}: {} steps, {} store errors, cursor lag {}",
            p.id, p.steps, p.store_errors, p.cursor_lag
        );
    }
    Ok(())
}

fn cmd_db_server(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7070");
    let n = args.get_parse("n-examples", 4096usize)?;
    let init = args.get_parse("init-weight", 1.0f64)?;
    // The store tracks train-split weights only.
    let n_weights = issgd::coordinator::Master::store_size(&RunConfig {
        n_examples: n,
        ..RunConfig::default()
    });
    let store: Arc<dyn WeightStore> = match args.get("store-path") {
        Some(path) => {
            let d = DurableStore::open_or_create(
                std::path::Path::new(path),
                n_weights,
                init,
                Default::default(),
            )?;
            log_info!(
                "db",
                "durable store at {path}: write seq {}, floor {}",
                d.write_seq(),
                d.compact_floor()
            );
            Arc::new(d)
        }
        None => Arc::new(MemStore::new(n_weights, init)),
    };
    let mut opts = issgd::weightstore::server::ServerOptions::default();
    // Slow-client eviction cap for the event loop (MiB of queued
    // responses per connection); 0 picks the default.
    match args.get_parse("write-queue-mb", 0usize)? {
        0 => {}
        mb => opts.max_write_queue = mb << 20,
    }
    opts.telemetry_dump = args.get("telemetry-dump").map(std::path::PathBuf::from);
    let server = Server::bind_with_options(addr, store, opts)?;
    log_info!(
        "db",
        "weight store listening on {} ({n_weights} weights)",
        server.local_addr()?
    );
    server.serve()
}

/// Scrape a live db-server's telemetry registry (`FetchMetrics` opcode)
/// and print it as a Prometheus-style exposition (default) or pretty
/// JSON (`--format json`).
fn cmd_metrics(args: &Args) -> Result<()> {
    let addr = match args.positional().get(1) {
        Some(a) => a.as_str(),
        None => args.require("store").map_err(|e| anyhow::anyhow!(e))?,
    };
    let client = issgd::weightstore::client::Client::connect(addr)?;
    let text = client.fetch_metrics()?;
    match args.get_or("format", "prom") {
        "prom" => {
            let snap = issgd::telemetry::Snapshot::from_json_str(&text)?;
            print!("{}", snap.to_prometheus());
        }
        "json" => {
            let parsed = issgd::util::json::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("bad metrics payload: {e}"))?;
            println!("{}", parsed.to_pretty());
        }
        other => bail!("unknown metrics format {other:?} (expected prom|json)"),
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    use issgd::coordinator::WorkerState;
    use issgd::data::{shards, split_indices, SplitSpec, SynthDataset, SynthSpec};
    use issgd::runtime::Engine;
    use std::sync::atomic::AtomicBool;

    let addr = args.require("store").map_err(|e| anyhow::anyhow!(e))?;
    let cfg = RunConfig::default().apply_args(args)?;
    let worker_id = args.get_parse("worker-id", 0usize)?;
    anyhow::ensure!(worker_id < cfg.n_workers, "worker-id out of range");

    let score = cfg.strategy.score_source();
    let engine = Engine::load_entries(&artifacts_dir(&cfg.model), &[score.required_entry()])?;
    let manifest = engine.manifest().clone();
    let spec = if manifest.input_dim == 64 {
        SynthSpec::tiny(cfg.n_examples)
    } else {
        SynthSpec {
            dim: manifest.input_dim,
            ..SynthSpec::svhn_like(cfg.n_examples)
        }
    };
    let data = Arc::new(SynthDataset::generate(cfg.seed, spec));
    let (train_idx, _, _) = split_indices(cfg.n_examples, SplitSpec::default());
    let shard = shards(train_idx.len(), cfg.n_workers)[worker_id];
    // A pool (even for one logical worker) so delta fetches coalesce with
    // any in-process helpers and a poisoned connection heals transparently.
    let store = Arc::new(issgd::weightstore::client::ClientPool::new(addr, 2));
    store.now().context("store unreachable")?;
    log_info!(
        "worker",
        "worker {worker_id}/{} scoring shard {}..{} against {addr}",
        cfg.n_workers,
        shard.start,
        shard.end
    );
    let mut w = WorkerState::new_with_score(
        worker_id,
        shard,
        &manifest,
        data,
        Arc::new(train_idx),
        store,
        score,
    );
    let stop = AtomicBool::new(false); // runs until killed
    w.run_live(&engine, &stop, None)
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional()
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let mut scale = ExperimentScale::default();
    scale.seeds = args.get_parse("seeds", scale.seeds)?;
    scale.steps = args.get_parse("steps", scale.steps)?;
    scale.n_examples = args.get_parse("n-examples", scale.n_examples)?;
    if let Some(m) = args.get("model") {
        scale.model = m.to_string();
    }
    scale.live_peers = args.flag("live-peers");
    scale.store_path = args.get("store-path").map(String::from);
    if scale.store_path.is_some() && !scale.live_peers {
        issgd::log_warn!(
            "exp",
            "--store-path only backs the --live-peers asgd arms; the deterministic sims \
             use in-memory stores and will NOT touch it"
        );
    }
    log_info!(
        "exp",
        "experiment {which}: model={} seeds={} steps={} n={}{}",
        scale.model,
        scale.seeds,
        scale.steps,
        scale.n_examples,
        if scale.live_peers { " (live peers)" } else { "" }
    );
    match which {
        "fig2" => {
            experiments::fig2::run(&scale)?;
        }
        "fig3" => experiments::fig3::run(&scale)?,
        "fig4" => experiments::fig4::run(&scale)?,
        "table1" => {
            experiments::table1::run(&scale)?;
        }
        "staleness" => experiments::staleness::run(&scale)?,
        "asgd" => {
            experiments::asgd::run(&scale)?;
        }
        "adaptive" => {
            experiments::adaptive::run(&scale)?;
        }
        "strategy-matrix" => {
            experiments::strategy_matrix::run(&scale)?;
        }
        "all" => {
            // fig2/fig3/table1 share the four settings runs.
            let engine = experiments::runner::engine_for(&scale)?;
            let runs = experiments::fig2::run_settings(&scale, &engine)?;
            experiments::fig2::emit(&runs)?;
            experiments::fig3::emit(&runs)?;
            experiments::table1::emit(&runs)?;
            experiments::fig4::run(&scale)?;
            experiments::staleness::run(&scale)?;
            experiments::asgd::run(&scale)?;
            experiments::adaptive::run(&scale)?;
            experiments::strategy_matrix::run(&scale)?;
        }
        other => bail!(
            "unknown experiment {other:?} \
             (fig2|fig3|fig4|table1|staleness|asgd|adaptive|strategy-matrix|all)"
        ),
    }
    println!("CSVs written to {}", experiments::results_dir().display());
    Ok(())
}

fn cmd_plot(args: &Args) -> Result<()> {
    use issgd::util::csv::Table;
    use issgd::util::plot::{render, PlotOptions, Series};

    let path = args
        .positional()
        .get(1)
        .context("usage: issgd plot <file.csv> [--log-y]")?;
    let table = Table::load(std::path::Path::new(path))?;
    let steps = table
        .column("step")
        .context("CSV has no 'step' column")?
        .to_vec();
    // Plot every *_median column (the quartile CSVs), else every non-step
    // numeric column.
    let mut names = table.columns_with_suffix("_median");
    if names.is_empty() {
        names = table
            .columns
            .iter()
            .filter(|c| *c != "step")
            .map(String::as_str)
            .collect();
    }
    let series: Vec<Series> = names
        .iter()
        .map(|name| Series {
            name: name.trim_end_matches("_median").to_string(),
            xs: steps.clone(),
            ys: table.column(name).unwrap().to_vec(),
        })
        .collect();
    let opts = PlotOptions {
        width: args.get_parse("width", 72usize)?,
        height: args.get_parse("height", 20usize)?,
        title: path.to_string(),
        log_y: args.flag("log-y"),
    };
    print!("{}", render(&series, &opts));
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let model = args.get_or("model", "small");
    let dir = artifacts_dir(model);
    let m = Manifest::load(&dir)?;
    println!("config:       {}", m.config);
    println!("artifacts:    {}", dir.display());
    println!("dims:         {:?}", m.dims);
    println!("n_params:     {}", m.n_params);
    println!(
        "batches:      train {}, score {}, eval {}",
        m.batch_train, m.batch_score, m.batch_eval
    );
    for (name, file) in &m.artifacts {
        println!("  entry point {name}: {file}");
    }
    Ok(())
}
