//! Micro/macro-benchmark harness (criterion is unavailable offline).
//!
//! analyze: allow-module(wallclock): a benchmark harness times wall clock
//!
//! Usage in a `[[bench]] harness = false` target:
//!
//! ```ignore
//! let mut h = Harness::from_env("sampler");
//! h.bench("fenwick/sample/4096", || { ... });
//! h.finish();
//! ```
//!
//! Reports min / median / mean / p95 over timed samples after a warmup,
//! criterion-style, plus optional throughput.  `--quick` (or env
//! `ISSGD_BENCH_QUICK=1`) shrinks budgets so `cargo bench` stays usable on
//! a single-core box.
//!
//! `--json <path>` additionally **appends** one JSON object per benchmark
//! (JSON-lines, so several bench binaries sharing one invocation — e.g.
//! `cargo bench --bench sampler --bench weightstore -- --json out.json` —
//! accumulate into a single machine-readable file).  CI uploads it as a
//! perf-trajectory artifact.  Fields: `group`, `name`, `samples`,
//! `min_ns`/`median_ns`/`mean_ns`/`p95_ns`/`p99_ns`, and `items_per_sec`
//! when throughput was declared.
//!
//! Benchmarks that collect their own latency samples (e.g. per-operation
//! timings gathered across many client threads in the connection-scale
//! bench) feed them in through [`Harness::record_samples`], which reuses
//! the same stats/printing/JSON pipeline without the harness driving the
//! timing loop.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub p99: Duration,
    /// items/sec if throughput was declared.
    pub throughput: Option<f64>,
}

pub struct Harness {
    group: String,
    /// Per-benchmark wall budget.
    budget: Duration,
    max_samples: usize,
    results: Vec<BenchResult>,
    /// Append results here as JSON lines on `finish` (from `--json`).
    json_path: Option<PathBuf>,
}

impl Harness {
    pub fn new(group: &str, budget: Duration, max_samples: usize) -> Harness {
        println!("\n== bench group: {group} ==");
        Harness {
            group: group.to_string(),
            budget,
            max_samples,
            results: Vec::new(),
            json_path: None,
        }
    }

    /// Budgets from argv/env: default 2 s per benchmark, `--quick` = 0.3 s;
    /// `--json <path>` selects the machine-readable sink (module docs).
    pub fn from_env(group: &str) -> Harness {
        let argv: Vec<String> = std::env::args().collect();
        let quick = argv.iter().any(|a| a == "--quick")
            || std::env::var("ISSGD_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
        let mut h = if quick {
            Self::new(group, Duration::from_millis(300), 20)
        } else {
            Self::new(group, Duration::from_secs(2), 60)
        };
        h.json_path = argv
            .iter()
            .position(|a| a == "--json")
            .and_then(|i| argv.get(i + 1))
            .map(PathBuf::from);
        h
    }

    /// Route `finish` output to a JSON-lines file (the `--json` flag does
    /// this for `from_env` harnesses).
    pub fn with_json(mut self, path: &Path) -> Harness {
        self.json_path = Some(path.to_path_buf());
        self
    }

    /// Time `f` repeatedly; report stats.  Returns the result for callers
    /// that assert on regressions.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> BenchResult {
        self.bench_with_throughput(name, None, &mut f)
    }

    /// Like [`Harness::bench`] but records `items` processed per call so
    /// the report shows items/sec.
    pub fn bench_throughput(
        &mut self,
        name: &str,
        items: u64,
        mut f: impl FnMut(),
    ) -> BenchResult {
        self.bench_with_throughput(name, Some(items), &mut f)
    }

    fn bench_with_throughput(
        &mut self,
        name: &str,
        items: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> BenchResult {
        // Warmup: 2 calls or 10% of budget, whichever first.
        let warm_deadline = Instant::now() + self.budget / 10;
        for _ in 0..2 {
            f();
            if Instant::now() > warm_deadline {
                break;
            }
        }
        let mut samples: Vec<Duration> = Vec::new();
        let deadline = Instant::now() + self.budget;
        while samples.len() < self.max_samples
            && (samples.len() < 5 || Instant::now() < deadline)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        self.push_result(name, samples, items)
    }

    /// Fold externally-collected per-operation latency samples into the
    /// report — for benchmarks where the harness cannot drive the timing
    /// loop itself (e.g. many client threads each timing their own store
    /// round-trips).  `items` is the work per *sample* (usually 1 for
    /// per-op latencies), reported as items/sec against the mean.
    pub fn record_samples(
        &mut self,
        name: &str,
        samples: &[Duration],
        items: Option<u64>,
    ) -> BenchResult {
        assert!(!samples.is_empty(), "record_samples needs at least one sample");
        self.push_result(name, samples.to_vec(), items)
    }

    fn push_result(
        &mut self,
        name: &str,
        mut samples: Vec<Duration>,
        items: Option<u64>,
    ) -> BenchResult {
        samples.sort();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        let result = BenchResult {
            name: format!("{}/{}", self.group, name),
            samples: n,
            min: samples[0],
            median: samples[n / 2],
            mean,
            p95: samples[(n * 95 / 100).min(n - 1)],
            p99: samples[(n * 99 / 100).min(n - 1)],
            throughput: items.map(|i| i as f64 / mean.as_secs_f64()),
        };
        print_result(&result);
        self.results.push(result.clone());
        result
    }

    /// Print the closing summary (call last) and, with a JSON sink
    /// configured, append the machine-readable results.
    pub fn finish(self) -> Vec<BenchResult> {
        println!("== {} done: {} benchmarks ==", self.group, self.results.len());
        if let Some(path) = &self.json_path {
            if let Err(e) = append_json(path, &self.group, &self.results) {
                crate::log_warn!("bench", "could not write {}: {e}", path.display());
            } else {
                println!("== {} results appended to {} ==", self.group, path.display());
            }
        }
        self.results
    }
}

fn append_json(path: &Path, group: &str, results: &[BenchResult]) -> anyhow::Result<()> {
    use crate::util::json::Json;
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    for r in results {
        let mut pairs = vec![
            ("group", Json::Str(group.to_string())),
            ("name", Json::Str(r.name.clone())),
            ("samples", Json::Num(r.samples as f64)),
            ("min_ns", Json::Num(r.min.as_nanos() as f64)),
            ("median_ns", Json::Num(r.median.as_nanos() as f64)),
            ("mean_ns", Json::Num(r.mean.as_nanos() as f64)),
            ("p95_ns", Json::Num(r.p95.as_nanos() as f64)),
            ("p99_ns", Json::Num(r.p99.as_nanos() as f64)),
        ];
        if let Some(tp) = r.throughput {
            pairs.push(("items_per_sec", Json::Num(tp)));
        }
        writeln!(f, "{}", Json::obj(pairs).to_string())?;
    }
    Ok(())
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:8.3} s ")
    } else if s >= 1e-3 {
        format!("{:8.3} ms", s * 1e3)
    } else {
        format!("{:8.3} µs", s * 1e6)
    }
}

fn print_result(r: &BenchResult) {
    let tp = match r.throughput {
        Some(t) if t >= 1e6 => format!("  {:9.2} Mitems/s", t / 1e6),
        Some(t) if t >= 1e3 => format!("  {:9.2} Kitems/s", t / 1e3),
        Some(t) => format!("  {t:9.2} items/s"),
        None => String::new(),
    };
    println!(
        "{:<48} min {}  med {}  mean {}  p95 {}  p99 {}  (n={}){tp}",
        r.name,
        fmt_dur(r.min),
        fmt_dur(r.median),
        fmt_dur(r.mean),
        fmt_dur(r.p95),
        fmt_dur(r.p99),
        r.samples
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut h = Harness::new("test", Duration::from_millis(50), 10);
        let r = h.bench("sleep", || std::thread::sleep(Duration::from_micros(200)));
        assert!(r.samples >= 5);
        assert!(r.min >= Duration::from_micros(200));
        assert!(r.min <= r.median && r.median <= r.p95 && r.p95 <= r.p99);
        let r2 = h.bench_throughput("tp", 1000, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r2.throughput.unwrap() > 0.0);
        assert_eq!(h.finish().len(), 2);
    }

    #[test]
    fn record_samples_matches_driven_stats() {
        let mut h = Harness::new("test", Duration::from_millis(10), 5);
        // 100 samples 1..=100 ms: median = 51st, p95 = 96th, p99 = 100th.
        let samples: Vec<Duration> =
            (1..=100u64).map(Duration::from_millis).collect();
        let r = h.record_samples("external", &samples, Some(1));
        assert_eq!(r.samples, 100);
        assert_eq!(r.min, Duration::from_millis(1));
        assert_eq!(r.median, Duration::from_millis(51));
        assert_eq!(r.p95, Duration::from_millis(96));
        assert_eq!(r.p99, Duration::from_millis(100));
        assert!(r.throughput.unwrap() > 0.0);
        assert_eq!(h.finish().len(), 1);
    }

    #[test]
    fn json_sink_appends_parseable_lines() {
        use crate::util::json::Json;
        let path = std::env::temp_dir().join(format!("issgd-bench-json-{}", std::process::id()));
        let _ = std::fs::remove_file(&path);
        // Two groups appending to one file, like two bench binaries in one
        // `cargo bench -- --json` invocation.
        for group in ["g1", "g2"] {
            let mut h =
                Harness::new(group, Duration::from_millis(20), 5).with_json(&path);
            h.bench_throughput("op", 10, || {
                std::hint::black_box(1 + 1);
            });
            h.finish();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for (line, group) in lines.iter().zip(["g1", "g2"]) {
            let v = Json::parse(line).unwrap();
            assert_eq!(v.req_str("group").unwrap(), group);
            assert!(v.req_f64("median_ns").unwrap() >= 0.0);
            assert!(v.req_f64("p99_ns").unwrap() >= 0.0);
            assert!(v.req_f64("items_per_sec").unwrap() > 0.0);
            assert!(v.req_str("name").unwrap().starts_with(group));
        }
        let _ = std::fs::remove_file(&path);
    }
}
