//! TCP client layer: [`Client`] (one connection) and [`ClientPool`] (a
//! bounded set of connections shared by many actors), both implementing
//! [`WeightStore`] against a remote server.
//!
//! ## Connection discipline (`Client`)
//!
//! A `Client` owns at most one `TcpStream` behind a mutex.  Every call is
//! strictly request/response on the wire, and two failure modes that used
//! to be silent are now handled explicitly:
//!
//! - **Desync poisoning.**  If any frame-level error occurs mid-call
//!   (write failed, read timed out, response undecodable), the stream may
//!   have a partial frame in flight — pairing the *next* request with
//!   those stale bytes would hand the caller another call's answer.  The
//!   connection is therefore poisoned (dropped) on any frame-level error;
//!   the next call transparently reconnects with bounded exponential
//!   backoff.  A failed call is *never* retried automatically: requests
//!   like `ApplyGrad` are not idempotent, and the caller (workers already
//!   count `store_errors`) owns the retry decision.
//! - **Timeouts.**  Connect, read, and write all carry configurable
//!   timeouts ([`ClientOptions`]), so a hung or dead server surfaces as an
//!   error instead of blocking an actor forever.
//!
//! `Response::Err` — a server-side *request* error on a healthy framed
//! stream — does not poison the connection.
//!
//! ## Pooling (`ClientPool`)
//!
//! `ClientPool` keeps up to `max_conns` lazily-created `Client`s and
//! checks one out per call, so any number of threads can share one pool
//! handle without serializing on a single socket.  Poisoned connections
//! heal themselves on next checkout via the `Client` reconnect path.
//! `fetch_weights_since` additionally *coalesces*: concurrent callers
//! behind the same cursor share one in-flight fetch and all receive its
//! (cloned) result — N maintainers polling the same sequence floor cost
//! one round-trip, not N.
use std::collections::BTreeMap;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::protocol::{read_frame, write_frame, Request, Response};
use super::{ParamsDelta, StoreStats, WeightDelta, WeightSnapshot, WeightStore};

/// Timeout/backoff knobs for [`Client`] (and, via [`ClientPool`], every
/// pooled connection).
#[derive(Debug, Clone)]
pub struct ClientOptions {
    /// TCP connect timeout per address attempt.
    pub connect_timeout: Duration,
    /// Read *and* write timeout per syscall.  Applies per `read`/`write`
    /// call, so a slowly-streaming but live server keeps resetting it; a
    /// fully hung one errors out within one period.
    pub io_timeout: Duration,
    /// Connection attempts per (re)connect before giving up on a call.
    pub connect_attempts: u32,
    /// Backoff before the 2nd connection attempt; doubles per attempt.
    pub backoff_base: Duration,
    /// Ceiling on the exponential backoff.
    pub backoff_cap: Duration,
}

impl Default for ClientOptions {
    fn default() -> ClientOptions {
        ClientOptions {
            connect_timeout: Duration::from_secs(5),
            io_timeout: Duration::from_secs(30),
            connect_attempts: 3,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_secs(2),
        }
    }
}

pub struct Client {
    addr: String,
    opts: ClientOptions,
    /// `None` = not connected (never connected, or poisoned by a
    /// frame-level error).  The next call reconnects.
    stream: Mutex<Option<TcpStream>>,
    /// Whether this client has ever held a live connection — separates a
    /// lazy first dial from a genuine *re*connect in `client.reconnects`.
    ever_connected: AtomicBool,
}

impl Client {
    /// Connect eagerly with default options (bad addresses fail here, not
    /// on first use).
    pub fn connect(addr: &str) -> Result<Client> {
        Client::connect_with(addr, ClientOptions::default())
    }

    /// Connect eagerly with explicit options.
    pub fn connect_with(addr: &str, opts: ClientOptions) -> Result<Client> {
        let stream = Client::open(addr, &opts)?;
        Ok(Client {
            addr: addr.to_string(),
            opts,
            stream: Mutex::new(Some(stream)),
            ever_connected: AtomicBool::new(true),
        })
    }

    /// Create without connecting; the first call dials.  Used by
    /// [`ClientPool`] so checkout never blocks on the network.
    pub fn lazy(addr: &str, opts: ClientOptions) -> Client {
        Client {
            addr: addr.to_string(),
            opts,
            stream: Mutex::new(None),
            ever_connected: AtomicBool::new(false),
        }
    }

    /// One TCP dial honoring `connect_timeout`, with per-syscall i/o
    /// timeouts installed on the resulting stream.
    fn open(addr: &str, opts: &ClientOptions) -> Result<TcpStream> {
        let mut last: Option<std::io::Error> = None;
        for sockaddr in addr
            .to_socket_addrs()
            .with_context(|| format!("resolving store address {addr}"))?
        {
            match TcpStream::connect_timeout(&sockaddr, opts.connect_timeout) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    stream.set_read_timeout(Some(opts.io_timeout)).ok();
                    stream.set_write_timeout(Some(opts.io_timeout)).ok();
                    return Ok(stream);
                }
                Err(e) => last = Some(e),
            }
        }
        match last {
            Some(e) => Err(e).with_context(|| format!("connecting to store at {addr}")),
            None => Err(anyhow!("store address {addr} resolved to nothing")),
        }
    }

    /// Dial with bounded exponential backoff between attempts.
    fn open_with_backoff(addr: &str, opts: &ClientOptions) -> Result<TcpStream> {
        let mut backoff = opts.backoff_base;
        let mut attempt = 0u32;
        loop {
            match Client::open(addr, opts) {
                Ok(stream) => return Ok(stream),
                Err(e) => {
                    attempt += 1;
                    if attempt >= opts.connect_attempts.max(1) {
                        return Err(e).with_context(|| {
                            format!("giving up after {attempt} connection attempts")
                        });
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(opts.backoff_cap);
                }
            }
        }
    }

    fn call(&self, req: Request) -> Result<Response> {
        let mut guard = self.stream.lock().unwrap();
        if guard.is_none() {
            // Reconnect after poisoning (or first use of a lazy client).
            // The mutex is held through the backoff: concurrent callers
            // would only race to dial the same dead server.
            *guard = Some(Client::open_with_backoff(&self.addr, &self.opts)?);
            if self.ever_connected.swap(true, Ordering::Relaxed) {
                crate::telemetry::counter("client.reconnects").inc();
            }
        }
        let stream = guard
            .as_mut()
            .context("store connection unavailable after reconnect")?;
        let exchanged: Result<Response> = (|| {
            write_frame(stream, &req.encode())?;
            let frame = read_frame(stream)?;
            Response::decode(&frame)
        })();
        match exchanged {
            // A decoded response means the stream is still framed
            // correctly; `Response::Err` surfaces via into_result
            // without poisoning.
            Ok(resp) => resp.into_result(),
            Err(e) => {
                // Frame-level failure: a partial frame may be in flight
                // either direction, so this stream can never be trusted
                // to pair requests with responses again.
                *guard = None;
                crate::telemetry::counter("client.protocol_errors").inc();
                Err(e).context("store connection poisoned (will reconnect on next call)")
            }
        }
    }

    /// Ask the remote server to stop accepting connections.
    pub fn shutdown_server(&self) -> Result<()> {
        match self.call(Request::Shutdown)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response to shutdown: {other:?}"),
        }
    }

    /// Scrape the server's telemetry registry; returns the snapshot as
    /// `util::json` text (`telemetry::Snapshot::from_json_str` parses it).
    pub fn fetch_metrics(&self) -> Result<String> {
        match self.call(Request::FetchMetrics)? {
            Response::Metrics(text) => Ok(text),
            other => bail!("unexpected response to metrics scrape: {other:?}"),
        }
    }
}

impl WeightStore for Client {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<()> {
        match self.call(Request::PushParams { version, bytes })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn fetch_params(&self, than: u64) -> Result<Option<(u64, Vec<u8>)>> {
        match self.call(Request::FetchParams { than })? {
            Response::Params(p) => Ok(p),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn push_params_layers(
        &self,
        version: u64,
        full: bool,
        layers: &[(String, Vec<u8>)],
    ) -> Result<()> {
        match self.call(Request::PushParamsLayers {
            version,
            full,
            layers: layers.to_vec(),
        })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn fetch_params_since(&self, than: u64) -> Result<Option<ParamsDelta>> {
        match self.call(Request::FetchParamsSince { than })? {
            Response::ParamsDelta(d) => Ok(d),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn params_version(&self) -> Result<u64> {
        match self.call(Request::ParamsVersion)? {
            Response::Version(v) => Ok(v),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn push_weights(&self, start: usize, weights: &[f32], param_version: u64) -> Result<()> {
        match self.call(Request::PushWeights {
            start: start as u64,
            param_version,
            weights: weights.to_vec(),
        })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn fetch_weights(&self) -> Result<WeightSnapshot> {
        match self.call(Request::FetchWeights)? {
            Response::Weights(snap) => Ok(snap),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn fetch_weights_since(&self, seq: u64) -> Result<WeightDelta> {
        match self.call(Request::FetchWeightsSince { seq })? {
            Response::WeightsDelta(delta) => Ok(delta),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn apply_grad(&self, scale: f32, grad: &[f32]) -> Result<u64> {
        match self.call(Request::ApplyGrad {
            scale,
            grad: grad.to_vec(),
        })? {
            Response::Version(v) => Ok(v),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn save_cursor(&self, name: &str, seq: u64) -> Result<()> {
        match self.call(Request::SaveCursor {
            name: name.to_string(),
            seq,
        })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn load_cursor(&self, name: &str) -> Result<Option<u64>> {
        match self.call(Request::LoadCursor {
            name: name.to_string(),
        })? {
            Response::Cursor(c) => Ok(c),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn drop_cursor(&self, name: &str) -> Result<()> {
        match self.call(Request::DropCursor {
            name: name.to_string(),
        })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn now(&self) -> Result<u64> {
        match self.call(Request::Now)? {
            Response::Now(t) => Ok(t),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn stats(&self) -> Result<StoreStats> {
        match self.call(Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => bail!("unexpected response: {other:?}"),
        }
    }
}

/// One coalesced `fetch_weights_since` in flight: the leader publishes
/// the result here; followers wait on the condvar and clone it.  The
/// error arm is a `String` because `anyhow::Error` is not `Clone`.
struct FetchFlight {
    done: Mutex<Option<std::result::Result<WeightDelta, String>>>,
    cv: Condvar,
}

/// A bounded pool of [`Client`] connections sharing one server address.
///
/// Cloneable-by-`Arc` and safe to hand to every actor in a process: each
/// call checks a connection out (waiting if all `max_conns` are busy),
/// runs exactly one request/response on it, and checks it back in.
/// Connections are created lazily up to the cap and heal from poisoning
/// transparently.  See the module docs for the coalescing contract.
pub struct ClientPool {
    addr: String,
    opts: ClientOptions,
    max_conns: usize,
    /// Checked-in connections.  Paired with `available` for checkout
    /// waits.
    idle: Mutex<Vec<Client>>,
    available: Condvar,
    /// Connections in existence (idle + checked out); bounded by
    /// `max_conns`.
    live: AtomicUsize,
    /// In-flight coalesced fetches keyed by cursor sequence.
    inflight: Mutex<BTreeMap<u64, std::sync::Arc<FetchFlight>>>,
}

impl ClientPool {
    /// Pool against `addr` with default per-connection options.
    /// `max_conns` is clamped to ≥ 1.
    pub fn new(addr: &str, max_conns: usize) -> ClientPool {
        ClientPool::with_options(addr, max_conns, ClientOptions::default())
    }

    pub fn with_options(addr: &str, max_conns: usize, opts: ClientOptions) -> ClientPool {
        ClientPool {
            addr: addr.to_string(),
            opts,
            max_conns: max_conns.max(1),
            idle: Mutex::new(Vec::new()),
            available: Condvar::new(),
            live: AtomicUsize::new(0),
            inflight: Mutex::new(BTreeMap::new()),
        }
    }

    /// Take a connection: an idle one, a freshly created one while under
    /// the cap, or block until a peer checks one in.
    fn checkout(&self) -> Client {
        let mut idle = self.idle.lock().unwrap();
        loop {
            if let Some(client) = idle.pop() {
                return client;
            }
            if self.live.load(Ordering::SeqCst) < self.max_conns {
                self.live.fetch_add(1, Ordering::SeqCst);
                // Lazy: no network under the lock; the call itself dials.
                return Client::lazy(&self.addr, self.opts.clone());
            }
            idle = self.available.wait(idle).unwrap();
        }
    }

    fn checkin(&self, client: Client) {
        self.idle.lock().unwrap().push(client);
        self.available.notify_one();
    }

    /// Run `f` with a checked-out connection; always checks back in
    /// (poisoned connections self-heal on their next use).
    fn with_conn<T>(&self, f: impl FnOnce(&Client) -> Result<T>) -> Result<T> {
        let client = self.checkout();
        let result = f(&client);
        self.checkin(client);
        result
    }
}

impl WeightStore for ClientPool {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<()> {
        self.with_conn(|c| c.push_params(version, bytes))
    }

    fn fetch_params(&self, than: u64) -> Result<Option<(u64, Vec<u8>)>> {
        self.with_conn(|c| c.fetch_params(than))
    }

    fn push_params_layers(
        &self,
        version: u64,
        full: bool,
        layers: &[(String, Vec<u8>)],
    ) -> Result<()> {
        self.with_conn(|c| c.push_params_layers(version, full, layers))
    }

    fn fetch_params_since(&self, than: u64) -> Result<Option<ParamsDelta>> {
        self.with_conn(|c| c.fetch_params_since(than))
    }

    fn params_version(&self) -> Result<u64> {
        self.with_conn(|c| c.params_version())
    }

    fn push_weights(&self, start: usize, weights: &[f32], param_version: u64) -> Result<()> {
        self.with_conn(|c| c.push_weights(start, weights, param_version))
    }

    fn fetch_weights(&self) -> Result<WeightSnapshot> {
        self.with_conn(|c| c.fetch_weights())
    }

    /// Coalesced: concurrent callers behind the same `seq` share one
    /// round-trip.  The leader (first caller for a given seq) performs
    /// the fetch; followers block on the flight and clone its result.
    /// Correctness note: a follower may receive a delta computed slightly
    /// *after* it asked — that is the same read the leader got, and any
    /// delta for `seq` taken at-or-after call time satisfies the cursor
    /// contract (consumers advance to `delta.to` and re-poll).
    fn fetch_weights_since(&self, seq: u64) -> Result<WeightDelta> {
        enum Role {
            Leader(std::sync::Arc<FetchFlight>),
            Follower(std::sync::Arc<FetchFlight>),
        }
        let role = {
            let mut inflight = self.inflight.lock().unwrap();
            match inflight.get(&seq) {
                Some(flight) => Role::Follower(std::sync::Arc::clone(flight)),
                None => {
                    let flight = std::sync::Arc::new(FetchFlight {
                        done: Mutex::new(None),
                        cv: Condvar::new(),
                    });
                    inflight.insert(seq, std::sync::Arc::clone(&flight));
                    Role::Leader(flight)
                }
            }
            // inflight guard drops here — never held across the network
            // call or the flight's own lock.
        };
        match role {
            Role::Leader(flight) => {
                let result = self.with_conn(|c| c.fetch_weights_since(seq));
                {
                    let mut done = flight.done.lock().unwrap();
                    *done = Some(match &result {
                        Ok(delta) => Ok(delta.clone()),
                        Err(e) => Err(format!("{e:#}")),
                    });
                }
                flight.cv.notify_all();
                self.inflight.lock().unwrap().remove(&seq);
                result
            }
            Role::Follower(flight) => {
                crate::telemetry::counter("pool.coalesced_fetches").inc();
                let mut done = flight.done.lock().unwrap();
                while done.is_none() {
                    done = flight.cv.wait(done).unwrap();
                }
                match done.as_ref() {
                    Some(Ok(delta)) => Ok(delta.clone()),
                    Some(Err(e)) => Err(anyhow!("coalesced fetch failed: {e}")),
                    // The wait loop above only exits on Some; answer a
                    // (can't-happen) bare wakeup with an error, not a panic.
                    None => Err(anyhow!("coalesced fetch signaled without a result")),
                }
            }
        }
    }

    fn apply_grad(&self, scale: f32, grad: &[f32]) -> Result<u64> {
        self.with_conn(|c| c.apply_grad(scale, grad))
    }

    fn save_cursor(&self, name: &str, seq: u64) -> Result<()> {
        self.with_conn(|c| c.save_cursor(name, seq))
    }

    fn load_cursor(&self, name: &str) -> Result<Option<u64>> {
        self.with_conn(|c| c.load_cursor(name))
    }

    fn drop_cursor(&self, name: &str) -> Result<()> {
        self.with_conn(|c| c.drop_cursor(name))
    }

    fn now(&self) -> Result<u64> {
        self.with_conn(|c| c.now())
    }

    fn stats(&self) -> Result<StoreStats> {
        self.with_conn(|c| c.stats())
    }
}
