//! TCP client: a [`WeightStore`] implementation backed by a remote server.
//!
//! One `TcpStream` per client, requests are strictly request/response, and
//! the stream sits behind a `Mutex` so a client handle can be shared across
//! threads (each actor normally owns its own client, though — connections
//! are cheap at this scale).

use std::net::TcpStream;
use std::sync::Mutex;

use anyhow::{bail, Result};

use super::protocol::{read_frame, write_frame, Request, Response};
use super::{ParamsDelta, StoreStats, WeightDelta, WeightSnapshot, WeightStore};

pub struct Client {
    stream: Mutex<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            stream: Mutex::new(stream),
        })
    }

    fn call(&self, req: Request) -> Result<Response> {
        let mut stream = self.stream.lock().unwrap();
        write_frame(&mut *stream, &req.encode())?;
        let frame = read_frame(&mut *stream)?;
        Response::decode(&frame)?.into_result()
    }

    /// Ask the remote server to stop accepting connections.
    pub fn shutdown_server(&self) -> Result<()> {
        match self.call(Request::Shutdown)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response to shutdown: {other:?}"),
        }
    }
}

impl WeightStore for Client {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<()> {
        match self.call(Request::PushParams { version, bytes })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn fetch_params(&self, than: u64) -> Result<Option<(u64, Vec<u8>)>> {
        match self.call(Request::FetchParams { than })? {
            Response::Params(p) => Ok(p),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn push_params_layers(
        &self,
        version: u64,
        full: bool,
        layers: &[(String, Vec<u8>)],
    ) -> Result<()> {
        match self.call(Request::PushParamsLayers {
            version,
            full,
            layers: layers.to_vec(),
        })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn fetch_params_since(&self, than: u64) -> Result<Option<ParamsDelta>> {
        match self.call(Request::FetchParamsSince { than })? {
            Response::ParamsDelta(d) => Ok(d),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn params_version(&self) -> Result<u64> {
        match self.call(Request::ParamsVersion)? {
            Response::Version(v) => Ok(v),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn push_weights(&self, start: usize, weights: &[f32], param_version: u64) -> Result<()> {
        match self.call(Request::PushWeights {
            start: start as u64,
            param_version,
            weights: weights.to_vec(),
        })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn fetch_weights(&self) -> Result<WeightSnapshot> {
        match self.call(Request::FetchWeights)? {
            Response::Weights(snap) => Ok(snap),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn fetch_weights_since(&self, seq: u64) -> Result<WeightDelta> {
        match self.call(Request::FetchWeightsSince { seq })? {
            Response::WeightsDelta(delta) => Ok(delta),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn apply_grad(&self, scale: f32, grad: &[f32]) -> Result<u64> {
        match self.call(Request::ApplyGrad {
            scale,
            grad: grad.to_vec(),
        })? {
            Response::Version(v) => Ok(v),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn save_cursor(&self, name: &str, seq: u64) -> Result<()> {
        match self.call(Request::SaveCursor {
            name: name.to_string(),
            seq,
        })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn load_cursor(&self, name: &str) -> Result<Option<u64>> {
        match self.call(Request::LoadCursor {
            name: name.to_string(),
        })? {
            Response::Cursor(c) => Ok(c),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn drop_cursor(&self, name: &str) -> Result<()> {
        match self.call(Request::DropCursor {
            name: name.to_string(),
        })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn now(&self) -> Result<u64> {
        match self.call(Request::Now)? {
            Response::Now(t) => Ok(t),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    fn stats(&self) -> Result<StoreStats> {
        match self.call(Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => bail!("unexpected response: {other:?}"),
        }
    }
}
