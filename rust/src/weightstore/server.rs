//! TCP server exposing a [`MemStore`] to remote masters/workers.
//!
//! Thread-per-connection over std::net (tokio is unavailable offline, and
//! the connection count here is tiny: one master + a handful of workers).
//! The accept loop exits when any client sends `Shutdown`, letting
//! integration tests and the `issgd db-server` subcommand terminate
//! cleanly.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::protocol::{read_frame, write_frame, Request, Response};
use super::{MemStore, WeightStore};
use crate::log_debug;

pub struct Server {
    listener: TcpListener,
    store: Arc<MemStore>,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind to `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port).
    pub fn bind(addr: &str, store: Arc<MemStore>) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        Ok(Server {
            listener,
            store,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The actual bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a client sends `Shutdown`.  Each connection gets its own
    /// thread; per-request errors are answered as `Response::Err`, i/o
    /// errors drop the connection (the peer retries or dies, its choice).
    pub fn serve(self) -> Result<()> {
        // The accept loop is unblocked on shutdown by a self-connection
        // made from the handler thread that received Shutdown.
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    log_debug!("db", "accept error: {e}");
                    continue;
                }
            };
            let store = Arc::clone(&self.store);
            let stop = Arc::clone(&self.stop);
            let addr = self.local_addr()?;
            std::thread::spawn(move || {
                if let Err(e) = handle_connection(stream, &store, &stop, addr) {
                    log_debug!("db", "connection ended: {e}");
                }
            });
        }
        Ok(())
    }

    /// Serve in a background thread; returns `(addr, join-handle)`.
    pub fn serve_in_background(self) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
        let addr = self.local_addr()?;
        let handle = std::thread::spawn(move || {
            if let Err(e) = self.serve() {
                crate::log_error!("db", "server error: {e}");
            }
        });
        Ok((addr, handle))
    }
}

fn handle_connection(
    mut stream: TcpStream,
    store: &MemStore,
    stop: &AtomicBool,
    self_addr: std::net::SocketAddr,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer closed
        };
        let req = Request::decode(&frame)?;
        if matches!(req, Request::Shutdown) {
            stop.store(true, Ordering::SeqCst);
            write_frame(&mut stream, &Response::Ok.encode())?;
            // Poke the accept loop so it observes the stop flag.
            let _ = TcpStream::connect(self_addr);
            return Ok(());
        }
        let resp = dispatch(store, req);
        write_frame(&mut stream, &resp.encode())?;
    }
}

fn dispatch(store: &MemStore, req: Request) -> Response {
    let result: Result<Response> = (|| {
        Ok(match req {
            Request::PushParams { version, bytes } => {
                store.push_params(version, bytes)?;
                Response::Ok
            }
            Request::FetchParams { than } => Response::Params(store.fetch_params(than)?),
            Request::ParamsVersion => Response::Version(store.params_version()?),
            Request::PushWeights {
                start,
                param_version,
                weights,
            } => {
                store.push_weights(start as usize, &weights, param_version)?;
                Response::Ok
            }
            Request::FetchWeights => Response::Weights(store.fetch_weights()?),
            Request::FetchWeightsSince { seq } => {
                Response::WeightsDelta(store.fetch_weights_since(seq)?)
            }
            Request::ApplyGrad { scale, grad } => {
                Response::Version(store.apply_grad(scale, &grad)?)
            }
            Request::Now => Response::Now(store.now()?),
            Request::Stats => Response::Stats(store.stats()?),
            Request::Shutdown => unreachable!("handled by caller"),
        })
    })();
    result.unwrap_or_else(|e| Response::Err(e.to_string()))
}
