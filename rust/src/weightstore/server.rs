//! TCP server exposing any [`WeightStore`] to remote masters/workers.
//!
//! Thread-per-connection over std::net (tokio is unavailable offline, and
//! the connection count here is tiny: one master + a handful of workers).
//! The server is generic over its backend — `issgd db-server` hands it a
//! [`super::MemStore`] or a [`super::durable::DurableStore`]; tests wrap
//! either in a [`super::faulty::FaultyStore`] — so one transport serves
//! every storage engine.
//!
//! The accept loop exits when any client sends `Shutdown`, letting
//! integration tests and the `issgd db-server` subcommand terminate
//! cleanly.  Connection reads poll at [`READ_POLL`] against the stop
//! flag: a hung or idle client can no longer pin its handler thread
//! forever after `Shutdown` (previously only the accept loop was
//! unblocked by a self-connection; handler threads blocked in a frame
//! read leaked).  Partial frames accumulate across polls, so slow-but-
//! live clients are unaffected.

use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::protocol::{write_frame, Request, Response, MAX_FRAME};
use super::WeightStore;
use crate::log_debug;

/// How often a blocked connection read re-checks the stop flag.
const READ_POLL: std::time::Duration = std::time::Duration::from_millis(100);

/// Per-syscall write timeout.  A client that stops *reading* would
/// otherwise block its handler in `write_frame` forever — past the stop
/// flag, and since [`Server::serve`] joins handlers on shutdown, past the
/// server's lifetime too.  The timeout is per `write` call, so a slowly
/// draining but live client keeps making progress; only a fully stalled
/// one gets its connection dropped.
const WRITE_STALL: std::time::Duration = std::time::Duration::from_secs(5);

pub struct Server {
    listener: TcpListener,
    store: Arc<dyn WeightStore>,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind to `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port).
    pub fn bind(addr: &str, store: Arc<dyn WeightStore>) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        Ok(Server {
            listener,
            store,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The actual bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a client sends `Shutdown`.  Each connection gets its own
    /// thread; per-request errors are answered as `Response::Err`, i/o
    /// errors drop the connection (the peer retries or dies, its choice).
    ///
    /// On shutdown every handler thread is joined before returning (each
    /// notices the stop flag within one [`READ_POLL`]), so when `serve`
    /// returns no handler still holds a store handle — a caller may drop
    /// the server and immediately reopen a durable backend's directory
    /// without racing a late write from a lingering connection.
    pub fn serve(self) -> Result<()> {
        // The accept loop is unblocked on shutdown by a self-connection
        // made from the handler thread that received Shutdown.
        let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            // Reap finished handlers as we go (dropping a finished
            // JoinHandle detaches and frees the thread) so a long-lived
            // server does not accumulate one joinable stack per
            // connection it ever served.
            handlers.retain(|h| !h.is_finished());
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    log_debug!("db", "accept error: {e}");
                    continue;
                }
            };
            let store = Arc::clone(&self.store);
            let stop = Arc::clone(&self.stop);
            let addr = self.local_addr()?;
            handlers.push(std::thread::spawn(move || {
                if let Err(e) = handle_connection(stream, store.as_ref(), &stop, addr) {
                    log_debug!("db", "connection ended: {e}");
                }
            }));
        }
        for h in handlers {
            let _ = h.join();
        }
        Ok(())
    }

    /// Serve in a background thread; returns `(addr, join-handle)`.
    pub fn serve_in_background(self) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
        let addr = self.local_addr()?;
        let handle = std::thread::spawn(move || {
            if let Err(e) = self.serve() {
                crate::log_error!("db", "server error: {e}");
            }
        });
        Ok((addr, handle))
    }
}

/// Outcome of one stoppable frame read.
enum FrameRead {
    Frame(Vec<u8>),
    /// Peer closed (cleanly or mid-frame): drop the connection.
    Closed,
    /// The stop flag flipped: release the handler thread.
    Stopped,
}

fn handle_connection(
    mut stream: TcpStream,
    store: &dyn WeightStore,
    stop: &AtomicBool,
    self_addr: std::net::SocketAddr,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Poll reads so this thread observes `stop` even while idle or facing
    // a hung client — the handler-leak fix (see module docs) — and bound
    // write stalls so a client that stops reading cannot pin us either.
    stream.set_read_timeout(Some(READ_POLL)).ok();
    stream.set_write_timeout(Some(WRITE_STALL)).ok();
    loop {
        let frame = match read_frame_stoppable(&mut stream, stop)? {
            FrameRead::Frame(f) => f,
            FrameRead::Closed | FrameRead::Stopped => return Ok(()),
        };
        let req = Request::decode(&frame)?;
        if matches!(req, Request::Shutdown) {
            stop.store(true, Ordering::SeqCst);
            write_frame(&mut stream, &Response::Ok.encode())?;
            // Poke the accept loop so it observes the stop flag.
            let _ = TcpStream::connect(self_addr);
            return Ok(());
        }
        let resp = dispatch(store, req);
        write_frame(&mut stream, &resp.encode())?;
    }
}

/// Length-prefixed frame read that re-checks `stop` on every read-timeout
/// tick.  Partial data accumulates across ticks, so a slow client's frame
/// survives any number of polls.
fn read_frame_stoppable(stream: &mut TcpStream, stop: &AtomicBool) -> Result<FrameRead> {
    let mut len_buf = [0u8; 4];
    match read_full_stoppable(stream, &mut len_buf, stop)? {
        FullRead::Done => {}
        FullRead::Closed => return Ok(FrameRead::Closed),
        FullRead::Stopped => return Ok(FrameRead::Stopped),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    anyhow::ensure!(len <= MAX_FRAME, "frame length {len} exceeds cap");
    let mut payload = vec![0u8; len];
    match read_full_stoppable(stream, &mut payload, stop)? {
        FullRead::Done => Ok(FrameRead::Frame(payload)),
        FullRead::Closed => Ok(FrameRead::Closed),
        FullRead::Stopped => Ok(FrameRead::Stopped),
    }
}

enum FullRead {
    Done,
    Closed,
    Stopped,
}

fn read_full_stoppable(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
) -> Result<FullRead> {
    let mut filled = 0usize;
    while filled < buf.len() {
        if stop.load(Ordering::SeqCst) {
            return Ok(FullRead::Stopped);
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => return Ok(FullRead::Closed),
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(FullRead::Done)
}

fn dispatch(store: &dyn WeightStore, req: Request) -> Response {
    let result: Result<Response> = (|| {
        Ok(match req {
            Request::PushParams { version, bytes } => {
                store.push_params(version, bytes)?;
                Response::Ok
            }
            Request::FetchParams { than } => Response::Params(store.fetch_params(than)?),
            Request::PushParamsLayers {
                version,
                full,
                layers,
            } => {
                store.push_params_layers(version, full, &layers)?;
                Response::Ok
            }
            Request::FetchParamsSince { than } => {
                Response::ParamsDelta(store.fetch_params_since(than)?)
            }
            Request::ParamsVersion => Response::Version(store.params_version()?),
            Request::PushWeights {
                start,
                param_version,
                weights,
            } => {
                store.push_weights(start as usize, &weights, param_version)?;
                Response::Ok
            }
            Request::FetchWeights => Response::Weights(store.fetch_weights()?),
            Request::FetchWeightsSince { seq } => {
                Response::WeightsDelta(store.fetch_weights_since(seq)?)
            }
            Request::ApplyGrad { scale, grad } => {
                Response::Version(store.apply_grad(scale, &grad)?)
            }
            Request::SaveCursor { name, seq } => {
                store.save_cursor(&name, seq)?;
                Response::Ok
            }
            Request::LoadCursor { name } => Response::Cursor(store.load_cursor(&name)?),
            Request::DropCursor { name } => {
                store.drop_cursor(&name)?;
                Response::Ok
            }
            Request::Now => Response::Now(store.now()?),
            Request::Stats => Response::Stats(store.stats()?),
            Request::Shutdown => unreachable!("handled by caller"),
        })
    })();
    result.unwrap_or_else(|e| Response::Err(e.to_string()))
}
