//! Event-driven TCP server exposing any [`WeightStore`] to remote
//! masters/workers/peers.
//!
//! One thread, one `poll(2)` loop (via the zero-dependency [`super::sys`]
//! shim — tokio/mio are unavailable offline), every socket nonblocking.
//! Each connection owns a read buffer that accumulates partial frames and
//! a write buffer of queued responses:
//!
//! - **Accept**: the listener is polled alongside the connections; ready
//!   means accept-until-`WouldBlock`, so a connect storm drains in one
//!   tick instead of one accept per tick.
//! - **Read + pipelining**: a readable connection is drained to its read
//!   buffer, then *every* complete frame in the buffer is decoded and
//!   dispatched, in arrival order.  Clients may therefore pipeline many
//!   requests without waiting for responses; responses are queued in
//!   request order (the in-order contract documented in
//!   [`super::protocol`]).
//! - **Write batching**: responses accumulate in the write buffer and are
//!   flushed with as few `write` syscalls as the socket accepts; whatever
//!   does not fit stays queued and the socket is polled for `POLLOUT`.
//! - **Slow-client eviction**: a connection whose pending write queue
//!   exceeds [`ServerOptions::max_write_queue`] is dropped.  This replaces
//!   the old thread-per-connection `WRITE_STALL` write timeout: back
//!   pressure is now measured in bytes queued, not seconds stalled, and a
//!   stalled reader can no longer pin server resources beyond its cap.
//!
//! Malformed traffic splits into two cases (see ISSUE 8): a *well-framed
//! but undecodable* payload gets a `Response::Err` answer and bumps the
//! `protocol_errors` counter surfaced through `Stats` — the connection
//! stays up; *framing-level corruption* (a length prefix beyond
//! [`MAX_FRAME`]) means the byte stream itself can't be trusted, so the
//! connection is dropped.
//!
//! The loop exits when any client sends `Shutdown`.  Because the loop is
//! single-threaded, the shutdown/join contract that `integration_durable`
//! relies on is trivial: when [`Server::serve`] returns, no code anywhere
//! still holds the store handle through the server — a caller may drop the
//! server and immediately reopen a durable backend's directory without
//! racing a late write.  Pending responses (including the `Ok` answer to
//! `Shutdown` itself) get a short, bounded best-effort flush before the
//! remaining connections are dropped; idle and hung connections observe
//! EOF at that point.
//!
//! The server is generic over its backend — `issgd db-server` hands it a
//! [`super::MemStore`] or a [`super::durable::DurableStore`]; tests wrap
//! either in a [`super::faulty::FaultyStore`] — so one transport serves
//! every storage engine.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::Arc;

use anyhow::Result;

use super::protocol::{Request, Response, MAX_FRAME};
use super::sys;
use super::WeightStore;
use crate::log_debug;

/// Poll timeout per loop tick.  Every event the loop reacts to arrives
/// through a polled fd, so this is defensive liveness only (retrying
/// flushes after transient weirdness), not a correctness knob.
const POLL_TICK_MS: i32 = 500;

/// Bound on the post-shutdown flush: how many short poll ticks pending
/// responses get before the remaining connections are dropped anyway.
/// Counted ticks rather than a wall-clock deadline keep the server free
/// of `Instant::now` (the determinism lint bans it tree-wide).
const SHUTDOWN_DRAIN_TICKS: u32 = 50;
/// Poll timeout per shutdown-drain tick (ms); with the tick cap above the
/// drain is bounded by ~1s of poll waiting.
const SHUTDOWN_DRAIN_TICK_MS: i32 = 20;

/// Max bytes pulled off one socket per loop tick.  Bounds both the
/// latency one firehosing client can inflict on its neighbours and the
/// read-buffer growth between decode passes.
const READ_SLICE_PER_TICK: usize = 1 << 20;

/// Tuning knobs for [`Server`]; `Default` matches `Server::bind`.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// A connection whose queued-but-unsent responses exceed this many
    /// bytes is evicted (slow-client back pressure).  Must comfortably
    /// exceed the largest single response the deployment can produce —
    /// a full `FetchWeights` snapshot is ~24 bytes/example — since even a
    /// prompt reader briefly queues each response it asked for.
    pub max_write_queue: usize,
    /// Flight recorder: append a JSONL telemetry snapshot to this path
    /// roughly once a second (`issgd db-server --telemetry-dump <path>`).
    pub telemetry_dump: Option<std::path::PathBuf>,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions {
            max_write_queue: 64 << 20,
            telemetry_dump: None,
        }
    }
}

pub struct Server {
    listener: TcpListener,
    store: Arc<dyn WeightStore>,
    opts: ServerOptions,
}

/// One live connection's state in the event loop.
struct Conn {
    stream: TcpStream,
    /// Inbound bytes; `rpos..` is the unconsumed suffix (partial-frame
    /// accumulation across ticks).
    rbuf: Vec<u8>,
    rpos: usize,
    /// Outbound bytes; `wpos..` is the not-yet-written suffix.
    wbuf: Vec<u8>,
    wpos: usize,
    /// Peer half-closed (EOF seen): answer what was already received,
    /// flush, then close.
    close_after_flush: bool,
    /// Connection is finished (error, eviction, framing corruption, or
    /// flushed after close) and will be dropped at end of tick.
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            rpos: 0,
            wbuf: Vec::new(),
            wpos: 0,
            close_after_flush: false,
            dead: false,
        }
    }

    /// Bytes queued for the peer but not yet accepted by the socket.
    fn pending(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Queue one response frame (length prefix + payload).
    fn queue_response(&mut self, resp: &Response) {
        let payload = resp.encode();
        self.wbuf.extend((payload.len() as u32).to_le_bytes());
        self.wbuf.extend(payload);
    }

    /// Drain the socket into `rbuf` until `WouldBlock`, EOF, or the
    /// per-tick fairness slice is used up.
    fn fill_read_buf(&mut self) {
        let mut scratch = [0u8; 64 * 1024];
        let mut taken = 0usize;
        loop {
            match self.stream.read(&mut scratch) {
                Ok(0) => {
                    self.close_after_flush = true;
                    return;
                }
                Ok(n) => {
                    // analyze: allow(panics): Read::read returns n <= buf.len() by contract
                    self.rbuf.extend(&scratch[..n]);
                    taken += n;
                    if taken >= READ_SLICE_PER_TICK {
                        return;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    log_debug!("db", "read error, dropping connection: {e}");
                    self.dead = true;
                    return;
                }
            }
        }
    }

    /// Write as much queued output as the socket will take right now.
    fn flush_write_buf(&mut self) {
        while self.pending() > 0 {
            // analyze: allow(panics): wpos <= wbuf.len() — write() returns at most the slice length
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    log_debug!("db", "write error, dropping connection: {e}");
                    self.dead = true;
                    return;
                }
            }
        }
        if self.pending() == 0 {
            self.wbuf.clear();
            self.wpos = 0;
            if self.close_after_flush {
                self.dead = true;
            }
        }
    }

    /// Drop the consumed prefix of the read buffer so it doesn't grow
    /// without bound across pipelined batches.
    fn compact_read_buf(&mut self) {
        if self.rpos > 0 {
            self.rbuf.drain(..self.rpos);
            self.rpos = 0;
        }
    }
}

impl Server {
    /// Bind to `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) with
    /// default options.
    pub fn bind(addr: &str, store: Arc<dyn WeightStore>) -> Result<Server> {
        Server::bind_with_options(addr, store, ServerOptions::default())
    }

    /// Bind with explicit [`ServerOptions`] (tests use a tiny
    /// `max_write_queue` to exercise slow-client eviction).
    pub fn bind_with_options(
        addr: &str,
        store: Arc<dyn WeightStore>,
        opts: ServerOptions,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        Ok(Server {
            listener,
            store,
            opts,
        })
    }

    /// The actual bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Run the event loop until a client sends `Shutdown`.
    ///
    /// Per-request errors are answered as `Response::Err`; i/o errors and
    /// framing corruption drop the offending connection only.  When this
    /// returns, every connection has been dropped and nothing still holds
    /// the store handle through the server (see module docs).
    pub fn serve(self) -> Result<()> {
        self.listener.set_nonblocking(true)?;
        let mut conns: Vec<Conn> = Vec::new();
        let mut fds: Vec<sys::PollFd> = Vec::new();
        // Single-threaded loop, so plain locals — not atomics — carry the
        // stop flag and the protocol-error count.
        let mut stop = false;
        let mut protocol_errors: u64 = 0;

        // Pre-register the canonical metric set so a `FetchMetrics` scrape
        // exposes the full schema from the first tick, then grab the
        // per-tick handles once (the registry lock is not for hot loops).
        crate::telemetry::register_store_metrics();
        let tick_hist = crate::telemetry::histogram("server.tick_ns");
        let evictions = crate::telemetry::counter("server.evictions");
        let mut dumper = None;
        if let Some(p) = &self.opts.telemetry_dump {
            dumper = Some(crate::telemetry::Dumper::new(p, std::time::Duration::from_secs(1)));
        }

        while !stop {
            fds.clear();
            fds.push(sys::PollFd::new(self.listener.as_raw_fd(), sys::POLLIN));
            for c in &conns {
                let mut events = sys::POLLIN;
                if c.pending() > 0 {
                    events |= sys::POLLOUT;
                }
                fds.push(sys::PollFd::new(c.stream.as_raw_fd(), events));
            }
            sys::poll(&mut fds, POLL_TICK_MS)?;
            // Time the work slice of the tick only — the poll wait above
            // is idle time and would swamp the latency histogram.
            let tick = crate::telemetry::start();

            // Service existing connections first: `fds[1..]` maps onto the
            // first `fds.len() - 1` conns, and accepting first would push
            // unpolled entries past that prefix.
            let polled = fds.len() - 1;
            for (i, conn) in conns.iter_mut().enumerate().take(polled) {
                let revents = fds[i + 1].revents;
                if revents & (sys::POLLIN | sys::POLL_ANY_ERR) != 0 {
                    conn.fill_read_buf();
                    if !conn.dead {
                        process_frames(conn, self.store.as_ref(), &mut stop, &mut protocol_errors);
                    }
                }
                if !conn.dead && (conn.pending() > 0 || conn.close_after_flush) {
                    // Flush eagerly: freshly queued responses shouldn't
                    // wait a poll tick for a POLLOUT edge.
                    conn.flush_write_buf();
                }
                if !conn.dead && conn.pending() > self.opts.max_write_queue {
                    log_debug!(
                        "db",
                        "evicting slow client: {} bytes pending (cap {})",
                        conn.pending(),
                        self.opts.max_write_queue
                    );
                    evictions.inc();
                    conn.dead = true;
                }
            }
            conns.retain(|c| !c.dead);
            if fds[0].revents != 0 {
                self.accept_ready(&mut conns);
            }
            tick_hist.record_elapsed(&tick);
            if let Some(d) = dumper.as_mut() {
                d.tick();
            }
        }

        self.drain_after_shutdown(conns);
        Ok(())
    }

    /// Accept until `WouldBlock`; new sockets become nonblocking conns.
    fn accept_ready(&self, conns: &mut Vec<Conn>) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    stream.set_nodelay(true).ok();
                    conns.push(Conn::new(stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    log_debug!("db", "accept error: {e}");
                    return;
                }
            }
        }
    }

    /// Best-effort bounded flush of queued responses after `Shutdown` —
    /// most importantly the `Ok` owed to whoever requested it — then drop
    /// everything.
    fn drain_after_shutdown(&self, mut conns: Vec<Conn>) {
        let mut fds: Vec<sys::PollFd> = Vec::new();
        for _ in 0..SHUTDOWN_DRAIN_TICKS {
            conns.retain(|c| !c.dead && c.pending() > 0);
            if conns.is_empty() {
                return;
            }
            fds.clear();
            for c in &conns {
                fds.push(sys::PollFd::new(c.stream.as_raw_fd(), sys::POLLOUT));
            }
            if sys::poll(&mut fds, SHUTDOWN_DRAIN_TICK_MS).is_err() {
                return;
            }
            for conn in conns.iter_mut() {
                conn.flush_write_buf();
            }
        }
    }

    /// Serve in a background thread; returns `(addr, join-handle)`.
    pub fn serve_in_background(self) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
        let addr = self.local_addr()?;
        let handle = std::thread::spawn(move || {
            if let Err(e) = self.serve() {
                crate::log_error!("db", "server error: {e}");
            }
        });
        Ok((addr, handle))
    }
}

/// Decode and dispatch every complete frame in `conn`'s read buffer
/// (request pipelining), queueing responses in request order.
fn process_frames(
    conn: &mut Conn,
    store: &dyn WeightStore,
    stop: &mut bool,
    protocol_errors: &mut u64,
) {
    loop {
        let avail = conn.rbuf.len() - conn.rpos;
        if avail < 4 {
            break;
        }
        let Some(hdr) = conn.rbuf.get(conn.rpos..conn.rpos + 4) else { break };
        let len = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]) as usize;
        if len > MAX_FRAME {
            // Framing-level corruption: the stream offset itself is no
            // longer trustworthy, so this connection cannot be saved.
            log_debug!("db", "frame length {len} exceeds cap, dropping connection");
            conn.dead = true;
            break;
        }
        if avail < 4 + len {
            break;
        }
        let Some(frame) = conn.rbuf.get(conn.rpos + 4..conn.rpos + 4 + len) else { break };
        match Request::decode(frame) {
            Ok(Request::Shutdown) => {
                conn.rpos += 4 + len;
                conn.queue_response(&Response::Ok);
                conn.close_after_flush = true;
                *stop = true;
                break;
            }
            Ok(req) => {
                let resp = dispatch(store, req, *protocol_errors);
                conn.rpos += 4 + len;
                conn.queue_response(&resp);
            }
            Err(e) => {
                // Well-framed but undecodable: answer in-band and keep
                // the connection (the frame boundary is still sound).
                *protocol_errors += 1;
                crate::telemetry::counter("server.protocol_errors").inc();
                conn.rpos += 4 + len;
                conn.queue_response(&Response::Err(format!("protocol error: {e}")));
            }
        }
    }
    conn.compact_read_buf();
}

fn dispatch(store: &dyn WeightStore, req: Request, protocol_errors: u64) -> Response {
    let result: Result<Response> = (|| {
        Ok(match req {
            Request::PushParams { version, bytes } => {
                store.push_params(version, bytes)?;
                Response::Ok
            }
            Request::FetchParams { than } => Response::Params(store.fetch_params(than)?),
            Request::PushParamsLayers {
                version,
                full,
                layers,
            } => {
                store.push_params_layers(version, full, &layers)?;
                Response::Ok
            }
            Request::FetchParamsSince { than } => {
                Response::ParamsDelta(store.fetch_params_since(than)?)
            }
            Request::ParamsVersion => Response::Version(store.params_version()?),
            Request::PushWeights {
                start,
                param_version,
                weights,
            } => {
                store.push_weights(start as usize, &weights, param_version)?;
                Response::Ok
            }
            Request::FetchWeights => Response::Weights(store.fetch_weights()?),
            Request::FetchWeightsSince { seq } => {
                Response::WeightsDelta(store.fetch_weights_since(seq)?)
            }
            Request::ApplyGrad { scale, grad } => {
                Response::Version(store.apply_grad(scale, &grad)?)
            }
            Request::SaveCursor { name, seq } => {
                store.save_cursor(&name, seq)?;
                Response::Ok
            }
            Request::LoadCursor { name } => Response::Cursor(store.load_cursor(&name)?),
            Request::DropCursor { name } => {
                store.drop_cursor(&name)?;
                Response::Ok
            }
            Request::Now => Response::Now(store.now()?),
            Request::FetchMetrics => {
                Response::Metrics(crate::telemetry::snapshot().to_json().to_string())
            }
            Request::Stats => {
                let mut stats = store.stats()?;
                // The raw backends can't see transport-level problems;
                // the server folds its own count in here (same pattern
                // as the driver-folded `push_calls_saved`).
                stats.protocol_errors = protocol_errors;
                Response::Stats(stats)
            }
            // `process_frames` intercepts Shutdown before dispatch; if a
            // refactor ever breaks that, answer in-band instead of
            // aborting the event loop.
            Request::Shutdown => Response::Err("shutdown is handled by the event loop".into()),
        })
    })();
    result.unwrap_or_else(|e| Response::Err(e.to_string()))
}
