//! Wire protocol for the TCP weight store: length-prefixed binary frames.
//!
//! Frame layout: `u32 little-endian payload length` + payload.  The payload
//! starts with a one-byte opcode followed by fixed-width little-endian
//! fields.  No varints, no schema evolution — the protocol is internal to
//! one release of this binary on both ends, so simplicity wins (this is
//! also roughly what the paper got from Redis: opaque blobs under keys).
//!
//! # Opcode table
//!
//! | op   | request                | op   | response            |
//! |------|------------------------|------|---------------------|
//! | 0x01 | `PushParams`           | 0x80 | `Ok`                |
//! | 0x02 | `FetchParams`          | 0x81 | `Err`               |
//! | 0x03 | `ParamsVersion`        | 0x82 | `Params`            |
//! | 0x04 | `PushWeights`          | 0x83 | `Version`           |
//! | 0x05 | `FetchWeights`         | 0x84 | `Weights`           |
//! | 0x06 | `Now`                  | 0x85 | `Now`               |
//! | 0x07 | `Stats`                | 0x86 | `Stats`             |
//! | 0x08 | `ApplyGrad`            | 0x87 | `WeightsDelta`      |
//! | 0x09 | `FetchWeightsSince`    | 0x88 | `Cursor`            |
//! | 0x0A | `SaveCursor`           | 0x89 | `ParamsDelta`       |
//! | 0x0B | `LoadCursor`           |      |                     |
//! | 0x0C | `PushParamsLayers`     |      |                     |
//! | 0x0D | `FetchParamsSince`     |      |                     |
//! | 0x0E | `DropCursor`           |      |                     |
//! | 0x0F | `Shutdown`             |      |                     |
//! | 0x10 | `FetchMetrics`         | 0x8A | `Metrics`           |
//!
//! The params-delta pair (`PushParamsLayers`/`FetchParamsSince` →
//! `ParamsDelta`) carries *named layer chunks* instead of the whole blob;
//! the version/fallback contract lives on
//! [`super::WeightStore::fetch_params_since`] and in the `weightstore`
//! module docs.  `DropCursor` removes a dead consumer's compaction pin.
//!
//! # Pipelining and the in-order response contract
//!
//! The transport is *pipelined*: a client may write any number of request
//! frames without waiting for responses.  The server guarantees that
//! responses come back **one per request, in request order, with nothing
//! skipped** — the k-th response frame on a connection always answers the
//! k-th request frame.  There are no request IDs on the wire; ordering
//! *is* the correlation mechanism, which is why a desynced stream must be
//! abandoned rather than resynchronized (see `client`'s poisoning rules).
//!
//! Two qualifications:
//!
//! * A *well-framed but undecodable* request (bad opcode, truncated
//!   fields) still consumes its slot in the order and is answered with
//!   `Response::Err` — the connection survives.  Only framing-level
//!   corruption (a length prefix over [`MAX_FRAME`]) kills the
//!   connection, because frame boundaries themselves are then lost.
//! * The contract is per-connection and ends with the connection: if the
//!   server evicts a slow reader or the connection drops, the unsent tail
//!   of the response stream is discarded — a client never observes
//!   reordering, only truncation.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use super::{LayerChunk, ParamsDelta, StoreStats, WeightDelta, WeightSnapshot};

/// Hard cap on frame size (128 MiB) — a corrupted length prefix must not
/// make the peer try to allocate the universe.
pub const MAX_FRAME: usize = 128 << 20;

/// Client → server requests.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    PushParams { version: u64, bytes: Vec<u8> },
    FetchParams { than: u64 },
    ParamsVersion,
    PushWeights { start: u64, param_version: u64, weights: Vec<f32> },
    FetchWeights,
    /// Incremental fetch: entries written since `seq` (0 = full table).
    FetchWeightsSince { seq: u64 },
    /// Parameter-server op: params -= scale * grad (ASGD peers, §6).
    ApplyGrad { scale: f32, grad: Vec<f32> },
    /// Persist a named consumer cursor (compaction pin + crash resume).
    SaveCursor { name: String, seq: u64 },
    /// Read back a named consumer cursor.
    LoadCursor { name: String },
    /// Publish named parameter layers (`full` = layout definition).
    PushParamsLayers {
        version: u64,
        full: bool,
        layers: Vec<(String, Vec<u8>)>,
    },
    /// Incremental parameter fetch: layers written since `than`.
    FetchParamsSince { than: u64 },
    /// Discard a named consumer cursor (dead-consumer pin removal).
    DropCursor { name: String },
    Now,
    Stats,
    /// Scrape the server's telemetry registry (read-only diagnostics).
    FetchMetrics,
    /// Ask the server process to exit its accept loop.
    Shutdown,
}

/// Server → client responses.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok,
    Err(String),
    Params(Option<(u64, Vec<u8>)>),
    Version(u64),
    Weights(WeightSnapshot),
    WeightsDelta(WeightDelta),
    Now(u64),
    Stats(StoreStats),
    /// A saved cursor (`None` = unknown consumer).
    Cursor(Option<u64>),
    /// A params delta (`None` = caller up to date / nothing published).
    ParamsDelta(Option<ParamsDelta>),
    /// A telemetry snapshot, serialized as `util::json` text (the
    /// `telemetry::Snapshot::to_json` schema).  Text rather than a binary
    /// table so the metric set can grow without a protocol change.
    Metrics(String),
}

// ---------------------------------------------------------------------------
// primitives
// ---------------------------------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated frame: need {n} bytes at {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    #[allow(dead_code)]
    fn f64_scalar(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(
            self.take(8)?.try_into().context("truncated f64 field")?,
        ))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(
            self.take(8)?.try_into().context("truncated u64 field")?,
        ))
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let len = self.u64()? as usize;
        Ok(self.take(len)?.to_vec())
    }

    /// Element count → byte count, rejecting lengths whose product would
    /// wrap (a wrapped length would pass `take`'s bound check with a
    /// bogus element count).
    fn vec_bytes(len: usize, width: usize) -> Result<usize> {
        len.checked_mul(width)
            .with_context(|| format!("vector length {len} overflows the frame"))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let len = self.u64()? as usize;
        let raw = self.take(Self::vec_bytes(len, 4)?)?;
        let mut out = Vec::with_capacity(len);
        for c in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().context("short f32 chunk")?));
        }
        Ok(out)
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let len = self.u64()? as usize;
        let raw = self.take(Self::vec_bytes(len, 8)?)?;
        let mut out = Vec::with_capacity(len);
        for c in raw.chunks_exact(8) {
            out.push(u64::from_le_bytes(c.try_into().context("short u64 chunk")?));
        }
        Ok(out)
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let len = self.u64()? as usize;
        let raw = self.take(Self::vec_bytes(len, 8)?)?;
        let mut out = Vec::with_capacity(len);
        for c in raw.chunks_exact(8) {
            out.push(f64::from_le_bytes(c.try_into().context("short f64 chunk")?));
        }
        Ok(out)
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes in frame", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend((b.len() as u64).to_le_bytes());
    out.extend(b);
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.extend((xs.len() as u64).to_le_bytes());
    for x in xs {
        out.extend(x.to_le_bytes());
    }
}

fn put_u64s(out: &mut Vec<u8>, xs: &[u64]) {
    out.extend((xs.len() as u64).to_le_bytes());
    for x in xs {
        out.extend(x.to_le_bytes());
    }
}

fn put_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    out.extend((xs.len() as u64).to_le_bytes());
    for x in xs {
        out.extend(x.to_le_bytes());
    }
}

// ---------------------------------------------------------------------------
// encode / decode
// ---------------------------------------------------------------------------

/// Payload of a [`Request::PushParams`] (opcode included), built from
/// borrows — shared with the durable journal so appends need not clone the
/// blob just to serialize it.
pub(crate) fn encode_push_params(version: u64, bytes: &[u8]) -> Vec<u8> {
    let mut p = vec![0x01];
    p.extend(version.to_le_bytes());
    put_bytes(&mut p, bytes);
    p
}

/// Payload of a [`Request::ApplyGrad`] (opcode included), from borrows.
pub(crate) fn encode_apply_grad(scale: f32, grad: &[f32]) -> Vec<u8> {
    let mut p = vec![0x08];
    p.extend(scale.to_le_bytes());
    put_f32s(&mut p, grad);
    p
}

/// Payload of a [`Request::PushParamsLayers`] (opcode included), from
/// borrows — shared with the durable journal, whose per-push params
/// record is exactly this frame (no whole-blob re-serialization).
/// Generic over the pair types so both owned `(String, Vec<u8>)` lists
/// and borrowed `(&str, &[u8])` views (the snapshot writer) encode
/// without cloning.
pub(crate) fn encode_push_params_layers<N: AsRef<str>, B: AsRef<[u8]>>(
    version: u64,
    full: bool,
    layers: &[(N, B)],
) -> Vec<u8> {
    let mut p = vec![0x0C];
    p.extend(version.to_le_bytes());
    p.push(full as u8);
    p.extend((layers.len() as u64).to_le_bytes());
    for (name, bytes) in layers {
        put_bytes(&mut p, name.as_ref().as_bytes());
        put_bytes(&mut p, bytes.as_ref());
    }
    p
}

/// Payload of a [`Response::WeightsDelta`] (opcode included), from a
/// borrow — the journal's per-push frame on the hot write path.
pub(crate) fn encode_weights_delta(delta: &WeightDelta) -> Vec<u8> {
    let mut p = vec![0x87];
    p.extend(delta.seq.to_le_bytes());
    p.extend(delta.n.to_le_bytes());
    p.push(delta.full as u8);
    put_u64s(&mut p, &delta.indices);
    put_f64s(&mut p, &delta.weights);
    put_u64s(&mut p, &delta.stamps);
    put_u64s(&mut p, &delta.param_versions);
    p
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::new();
        match self {
            Request::PushParams { version, bytes } => {
                return encode_push_params(*version, bytes);
            }
            Request::FetchParams { than } => {
                p.push(0x02);
                p.extend(than.to_le_bytes());
            }
            Request::ParamsVersion => p.push(0x03),
            Request::PushWeights {
                start,
                param_version,
                weights,
            } => {
                p.push(0x04);
                p.extend(start.to_le_bytes());
                p.extend(param_version.to_le_bytes());
                put_f32s(&mut p, weights);
            }
            Request::FetchWeights => p.push(0x05),
            Request::FetchWeightsSince { seq } => {
                p.push(0x09);
                p.extend(seq.to_le_bytes());
            }
            Request::ApplyGrad { scale, grad } => {
                return encode_apply_grad(*scale, grad);
            }
            Request::SaveCursor { name, seq } => {
                p.push(0x0A);
                put_bytes(&mut p, name.as_bytes());
                p.extend(seq.to_le_bytes());
            }
            Request::LoadCursor { name } => {
                p.push(0x0B);
                put_bytes(&mut p, name.as_bytes());
            }
            Request::PushParamsLayers {
                version,
                full,
                layers,
            } => {
                return encode_push_params_layers(*version, *full, layers);
            }
            Request::FetchParamsSince { than } => {
                p.push(0x0D);
                p.extend(than.to_le_bytes());
            }
            Request::DropCursor { name } => {
                p.push(0x0E);
                put_bytes(&mut p, name.as_bytes());
            }
            Request::Now => p.push(0x06),
            Request::Stats => p.push(0x07),
            Request::FetchMetrics => p.push(0x10),
            Request::Shutdown => p.push(0x0F),
        }
        p
    }

    pub fn decode(buf: &[u8]) -> Result<Request> {
        let mut c = Cursor::new(buf);
        let op = c.u8()?;
        let req = match op {
            0x01 => Request::PushParams {
                version: c.u64()?,
                bytes: c.bytes()?,
            },
            0x02 => Request::FetchParams { than: c.u64()? },
            0x03 => Request::ParamsVersion,
            0x04 => Request::PushWeights {
                start: c.u64()?,
                param_version: c.u64()?,
                weights: c.f32s()?,
            },
            0x05 => Request::FetchWeights,
            0x09 => Request::FetchWeightsSince { seq: c.u64()? },
            0x08 => Request::ApplyGrad {
                scale: {
                    let raw = c.take(4)?;
                    f32::from_le_bytes(raw.try_into().context("truncated f32 scale")?)
                },
                grad: c.f32s()?,
            },
            0x0A => Request::SaveCursor {
                name: String::from_utf8(c.bytes()?).context("cursor name not utf-8")?,
                seq: c.u64()?,
            },
            0x0B => Request::LoadCursor {
                name: String::from_utf8(c.bytes()?).context("cursor name not utf-8")?,
            },
            0x0C => {
                let version = c.u64()?;
                let full = c.u8()? != 0;
                let count = c.u64()? as usize;
                let mut layers = Vec::with_capacity(count.min(1 << 16));
                for _ in 0..count {
                    let name =
                        String::from_utf8(c.bytes()?).context("layer name not utf-8")?;
                    let bytes = c.bytes()?;
                    layers.push((name, bytes));
                }
                Request::PushParamsLayers {
                    version,
                    full,
                    layers,
                }
            }
            0x0D => Request::FetchParamsSince { than: c.u64()? },
            0x0E => Request::DropCursor {
                name: String::from_utf8(c.bytes()?).context("cursor name not utf-8")?,
            },
            0x06 => Request::Now,
            0x07 => Request::Stats,
            0x10 => Request::FetchMetrics,
            0x0F => Request::Shutdown,
            _ => bail!("unknown request opcode {op:#04x}"),
        };
        c.done()?;
        Ok(req)
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::new();
        match self {
            Response::Ok => p.push(0x80),
            Response::Err(msg) => {
                p.push(0x81);
                put_bytes(&mut p, msg.as_bytes());
            }
            Response::Params(opt) => {
                p.push(0x82);
                match opt {
                    None => p.push(0),
                    Some((v, b)) => {
                        p.push(1);
                        p.extend(v.to_le_bytes());
                        put_bytes(&mut p, b);
                    }
                }
            }
            Response::Version(v) => {
                p.push(0x83);
                p.extend(v.to_le_bytes());
            }
            Response::Weights(snap) => {
                p.push(0x84);
                put_f64s(&mut p, &snap.weights);
                put_u64s(&mut p, &snap.stamps);
                put_u64s(&mut p, &snap.param_versions);
            }
            Response::WeightsDelta(delta) => {
                return encode_weights_delta(delta);
            }
            Response::Now(t) => {
                p.push(0x85);
                p.extend(t.to_le_bytes());
            }
            Response::Cursor(opt) => {
                p.push(0x88);
                match opt {
                    None => p.push(0),
                    Some(seq) => {
                        p.push(1);
                        p.extend(seq.to_le_bytes());
                    }
                }
            }
            Response::ParamsDelta(opt) => {
                p.push(0x89);
                match opt {
                    None => p.push(0),
                    Some(d) => {
                        p.push(1);
                        p.extend(d.version.to_le_bytes());
                        p.push(d.full as u8);
                        p.extend((d.layers.len() as u64).to_le_bytes());
                        for l in &d.layers {
                            put_bytes(&mut p, l.name.as_bytes());
                            p.extend(l.version.to_le_bytes());
                            put_bytes(&mut p, &l.bytes);
                        }
                    }
                }
            }
            Response::Metrics(text) => {
                p.push(0x8A);
                put_bytes(&mut p, text.as_bytes());
            }
            Response::Stats(s) => {
                p.push(0x86);
                for v in [
                    s.param_pushes,
                    s.param_fetches,
                    s.weight_pushes,
                    s.weights_written,
                    s.snapshot_fetches,
                    s.grad_applies,
                    s.delta_fetches,
                    s.delta_entries,
                    s.params_delta_fetches,
                    s.params_delta_layers,
                    s.push_calls_saved,
                    s.protocol_errors,
                ] {
                    p.extend(v.to_le_bytes());
                }
            }
        }
        p
    }

    pub fn decode(buf: &[u8]) -> Result<Response> {
        let mut c = Cursor::new(buf);
        let op = c.u8()?;
        let resp = match op {
            0x80 => Response::Ok,
            0x81 => Response::Err(String::from_utf8_lossy(&c.bytes()?).into_owned()),
            0x82 => {
                let has = c.u8()? != 0;
                if has {
                    Response::Params(Some((c.u64()?, c.bytes()?)))
                } else {
                    Response::Params(None)
                }
            }
            0x83 => Response::Version(c.u64()?),
            0x84 => {
                let weights = c.f64s()?;
                let stamps = c.u64s()?;
                let param_versions = c.u64s()?;
                anyhow::ensure!(
                    weights.len() == stamps.len() && stamps.len() == param_versions.len(),
                    "snapshot arrays disagree on length"
                );
                Response::Weights(WeightSnapshot {
                    weights,
                    stamps,
                    param_versions,
                })
            }
            0x87 => {
                let seq = c.u64()?;
                let n = c.u64()?;
                let full = c.u8()? != 0;
                let indices = c.u64s()?;
                let weights = c.f64s()?;
                let stamps = c.u64s()?;
                let param_versions = c.u64s()?;
                anyhow::ensure!(
                    indices.len() == weights.len()
                        && weights.len() == stamps.len()
                        && stamps.len() == param_versions.len(),
                    "delta columns disagree on length"
                );
                // A full delta by definition carries the whole table, so
                // its `n` is backed by frame-capped column data.  Without
                // this check a corrupted tiny frame could claim
                // full + n≈usize::MAX and make apply_to's resize allocate
                // the universe.  (Incremental deltas never resize, so a
                // large `n` is legitimate there — big tables are exactly
                // the delta path's reason to exist.)
                anyhow::ensure!(
                    !full || indices.len() as u64 == n,
                    "full delta carries {} entries for a table of {n}",
                    indices.len()
                );
                Response::WeightsDelta(WeightDelta {
                    seq,
                    n,
                    full,
                    indices,
                    weights,
                    stamps,
                    param_versions,
                })
            }
            0x85 => Response::Now(c.u64()?),
            0x88 => {
                let has = c.u8()? != 0;
                if has {
                    Response::Cursor(Some(c.u64()?))
                } else {
                    Response::Cursor(None)
                }
            }
            0x89 => {
                let has = c.u8()? != 0;
                if !has {
                    Response::ParamsDelta(None)
                } else {
                    let version = c.u64()?;
                    let full = c.u8()? != 0;
                    let count = c.u64()? as usize;
                    let mut layers = Vec::with_capacity(count.min(1 << 16));
                    for _ in 0..count {
                        let name =
                            String::from_utf8(c.bytes()?).context("layer name not utf-8")?;
                        let lv = c.u64()?;
                        let bytes = c.bytes()?;
                        layers.push(LayerChunk {
                            name,
                            version: lv,
                            bytes,
                        });
                    }
                    Response::ParamsDelta(Some(ParamsDelta {
                        version,
                        full,
                        layers,
                    }))
                }
            }
            0x8A => Response::Metrics(
                String::from_utf8(c.bytes()?).context("metrics snapshot not utf-8")?,
            ),
            0x86 => Response::Stats(StoreStats {
                param_pushes: c.u64()?,
                param_fetches: c.u64()?,
                weight_pushes: c.u64()?,
                weights_written: c.u64()?,
                snapshot_fetches: c.u64()?,
                grad_applies: c.u64()?,
                delta_fetches: c.u64()?,
                delta_entries: c.u64()?,
                params_delta_fetches: c.u64()?,
                params_delta_layers: c.u64()?,
                push_calls_saved: c.u64()?,
                protocol_errors: c.u64()?,
            }),
            _ => bail!("unknown response opcode {op:#04x}"),
        };
        c.done()?;
        Ok(resp)
    }

    /// Map an error response into a rust error.
    pub fn into_result(self) -> Result<Response> {
        match self {
            Response::Err(msg) => bail!("store error: {msg}"),
            other => Ok(other),
        }
    }
}

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    anyhow::ensure!(payload.len() <= MAX_FRAME, "frame too large: {}", payload.len());
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf).context("reading frame length")?;
    let len = u32::from_le_bytes(len_buf) as usize;
    anyhow::ensure!(len <= MAX_FRAME, "frame length {len} exceeds cap");
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame body")?;
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: Request) {
        let enc = req.encode();
        assert_eq!(Request::decode(&enc).unwrap(), req);
    }

    fn roundtrip_resp(resp: Response) {
        let enc = resp.encode();
        assert_eq!(Response::decode(&enc).unwrap(), resp);
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_req(Request::PushParams {
            version: 7,
            bytes: vec![1, 2, 3, 255],
        });
        roundtrip_req(Request::FetchParams { than: 42 });
        roundtrip_req(Request::ParamsVersion);
        roundtrip_req(Request::PushWeights {
            start: 100,
            param_version: 3,
            weights: vec![1.5, -0.0, 3.25e-8],
        });
        roundtrip_req(Request::FetchWeights);
        roundtrip_req(Request::FetchWeightsSince { seq: 0 });
        roundtrip_req(Request::FetchWeightsSince { seq: u64::MAX });
        roundtrip_req(Request::ApplyGrad {
            scale: 0.125,
            grad: vec![1.0, -2.0, 3.5],
        });
        roundtrip_req(Request::SaveCursor {
            name: "master".into(),
            seq: u64::MAX,
        });
        roundtrip_req(Request::SaveCursor {
            name: String::new(),
            seq: 0,
        });
        roundtrip_req(Request::LoadCursor {
            name: "peer-3".into(),
        });
        roundtrip_req(Request::PushParamsLayers {
            version: 12,
            full: true,
            layers: vec![
                ("layer0".into(), vec![1, 2, 3, 4]),
                ("layer1".into(), Vec::new()),
            ],
        });
        roundtrip_req(Request::PushParamsLayers {
            version: 13,
            full: false,
            layers: vec![("layer1".into(), vec![9; 33])],
        });
        roundtrip_req(Request::FetchParamsSince { than: 0 });
        roundtrip_req(Request::FetchParamsSince { than: u64::MAX });
        roundtrip_req(Request::DropCursor {
            name: "peer-7".into(),
        });
        roundtrip_req(Request::Now);
        roundtrip_req(Request::Stats);
        roundtrip_req(Request::FetchMetrics);
        roundtrip_req(Request::Shutdown);
    }

    #[test]
    fn responses_roundtrip() {
        roundtrip_resp(Response::Ok);
        roundtrip_resp(Response::Err("boom".into()));
        roundtrip_resp(Response::Params(None));
        roundtrip_resp(Response::Params(Some((9, vec![7; 100]))));
        roundtrip_resp(Response::Version(11));
        roundtrip_resp(Response::Weights(WeightSnapshot {
            weights: vec![0.5, 2.0],
            stamps: vec![10, 20],
            param_versions: vec![1, 2],
        }));
        roundtrip_resp(Response::WeightsDelta(WeightDelta {
            seq: 99,
            n: 1000,
            full: false,
            indices: vec![3, 700, 999],
            weights: vec![0.25, 1.5, -0.0],
            stamps: vec![11, 22, 33],
            param_versions: vec![1, 2, 3],
        }));
        roundtrip_resp(Response::WeightsDelta(WeightDelta {
            seq: 0,
            n: 0,
            full: true,
            ..WeightDelta::default()
        }));
        roundtrip_resp(Response::Now(123456789));
        roundtrip_resp(Response::Cursor(None));
        roundtrip_resp(Response::Cursor(Some(42)));
        roundtrip_resp(Response::ParamsDelta(None));
        roundtrip_resp(Response::ParamsDelta(Some(ParamsDelta {
            version: 9,
            full: false,
            layers: vec![
                LayerChunk {
                    name: "layer2".into(),
                    version: 9,
                    bytes: vec![0, 255, 7],
                },
                LayerChunk {
                    name: "layer5".into(),
                    version: 8,
                    bytes: Vec::new(),
                },
            ],
        })));
        roundtrip_resp(Response::ParamsDelta(Some(ParamsDelta {
            version: 1,
            full: true,
            layers: vec![LayerChunk {
                name: "".into(),
                version: 1,
                bytes: vec![42; 17],
            }],
        })));
        roundtrip_resp(Response::Metrics(String::new()));
        roundtrip_resp(Response::Metrics(
            r#"{"counters":{"server.evictions":3},"gauges":{},"histograms":{}}"#.into(),
        ));
        roundtrip_resp(Response::Stats(StoreStats {
            param_pushes: 1,
            param_fetches: 2,
            weight_pushes: 3,
            weights_written: 4,
            snapshot_fetches: 5,
            grad_applies: 6,
            delta_fetches: 7,
            delta_entries: 8,
            params_delta_fetches: 9,
            params_delta_layers: 10,
            push_calls_saved: 11,
            protocol_errors: 12,
        }));
    }

    #[test]
    fn params_delta_frames_reject_truncation_and_trailing() {
        let enc = Response::ParamsDelta(Some(ParamsDelta {
            version: 3,
            full: true,
            layers: vec![
                LayerChunk {
                    name: "a".into(),
                    version: 2,
                    bytes: vec![1, 2, 3, 4],
                },
                LayerChunk {
                    name: "b".into(),
                    version: 3,
                    bytes: vec![5, 6],
                },
            ],
        }))
        .encode();
        for cut in 0..enc.len() {
            assert!(Response::decode(&enc[..cut]).is_err(), "prefix {cut} decoded");
        }
        let mut extra = enc.clone();
        extra.push(0);
        assert!(Response::decode(&extra).is_err());

        let enc = Request::PushParamsLayers {
            version: 4,
            full: false,
            layers: vec![("x".into(), vec![7, 8, 9])],
        }
        .encode();
        for cut in 0..enc.len() {
            assert!(Request::decode(&enc[..cut]).is_err(), "prefix {cut} decoded");
        }
        let mut extra = enc;
        extra.push(0);
        assert!(Request::decode(&extra).is_err());
    }

    #[test]
    fn metrics_frames_reject_truncation_and_trailing() {
        let enc = Response::Metrics(r#"{"counters":{"a":1}}"#.into()).encode();
        for cut in 0..enc.len() {
            assert!(Response::decode(&enc[..cut]).is_err(), "prefix {cut} decoded");
        }
        let mut extra = enc;
        extra.push(0);
        assert!(Response::decode(&extra).is_err());

        let enc = Request::FetchMetrics.encode();
        let mut extra = enc;
        extra.push(0);
        assert!(Request::decode(&extra).is_err());
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let enc = Request::PushWeights {
            start: 0,
            param_version: 0,
            weights: vec![1.0, 2.0],
        }
        .encode();
        assert!(Request::decode(&enc[..enc.len() - 1]).is_err());
        let mut extra = enc.clone();
        extra.push(0);
        assert!(Request::decode(&extra).is_err());
        assert!(Request::decode(&[0xEE]).is_err());
    }

    #[test]
    fn delta_frames_reject_truncation_and_trailing() {
        let enc = Response::WeightsDelta(WeightDelta {
            seq: 12,
            n: 50,
            full: false,
            indices: vec![1, 2],
            weights: vec![0.5, 1.5],
            stamps: vec![9, 10],
            param_versions: vec![3, 4],
        })
        .encode();
        // Every strict prefix must fail cleanly, never panic.
        for cut in 0..enc.len() {
            assert!(Response::decode(&enc[..cut]).is_err(), "prefix {cut} decoded");
        }
        let mut extra = enc.clone();
        extra.push(0);
        assert!(Response::decode(&extra).is_err());

        let enc = Request::FetchWeightsSince { seq: 7 }.encode();
        assert!(Request::decode(&enc[..enc.len() - 1]).is_err());
        let mut extra = enc;
        extra.push(0);
        assert!(Request::decode(&extra).is_err());
    }

    #[test]
    fn delta_rejects_absurd_table_size() {
        // A tiny frame claiming a near-usize::MAX table must not decode
        // (the consumer would try to allocate it on apply).
        let mut p = vec![0x87u8];
        p.extend(1u64.to_le_bytes()); // seq
        p.extend(u64::MAX.to_le_bytes()); // n
        p.push(1); // full
        put_u64s(&mut p, &[]);
        put_f64s(&mut p, &[]);
        put_u64s(&mut p, &[]);
        put_u64s(&mut p, &[]);
        assert!(Response::decode(&p).is_err());
    }

    #[test]
    fn delta_rejects_mismatched_columns() {
        // Hand-craft a frame whose index column is longer than the rest.
        let mut p = vec![0x87u8];
        p.extend(5u64.to_le_bytes()); // seq
        p.extend(10u64.to_le_bytes()); // n
        p.push(0); // full = false
        put_u64s(&mut p, &[1, 2, 3]); // 3 indices
        put_f64s(&mut p, &[0.5]); // ...but 1 weight
        put_u64s(&mut p, &[7]);
        put_u64s(&mut p, &[1]);
        assert!(Response::decode(&p).is_err());
    }

    #[test]
    fn framing_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap(), b"");
    }

    #[test]
    fn frame_length_cap_enforced() {
        let bad = (MAX_FRAME as u32 + 1).to_le_bytes();
        let mut stream: Vec<u8> = bad.to_vec();
        stream.extend([0u8; 16]);
        assert!(read_frame(&mut &stream[..]).is_err());
    }

    #[test]
    fn err_response_into_result() {
        assert!(Response::Err("x".into()).into_result().is_err());
        assert!(Response::Ok.into_result().is_ok());
    }
}
