//! Durable weight store: a [`MemStore`] serving engine journaled to disk —
//! the persistence layer the ROADMAP's production north star needs.  The
//! paper's deployment (§4.2) kept the weight database in Redis; ours kept
//! it in RAM only, so any db-server restart lost the whole table and every
//! delta cursor, forcing an O(N) re-score.  [`DurableStore`] closes that
//! gap.
//!
//! # Design
//!
//! * **Serving** is unchanged: reads (`fetch_weights`,
//!   `fetch_weights_since`, `fetch_params`) go straight to the inner
//!   [`MemStore`] and stay concurrent.  Mutations are serialized on the
//!   journal lock: apply to the `MemStore` (claiming the write sequence),
//!   then append one checksummed frame to the active log segment — the
//!   frame *is* the wire-codec message ([`segment`]), so a journaled push
//!   is byte-compatible with the delta a fetch would ship.
//! * **Segments** (`seg-XXXXXXXX.log`) roll at
//!   [`DurableOptions::segment_bytes`].  Every append is flushed to the
//!   OS, so a process crash loses nothing;
//!   [`DurableOptions::fsync`] additionally `fdatasync`s each append for
//!   power-loss durability.
//! * **Compaction** (threshold-triggered at
//!   [`DurableOptions::compact_after_bytes`], or explicit via
//!   [`DurableStore::compact`]): fold in-memory history up to the oldest
//!   saved consumer cursor ([`MemStore::compact_before`] — the cursor
//!   pins are the safety contract on
//!   [`WeightStore::save_cursor`]), write a full-snapshot checkpoint
//!   (`snap-XXXXXXXX.snap`, atomic tmp+rename+fsync), start a fresh
//!   segment, and delete everything the snapshot supersedes.  Disk usage
//!   is therefore bounded by snapshot size + `compact_after_bytes` +
//!   the active segment, and `write_seqs` history is finally truncated.
//! * **Recovery** ([`DurableStore::open`]): load the newest snapshot that
//!   scans clean, replay every later segment in order, truncate a torn
//!   final frame (the crash shape) instead of failing, and continue on a
//!   fresh segment.  Write sequences, stamps, parameter state, the
//!   compaction floor, saved consumer cursors and the store clock are all
//!   reproduced bit-exactly, so surviving consumers keep fetching
//!   *incrementally* across the restart — the whole point.
//!
//! # Snapshot format
//!
//! A snapshot is itself a frame file ([`segment`]): a [`SnapshotMeta`]
//! header, a params frame, one cursor frame per saved consumer, then the
//! full table as delta frames *grouped by write sequence* (ascending), so
//! loading is exactly the replay path and per-entry sequences survive.
//! After compaction most entries share the floor sequence, so the common
//! shape is one big frame plus a short recent tail.

use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::segment::{
    self, append_record, scan_file, Record, SnapshotMeta, SEGMENT_MAGIC, SNAPSHOT_MAGIC,
};
use super::{MemStore, StoreStats, WeightDelta, WeightSnapshot, WeightStore};
use crate::{log_info, log_warn};

/// Entries per snapshot delta frame (keeps frames under the codec cap for
/// any table size).
const SNAP_CHUNK: usize = 1 << 20;

/// Tuning knobs for [`DurableStore`].
#[derive(Debug, Clone)]
pub struct DurableOptions {
    /// Seal + roll the active segment at this many bytes.
    pub segment_bytes: u64,
    /// Run the compactor once this many journal bytes accumulated since
    /// the last snapshot (`0` = explicit [`DurableStore::compact`] only).
    pub compact_after_bytes: u64,
    /// `fdatasync` every append (power-loss durability).  Off by default:
    /// appends are still flushed to the OS, which survives process
    /// crashes — the shape the tests simulate.
    pub fsync: bool,
}

impl Default for DurableOptions {
    fn default() -> DurableOptions {
        DurableOptions {
            segment_bytes: 1 << 20,
            compact_after_bytes: 8 << 20,
            fsync: false,
        }
    }
}

struct LogState {
    file: BufWriter<File>,
    seg_index: u64,
    seg_bytes: u64,
    since_snapshot: u64,
}

/// The persistent [`WeightStore`] backend.  See the module docs.
pub struct DurableStore {
    mem: MemStore,
    dir: PathBuf,
    opts: DurableOptions,
    init_weight: f64,
    log: Mutex<LogState>,
    /// Set when a journal append fails: the in-memory state is then ahead
    /// of disk, so further mutations are refused rather than silently
    /// widening the recovery gap.
    wounded: AtomicBool,
    compactions_total: AtomicU64,
}

impl DurableStore {
    /// Initialise a fresh store at `dir` (created if missing; must not
    /// already hold a durable store).
    pub fn create(
        dir: &Path,
        n: usize,
        init_weight: f64,
        opts: DurableOptions,
    ) -> Result<DurableStore> {
        fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        let existing = segment::list_numbered(dir, "snap-", ".snap")?;
        anyhow::ensure!(
            existing.is_empty(),
            "{} already holds a durable store (snapshot {} present); use open",
            dir.display(),
            existing[0].0
        );
        // No snapshot ⇒ nothing here is durable yet: clear any debris a
        // crash mid-create left behind (a bare segment, a half-written
        // snapshot tmp) so `create_new` below cannot trip over it.
        gc_below(dir, u64::MAX);
        let mem = MemStore::new(n, init_weight);
        let store = DurableStore {
            mem,
            dir: dir.to_path_buf(),
            opts,
            init_weight,
            log: Mutex::new(open_segment(dir, 1)?),
            wounded: AtomicBool::new(false),
            compactions_total: AtomicU64::new(0),
        };
        // Checkpoint the initial state so `open` always has a snapshot to
        // start from; cover = 1 means "replay segment 1 onwards".
        store.write_checkpoint(1, store.mem.compact_floor())?;
        Ok(store)
    }

    /// Recover a store previously created at `dir`: newest valid snapshot
    /// + replay of the segment tail, truncating a torn final frame.
    pub fn open(dir: &Path, opts: DurableOptions) -> Result<DurableStore> {
        let snaps = segment::list_numbered(dir, "snap-", ".snap")?;
        anyhow::ensure!(
            !snaps.is_empty(),
            "{} holds no snapshot — not a durable store (use create)",
            dir.display()
        );
        // Newest snapshot that scans clean and complete wins.
        let mut chosen: Option<(SnapshotMeta, Vec<Record>)> = None;
        for (cover, path) in snaps.iter().rev() {
            match scan_file(path, SNAPSHOT_MAGIC) {
                Ok(scan) if !scan.torn => match scan.records.split_first() {
                    Some((Record::Meta(meta), rest)) => {
                        chosen = Some((meta.clone(), rest.to_vec()));
                        break;
                    }
                    _ => log_warn!("db", "snapshot {cover} lacks a header; skipping"),
                },
                Ok(_) => log_warn!("db", "snapshot {cover} is torn; falling back"),
                Err(e) => log_warn!("db", "snapshot {cover} unreadable ({e}); falling back"),
            }
        }
        let (meta, records) = chosen.context("no valid snapshot found")?;
        let mem = MemStore::new(meta.n as usize, meta.init_weight);
        for rec in &records {
            apply_record(&mem, rec, true)?;
        }
        mem.restore_floor(meta.floor);
        mem.force_write_seq(meta.next_seq);
        mem.advance_clock_to(meta.clock);

        // Replay segments the snapshot does not cover, oldest first.  Only
        // the FINAL segment may be torn (that is where a crash lands);
        // damage anywhere earlier means real data loss and is an error.
        let segs = segment::list_numbered(dir, "seg-", ".log")?;
        let live: Vec<&(u64, PathBuf)> = segs.iter().filter(|(k, _)| *k >= meta.cover).collect();
        let mut max_index = meta.cover.saturating_sub(1);
        let mut replayed_bytes = 0u64;
        for (pos, (k, path)) in live.iter().enumerate() {
            let scan = scan_file(path, SEGMENT_MAGIC)?;
            if scan.torn {
                // A magic-level stub — the crash landed during segment
                // creation, so the file never held a durable record — is
                // recognised by the ACTUAL file size (not the valid
                // prefix: a sealed segment whose first frame rotted also
                // scans to zero records, but its on-disk length betrays
                // it) AND by being the newest segment (creation stubs are
                // by construction where the journal ends).  Deleting it
                // is lossless — and required, or a later open would see a
                // non-final torn segment and refuse to recover.  Any
                // other tear away from the journal's end is real damage
                // and stays a hard error.
                if fs::metadata(path)?.len() < 8 && pos + 1 == live.len() {
                    log_warn!("db", "removing torn segment-creation stub {}", path.display());
                    let _ = fs::remove_file(path);
                    max_index = max_index.max(*k);
                    continue;
                }
                anyhow::ensure!(
                    pos + 1 == live.len(),
                    "corrupt frame mid-journal in {} (not the final segment)",
                    path.display()
                );
                log_warn!(
                    "db",
                    "truncating torn tail of {} at byte {}",
                    path.display(),
                    scan.valid_len
                );
                let f = OpenOptions::new().write(true).open(path)?;
                f.set_len(scan.valid_len)?;
                let _ = f.sync_all();
            }
            for rec in &scan.records {
                apply_record(&mem, rec, false)?;
            }
            replayed_bytes += scan.valid_len.saturating_sub(8);
            max_index = max_index.max(*k);
        }

        let next_index = max_index + 1;
        let store = DurableStore {
            mem,
            dir: dir.to_path_buf(),
            init_weight: meta.init_weight,
            log: Mutex::new(open_segment(dir, next_index)?),
            opts,
            wounded: AtomicBool::new(false),
            compactions_total: AtomicU64::new(0),
        };
        store.log.lock().unwrap().since_snapshot = replayed_bytes;
        // GC anything the chosen snapshot superseded (stray tmp files too).
        gc_below(dir, meta.cover);
        log_info!(
            "db",
            "recovered durable store at {}: n={} seq={} floor={} (snapshot {}, {} segment bytes replayed)",
            dir.display(),
            store.mem.n_examples(),
            store.mem.write_seq(),
            store.mem.compact_floor(),
            meta.cover,
            replayed_bytes
        );
        Ok(store)
    }

    /// [`DurableStore::open`] when `dir` holds a store (whose size must
    /// match `n`), [`DurableStore::create`] otherwise.
    pub fn open_or_create(
        dir: &Path,
        n: usize,
        init_weight: f64,
        opts: DurableOptions,
    ) -> Result<DurableStore> {
        let has_snapshot = dir.is_dir()
            && !segment::list_numbered(dir, "snap-", ".snap")?.is_empty();
        if has_snapshot {
            let store = Self::open(dir, opts)?;
            anyhow::ensure!(
                store.mem.n_examples() == n,
                "store at {} tracks {} examples, run needs {n}",
                dir.display(),
                store.mem.n_examples()
            );
            Ok(store)
        } else {
            Self::create(dir, n, init_weight, opts)
        }
    }

    /// Directory this store persists into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn n_examples(&self) -> usize {
        self.mem.n_examples()
    }

    /// Current global write sequence (mirrors [`MemStore::write_seq`]).
    pub fn write_seq(&self) -> u64 {
        self.mem.write_seq()
    }

    /// Current compaction floor (mirrors [`MemStore::compact_floor`]).
    pub fn compact_floor(&self) -> u64 {
        self.mem.compact_floor()
    }

    /// Compactions run by this instance (the counter does not persist).
    pub fn compactions(&self) -> u64 {
        self.compactions_total.load(Ordering::Relaxed)
    }

    /// Total bytes currently on disk (segments + snapshots).
    pub fn disk_bytes(&self) -> Result<u64> {
        let mut total = 0u64;
        for entry in fs::read_dir(&self.dir)? {
            total += entry?.metadata()?.len();
        }
        Ok(total)
    }

    /// Fold history, checkpoint, and GC now (also runs automatically at
    /// [`DurableOptions::compact_after_bytes`]).
    pub fn compact(&self) -> Result<()> {
        let mut log = self.log.lock().unwrap();
        self.check_wounded()?;
        self.compact_locked(&mut log)
    }

    fn check_wounded(&self) -> Result<()> {
        anyhow::ensure!(
            !self.wounded.load(Ordering::Acquire),
            "durable store wounded by an earlier journal failure; reopen to recover"
        );
        Ok(())
    }

    /// Append `rec` to the active segment (flush-per-record; optional
    /// fsync).  On failure the store is marked wounded: memory is ahead of
    /// disk and further mutations would widen the gap.
    fn append(&self, log: &mut LogState, rec: &Record) -> Result<()> {
        let res = (|| -> Result<u64> {
            let bytes = append_record(&mut log.file, rec)?;
            log.file.flush()?;
            if self.opts.fsync {
                log.file.get_ref().sync_data()?;
            }
            Ok(bytes)
        })();
        match res {
            Ok(b) => {
                log.seg_bytes += b;
                log.since_snapshot += b;
                Ok(())
            }
            Err(e) => {
                self.wounded.store(true, Ordering::Release);
                Err(e.context("journal append failed; durable store wounded"))
            }
        }
    }

    /// Roll/compact housekeeping after a successful append.
    fn after_append(&self, log: &mut LogState) -> Result<()> {
        if log.seg_bytes >= self.opts.segment_bytes {
            self.roll_segment(log)?;
        }
        if self.opts.compact_after_bytes > 0 && log.since_snapshot >= self.opts.compact_after_bytes
        {
            self.compact_locked(log)?;
        }
        Ok(())
    }

    fn roll_segment(&self, log: &mut LogState) -> Result<()> {
        log.file.flush()?;
        let _ = log.file.get_ref().sync_data();
        let mut fresh = open_segment(&self.dir, log.seg_index + 1)?;
        fresh.since_snapshot = log.since_snapshot;
        *log = fresh;
        Ok(())
    }

    /// The compactor.  Runs under the journal lock: writers are quiesced,
    /// readers keep going against the [`MemStore`].
    fn compact_locked(&self, log: &mut LogState) -> Result<()> {
        // 1. Fold in-memory history up to the oldest saved consumer cursor
        //    (the trait's cursor-safety contract).
        let floor = self.mem.compact_before(u64::MAX);
        // 2. Seal the active segment.
        log.file.flush()?;
        let _ = log.file.get_ref().sync_data();
        // 3. Checkpoint everything after it.
        let cover = log.seg_index + 1;
        self.write_checkpoint(cover, floor)?;
        // 4. Continue on a fresh segment; superseded files are garbage.
        *log = open_segment(&self.dir, cover)?;
        self.compactions_total.fetch_add(1, Ordering::Relaxed);
        gc_below(&self.dir, cover);
        Ok(())
    }

    /// Write `snap-{cover}.snap` atomically (tmp + fsync + rename) from
    /// the current in-memory state.
    fn write_checkpoint(&self, cover: u64, floor: u64) -> Result<()> {
        let (snap, seqs) = self.mem.dump_with_seqs();
        let (pv, pb) = self.mem.params_blob();
        let meta = SnapshotMeta {
            n: self.mem.n_examples() as u64,
            init_weight: self.init_weight,
            floor,
            next_seq: self.mem.write_seq(),
            clock: self.mem.now()?,
            cover,
        };
        let tmp = self.dir.join(format!("snap-{cover:08}.tmp"));
        let path = segment::snapshot_path(&self.dir, cover);
        {
            let file = File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
            let mut w = BufWriter::new(file);
            w.write_all(SNAPSHOT_MAGIC)?;
            append_record(&mut w, &Record::Meta(meta))?;
            append_record(&mut w, &Record::Params { version: pv, bytes: pb })?;
            for (name, seq) in self.mem.cursors_vec() {
                append_record(&mut w, &Record::Cursor { name, seq })?;
            }
            // Full table grouped by write sequence, ascending: loading is
            // exactly the replay path and per-entry sequences survive.
            let mut by_seq: std::collections::BTreeMap<u64, Vec<usize>> =
                std::collections::BTreeMap::new();
            for (i, &s) in seqs.iter().enumerate() {
                by_seq.entry(s).or_default().push(i);
            }
            for (seq, idxs) in &by_seq {
                for chunk in idxs.chunks(SNAP_CHUNK) {
                    let mut d = WeightDelta {
                        seq: *seq,
                        n: snap.len() as u64,
                        full: false,
                        ..WeightDelta::default()
                    };
                    for &i in chunk {
                        d.indices.push(i as u64);
                        d.weights.push(snap.weights[i]);
                        d.stamps.push(snap.stamps[i]);
                        d.param_versions.push(snap.param_versions[i]);
                    }
                    append_record(&mut w, &Record::Delta(d))?;
                }
            }
            w.flush()?;
            w.get_ref().sync_all()?;
        }
        fs::rename(&tmp, &path)?;
        if let Ok(d) = File::open(&self.dir) {
            let _ = d.sync_all();
        }
        Ok(())
    }
}

impl Drop for DurableStore {
    fn drop(&mut self) {
        if let Ok(mut log) = self.log.lock() {
            let _ = log.file.flush();
            let _ = log.file.get_ref().sync_data();
        }
    }
}

/// Replay one journaled/snapshot record into `mem`.  `in_snapshot`
/// restricts the record mix: grad records never appear in a checkpoint.
fn apply_record(mem: &MemStore, rec: &Record, in_snapshot: bool) -> Result<()> {
    match rec {
        Record::Delta(d) => {
            mem.restore_delta(d)?;
            if let Some(&max_stamp) = d.stamps.iter().max() {
                mem.advance_clock_to(max_stamp);
            }
        }
        Record::Params { version, bytes } => mem.restore_params(*version, bytes.clone()),
        Record::Grad { scale, grad } => {
            anyhow::ensure!(!in_snapshot, "grad record inside a snapshot");
            mem.apply_grad(*scale, grad)
                .context("replaying a journaled grad")?;
        }
        Record::Cursor { name, seq } => mem.restore_cursor(name.clone(), *seq),
        Record::Meta(_) => anyhow::bail!("unexpected meta record"),
    }
    Ok(())
}

fn open_segment(dir: &Path, index: u64) -> Result<LogState> {
    let path = segment::segment_path(dir, index);
    let file = OpenOptions::new()
        .create_new(true)
        .write(true)
        .open(&path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(SEGMENT_MAGIC)?;
    w.flush()?;
    Ok(LogState {
        file: w,
        seg_index: index,
        seg_bytes: 8,
        since_snapshot: 0,
    })
}

/// Best-effort deletion of everything a snapshot at `cover` supersedes.
fn gc_below(dir: &Path, cover: u64) {
    let doomed = |list: Result<Vec<(u64, PathBuf)>>| -> Vec<PathBuf> {
        list.map(|v| {
            v.into_iter()
                .filter(|(k, _)| *k < cover)
                .map(|(_, p)| p)
                .collect()
        })
        .unwrap_or_default()
    };
    let mut paths = doomed(segment::list_numbered(dir, "seg-", ".log"));
    paths.extend(doomed(segment::list_numbered(dir, "snap-", ".snap")));
    if let Ok(entries) = fs::read_dir(dir) {
        for entry in entries.flatten() {
            if entry.file_name().to_string_lossy().ends_with(".tmp") {
                paths.push(entry.path());
            }
        }
    }
    for p in paths {
        if let Err(e) = fs::remove_file(&p) {
            log_warn!("db", "gc could not remove {}: {e}", p.display());
        }
    }
}

impl WeightStore for DurableStore {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<()> {
        let mut log = self.log.lock().unwrap();
        self.check_wounded()?;
        self.mem.push_params(version, bytes.clone())?;
        self.append(&mut log, &Record::Params { version, bytes })?;
        self.after_append(&mut log)
    }

    fn fetch_params(&self, than: u64) -> Result<Option<(u64, Vec<u8>)>> {
        self.mem.fetch_params(than)
    }

    fn params_version(&self) -> Result<u64> {
        self.mem.params_version()
    }

    fn push_weights(&self, start: usize, weights: &[f32], param_version: u64) -> Result<()> {
        let mut log = self.log.lock().unwrap();
        self.check_wounded()?;
        let claimed = self.mem.push_weights_seq(start, weights, param_version)?;
        if let Some((seq, stamp)) = claimed {
            let mut d = WeightDelta {
                seq,
                n: self.mem.n_examples() as u64,
                full: false,
                ..WeightDelta::default()
            };
            d.indices.reserve(weights.len());
            d.weights.reserve(weights.len());
            d.stamps.reserve(weights.len());
            d.param_versions.reserve(weights.len());
            for (i, &w) in weights.iter().enumerate() {
                d.indices.push((start + i) as u64);
                d.weights.push(w as f64);
                d.stamps.push(stamp);
                d.param_versions.push(param_version);
            }
            self.append(&mut log, &Record::Delta(d))?;
            self.after_append(&mut log)?;
        }
        Ok(())
    }

    fn fetch_weights(&self) -> Result<WeightSnapshot> {
        self.mem.fetch_weights()
    }

    fn fetch_weights_since(&self, seq: u64) -> Result<WeightDelta> {
        self.mem.fetch_weights_since(seq)
    }

    fn apply_grad(&self, scale: f32, grad: &[f32]) -> Result<u64> {
        let mut log = self.log.lock().unwrap();
        self.check_wounded()?;
        let v = self.mem.apply_grad(scale, grad)?;
        self.append(
            &mut log,
            &Record::Grad {
                scale,
                grad: grad.to_vec(),
            },
        )?;
        self.after_append(&mut log)?;
        Ok(v)
    }

    fn save_cursor(&self, name: &str, seq: u64) -> Result<()> {
        let mut log = self.log.lock().unwrap();
        self.check_wounded()?;
        self.mem.save_cursor(name, seq)?;
        // Journal the clamped value actually stored.
        let stored = self.mem.load_cursor(name)?.unwrap_or(seq);
        self.append(
            &mut log,
            &Record::Cursor {
                name: name.to_string(),
                seq: stored,
            },
        )?;
        self.after_append(&mut log)
    }

    fn load_cursor(&self, name: &str) -> Result<Option<u64>> {
        self.mem.load_cursor(name)
    }

    fn now(&self) -> Result<u64> {
        self.mem.now()
    }

    fn stats(&self) -> Result<StoreStats> {
        self.mem.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct TempDir(PathBuf);

    impl TempDir {
        fn new(tag: &str) -> TempDir {
            static NEXT: AtomicU64 = AtomicU64::new(0);
            let k = NEXT.fetch_add(1, Ordering::Relaxed);
            let path = std::env::temp_dir()
                .join(format!("issgd-durable-{tag}-{}-{k}", std::process::id()));
            let _ = fs::remove_dir_all(&path);
            TempDir(path)
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.0);
        }
    }

    fn opts_manual() -> DurableOptions {
        DurableOptions {
            segment_bytes: 1 << 20,
            compact_after_bytes: 0,
            fsync: false,
        }
    }

    #[test]
    fn state_survives_crash_and_reopen_bit_exactly() {
        let dir = TempDir::new("roundtrip");
        let store = DurableStore::create(&dir.0, 32, 1.0, opts_manual()).unwrap();
        store.push_weights(3, &[2.0, 3.0, 4.0], 5).unwrap();
        store.push_weights(20, &[9.0], 6).unwrap();
        store.push_params(1, vec![0u8; 8]).unwrap();
        store.apply_grad(0.5, &[2.0, -2.0]).unwrap();
        store.save_cursor("master", store.write_seq()).unwrap();
        let want_table = store.fetch_weights().unwrap();
        let want_seq = store.write_seq();
        let want_params = store.fetch_params(0).unwrap();
        let want_now = store.now().unwrap();
        drop(store); // crash: appends were already flushed per-record

        let back = DurableStore::open(&dir.0, opts_manual()).unwrap();
        // Stamps included: the journal reproduces entries exactly.
        assert_eq!(back.fetch_weights().unwrap(), want_table);
        assert_eq!(back.write_seq(), want_seq);
        assert_eq!(back.fetch_params(0).unwrap(), want_params);
        assert_eq!(back.load_cursor("master").unwrap(), Some(want_seq));
        // The recovered clock never runs backwards past old stamps.
        let max_stamp = want_table.stamps.iter().copied().max().unwrap();
        assert!(back.now().unwrap() >= want_now.min(max_stamp));
        // A consumer at its saved cursor continues incrementally.
        let d = back.fetch_weights_since(want_seq).unwrap();
        assert!(!d.full);
        assert!(d.is_empty());
        // And the store keeps working.
        back.push_weights(0, &[7.0], 9).unwrap();
        let d = back.fetch_weights_since(want_seq).unwrap();
        assert_eq!(d.indices, vec![0]);
        assert_eq!(d.weights, vec![7.0]);
    }

    #[test]
    fn reopen_after_reopen_is_stable() {
        let dir = TempDir::new("twice");
        let store = DurableStore::create(&dir.0, 8, 0.5, opts_manual()).unwrap();
        store.push_weights(1, &[3.0], 1).unwrap();
        drop(store);
        let a = DurableStore::open(&dir.0, opts_manual()).unwrap();
        a.push_weights(2, &[4.0], 2).unwrap();
        let want = a.fetch_weights().unwrap();
        let seq = a.write_seq();
        drop(a);
        let b = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(b.fetch_weights().unwrap(), want);
        assert_eq!(b.write_seq(), seq);
    }

    #[test]
    fn compaction_bounds_files_and_keeps_pinned_consumers_incremental() {
        let dir = TempDir::new("compact");
        let opts = DurableOptions {
            segment_bytes: 1 << 12,
            compact_after_bytes: 1 << 13,
            fsync: false,
        };
        let store = DurableStore::create(&dir.0, 64, 1.0, opts).unwrap();
        let mut cursor = store.fetch_weights_since(0).unwrap().seq;
        let mut mirror = store.fetch_weights().unwrap();
        for round in 0..400u64 {
            let vals: Vec<f32> = (0..8).map(|i| (round + i) as f32 + 1.0).collect();
            store.push_weights((round as usize * 8) % 56, &vals, round + 1).unwrap();
            let d = store.fetch_weights_since(cursor).unwrap();
            assert!(!d.full, "pinned consumer demoted to full at round {round}");
            d.apply_to(&mut mirror).unwrap();
            cursor = d.seq;
            store.save_cursor("me", cursor).unwrap();
        }
        assert!(store.compactions() >= 2, "compactor never triggered");
        assert!(store.compact_floor() > 0);
        assert_eq!(mirror, store.fetch_weights().unwrap());
        // GC really deletes: the directory holds the latest snapshot plus
        // a small number of live segments, not 400 rounds of history.
        let files = fs::read_dir(&dir.0).unwrap().count();
        assert!(files <= 6, "GC left {files} files behind");
        // Recovery from the compacted state still works.
        let want = store.fetch_weights().unwrap();
        drop(store);
        let back = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(back.fetch_weights().unwrap(), want);
        assert_eq!(back.load_cursor("me").unwrap(), Some(cursor));
        assert!(!back.fetch_weights_since(cursor).unwrap().full);
    }

    #[test]
    fn explicit_compact_folds_below_oldest_pin() {
        let dir = TempDir::new("pin");
        let store = DurableStore::create(&dir.0, 16, 1.0, opts_manual()).unwrap();
        for i in 0..8 {
            store.push_weights(i, &[i as f32 + 2.0], 1).unwrap();
        }
        store.save_cursor("slow", 4).unwrap();
        store.save_cursor("fast", store.write_seq()).unwrap();
        store.compact().unwrap();
        assert_eq!(store.compact_floor(), 4);
        // The slow consumer still gets precise deltas from its pin.
        let d = store.fetch_weights_since(4).unwrap();
        assert!(!d.full);
        assert_eq!(d.indices, vec![3, 4, 5, 6, 7]);
    }

    #[test]
    fn create_refuses_an_existing_store_and_open_refuses_an_empty_dir() {
        let dir = TempDir::new("guard");
        let store = DurableStore::create(&dir.0, 4, 1.0, opts_manual()).unwrap();
        drop(store);
        assert!(DurableStore::create(&dir.0, 4, 1.0, opts_manual()).is_err());
        let empty = TempDir::new("empty");
        fs::create_dir_all(&empty.0).unwrap();
        assert!(DurableStore::open(&empty.0, opts_manual()).is_err());
    }

    #[test]
    fn open_or_create_checks_the_table_size() {
        let dir = TempDir::new("size");
        let store = DurableStore::open_or_create(&dir.0, 8, 1.0, opts_manual()).unwrap();
        store.push_weights(0, &[2.0], 1).unwrap();
        drop(store);
        assert!(DurableStore::open_or_create(&dir.0, 9, 1.0, opts_manual()).is_err());
        let back = DurableStore::open_or_create(&dir.0, 8, 1.0, opts_manual()).unwrap();
        assert_eq!(back.fetch_weights().unwrap().weights[0], 2.0);
    }

    #[test]
    fn torn_final_frame_is_truncated_on_open() {
        let dir = TempDir::new("torn");
        let store = DurableStore::create(&dir.0, 8, 1.0, opts_manual()).unwrap();
        store.push_weights(0, &[5.0], 1).unwrap();
        store.push_weights(1, &[6.0], 2).unwrap();
        drop(store);
        // Append half a frame header to the active segment: the classic
        // crash-mid-append shape.
        let segs = segment::list_numbered(&dir.0, "seg-", ".log").unwrap();
        let (_, last) = segs.last().unwrap();
        let mut f = OpenOptions::new().append(true).open(last).unwrap();
        f.write_all(&[0xAB, 0xCD, 0xEF]).unwrap();
        drop(f);
        let back = DurableStore::open(&dir.0, opts_manual()).unwrap();
        let snap = back.fetch_weights().unwrap();
        assert_eq!(snap.weights[0], 5.0);
        assert_eq!(snap.weights[1], 6.0);
        // The tear is gone from disk: another open replays cleanly.
        drop(back);
        let again = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(again.fetch_weights().unwrap(), snap);
    }

    #[test]
    fn magic_level_stub_does_not_brick_later_reopens() {
        // Crash DURING segment creation: the newest segment is shorter
        // than its magic.  The first reopen must absorb that; the second
        // reopen must not refuse recovery because a non-final torn stub
        // is sitting mid-journal (regression: recovery used to truncate
        // the stub to zero bytes and keep it forever).
        let dir = TempDir::new("stub");
        let store = DurableStore::create(&dir.0, 8, 1.0, opts_manual()).unwrap();
        store.push_weights(0, &[5.0], 1).unwrap();
        let want = store.fetch_weights().unwrap();
        drop(store);
        // Simulate the torn-creation stub as the newest segment.
        let segs = segment::list_numbered(&dir.0, "seg-", ".log").unwrap();
        let (top, _) = segs.last().unwrap();
        std::fs::write(segment::segment_path(&dir.0, top + 1), [0x49u8, 0x53]).unwrap();
        let back = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(back.fetch_weights().unwrap(), want);
        back.push_weights(1, &[6.0], 2).unwrap();
        let want = back.fetch_weights().unwrap();
        drop(back);
        // Second reopen: the stub must be gone, recovery clean.
        let again = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(again.fetch_weights().unwrap(), want);
        for (_, path) in segment::list_numbered(&dir.0, "seg-", ".log").unwrap() {
            assert!(std::fs::metadata(&path).unwrap().len() >= 8, "stub survived recovery");
        }
    }

    #[test]
    fn grad_replay_reproduces_parameters() {
        let dir = TempDir::new("grad");
        let store = DurableStore::create(&dir.0, 4, 1.0, opts_manual()).unwrap();
        let mut blob = Vec::new();
        for v in [1.0f32, 2.0] {
            blob.extend(v.to_le_bytes());
        }
        store.push_params(1, blob).unwrap();
        store.apply_grad(0.25, &[4.0, -4.0]).unwrap();
        store.apply_grad(0.25, &[4.0, -4.0]).unwrap();
        let want = store.fetch_params(0).unwrap().unwrap();
        assert_eq!(want.0, 3);
        drop(store);
        let back = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(back.fetch_params(0).unwrap().unwrap(), want);
        assert_eq!(back.params_version().unwrap(), 3);
    }
}
