//! Durable weight store: a [`MemStore`] serving engine journaled to disk —
//! the persistence layer the ROADMAP's production north star needs.  The
//! paper's deployment (§4.2) kept the weight database in Redis; ours kept
//! it in RAM only, so any db-server restart lost the whole table and every
//! delta cursor, forcing an O(N) re-score.  [`DurableStore`] closes that
//! gap.
//!
//! # Design
//!
//! * **Serving** is unchanged: reads (`fetch_weights`,
//!   `fetch_weights_since`, `fetch_params`, `fetch_params_since`) go
//!   straight to the inner [`MemStore`] and stay concurrent.  Mutations
//!   are serialized on the journal lock: apply to the `MemStore`
//!   (claiming the write sequence), then append one checksummed frame to
//!   the active log segment — the frame *is* the wire-codec message
//!   ([`segment`]), so a journaled push is byte-compatible with the delta
//!   a fetch would ship.  A layer-wise parameter publish journals only
//!   the layers it carried ([`segment::Record::ParamsLayers`]), never the
//!   whole blob.
//! * **Segments** (`seg-XXXXXXXX.log`) roll at
//!   [`DurableOptions::segment_bytes`].  Every append is flushed to the
//!   OS, so a process crash loses nothing;
//!   [`DurableOptions::fsync`] additionally `fdatasync`s each append for
//!   power-loss durability.
//! * **Compaction** runs on a dedicated **background thread** (signalled
//!   at [`DurableOptions::compact_after_bytes`] journal bytes, or driven
//!   synchronously via [`DurableStore::compact`]): expire stale consumer
//!   cursors ([`DurableOptions::cursor_max_age`] — a dead consumer's pin
//!   no longer blocks the floor forever), fold in-memory history up to
//!   the oldest surviving saved cursor ([`MemStore::compact_before`] —
//!   the cursor pins are the safety contract on
//!   [`WeightStore::save_cursor`]), then — briefly under the journal
//!   lock — seal the active segment, memcpy a point-in-time dump, and
//!   start a fresh segment.  Serialization, checksumming, fsync and GC of
//!   the snapshot (`snap-XXXXXXXX.snap`, atomic tmp+rename+fsync) all
//!   happen *off* the journal lock, so the push hot path never pays a
//!   fold-checkpoint-GC cycle inline — its worst case is the seal+dump
//!   memcpy.  Disk usage stays bounded by snapshot size +
//!   `compact_after_bytes` + the active segment.
//! * **Recovery** ([`DurableStore::open`]): load the newest snapshot that
//!   scans clean, replay every later segment in order, truncate a torn
//!   final frame (the crash shape) instead of failing, and continue on a
//!   fresh segment.  Write sequences, stamps, parameter layers (bytes,
//!   per-layer versions, head version, params floor), the compaction
//!   floor, saved consumer cursors (with their save stamps) and the store
//!   clock are all reproduced bit-exactly, so surviving consumers keep
//!   fetching *incrementally* across the restart — weights **and**
//!   params — which is the whole point.
//!
//! # Snapshot format
//!
//! A snapshot is itself a frame file ([`segment`]): a [`SnapshotMeta`]
//! header, one params-layer patch record per layer (layout order, each
//! tagged with the params version that last wrote it — the differential
//! checkpoint shape: after a steady run most layers share an old base
//! version and only the recently-patched ones differ), one cursor frame
//! per saved consumer, then the full weight table as delta frames
//! *grouped by write sequence* (ascending), so loading is exactly the
//! replay path and per-entry sequences survive.

use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{Context, Result};

use super::segment::{
    self, append_record, scan_file, Record, SnapshotMeta, SEGMENT_MAGIC, SNAPSHOT_MAGIC,
};
use super::{LayerChunk, MemStore, ParamsDelta, StoreStats, WeightDelta, WeightSnapshot, WeightStore};
use crate::{log_info, log_warn};

/// Entries per snapshot delta frame (keeps frames under the codec cap for
/// any table size).
const SNAP_CHUNK: usize = 1 << 20;

/// Tuning knobs for [`DurableStore`].
#[derive(Debug, Clone)]
pub struct DurableOptions {
    /// Seal + roll the active segment at this many bytes.
    pub segment_bytes: u64,
    /// Signal the background compactor once this many journal bytes
    /// accumulated since the last snapshot (`0` = explicit
    /// [`DurableStore::compact`] only, and no compactor thread is
    /// spawned).
    pub compact_after_bytes: u64,
    /// `fdatasync` every append (power-loss durability).  Off by default:
    /// appends are still flushed to the OS, which survives process
    /// crashes — the shape the tests simulate.
    pub fsync: bool,
    /// Expire saved consumer cursors not re-saved for this long (store
    /// clock) at the start of every compaction.  `None` (default) keeps
    /// the old behaviour: pins live until dropped.  An expired consumer
    /// that returns simply degrades to the full-table fallback on its
    /// next fetch — the documented trade for an unblockable floor.
    pub cursor_max_age: Option<std::time::Duration>,
}

impl Default for DurableOptions {
    fn default() -> DurableOptions {
        DurableOptions {
            segment_bytes: 1 << 20,
            compact_after_bytes: 8 << 20,
            fsync: false,
            cursor_max_age: None,
        }
    }
}

struct LogState {
    file: BufWriter<File>,
    seg_index: u64,
    seg_bytes: u64,
    since_snapshot: u64,
}

/// Background-compactor doorbell.
struct CompactorSignal {
    /// A compaction is requested or in flight (cleared when the run
    /// finishes, so [`DurableStore::quiesce_compactor`] can wait on it).
    pending: bool,
    shutdown: bool,
}

/// Point-in-time dump the checkpoint writer serializes off the journal
/// lock: taking it is a memcpy; everything expensive happens later.
struct CheckpointState {
    meta: SnapshotMeta,
    /// Layer chunks in layout order, each with its last-write version.
    params: Vec<LayerChunk>,
    /// `(name, seq, saved_at)` per saved consumer cursor.
    cursors: Vec<(String, u64, u64)>,
    snap: WeightSnapshot,
    seqs: Vec<u64>,
}

/// Everything shared between the serving handle and the compactor thread.
struct Core {
    mem: MemStore,
    dir: PathBuf,
    opts: DurableOptions,
    init_weight: f64,
    log: Mutex<LogState>,
    /// Set when a journal append fails: the in-memory state is then ahead
    /// of disk, so further mutations are refused rather than silently
    /// widening the recovery gap.
    wounded: AtomicBool,
    compactions_total: AtomicU64,
    /// Serializes compaction cycles (background vs explicit).
    compact_serial: Mutex<()>,
    signal: Mutex<CompactorSignal>,
    signal_cv: Condvar,
}

/// The persistent [`WeightStore`] backend.  See the module docs.
pub struct DurableStore {
    core: Arc<Core>,
    /// Joined on drop, so no compaction outlives the store handle.
    compactor: Option<std::thread::JoinHandle<()>>,
}

impl DurableStore {
    /// Initialise a fresh store at `dir` (created if missing; must not
    /// already hold a durable store).
    pub fn create(
        dir: &Path,
        n: usize,
        init_weight: f64,
        opts: DurableOptions,
    ) -> Result<DurableStore> {
        fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        let existing = segment::list_numbered(dir, "snap-", ".snap")?;
        anyhow::ensure!(
            existing.is_empty(),
            "{} already holds a durable store (snapshot {} present); use open",
            dir.display(),
            existing[0].0
        );
        // No snapshot ⇒ nothing here is durable yet: clear any debris a
        // crash mid-create left behind (a bare segment, a half-written
        // snapshot tmp) so `create_new` below cannot trip over it.
        gc_below(dir, u64::MAX);
        let mem = MemStore::new(n, init_weight);
        let core = Arc::new(Core {
            mem,
            dir: dir.to_path_buf(),
            opts,
            init_weight,
            log: Mutex::new(open_segment(dir, 1)?),
            wounded: AtomicBool::new(false),
            compactions_total: AtomicU64::new(0),
            compact_serial: Mutex::new(()),
            signal: Mutex::new(CompactorSignal {
                pending: false,
                shutdown: false,
            }),
            signal_cv: Condvar::new(),
        });
        // Checkpoint the initial state so `open` always has a snapshot to
        // start from; cover = 1 means "replay segment 1 onwards".
        let state = core.dump_state(core.mem.compact_floor(), 1)?;
        core.write_checkpoint(&state)?;
        Ok(Self::with_compactor(core))
    }

    /// Recover a store previously created at `dir`: newest valid snapshot
    /// + replay of the segment tail, truncating a torn final frame.
    pub fn open(dir: &Path, opts: DurableOptions) -> Result<DurableStore> {
        let snaps = segment::list_numbered(dir, "snap-", ".snap")?;
        anyhow::ensure!(
            !snaps.is_empty(),
            "{} holds no snapshot — not a durable store (use create)",
            dir.display()
        );
        // Newest snapshot that scans clean and complete wins.
        let mut chosen: Option<(SnapshotMeta, Vec<Record>)> = None;
        for (cover, path) in snaps.iter().rev() {
            match scan_file(path, SNAPSHOT_MAGIC) {
                Ok(scan) if !scan.torn => match scan.records.split_first() {
                    Some((Record::Meta(meta), rest)) => {
                        chosen = Some((meta.clone(), rest.to_vec()));
                        break;
                    }
                    _ => log_warn!("db", "snapshot {cover} lacks a header; skipping"),
                },
                Ok(_) => log_warn!("db", "snapshot {cover} is torn; falling back"),
                Err(e) => log_warn!("db", "snapshot {cover} unreadable ({e}); falling back"),
            }
        }
        let (meta, records) = chosen.context("no valid snapshot found")?;
        let mem = MemStore::new(meta.n as usize, meta.init_weight);
        for rec in &records {
            apply_record(&mem, rec, true)?;
        }
        // Snapshot params records only append layers; the head version
        // and floor live in the meta.
        mem.restore_params_meta(meta.params_version, meta.params_floor);
        mem.restore_floor(meta.floor);
        mem.force_write_seq(meta.next_seq);
        mem.advance_clock_to(meta.clock);

        // Replay segments the snapshot does not cover, oldest first.  Only
        // the FINAL segment may be torn (that is where a crash lands);
        // damage anywhere earlier means real data loss and is an error.
        let segs = segment::list_numbered(dir, "seg-", ".log")?;
        let live: Vec<&(u64, PathBuf)> = segs.iter().filter(|(k, _)| *k >= meta.cover).collect();
        let mut max_index = meta.cover.saturating_sub(1);
        let mut replayed_bytes = 0u64;
        for (pos, (k, path)) in live.iter().enumerate() {
            let scan = scan_file(path, SEGMENT_MAGIC)?;
            if scan.torn {
                // A magic-level stub — the crash landed during segment
                // creation, so the file never held a durable record — is
                // recognised by the ACTUAL file size (not the valid
                // prefix: a sealed segment whose first frame rotted also
                // scans to zero records, but its on-disk length betrays
                // it) AND by being the newest segment (creation stubs are
                // by construction where the journal ends).  Deleting it
                // is lossless — and required, or a later open would see a
                // non-final torn segment and refuse to recover.  Any
                // other tear away from the journal's end is real damage
                // and stays a hard error.
                if fs::metadata(path)?.len() < 8 && pos + 1 == live.len() {
                    log_warn!("db", "removing torn segment-creation stub {}", path.display());
                    let _ = fs::remove_file(path);
                    max_index = max_index.max(*k);
                    continue;
                }
                anyhow::ensure!(
                    pos + 1 == live.len(),
                    "corrupt frame mid-journal in {} (not the final segment)",
                    path.display()
                );
                log_warn!(
                    "db",
                    "truncating torn tail of {} at byte {}",
                    path.display(),
                    scan.valid_len
                );
                let f = OpenOptions::new().write(true).open(path)?;
                f.set_len(scan.valid_len)?;
                let _ = f.sync_all();
            }
            for rec in &scan.records {
                apply_record(&mem, rec, false)?;
            }
            replayed_bytes += scan.valid_len.saturating_sub(8);
            max_index = max_index.max(*k);
        }

        let next_index = max_index + 1;
        let core = Arc::new(Core {
            mem,
            dir: dir.to_path_buf(),
            init_weight: meta.init_weight,
            log: Mutex::new(open_segment(dir, next_index)?),
            opts,
            wounded: AtomicBool::new(false),
            compactions_total: AtomicU64::new(0),
            compact_serial: Mutex::new(()),
            signal: Mutex::new(CompactorSignal {
                pending: false,
                shutdown: false,
            }),
            signal_cv: Condvar::new(),
        });
        core.log.lock().unwrap().since_snapshot = replayed_bytes;
        // GC anything the chosen snapshot superseded (stray tmp files too).
        gc_below(dir, meta.cover);
        log_info!(
            "db",
            "recovered durable store at {}: n={} seq={} floor={} (snapshot {}, {} segment bytes replayed)",
            dir.display(),
            core.mem.n_examples(),
            core.mem.write_seq(),
            core.mem.compact_floor(),
            meta.cover,
            replayed_bytes
        );
        Ok(Self::with_compactor(core))
    }

    /// Wrap a recovered/created core, spawning the background compactor
    /// when threshold-triggered compaction is enabled.
    fn with_compactor(core: Arc<Core>) -> DurableStore {
        let compactor = if core.opts.compact_after_bytes > 0 {
            let thread_core = Arc::clone(&core);
            Some(
                std::thread::Builder::new()
                    .name("issgd-compactor".into())
                    .spawn(move || compactor_loop(thread_core))
                    .expect("spawning the compactor thread"),
            )
        } else {
            None
        };
        DurableStore { core, compactor }
    }

    /// [`DurableStore::open`] when `dir` holds a store (whose size must
    /// match `n`), [`DurableStore::create`] otherwise.
    pub fn open_or_create(
        dir: &Path,
        n: usize,
        init_weight: f64,
        opts: DurableOptions,
    ) -> Result<DurableStore> {
        let has_snapshot = dir.is_dir()
            && !segment::list_numbered(dir, "snap-", ".snap")?.is_empty();
        if has_snapshot {
            let store = Self::open(dir, opts)?;
            anyhow::ensure!(
                store.core.mem.n_examples() == n,
                "store at {} tracks {} examples, run needs {n}",
                dir.display(),
                store.core.mem.n_examples()
            );
            Ok(store)
        } else {
            Self::create(dir, n, init_weight, opts)
        }
    }

    /// Directory this store persists into.
    pub fn dir(&self) -> &Path {
        &self.core.dir
    }

    pub fn n_examples(&self) -> usize {
        self.core.mem.n_examples()
    }

    /// Current global write sequence (mirrors [`MemStore::write_seq`]).
    pub fn write_seq(&self) -> u64 {
        self.core.mem.write_seq()
    }

    /// Current compaction floor (mirrors [`MemStore::compact_floor`]).
    pub fn compact_floor(&self) -> u64 {
        self.core.mem.compact_floor()
    }

    /// Compactions run by this instance (the counter does not persist).
    pub fn compactions(&self) -> u64 {
        self.core.compactions_total.load(Ordering::Relaxed)
    }

    /// Total bytes currently on disk (segments + snapshots).
    pub fn disk_bytes(&self) -> Result<u64> {
        let mut total = 0u64;
        for entry in fs::read_dir(&self.core.dir)? {
            total += entry?.metadata()?.len();
        }
        Ok(total)
    }

    /// Fold history, checkpoint, and GC now, synchronously (the
    /// background compactor runs the same cycle at
    /// [`DurableOptions::compact_after_bytes`]).
    pub fn compact(&self) -> Result<()> {
        self.core.compact_now()
    }

    /// Block until no background compaction is requested or in flight
    /// (tests and orderly shutdowns; a no-op when the compactor is idle).
    pub fn quiesce_compactor(&self) {
        while self.core.signal.lock().unwrap().pending {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }
}

impl Drop for DurableStore {
    fn drop(&mut self) {
        {
            let mut sig = self.core.signal.lock().unwrap();
            sig.shutdown = true;
        }
        self.core.signal_cv.notify_all();
        // Join-on-drop: no compaction (or half-written snapshot tmp)
        // outlives the handle.
        if let Some(h) = self.compactor.take() {
            let _ = h.join();
        }
        if let Ok(mut log) = self.core.log.lock() {
            let _ = log.file.flush();
            let _ = log.file.get_ref().sync_data();
        }
    }
}

/// The background compactor: wait for the doorbell, run one cycle, clear
/// the flag *after* the run so `quiesce_compactor` covers the whole
/// window.  A trigger arriving mid-run is absorbed by the running cycle;
/// if the journal is still over threshold afterwards, the next append
/// rings again.  A panicking cycle (e.g. a mutex poisoned by a writer
/// panic) is caught like an error: `pending` is always cleared, so
/// `quiesce_compactor` can never hang on a dead run and `after_append`
/// can always re-ring the bell.
fn compactor_loop(core: Arc<Core>) {
    loop {
        {
            let mut sig = core.signal.lock().unwrap();
            while !sig.pending && !sig.shutdown {
                sig = core.signal_cv.wait(sig).unwrap();
            }
            if sig.shutdown {
                return;
            }
        }
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| core.compact_now()));
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                log_warn!("db", "background compaction failed (will retry): {e}");
                // Don't spin hot on a persistent failure (e.g. a wounded
                // journal); the next trigger or explicit compact retries.
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            Err(_) => {
                log_warn!("db", "background compaction panicked (will retry)");
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
        let mut sig = core.signal.lock().unwrap();
        sig.pending = false;
    }
}

impl Core {
    fn check_wounded(&self) -> Result<()> {
        anyhow::ensure!(
            !self.wounded.load(Ordering::Acquire),
            "durable store wounded by an earlier journal failure; reopen to recover"
        );
        Ok(())
    }

    /// Append `rec` to the active segment (flush-per-record; optional
    /// fsync).  On failure the store is marked wounded: memory is ahead of
    /// disk and further mutations would widen the gap.
    fn append(&self, log: &mut LogState, rec: &Record) -> Result<()> {
        let res = (|| -> Result<u64> {
            let bytes = append_record(&mut log.file, rec)?;
            let sync = crate::telemetry::start();
            log.file.flush()?;
            if self.opts.fsync {
                // analyze: allow(blocking): opt-in DurableOptions::fsync durability contract; tick-path cost is measured by the journal.fsync_ns histogram, not hidden behind the compactor seam
                log.file.get_ref().sync_data()?;
            }
            crate::telemetry::histogram("journal.fsync_ns").record_elapsed(&sync);
            Ok(bytes)
        })();
        match res {
            Ok(b) => {
                log.seg_bytes += b;
                log.since_snapshot += b;
                crate::telemetry::counter("journal.bytes").add(b);
                Ok(())
            }
            Err(e) => {
                self.wounded.store(true, Ordering::Release);
                Err(e.context("journal append failed; durable store wounded"))
            }
        }
    }

    /// Roll/compact housekeeping after a successful append.  Compaction is
    /// only *signalled* from here — the fold-checkpoint-GC cycle runs on
    /// the background thread, off the push hot path.
    fn after_append(&self, log: &mut LogState) -> Result<()> {
        if log.seg_bytes >= self.opts.segment_bytes {
            self.roll_segment(log)?;
        }
        if self.opts.compact_after_bytes > 0 && log.since_snapshot >= self.opts.compact_after_bytes
        {
            let mut sig = self.signal.lock().unwrap();
            if !sig.pending {
                sig.pending = true;
                self.signal_cv.notify_one();
            }
        }
        Ok(())
    }

    fn roll_segment(&self, log: &mut LogState) -> Result<()> {
        log.file.flush()?;
        // analyze: allow(blocking): one sync per sealed segment, amortized over segment_bytes of appends; seals the segment before the background compactor may GC its predecessors
        let _ = log.file.get_ref().sync_data();
        let mut fresh = open_segment(&self.dir, log.seg_index + 1)?;
        fresh.since_snapshot = log.since_snapshot;
        *log = fresh;
        Ok(())
    }

    /// One full compaction cycle.  Writers are only quiesced for the
    /// seal+dump memcpy; serialization, fsync and GC run concurrently
    /// with new pushes (which land in the fresh post-`cover` segment and
    /// are therefore replayed over the snapshot on recovery — no overlap,
    /// no loss: every mutation holds the journal lock, so the dump is
    /// exactly the state covered by the sealed segments).
    fn compact_now(&self) -> Result<()> {
        let _serial = self.compact_serial.lock().unwrap();
        self.check_wounded()?;
        let cycle = crate::telemetry::start();
        // 0. Reap pins from dead consumers so they stop clamping the fold.
        //    No journal record needed: the checkpoint below omits them and
        //    supersedes every segment holding their saves.
        if let Some(max_age) = self.opts.cursor_max_age {
            let cutoff = self.mem.now()?.saturating_sub(max_age.as_nanos() as u64);
            for (name, seq) in self.mem.expire_cursors(cutoff) {
                log_warn!(
                    "db",
                    "expired stale consumer cursor {name:?} (was pinning seq {seq})"
                );
            }
        }
        // 1. Fold in-memory history up to the oldest saved consumer cursor
        //    (the trait's cursor-safety contract).
        let floor = self.mem.compact_before(u64::MAX);
        crate::telemetry::gauge("compact.floor").set(floor as f64);
        // 2. Seal the active segment and memcpy the state it covers, then
        //    hand writers a fresh segment — the only part under the lock.
        let (cover, state) = {
            let mut log = self.log.lock().unwrap();
            self.check_wounded()?;
            log.file.flush()?;
            let _ = log.file.get_ref().sync_data();
            let cover = log.seg_index + 1;
            let state = self.dump_state(floor, cover)?;
            *log = open_segment(&self.dir, cover)?;
            (cover, state)
        };
        // 3. Serialize + fsync the checkpoint and GC superseded files,
        //    concurrent with new writes.
        self.write_checkpoint(&state)?;
        self.compactions_total.fetch_add(1, Ordering::Relaxed);
        gc_below(&self.dir, cover);
        crate::telemetry::histogram("compact.duration_ns").record_elapsed(&cycle);
        Ok(())
    }

    /// Point-in-time copy of everything a checkpoint needs (memcpy only).
    fn dump_state(&self, floor: u64, cover: u64) -> Result<CheckpointState> {
        let (snap, seqs) = self.mem.dump_with_seqs();
        let (params_version, params_floor, params) = self.mem.params_layers_dump();
        Ok(CheckpointState {
            meta: SnapshotMeta {
                n: self.mem.n_examples() as u64,
                init_weight: self.init_weight,
                floor,
                next_seq: self.mem.write_seq(),
                clock: self.mem.now()?,
                cover,
                params_version,
                params_floor,
            },
            params,
            cursors: self.mem.cursors_vec(),
            snap,
            seqs,
        })
    }

    /// Write `snap-{cover}.snap` atomically (tmp + fsync + rename) from a
    /// point-in-time dump.
    fn write_checkpoint(&self, state: &CheckpointState) -> Result<()> {
        let cover = state.meta.cover;
        let tmp = self.dir.join(format!("snap-{cover:08}.tmp"));
        let path = segment::snapshot_path(&self.dir, cover);
        {
            let file = File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
            let mut w = BufWriter::new(file);
            w.write_all(SNAPSHOT_MAGIC)?;
            append_record(&mut w, &Record::Meta(state.meta.clone()))?;
            // Params: one patch record per layer in layout order, tagged
            // with the version that last wrote it (see the module docs) —
            // encoded from borrows, so the checkpoint never clones the
            // parameter payload a second time.
            for l in &state.params {
                segment::append_params_layer_patch(&mut w, l.version, &l.name, &l.bytes)?;
            }
            for (name, seq, stamp) in &state.cursors {
                append_record(
                    &mut w,
                    &Record::Cursor {
                        name: name.clone(),
                        seq: *seq,
                        stamp: *stamp,
                    },
                )?;
            }
            // Full table grouped by write sequence, ascending: loading is
            // exactly the replay path and per-entry sequences survive.
            let mut by_seq: std::collections::BTreeMap<u64, Vec<usize>> =
                std::collections::BTreeMap::new();
            for (i, &s) in state.seqs.iter().enumerate() {
                by_seq.entry(s).or_default().push(i);
            }
            for (seq, idxs) in &by_seq {
                for chunk in idxs.chunks(SNAP_CHUNK) {
                    let mut d = WeightDelta {
                        seq: *seq,
                        n: state.snap.len() as u64,
                        full: false,
                        ..WeightDelta::default()
                    };
                    for &i in chunk {
                        d.indices.push(i as u64);
                        d.weights.push(state.snap.weights[i]);
                        d.stamps.push(state.snap.stamps[i]);
                        d.param_versions.push(state.snap.param_versions[i]);
                    }
                    append_record(&mut w, &Record::Delta(d))?;
                }
            }
            w.flush()?;
            w.get_ref().sync_all()?;
        }
        fs::rename(&tmp, &path)?;
        if let Ok(d) = File::open(&self.dir) {
            let _ = d.sync_all();
        }
        Ok(())
    }
}

/// Replay one journaled/snapshot record into `mem`.  `in_snapshot`
/// restricts the record mix (grad records never appear in a checkpoint)
/// and switches params-layer records from push replay to layout-ordered
/// append (see the snapshot format docs).
fn apply_record(mem: &MemStore, rec: &Record, in_snapshot: bool) -> Result<()> {
    match rec {
        Record::Delta(d) => {
            mem.restore_delta(d)?;
            if let Some(&max_stamp) = d.stamps.iter().max() {
                mem.advance_clock_to(max_stamp);
            }
        }
        Record::Params { version, bytes } => mem.restore_params(*version, bytes.clone()),
        Record::ParamsLayers {
            version,
            full,
            layers,
        } => {
            if in_snapshot {
                // One layer per record, layout order, version = the
                // layer's last write; head version/floor come from meta.
                for (name, bytes) in layers {
                    mem.snapshot_append_param_layer(name.clone(), *version, bytes.clone());
                }
            } else {
                mem.replay_params_layers(*version, *full, layers)
                    .context("replaying a journaled layer push")?;
            }
        }
        Record::Grad { scale, grad } => {
            anyhow::ensure!(!in_snapshot, "grad record inside a snapshot");
            mem.apply_grad(*scale, grad)
                .context("replaying a journaled grad")?;
        }
        Record::Cursor { name, seq, stamp } => mem.restore_cursor(name.clone(), *seq, *stamp),
        Record::DropCursor { name } => {
            anyhow::ensure!(!in_snapshot, "drop-cursor record inside a snapshot");
            mem.drop_cursor(name)?;
        }
        Record::Meta(_) => anyhow::bail!("unexpected meta record"),
    }
    Ok(())
}

fn open_segment(dir: &Path, index: u64) -> Result<LogState> {
    let path = segment::segment_path(dir, index);
    let file = OpenOptions::new()
        .create_new(true)
        .write(true)
        .open(&path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(SEGMENT_MAGIC)?;
    w.flush()?;
    Ok(LogState {
        file: w,
        seg_index: index,
        seg_bytes: 8,
        since_snapshot: 0,
    })
}

/// Best-effort deletion of everything a snapshot at `cover` supersedes.
fn gc_below(dir: &Path, cover: u64) {
    let doomed = |list: Result<Vec<(u64, PathBuf)>>| -> Vec<PathBuf> {
        list.map(|v| {
            v.into_iter()
                .filter(|(k, _)| *k < cover)
                .map(|(_, p)| p)
                .collect()
        })
        .unwrap_or_default()
    };
    let mut paths = doomed(segment::list_numbered(dir, "seg-", ".log"));
    paths.extend(doomed(segment::list_numbered(dir, "snap-", ".snap")));
    if let Ok(entries) = fs::read_dir(dir) {
        for entry in entries.flatten() {
            if entry.file_name().to_string_lossy().ends_with(".tmp") {
                paths.push(entry.path());
            }
        }
    }
    for p in paths {
        if let Err(e) = fs::remove_file(&p) {
            log_warn!("db", "gc could not remove {}: {e}", p.display());
        }
    }
}

impl WeightStore for DurableStore {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<()> {
        let core = &*self.core;
        let mut log = core.log.lock().unwrap();
        core.check_wounded()?;
        core.mem.push_params(version, bytes.clone())?;
        core.append(&mut log, &Record::Params { version, bytes })?;
        core.after_append(&mut log)
    }

    fn push_params_layers(
        &self,
        version: u64,
        full: bool,
        layers: &[(String, Vec<u8>)],
    ) -> Result<()> {
        let core = &*self.core;
        let mut log = core.log.lock().unwrap();
        core.check_wounded()?;
        core.mem.push_params_layers(version, full, layers)?;
        // The journal record carries exactly the layers the push did —
        // O(dirty layers) disk bytes, never the whole blob.
        core.append(
            &mut log,
            &Record::ParamsLayers {
                version,
                full,
                layers: layers.to_vec(),
            },
        )?;
        core.after_append(&mut log)
    }

    fn fetch_params(&self, than: u64) -> Result<Option<(u64, Vec<u8>)>> {
        self.core.mem.fetch_params(than)
    }

    fn fetch_params_since(&self, than: u64) -> Result<Option<ParamsDelta>> {
        self.core.mem.fetch_params_since(than)
    }

    fn params_version(&self) -> Result<u64> {
        self.core.mem.params_version()
    }

    fn push_weights(&self, start: usize, weights: &[f32], param_version: u64) -> Result<()> {
        let core = &*self.core;
        let mut log = core.log.lock().unwrap();
        core.check_wounded()?;
        let claimed = core.mem.push_weights_seq(start, weights, param_version)?;
        if let Some((seq, stamp)) = claimed {
            let mut d = WeightDelta {
                seq,
                n: core.mem.n_examples() as u64,
                full: false,
                ..WeightDelta::default()
            };
            d.indices.reserve(weights.len());
            d.weights.reserve(weights.len());
            d.stamps.reserve(weights.len());
            d.param_versions.reserve(weights.len());
            for (i, &w) in weights.iter().enumerate() {
                d.indices.push((start + i) as u64);
                d.weights.push(w as f64);
                d.stamps.push(stamp);
                d.param_versions.push(param_version);
            }
            core.append(&mut log, &Record::Delta(d))?;
            core.after_append(&mut log)?;
        }
        Ok(())
    }

    fn fetch_weights(&self) -> Result<WeightSnapshot> {
        self.core.mem.fetch_weights()
    }

    fn fetch_weights_since(&self, seq: u64) -> Result<WeightDelta> {
        self.core.mem.fetch_weights_since(seq)
    }

    fn apply_grad(&self, scale: f32, grad: &[f32]) -> Result<u64> {
        let core = &*self.core;
        let mut log = core.log.lock().unwrap();
        core.check_wounded()?;
        let v = core.mem.apply_grad(scale, grad)?;
        core.append(
            &mut log,
            &Record::Grad {
                scale,
                grad: grad.to_vec(),
            },
        )?;
        core.after_append(&mut log)?;
        Ok(v)
    }

    fn save_cursor(&self, name: &str, seq: u64) -> Result<()> {
        let core = &*self.core;
        let mut log = core.log.lock().unwrap();
        core.check_wounded()?;
        // Journal the clamped value + stamp actually stored, so replay
        // reproduces the pin (and its expiry age) bit-exactly.
        let (stored, stamp) = core.mem.save_cursor_pin(name, seq)?;
        core.append(
            &mut log,
            &Record::Cursor {
                name: name.to_string(),
                seq: stored,
                stamp,
            },
        )?;
        core.after_append(&mut log)
    }

    fn load_cursor(&self, name: &str) -> Result<Option<u64>> {
        self.core.mem.load_cursor(name)
    }

    fn drop_cursor(&self, name: &str) -> Result<()> {
        let core = &*self.core;
        let mut log = core.log.lock().unwrap();
        core.check_wounded()?;
        core.mem.drop_cursor(name)?;
        core.append(
            &mut log,
            &Record::DropCursor {
                name: name.to_string(),
            },
        )?;
        core.after_append(&mut log)
    }

    fn now(&self) -> Result<u64> {
        self.core.mem.now()
    }

    fn stats(&self) -> Result<StoreStats> {
        self.core.mem.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct TempDir(PathBuf);

    impl TempDir {
        fn new(tag: &str) -> TempDir {
            static NEXT: AtomicU64 = AtomicU64::new(0);
            let k = NEXT.fetch_add(1, Ordering::Relaxed);
            let path = std::env::temp_dir()
                .join(format!("issgd-durable-{tag}-{}-{k}", std::process::id()));
            let _ = fs::remove_dir_all(&path);
            TempDir(path)
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.0);
        }
    }

    fn opts_manual() -> DurableOptions {
        DurableOptions {
            segment_bytes: 1 << 20,
            compact_after_bytes: 0,
            ..DurableOptions::default()
        }
    }

    #[test]
    fn state_survives_crash_and_reopen_bit_exactly() {
        let dir = TempDir::new("roundtrip");
        let store = DurableStore::create(&dir.0, 32, 1.0, opts_manual()).unwrap();
        store.push_weights(3, &[2.0, 3.0, 4.0], 5).unwrap();
        store.push_weights(20, &[9.0], 6).unwrap();
        store.push_params(1, vec![0u8; 8]).unwrap();
        store.apply_grad(0.5, &[2.0, -2.0]).unwrap();
        store.save_cursor("master", store.write_seq()).unwrap();
        let want_table = store.fetch_weights().unwrap();
        let want_seq = store.write_seq();
        let want_params = store.fetch_params(0).unwrap();
        let want_now = store.now().unwrap();
        drop(store); // crash: appends were already flushed per-record

        let back = DurableStore::open(&dir.0, opts_manual()).unwrap();
        // Stamps included: the journal reproduces entries exactly.
        assert_eq!(back.fetch_weights().unwrap(), want_table);
        assert_eq!(back.write_seq(), want_seq);
        assert_eq!(back.fetch_params(0).unwrap(), want_params);
        assert_eq!(back.load_cursor("master").unwrap(), Some(want_seq));
        // The recovered clock never runs backwards past old stamps.
        let max_stamp = want_table.stamps.iter().copied().max().unwrap();
        assert!(back.now().unwrap() >= want_now.min(max_stamp));
        // A consumer at its saved cursor continues incrementally.
        let d = back.fetch_weights_since(want_seq).unwrap();
        assert!(!d.full);
        assert!(d.is_empty());
        // And the store keeps working.
        back.push_weights(0, &[7.0], 9).unwrap();
        let d = back.fetch_weights_since(want_seq).unwrap();
        assert_eq!(d.indices, vec![0]);
        assert_eq!(d.weights, vec![7.0]);
    }

    #[test]
    fn reopen_after_reopen_is_stable() {
        let dir = TempDir::new("twice");
        let store = DurableStore::create(&dir.0, 8, 0.5, opts_manual()).unwrap();
        store.push_weights(1, &[3.0], 1).unwrap();
        drop(store);
        let a = DurableStore::open(&dir.0, opts_manual()).unwrap();
        a.push_weights(2, &[4.0], 2).unwrap();
        let want = a.fetch_weights().unwrap();
        let seq = a.write_seq();
        drop(a);
        let b = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(b.fetch_weights().unwrap(), want);
        assert_eq!(b.write_seq(), seq);
    }

    #[test]
    fn compaction_bounds_files_and_keeps_pinned_consumers_incremental() {
        let dir = TempDir::new("compact");
        let opts = DurableOptions {
            segment_bytes: 1 << 12,
            compact_after_bytes: 1 << 13,
            ..DurableOptions::default()
        };
        let store = DurableStore::create(&dir.0, 64, 1.0, opts).unwrap();
        let mut cursor = store.fetch_weights_since(0).unwrap().seq;
        let mut mirror = store.fetch_weights().unwrap();
        for round in 0..400u64 {
            let vals: Vec<f32> = (0..8).map(|i| (round + i) as f32 + 1.0).collect();
            store.push_weights((round as usize * 8) % 56, &vals, round + 1).unwrap();
            let d = store.fetch_weights_since(cursor).unwrap();
            assert!(!d.full, "pinned consumer demoted to full at round {round}");
            d.apply_to(&mut mirror).unwrap();
            cursor = d.seq;
            store.save_cursor("me", cursor).unwrap();
        }
        // Compactions run on the background thread now: wait for them.
        // analyze: allow(wallclock): test waits on a real background thread
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        // analyze: allow(wallclock): test waits on a real background thread
        while store.compactions() < 2 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        store.quiesce_compactor();
        assert!(store.compactions() >= 2, "compactor never triggered");
        assert!(store.compact_floor() > 0);
        assert_eq!(mirror, store.fetch_weights().unwrap());
        // GC really deletes: the directory holds the latest snapshot plus
        // a small number of live segments, not 400 rounds of history.
        let files = fs::read_dir(&dir.0).unwrap().count();
        assert!(files <= 8, "GC left {files} files behind");
        // Recovery from the compacted state still works.
        let want = store.fetch_weights().unwrap();
        drop(store);
        let back = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(back.fetch_weights().unwrap(), want);
        assert_eq!(back.load_cursor("me").unwrap(), Some(cursor));
        assert!(!back.fetch_weights_since(cursor).unwrap().full);
    }

    #[test]
    fn explicit_compact_folds_below_oldest_pin() {
        let dir = TempDir::new("pin");
        let store = DurableStore::create(&dir.0, 16, 1.0, opts_manual()).unwrap();
        for i in 0..8 {
            store.push_weights(i, &[i as f32 + 2.0], 1).unwrap();
        }
        store.save_cursor("slow", 4).unwrap();
        store.save_cursor("fast", store.write_seq()).unwrap();
        store.compact().unwrap();
        assert_eq!(store.compact_floor(), 4);
        // The slow consumer still gets precise deltas from its pin.
        let d = store.fetch_weights_since(4).unwrap();
        assert!(!d.full);
        assert_eq!(d.indices, vec![3, 4, 5, 6, 7]);
    }

    #[test]
    fn dropped_cursor_unblocks_the_floor_and_stays_dropped_after_reopen() {
        let dir = TempDir::new("dropcur");
        let store = DurableStore::create(&dir.0, 16, 1.0, opts_manual()).unwrap();
        for i in 0..8 {
            store.push_weights(i, &[i as f32 + 2.0], 1).unwrap();
        }
        let head = store.write_seq();
        store.save_cursor("dead-peer", 3).unwrap();
        store.save_cursor("live", head).unwrap();
        store.compact().unwrap();
        assert_eq!(store.compact_floor(), 3, "dead pin clamps the fold");
        // The peer died; drop its pin and the floor advances past it.
        store.drop_cursor("dead-peer").unwrap();
        store.compact().unwrap();
        assert_eq!(store.compact_floor(), head);
        // The drop is journaled: a reopen must not resurrect the pin.
        drop(store);
        let back = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(back.load_cursor("dead-peer").unwrap(), None);
        assert_eq!(back.load_cursor("live").unwrap(), Some(head));
        assert_eq!(back.compact_floor(), head);
    }

    #[test]
    fn cursor_expiry_reaps_dead_pins_at_compaction() {
        let dir = TempDir::new("expire");
        let opts = DurableOptions {
            segment_bytes: 1 << 20,
            compact_after_bytes: 0,
            cursor_max_age: Some(std::time::Duration::from_millis(25)),
            ..DurableOptions::default()
        };
        let store = DurableStore::create(&dir.0, 16, 1.0, opts).unwrap();
        for i in 0..8 {
            store.push_weights(i, &[i as f32 + 2.0], 1).unwrap();
        }
        let head = store.write_seq();
        // A peer pins, then dies (never saves again).
        store.save_cursor("dead-peer", 2).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(60));
        // The live consumer keeps re-saving: its pin stays fresh.
        store.save_cursor("live", head).unwrap();
        store.compact().unwrap();
        assert_eq!(store.load_cursor("dead-peer").unwrap(), None, "stale pin survived");
        assert_eq!(store.load_cursor("live").unwrap(), Some(head));
        assert_eq!(
            store.compact_floor(),
            head,
            "floor failed to advance past the dead pin"
        );
    }

    #[test]
    fn create_refuses_an_existing_store_and_open_refuses_an_empty_dir() {
        let dir = TempDir::new("guard");
        let store = DurableStore::create(&dir.0, 4, 1.0, opts_manual()).unwrap();
        drop(store);
        assert!(DurableStore::create(&dir.0, 4, 1.0, opts_manual()).is_err());
        let empty = TempDir::new("empty");
        fs::create_dir_all(&empty.0).unwrap();
        assert!(DurableStore::open(&empty.0, opts_manual()).is_err());
    }

    #[test]
    fn open_or_create_checks_the_table_size() {
        let dir = TempDir::new("size");
        let store = DurableStore::open_or_create(&dir.0, 8, 1.0, opts_manual()).unwrap();
        store.push_weights(0, &[2.0], 1).unwrap();
        drop(store);
        assert!(DurableStore::open_or_create(&dir.0, 9, 1.0, opts_manual()).is_err());
        let back = DurableStore::open_or_create(&dir.0, 8, 1.0, opts_manual()).unwrap();
        assert_eq!(back.fetch_weights().unwrap().weights[0], 2.0);
    }

    #[test]
    fn torn_final_frame_is_truncated_on_open() {
        let dir = TempDir::new("torn");
        let store = DurableStore::create(&dir.0, 8, 1.0, opts_manual()).unwrap();
        store.push_weights(0, &[5.0], 1).unwrap();
        store.push_weights(1, &[6.0], 2).unwrap();
        drop(store);
        // Append half a frame header to the active segment: the classic
        // crash-mid-append shape.
        let segs = segment::list_numbered(&dir.0, "seg-", ".log").unwrap();
        let (_, last) = segs.last().unwrap();
        let mut f = OpenOptions::new().append(true).open(last).unwrap();
        f.write_all(&[0xAB, 0xCD, 0xEF]).unwrap();
        drop(f);
        let back = DurableStore::open(&dir.0, opts_manual()).unwrap();
        let snap = back.fetch_weights().unwrap();
        assert_eq!(snap.weights[0], 5.0);
        assert_eq!(snap.weights[1], 6.0);
        // The tear is gone from disk: another open replays cleanly.
        drop(back);
        let again = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(again.fetch_weights().unwrap(), snap);
    }

    #[test]
    fn magic_level_stub_does_not_brick_later_reopens() {
        // Crash DURING segment creation: the newest segment is shorter
        // than its magic.  The first reopen must absorb that; the second
        // reopen must not refuse recovery because a non-final torn stub
        // is sitting mid-journal (regression: recovery used to truncate
        // the stub to zero bytes and keep it forever).
        let dir = TempDir::new("stub");
        let store = DurableStore::create(&dir.0, 8, 1.0, opts_manual()).unwrap();
        store.push_weights(0, &[5.0], 1).unwrap();
        let want = store.fetch_weights().unwrap();
        drop(store);
        // Simulate the torn-creation stub as the newest segment.
        let segs = segment::list_numbered(&dir.0, "seg-", ".log").unwrap();
        let (top, _) = segs.last().unwrap();
        std::fs::write(segment::segment_path(&dir.0, top + 1), [0x49u8, 0x53]).unwrap();
        let back = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(back.fetch_weights().unwrap(), want);
        back.push_weights(1, &[6.0], 2).unwrap();
        let want = back.fetch_weights().unwrap();
        drop(back);
        // Second reopen: the stub must be gone, recovery clean.
        let again = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(again.fetch_weights().unwrap(), want);
        for (_, path) in segment::list_numbered(&dir.0, "seg-", ".log").unwrap() {
            assert!(std::fs::metadata(&path).unwrap().len() >= 8, "stub survived recovery");
        }
    }

    #[test]
    fn grad_replay_reproduces_parameters() {
        let dir = TempDir::new("grad");
        let store = DurableStore::create(&dir.0, 4, 1.0, opts_manual()).unwrap();
        let mut blob = Vec::new();
        for v in [1.0f32, 2.0] {
            blob.extend(v.to_le_bytes());
        }
        store.push_params(1, blob).unwrap();
        store.apply_grad(0.25, &[4.0, -4.0]).unwrap();
        store.apply_grad(0.25, &[4.0, -4.0]).unwrap();
        let want = store.fetch_params(0).unwrap().unwrap();
        assert_eq!(want.0, 3);
        drop(store);
        let back = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(back.fetch_params(0).unwrap().unwrap(), want);
        assert_eq!(back.params_version().unwrap(), 3);
    }

    // -- layer-wise params ---------------------------------------------------

    fn lc(name: &str, bytes: &[u8]) -> (String, Vec<u8>) {
        (name.to_string(), bytes.to_vec())
    }

    #[test]
    fn layer_pushes_journal_layerwise_and_recover_bit_exactly() {
        let dir = TempDir::new("layers");
        let store = DurableStore::create(&dir.0, 4, 1.0, opts_manual()).unwrap();
        store
            .push_params_layers(1, true, &[lc("a", &[1, 1, 1, 1]), lc("b", &[2, 2, 2, 2])])
            .unwrap();
        store.push_params_layers(2, false, &[lc("b", &[9, 9, 9, 9])]).unwrap();
        store.push_params_layers(3, false, &[lc("a", &[7, 7, 7, 7])]).unwrap();
        let want_blob = store.fetch_params(0).unwrap().unwrap();
        // A consumer at version 2 is owed exactly layer "a".
        let want_delta = store.fetch_params_since(2).unwrap().unwrap();
        assert!(!want_delta.full);
        assert_eq!(want_delta.len(), 1);
        drop(store); // crash: replay from the journal alone

        let back = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(back.fetch_params(0).unwrap().unwrap(), want_blob);
        assert_eq!(back.params_version().unwrap(), 3);
        // Per-layer versions survived: the same consumer is owed the same
        // delta, and an up-to-date one is owed nothing.
        assert_eq!(back.fetch_params_since(2).unwrap().unwrap(), want_delta);
        assert!(back.fetch_params_since(3).unwrap().is_none());
    }

    #[test]
    fn snapshot_preserves_layer_versions_and_params_floor() {
        let dir = TempDir::new("layersnap");
        let store = DurableStore::create(&dir.0, 4, 1.0, opts_manual()).unwrap();
        store
            .push_params_layers(1, true, &[lc("a", &[1, 1]), lc("b", &[2, 2]), lc("c", &[3, 3])])
            .unwrap();
        store.push_params_layers(2, false, &[lc("c", &[4, 4])]).unwrap();
        // Checkpoint, then keep journaling on top of the snapshot.
        store.compact().unwrap();
        store.push_params_layers(3, false, &[lc("b", &[5, 5])]).unwrap();
        let want_blob = store.fetch_params(0).unwrap().unwrap();
        drop(store);

        let back = DurableStore::open(&dir.0, opts_manual()).unwrap();
        assert_eq!(back.fetch_params(0).unwrap().unwrap(), want_blob);
        // Layer versions are exact across snapshot + journal replay: a
        // consumer at 1 is owed b and c, at 2 only b.
        let d = back.fetch_params_since(1).unwrap().unwrap();
        assert!(!d.full);
        let names: Vec<&str> = d.layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["b", "c"]);
        let d = back.fetch_params_since(2).unwrap().unwrap();
        assert_eq!(d.len(), 1);
        assert_eq!(d.layers[0].name, "b");
        // The params floor survived too: a pre-layout cursor gets full.
        let d = back.fetch_params_since(u64::MAX).unwrap().unwrap();
        assert!(d.full);
        assert_eq!(d.len(), 3);
    }
}
