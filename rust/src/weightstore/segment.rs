//! On-disk framing for the durable weight store: length-prefixed,
//! CRC-32-checksummed records whose payloads reuse the TCP wire codec
//! ([`super::protocol`]), so disk and network stay **one format** — a
//! weight write is journaled as the exact [`WeightDelta`] frame a delta
//! fetch would ship, a parameter publish as its `PushParams` request, a
//! parameter-server update as its `ApplyGrad` request.
//!
//! # File layout
//!
//! Both file kinds share the frame format and differ only in magic +
//! record mix:
//!
//! ```text
//! segment  (seg-XXXXXXXX.log):   "ISGDLG02" frame*
//! snapshot (snap-XXXXXXXX.snap): "ISGDSN02" meta-frame params-layer-frame*
//!                                cursor-frame* delta-frame*
//! frame:                         u32 payload-len | u32 crc32(payload) |
//!                                payload = tag byte + codec bytes
//! ```
//!
//! [`scan_file`] reads frames until EOF or the first torn/corrupt frame:
//! a partial header, a partial payload, a length beyond the cap, or a CRC
//! mismatch all mark a **torn tail** — the crash shape recovery exists
//! for — and scanning stops there without error.  A CRC-*valid* payload
//! that fails to decode is not a tear (the bytes arrived intact) and is
//! surfaced as a hard error.

use std::fs::File;
use std::io::{BufReader, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::protocol::{
    encode_apply_grad, encode_push_params, encode_push_params_layers, encode_weights_delta,
    Request, Response, MAX_FRAME,
};
use super::WeightDelta;

/// First bytes of every log segment file.  The trailing two digits
/// version the record format: 02 added the cursor save stamp, the
/// params-layer record, and the params version/floor meta fields —
/// a store written by an 01 binary fails `open` with an explicit
/// wrong-magic error instead of a corruption-shaped decode failure.
pub const SEGMENT_MAGIC: &[u8; 8] = b"ISGDLG02";
/// First bytes of every snapshot checkpoint file (versioned like
/// [`SEGMENT_MAGIC`]).
pub const SNAPSHOT_MAGIC: &[u8; 8] = b"ISGDSN02";

const TAG_DELTA: u8 = 1;
const TAG_PARAMS: u8 = 2;
const TAG_GRAD: u8 = 3;
const TAG_CURSOR: u8 = 4;
const TAG_META: u8 = 5;
const TAG_PARAMS_LAYERS: u8 = 6;
const TAG_DROP_CURSOR: u8 = 7;

/// One journaled operation (or snapshot constituent).
#[derive(Debug, Clone, PartialEq)]
pub enum Record {
    /// A weight write: the exact entries one `push_weights` created,
    /// carrying the write sequence it claimed (payload codec:
    /// [`Response::WeightsDelta`]).
    Delta(WeightDelta),
    /// A whole-blob parameter publish (payload codec:
    /// [`Request::PushParams`]) — the legacy path; layer pushes journal
    /// [`Record::ParamsLayers`] instead, so a params record carries only
    /// the layers that actually changed.
    Params { version: u64, bytes: Vec<u8> },
    /// A layer-wise parameter publish (payload codec:
    /// [`Request::PushParamsLayers`]).  In a journal this is the exact
    /// push replayed; in a snapshot it is one layout-ordered layer patch
    /// whose `version` is the layer's last write (the differential
    /// checkpoint shape: base layers + newer patches, replayed in order).
    ParamsLayers {
        version: u64,
        full: bool,
        layers: Vec<(String, Vec<u8>)>,
    },
    /// A parameter-server update (payload codec: [`Request::ApplyGrad`]);
    /// replay recomputes the identical f32 arithmetic.
    Grad { scale: f32, grad: Vec<f32> },
    /// A consumer cursor save ([`super::WeightStore::save_cursor`]),
    /// carrying the store-clock stamp of the save (the max-age expiry
    /// signal survives restarts).
    Cursor { name: String, seq: u64, stamp: u64 },
    /// A consumer cursor removal ([`super::WeightStore::drop_cursor`]).
    DropCursor { name: String },
    /// Snapshot header — first record of every snapshot file.
    Meta(SnapshotMeta),
}

/// Snapshot header: everything `DurableStore::open` needs besides the
/// restored records themselves.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SnapshotMeta {
    /// Table size (examples tracked).
    pub n: u64,
    /// The store's initial weight (reproduces `create` parameters).
    pub init_weight: f64,
    /// Compaction floor at snapshot time.
    pub floor: u64,
    /// Global write-sequence counter at snapshot time.
    pub next_seq: u64,
    /// Store clock (ns) at snapshot time — restarts keep stamps monotonic.
    pub clock: u64,
    /// Segments with index `>= cover` postdate this snapshot and must be
    /// replayed; segments below it are garbage once the snapshot is
    /// durable.
    pub cover: u64,
    /// Params head version at snapshot time.
    pub params_version: u64,
    /// Params floor at snapshot time (layout-definition point).
    pub params_floor: u64,
}

impl Record {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            // The by-ref payload builders are the SAME functions the wire
            // encoders delegate to — one codec, and no cloning of the
            // delta/blob vectors on the journal's hot write path.
            Record::Delta(d) => {
                out.push(TAG_DELTA);
                out.extend(encode_weights_delta(d));
            }
            Record::Params { version, bytes } => {
                out.push(TAG_PARAMS);
                out.extend(encode_push_params(*version, bytes));
            }
            Record::ParamsLayers {
                version,
                full,
                layers,
            } => {
                out.push(TAG_PARAMS_LAYERS);
                out.extend(encode_push_params_layers(*version, *full, layers));
            }
            Record::Grad { scale, grad } => {
                out.push(TAG_GRAD);
                out.extend(encode_apply_grad(*scale, grad));
            }
            Record::Cursor { name, seq, stamp } => {
                out.push(TAG_CURSOR);
                let raw = name.as_bytes();
                out.extend((raw.len() as u64).to_le_bytes());
                out.extend(raw);
                out.extend(seq.to_le_bytes());
                out.extend(stamp.to_le_bytes());
            }
            Record::DropCursor { name } => {
                out.push(TAG_DROP_CURSOR);
                let raw = name.as_bytes();
                out.extend((raw.len() as u64).to_le_bytes());
                out.extend(raw);
            }
            Record::Meta(m) => {
                out.push(TAG_META);
                out.extend(m.n.to_le_bytes());
                out.extend(m.init_weight.to_le_bytes());
                out.extend(m.floor.to_le_bytes());
                out.extend(m.next_seq.to_le_bytes());
                out.extend(m.clock.to_le_bytes());
                out.extend(m.cover.to_le_bytes());
                out.extend(m.params_version.to_le_bytes());
                out.extend(m.params_floor.to_le_bytes());
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Record> {
        let Some((&tag, body)) = buf.split_first() else {
            bail!("empty record");
        };
        let mut body = body;
        let rec = match tag {
            TAG_DELTA => match Response::decode(body)? {
                Response::WeightsDelta(d) => Record::Delta(d),
                other => bail!("delta record holds {other:?}"),
            },
            TAG_PARAMS => match Request::decode(body)? {
                Request::PushParams { version, bytes } => Record::Params { version, bytes },
                other => bail!("params record holds {other:?}"),
            },
            TAG_PARAMS_LAYERS => match Request::decode(body)? {
                Request::PushParamsLayers {
                    version,
                    full,
                    layers,
                } => Record::ParamsLayers {
                    version,
                    full,
                    layers,
                },
                other => bail!("params-layers record holds {other:?}"),
            },
            TAG_GRAD => match Request::decode(body)? {
                Request::ApplyGrad { scale, grad } => Record::Grad { scale, grad },
                other => bail!("grad record holds {other:?}"),
            },
            TAG_CURSOR => {
                let len = take_u64(&mut body)? as usize;
                let raw = take(&mut body, len)?;
                let name = String::from_utf8(raw.to_vec()).context("cursor name not utf-8")?;
                let seq = take_u64(&mut body)?;
                let stamp = take_u64(&mut body)?;
                anyhow::ensure!(body.is_empty(), "trailing bytes in cursor record");
                Record::Cursor { name, seq, stamp }
            }
            TAG_DROP_CURSOR => {
                let len = take_u64(&mut body)? as usize;
                let raw = take(&mut body, len)?;
                let name = String::from_utf8(raw.to_vec()).context("cursor name not utf-8")?;
                anyhow::ensure!(body.is_empty(), "trailing bytes in drop-cursor record");
                Record::DropCursor { name }
            }
            TAG_META => {
                let meta = SnapshotMeta {
                    n: take_u64(&mut body)?,
                    init_weight: f64::from_le_bytes(
                        take(&mut body, 8)?.try_into().context("short f64 field")?,
                    ),
                    floor: take_u64(&mut body)?,
                    next_seq: take_u64(&mut body)?,
                    clock: take_u64(&mut body)?,
                    cover: take_u64(&mut body)?,
                    params_version: take_u64(&mut body)?,
                    params_floor: take_u64(&mut body)?,
                };
                anyhow::ensure!(body.is_empty(), "trailing bytes in meta record");
                Record::Meta(meta)
            }
            other => bail!("unknown record tag {other}"),
        };
        Ok(rec)
    }
}

fn take<'a>(buf: &mut &'a [u8], n: usize) -> Result<&'a [u8]> {
    anyhow::ensure!(buf.len() >= n, "truncated record: need {n} bytes, have {}", buf.len());
    let (head, tail) = buf.split_at(n);
    *buf = tail;
    Ok(head)
}

fn take_u64(buf: &mut &[u8]) -> Result<u64> {
    Ok(u64::from_le_bytes(
        take(buf, 8)?.try_into().context("short u64 field")?,
    ))
}

/// CRC-32 (IEEE 802.3, reflected) — bitwise, no table: recovery-path
/// throughput is irrelevant next to disk I/O.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Append one checksummed frame; returns the bytes written (header +
/// payload).
pub fn append_record(w: &mut impl Write, rec: &Record) -> Result<u64> {
    append_frame(w, &rec.encode())
}

/// Append one single-layer [`Record::ParamsLayers`] patch frame built
/// entirely from borrows — the snapshot writer's per-layer record, which
/// must not clone a `paper`-scale layer payload just to reach the
/// encoder.  Byte-identical to `append_record` on the equivalent owned
/// record (tested).
pub fn append_params_layer_patch(
    w: &mut impl Write,
    version: u64,
    name: &str,
    bytes: &[u8],
) -> Result<u64> {
    let mut payload = vec![TAG_PARAMS_LAYERS];
    payload.extend(encode_push_params_layers(version, false, &[(name, bytes)]));
    append_frame(w, &payload)
}

fn append_frame(w: &mut impl Write, payload: &[u8]) -> Result<u64> {
    anyhow::ensure!(payload.len() <= MAX_FRAME, "record too large: {} bytes", payload.len());
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&crc32(payload).to_le_bytes())?;
    w.write_all(payload)?;
    Ok(8 + payload.len() as u64)
}

/// What [`scan_file`] recovered from one file.
#[derive(Debug)]
pub struct FileScan {
    /// Frames that survived, in file order.
    pub records: Vec<Record>,
    /// Byte offset up to which the file is valid (truncate here to drop a
    /// torn tail).
    pub valid_len: u64,
    /// True when a torn/corrupt tail was found after `valid_len`.
    pub torn: bool,
}

/// Read `path` (which must start with `magic`) frame by frame until EOF or
/// the first torn frame.  See the module docs for what counts as a tear
/// versus a hard error.  A file too short to even hold its magic is
/// treated as torn-at-zero, not an error (a crash can land mid-creation).
pub fn scan_file(path: &Path, magic: &[u8; 8]) -> Result<FileScan> {
    let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(file);
    let mut head = [0u8; 8];
    if read_full(&mut r, &mut head)? < 8 {
        return Ok(FileScan {
            records: Vec::new(),
            valid_len: 0,
            torn: true,
        });
    }
    anyhow::ensure!(
        &head == magic,
        "{} has wrong magic {head:?} (expected {magic:?})",
        path.display()
    );
    let mut off = 8u64;
    let mut records = Vec::new();
    let mut torn = false;
    loop {
        let mut hdr = [0u8; 8];
        let got = read_full(&mut r, &mut hdr)?;
        if got == 0 {
            break; // clean EOF
        }
        if got < 8 {
            torn = true;
            break;
        }
        let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        if len > MAX_FRAME {
            torn = true;
            break;
        }
        let mut payload = vec![0u8; len];
        if read_full(&mut r, &mut payload)? < len {
            torn = true;
            break;
        }
        if crc32(&payload) != crc {
            torn = true;
            break;
        }
        let rec = Record::decode(&payload)
            .with_context(|| format!("record at byte {off} of {}", path.display()))?;
        records.push(rec);
        off += 8 + len as u64;
    }
    Ok(FileScan {
        records,
        valid_len: off,
        torn,
    })
}

fn read_full(r: &mut impl Read, buf: &mut [u8]) -> Result<usize> {
    let mut filled = 0usize;
    while filled < buf.len() {
        let n = r.read(&mut buf[filled..])?;
        if n == 0 {
            break;
        }
        filled += n;
    }
    Ok(filled)
}

pub fn segment_path(dir: &Path, index: u64) -> PathBuf {
    dir.join(format!("seg-{index:08}.log"))
}

pub fn snapshot_path(dir: &Path, cover: u64) -> PathBuf {
    dir.join(format!("snap-{cover:08}.snap"))
}

/// Files in `dir` named `{prefix}{number}{suffix}`, sorted by number.
pub fn list_numbered(dir: &Path, prefix: &str, suffix: &str) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(rest) = name.strip_prefix(prefix) {
            if let Some(num) = rest.strip_suffix(suffix) {
                if let Ok(k) = num.parse::<u64>() {
                    out.push((k, entry.path()));
                }
            }
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_records() -> Vec<Record> {
        vec![
            Record::Delta(WeightDelta {
                seq: 7,
                n: 100,
                full: false,
                indices: vec![3, 4, 90],
                weights: vec![0.5, 1.5, 9.0],
                stamps: vec![11, 11, 22],
                param_versions: vec![1, 1, 2],
            }),
            Record::Params {
                version: 3,
                bytes: vec![1, 2, 3, 255],
            },
            Record::ParamsLayers {
                version: 4,
                full: false,
                layers: vec![("layer0".into(), vec![7, 7, 7, 7]), ("layer2".into(), vec![])],
            },
            Record::ParamsLayers {
                version: 1,
                full: true,
                layers: vec![("layer0".into(), vec![1, 2])],
            },
            Record::Grad {
                scale: 0.125,
                grad: vec![1.0, -2.0],
            },
            Record::Cursor {
                name: "master".into(),
                seq: 42,
                stamp: 777,
            },
            Record::DropCursor {
                name: "peer-3".into(),
            },
            Record::Meta(SnapshotMeta {
                n: 100,
                init_weight: 1.5,
                floor: 3,
                next_seq: 9,
                clock: 1234,
                cover: 2,
                params_version: 6,
                params_floor: 1,
            }),
        ]
    }

    #[test]
    fn records_roundtrip() {
        for rec in sample_records() {
            let enc = rec.encode();
            assert_eq!(Record::decode(&enc).unwrap(), rec);
        }
    }

    #[test]
    fn record_decode_rejects_truncation_and_trailing() {
        for rec in sample_records() {
            let enc = rec.encode();
            for cut in 0..enc.len() {
                assert!(Record::decode(&enc[..cut]).is_err(), "prefix {cut} decoded");
            }
            let mut extra = enc.clone();
            extra.push(0);
            assert!(Record::decode(&extra).is_err());
        }
    }

    #[test]
    fn params_layer_patch_frames_match_the_record_encoder() {
        let mut borrowed: Vec<u8> = Vec::new();
        append_params_layer_patch(&mut borrowed, 7, "L3", &[1, 2, 3]).unwrap();
        let mut owned: Vec<u8> = Vec::new();
        append_record(
            &mut owned,
            &Record::ParamsLayers {
                version: 7,
                full: false,
                layers: vec![("L3".into(), vec![1, 2, 3])],
            },
        )
        .unwrap();
        assert_eq!(borrowed, owned);
    }

    #[test]
    fn crc32_matches_known_vector() {
        // The classic check value for CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    fn temp_file(tag: &str) -> PathBuf {
        static NEXT: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let k = NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::env::temp_dir().join(format!("issgd-seg-{tag}-{}-{k}", std::process::id()))
    }

    fn write_file(path: &Path, records: &[Record]) -> Vec<u8> {
        let mut buf: Vec<u8> = SEGMENT_MAGIC.to_vec();
        for rec in records {
            append_record(&mut buf, rec).unwrap();
        }
        std::fs::write(path, &buf).unwrap();
        buf
    }

    #[test]
    fn scan_reads_back_everything() {
        let path = temp_file("scan");
        let records = sample_records();
        let bytes = write_file(&path, &records);
        let scan = scan_file(&path, SEGMENT_MAGIC).unwrap();
        assert!(!scan.torn);
        assert_eq!(scan.valid_len, bytes.len() as u64);
        assert_eq!(scan.records, records);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn scan_stops_at_any_torn_tail() {
        let path = temp_file("torn");
        let records = sample_records();
        let bytes = write_file(&path, &records);
        // Every strict prefix recovers a (possibly empty) record prefix
        // and flags the tear — never errors, never panics.
        for cut in 8..bytes.len() {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            let scan = scan_file(&path, SEGMENT_MAGIC).unwrap();
            assert!(scan.torn || scan.valid_len == cut as u64);
            assert!(scan.valid_len <= cut as u64);
            assert!(scan.records.len() <= records.len());
            // The recovered prefix is intact record-for-record.
            for (a, b) in scan.records.iter().zip(&records) {
                assert_eq!(a, b);
            }
        }
        // Shorter than the magic: torn-at-zero, not an error.
        std::fs::write(&path, &bytes[..5]).unwrap();
        let scan = scan_file(&path, SEGMENT_MAGIC).unwrap();
        assert!(scan.torn);
        assert_eq!(scan.valid_len, 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn scan_flags_corrupt_crc_as_torn() {
        let path = temp_file("crc");
        let records = sample_records();
        let mut bytes = write_file(&path, &records[..2]);
        // Flip one payload byte of the SECOND frame: frame 1 survives,
        // frame 2 is a tear.
        let first_frame_end = 8 + 8 + records[0].encode().len();
        let idx = first_frame_end + 12;
        bytes[idx] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let scan = scan_file(&path, SEGMENT_MAGIC).unwrap();
        assert!(scan.torn);
        assert_eq!(scan.records.len(), 1);
        assert_eq!(scan.valid_len, first_frame_end as u64);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn scan_rejects_wrong_magic() {
        let path = temp_file("magic");
        write_file(&path, &[]);
        assert!(scan_file(&path, SNAPSHOT_MAGIC).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn numbered_listing_sorts() {
        let dir = temp_file("list");
        std::fs::create_dir_all(&dir).unwrap();
        for k in [3u64, 1, 2] {
            std::fs::write(segment_path(&dir, k), b"x").unwrap();
        }
        std::fs::write(dir.join("unrelated.txt"), b"x").unwrap();
        let listed = list_numbered(&dir, "seg-", ".log").unwrap();
        let nums: Vec<u64> = listed.iter().map(|(k, _)| *k).collect();
        assert_eq!(nums, vec![1, 2, 3]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
