//! Thin zero-dependency `poll(2)` shim for the event-loop server.
//!
//! tokio/mio (and even the `libc` crate) are unavailable offline, so the
//! handful of constants and the one syscall wrapper the server needs are
//! declared here directly.  `poll(2)` is POSIX and the constant values
//! below are identical on every unix this crate builds on (Linux, macOS,
//! BSDs); the only platform split is the width of `nfds_t`.
//!
//! Kept deliberately minimal: one struct, five event bits, one function.
//! If the per-tick O(connections) pollfd scan ever becomes the measured
//! bottleneck, this is the seam where an epoll/kqueue backend slots in
//! without touching the server's state machine.

use std::io;
use std::os::unix::io::RawFd;

/// `struct pollfd` from `<poll.h>` — layout is fixed by POSIX.
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct PollFd {
    pub fd: RawFd,
    pub events: i16,
    pub revents: i16,
}

impl PollFd {
    pub fn new(fd: RawFd, events: i16) -> PollFd {
        PollFd { fd, events, revents: 0 }
    }
}

/// Data may be read without blocking.
pub const POLLIN: i16 = 0x001;
/// Data may be written without blocking.
pub const POLLOUT: i16 = 0x004;
/// Error condition (revents only).
pub const POLLERR: i16 = 0x008;
/// Peer hung up (revents only).
pub const POLLHUP: i16 = 0x010;
/// Invalid fd (revents only).
pub const POLLNVAL: i16 = 0x020;

/// Any revents bit that means "this socket needs service even if you were
/// only waiting for readability".
pub const POLL_ANY_ERR: i16 = POLLERR | POLLHUP | POLLNVAL;

#[cfg(target_os = "linux")]
type NfdsT = u64;
#[cfg(not(target_os = "linux"))]
type NfdsT = u32;

extern "C" {
    #[link_name = "poll"]
    fn c_poll(fds: *mut PollFd, nfds: NfdsT, timeout_ms: i32) -> i32;
}

/// Block until any fd in `fds` is ready or `timeout_ms` elapses (-1 =
/// forever).  Returns the number of ready fds (0 = timeout); `EINTR` is
/// retried internally so callers never see a spurious error from a
/// signal.
pub fn poll(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    loop {
        let rc = unsafe { c_poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn poll_times_out_on_idle_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        let mut fds = [PollFd::new(server_side.as_raw_fd(), POLLIN)];
        let n = poll(&mut fds, 10).unwrap();
        assert_eq!(n, 0, "idle socket reported ready");
        assert_eq!(fds[0].revents, 0);
    }

    #[test]
    fn poll_reports_readable_and_writable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        client.write_all(b"x").unwrap();
        // The byte needs a moment to cross loopback; poll blocks for it.
        let mut fds = [PollFd::new(server_side.as_raw_fd(), POLLIN | POLLOUT)];
        let n = poll(&mut fds, 1000).unwrap();
        assert!(n >= 1);
        assert_ne!(fds[0].revents & POLLIN, 0, "written byte not readable");
        assert_ne!(fds[0].revents & POLLOUT, 0, "fresh socket not writable");
    }

    #[test]
    fn poll_reports_hangup_or_eof() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(client);
        let mut fds = [PollFd::new(server_side.as_raw_fd(), POLLIN)];
        let n = poll(&mut fds, 1000).unwrap();
        assert_eq!(n, 1);
        // A closed peer is either POLLIN-with-EOF or POLLHUP depending on
        // the platform; both mean "service this socket".
        assert_ne!(fds[0].revents & (POLLIN | POLL_ANY_ERR), 0);
    }
}
