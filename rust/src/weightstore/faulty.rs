//! Deterministic fault injection for any [`WeightStore`] — the sanctioned
//! chaos entry point.
//!
//! [`FaultyStore`] is a decorator: it implements [`WeightStore`], wraps any
//! inner store (an in-process [`MemStore`], a TCP
//! [`crate::weightstore::client::Client`], even another `FaultyStore`),
//! and injects failures the way dslab-style simulators drive distributed
//! systems — from a *seeded* RNG and a *virtual-time* [`FaultClock`], so a
//! failure schedule is a pure function of the seed and the op sequence,
//! never of wall-clock scheduling.  Under a serialized op order (the
//! lockstep mode of `coordinator::peer_live`, or any single-threaded
//! driver) the entire chaos run is bit-reproducible.
//!
//! Injected fault classes ([`FaultSpec`]):
//!
//! * **Transient errors** — fallible ops return `Err` *before* touching
//!   the inner store, so a failed push leaves no partial write behind.
//!   Callers built for §4.2 fire-and-forget (worker backoff, peer pending
//!   retries, the master's swallowed sync) must survive these.
//! * **Latency** — every op advances the virtual clock by a base cost plus
//!   a seeded random extra.  Nothing sleeps: latency exists so schedules
//!   expressed in virtual time (`fault_until`) are deterministic.
//! * **Delta withholding / reordering** — `fetch_weights_since` may return
//!   an *empty* delta with the caller's own cursor (no progress: the whole
//!   batch of writes arrives later), or a random *subset* of the real
//!   entries, again without advancing the cursor.  Because delta entries
//!   are absolute values and the cursor never moves past undelivered
//!   writes, both faults preserve the store's replay contract: consumers
//!   see writes late and out of order, but never lose one — exactly the
//!   regime the paper's "factors ... not updated instantly" claim is
//!   about.  Full deltas (cursor 0 / resync) are never tampered with, so
//!   a consumer can always bootstrap.  **Params deltas** join the same
//!   surface: an incremental `fetch_params_since` may be withheld
//!   (reported as "up to date"), so consumers train on stale layers until
//!   a later fetch delivers them — full params deltas, like full weight
//!   deltas, always pass through.
//!
//! Faults stop at the `fault_until` virtual-time horizon (if set) or when
//! [`FaultyStore::set_enabled`]`(false)` is called, which is how
//! convergence tests model a transient outage followed by recovery.
//!
//! Caveat: [`WeightStore::now`] returns the *virtual* clock, but entry
//! stamps written by the inner store still come from its own clock — wrap
//! stores only for `StalenessUnit::Versions` runs (all current users) or
//! ignore wall-clock staleness under injection.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::util::rng::Pcg64;

use super::{ParamsDelta, StoreStats, WeightDelta, WeightSnapshot, WeightStore};

/// Virtual time shared by a [`FaultyStore`] and its tests: a monotonic
/// nanosecond counter advanced by store ops, never by wall clocks.
#[derive(Debug, Default)]
pub struct FaultClock {
    nanos: AtomicU64,
}

impl FaultClock {
    pub fn new() -> Arc<FaultClock> {
        Arc::new(FaultClock::default())
    }

    /// Current virtual time in nanoseconds.
    pub fn now(&self) -> u64 {
        self.nanos.load(Ordering::Acquire)
    }

    /// Advance the clock by `ns`; returns the new time.
    pub fn advance(&self, ns: u64) -> u64 {
        self.nanos.fetch_add(ns, Ordering::AcqRel) + ns
    }
}

/// The fault schedule of one [`FaultyStore`] — probabilities are rolled
/// per op from the seeded RNG; all times are virtual nanoseconds.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    /// RNG seed: same seed + same op order ⇒ same injected schedule.
    pub seed: u64,
    /// Probability a fallible op returns an injected transient error.
    pub error_prob: f64,
    /// Probability a non-full delta fetch is withheld entirely (empty
    /// delta, cursor unchanged — the writes arrive on a later fetch).
    pub withhold_prob: f64,
    /// Probability a non-full delta fetch delivers only a random subset of
    /// its entries (cursor unchanged — the rest arrive later, reordered
    /// relative to newer writes).
    pub partial_prob: f64,
    /// Virtual ns every op costs.
    pub op_latency: u64,
    /// Upper bound on additional seeded per-op latency (0 = none).
    pub max_extra_latency: u64,
    /// Inject nothing before this virtual time (0 = immediately) — lets a
    /// run's setup traffic through before the outage begins.
    pub fault_from: u64,
    /// Inject nothing once the virtual clock passes this horizon
    /// (`None` = faults never expire) — the "transient outage" shape.
    pub fault_until: Option<u64>,
}

impl FaultSpec {
    /// A spec that injects nothing (ops still tick the clock by 1 ns).
    pub fn quiet(seed: u64) -> FaultSpec {
        FaultSpec {
            seed,
            error_prob: 0.0,
            withhold_prob: 0.0,
            partial_prob: 0.0,
            op_latency: 1,
            max_extra_latency: 0,
            fault_from: 0,
            fault_until: None,
        }
    }

    pub fn with_errors(mut self, p: f64) -> FaultSpec {
        self.error_prob = p;
        self
    }

    pub fn with_withholding(mut self, p: f64) -> FaultSpec {
        self.withhold_prob = p;
        self
    }

    pub fn with_partial_deltas(mut self, p: f64) -> FaultSpec {
        self.partial_prob = p;
        self
    }

    pub fn with_latency(mut self, base: u64, max_extra: u64) -> FaultSpec {
        self.op_latency = base;
        self.max_extra_latency = max_extra;
        self
    }

    pub fn with_fault_until(mut self, horizon: u64) -> FaultSpec {
        self.fault_until = Some(horizon);
        self
    }

    /// Faults are live only inside `[from, until)` virtual ns — the
    /// "outage in the middle of a healthy run" shape.
    pub fn with_fault_window(mut self, from: u64, until: u64) -> FaultSpec {
        self.fault_from = from;
        self.fault_until = Some(until);
        self
    }
}

/// Injection counters (diagnostics; tests assert the schedule fired).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub injected_errors: u64,
    pub withheld_deltas: u64,
    pub partial_deltas: u64,
    /// Incremental params deltas withheld (reported as "up to date"; the
    /// layers arrive on a later fetch — the cursor never moved).
    pub withheld_params: u64,
    /// Ops observed (clock ticks), including ones that then failed.
    pub ops: u64,
}

/// The decorator.  See the module docs for semantics.
pub struct FaultyStore {
    inner: Arc<dyn WeightStore>,
    spec: FaultSpec,
    clock: Arc<FaultClock>,
    rng: Mutex<Pcg64>,
    enabled: AtomicBool,
    injected_errors: AtomicU64,
    withheld_deltas: AtomicU64,
    partial_deltas: AtomicU64,
    withheld_params: AtomicU64,
    ops: AtomicU64,
}

impl FaultyStore {
    /// Wrap `inner` with its own fresh [`FaultClock`].
    pub fn new(inner: Arc<dyn WeightStore>, spec: FaultSpec) -> FaultyStore {
        Self::with_clock(inner, spec, FaultClock::new())
    }

    /// Wrap `inner` sharing an externally-owned clock (several stores, one
    /// timeline).
    pub fn with_clock(
        inner: Arc<dyn WeightStore>,
        spec: FaultSpec,
        clock: Arc<FaultClock>,
    ) -> FaultyStore {
        let rng = Mutex::new(Pcg64::new(spec.seed, 0xFA17));
        FaultyStore {
            inner,
            spec,
            clock,
            rng,
            enabled: AtomicBool::new(true),
            injected_errors: AtomicU64::new(0),
            withheld_deltas: AtomicU64::new(0),
            partial_deltas: AtomicU64::new(0),
            withheld_params: AtomicU64::new(0),
            ops: AtomicU64::new(0),
        }
    }

    /// The wrapped store (tests read the ground truth through this).
    pub fn inner(&self) -> Arc<dyn WeightStore> {
        Arc::clone(&self.inner)
    }

    /// The virtual clock driving the schedule.
    pub fn clock(&self) -> Arc<FaultClock> {
        Arc::clone(&self.clock)
    }

    /// Master switch: `false` turns the decorator into a pure passthrough
    /// (the clock still ticks).
    pub fn set_enabled(&self, enabled: bool) {
        self.enabled.store(enabled, Ordering::Release);
    }

    pub fn fault_stats(&self) -> FaultStats {
        FaultStats {
            injected_errors: self.injected_errors.load(Ordering::Relaxed),
            withheld_deltas: self.withheld_deltas.load(Ordering::Relaxed),
            partial_deltas: self.partial_deltas.load(Ordering::Relaxed),
            withheld_params: self.withheld_params.load(Ordering::Relaxed),
            ops: self.ops.load(Ordering::Relaxed),
        }
    }

    /// Whether injection is live at the current virtual time.
    fn active(&self) -> bool {
        if !self.enabled.load(Ordering::Acquire) {
            return false;
        }
        let now = self.clock.now();
        now >= self.spec.fault_from
            && match self.spec.fault_until {
                None => true,
                Some(horizon) => now < horizon,
            }
    }

    /// Advance the clock by the op cost (base + seeded extra).
    fn tick(&self) {
        self.ops.fetch_add(1, Ordering::Relaxed);
        let extra = if self.spec.max_extra_latency > 0 && self.active() {
            self.rng
                .lock()
                .unwrap()
                .next_below(self.spec.max_extra_latency + 1)
        } else {
            0
        };
        self.clock.advance(self.spec.op_latency.max(1) + extra);
    }

    /// One seeded Bernoulli roll (false when injection is off).
    fn roll(&self, p: f64) -> bool {
        if p <= 0.0 || !self.active() {
            return false;
        }
        self.rng.lock().unwrap().next_f64() < p
    }

    fn maybe_fail(&self, op: &str) -> Result<()> {
        if self.roll(self.spec.error_prob) {
            self.injected_errors.fetch_add(1, Ordering::Relaxed);
            crate::telemetry::counter("fault.injected_errors").inc();
            anyhow::bail!("injected transient {op} failure (virtual t = {} ns)", self.clock.now());
        }
        Ok(())
    }
}

impl WeightStore for FaultyStore {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<()> {
        self.tick();
        self.maybe_fail("push_params")?;
        self.inner.push_params(version, bytes)
    }

    fn fetch_params(&self, than: u64) -> Result<Option<(u64, Vec<u8>)>> {
        self.tick();
        self.maybe_fail("fetch_params")?;
        self.inner.fetch_params(than)
    }

    fn push_params_layers(
        &self,
        version: u64,
        full: bool,
        layers: &[(String, Vec<u8>)],
    ) -> Result<()> {
        self.tick();
        // Fail BEFORE the inner call: an injected push failure must leave
        // no partial layer write behind.
        self.maybe_fail("push_params_layers")?;
        self.inner.push_params_layers(version, full, layers)
    }

    fn fetch_params_since(&self, than: u64) -> Result<Option<ParamsDelta>> {
        self.tick();
        self.maybe_fail("fetch_params_since")?;
        let delta = self.inner.fetch_params_since(than)?;
        match delta {
            // Full deltas are the bootstrap/resync path — never withheld,
            // mirroring the weight-delta rule.
            Some(d) if !d.full => {
                if self.roll(self.spec.withhold_prob) {
                    // Withhold: report "up to date".  The caller's version
                    // cursor stays at `than`, layer bytes are absolute, so
                    // everything is re-delivered on a later fetch — params
                    // arrive late and possibly reordered, never corrupted.
                    self.withheld_params.fetch_add(1, Ordering::Relaxed);
                    crate::telemetry::counter("fault.withheld_params").inc();
                    Ok(None)
                } else {
                    Ok(Some(d))
                }
            }
            other => Ok(other),
        }
    }

    fn params_version(&self) -> Result<u64> {
        self.tick();
        self.inner.params_version()
    }

    fn push_weights(&self, start: usize, weights: &[f32], param_version: u64) -> Result<()> {
        self.tick();
        // Fail BEFORE the inner call: an injected push failure must leave
        // no partial write (callers retry the whole run).
        self.maybe_fail("push_weights")?;
        self.inner.push_weights(start, weights, param_version)
    }

    fn fetch_weights(&self) -> Result<WeightSnapshot> {
        self.tick();
        self.maybe_fail("fetch_weights")?;
        self.inner.fetch_weights()
    }

    fn fetch_weights_since(&self, seq: u64) -> Result<WeightDelta> {
        self.tick();
        self.maybe_fail("fetch_weights_since")?;
        let delta = self.inner.fetch_weights_since(seq)?;
        // Full deltas are the bootstrap/resync path — never tampered with,
        // so a brand-new consumer can always make first contact.
        if delta.full {
            return Ok(delta);
        }
        if self.roll(self.spec.withhold_prob) {
            // Withhold the whole batch: the caller's cursor stays at `seq`,
            // so every write is re-scanned (and delivered) on a later
            // fetch.  No lost updates — only lateness.
            self.withheld_deltas.fetch_add(1, Ordering::Relaxed);
            crate::telemetry::counter("fault.withheld_deltas").inc();
            return Ok(WeightDelta {
                seq,
                n: delta.n,
                full: false,
                ..WeightDelta::default()
            });
        }
        if !delta.is_empty() && self.roll(self.spec.partial_prob) {
            // Deliver a random subset now, the rest later: entries are
            // absolute values, so re-delivery (and arrival reordered
            // relative to newer writes) is idempotent.  The cursor again
            // stays at `seq`.
            self.partial_deltas.fetch_add(1, Ordering::Relaxed);
            crate::telemetry::counter("fault.partial_deltas").inc();
            let mut kept = WeightDelta {
                seq,
                n: delta.n,
                full: false,
                ..WeightDelta::default()
            };
            let mut rng = self.rng.lock().unwrap();
            for k in 0..delta.len() {
                if rng.next_below(2) == 0 {
                    kept.indices.push(delta.indices[k]);
                    kept.weights.push(delta.weights[k]);
                    kept.stamps.push(delta.stamps[k]);
                    kept.param_versions.push(delta.param_versions[k]);
                }
            }
            return Ok(kept);
        }
        Ok(delta)
    }

    fn apply_grad(&self, scale: f32, grad: &[f32]) -> Result<u64> {
        self.tick();
        self.maybe_fail("apply_grad")?;
        self.inner.apply_grad(scale, grad)
    }

    fn save_cursor(&self, name: &str, seq: u64) -> Result<()> {
        self.tick();
        // Fail BEFORE the inner call: an injected failure must leave the
        // saved pin untouched (callers re-save on their next sync).
        self.maybe_fail("save_cursor")?;
        self.inner.save_cursor(name, seq)
    }

    fn load_cursor(&self, name: &str) -> Result<Option<u64>> {
        self.tick();
        self.maybe_fail("load_cursor")?;
        self.inner.load_cursor(name)
    }

    fn drop_cursor(&self, name: &str) -> Result<()> {
        self.tick();
        // Fail BEFORE the inner call: a failed drop leaves the pin in
        // place (callers re-drop; the op is idempotent).
        self.maybe_fail("drop_cursor")?;
        self.inner.drop_cursor(name)
    }

    fn now(&self) -> Result<u64> {
        Ok(self.clock.now())
    }

    fn stats(&self) -> Result<StoreStats> {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::weightstore::MemStore;

    fn wrap(n: usize, spec: FaultSpec) -> (Arc<MemStore>, FaultyStore) {
        let mem = Arc::new(MemStore::new(n, 1.0));
        let store = FaultyStore::new(mem.clone() as Arc<dyn WeightStore>, spec);
        (mem, store)
    }

    #[test]
    fn quiet_spec_is_a_passthrough() {
        let (mem, store) = wrap(8, FaultSpec::quiet(1));
        store.push_weights(2, &[5.0, 6.0], 3).unwrap();
        assert_eq!(store.fetch_weights().unwrap(), mem.fetch_weights().unwrap());
        let d = store.fetch_weights_since(0).unwrap();
        assert!(d.full);
        assert_eq!(d.len(), 8);
        assert_eq!(store.fault_stats().injected_errors, 0);
        // Every op ticked the clock.
        assert!(store.clock().now() >= 3);
    }

    #[test]
    fn injected_errors_fire_and_leave_inner_untouched() {
        let (mem, store) = wrap(4, FaultSpec::quiet(7).with_errors(1.0));
        assert!(store.push_weights(0, &[9.0], 1).is_err());
        assert_eq!(mem.fetch_weights().unwrap().weights, vec![1.0; 4]);
        assert_eq!(mem.write_seq(), 1); // nothing reached the inner store
        assert!(store.fault_stats().injected_errors > 0);
    }

    #[test]
    fn withholding_preserves_the_replay_contract() {
        let (mem, store) = wrap(6, FaultSpec::quiet(3).with_withholding(1.0));
        let d0 = store.fetch_weights_since(0).unwrap();
        assert!(d0.full, "full deltas must never be withheld");
        let mut mirror = d0.to_snapshot().unwrap();
        let mut cursor = d0.seq;
        mem.push_weights(1, &[4.0, 5.0], 2).unwrap();
        // Withheld: empty delta, cursor unchanged.
        let d = store.fetch_weights_since(cursor).unwrap();
        assert!(d.is_empty());
        assert_eq!(d.seq, cursor);
        assert!(store.fault_stats().withheld_deltas > 0);
        // Outage over: the writes arrive late but complete.
        store.set_enabled(false);
        let d = store.fetch_weights_since(cursor).unwrap();
        d.apply_to(&mut mirror).unwrap();
        cursor = d.seq;
        assert_eq!(mirror, mem.fetch_weights().unwrap());
        let d = store.fetch_weights_since(cursor).unwrap();
        assert!(d.is_empty());
    }

    #[test]
    fn params_withholding_delays_but_never_loses_layers() {
        let (mem, store) = wrap(4, FaultSpec::quiet(21).with_withholding(1.0));
        mem.push_params_layers(1, true, &[("a".into(), vec![1, 1]), ("b".into(), vec![2, 2])])
            .unwrap();
        // Bootstrap (full) passes through untouched.
        let d = store.fetch_params_since(0).unwrap().unwrap();
        assert!(d.full, "full params deltas must never be withheld");
        let mut version = d.version;
        let mut mine: Vec<Vec<u8>> = d.layers.iter().map(|l| l.bytes.clone()).collect();
        // Incremental updates are withheld: the fetch claims "up to date".
        mem.push_params_layers(2, false, &[("b".into(), vec![9, 9])]).unwrap();
        assert!(store.fetch_params_since(version).unwrap().is_none());
        assert!(store.fault_stats().withheld_params > 0);
        // Outage over: the layer arrives late, nothing lost.
        store.set_enabled(false);
        let d = store.fetch_params_since(version).unwrap().unwrap();
        assert!(!d.full);
        for l in &d.layers {
            let idx = if l.name == "a" { 0 } else { 1 };
            mine[idx] = l.bytes.clone();
        }
        version = d.version;
        assert_eq!(version, 2);
        assert_eq!(mine.concat(), mem.fetch_params(0).unwrap().unwrap().1);
        assert!(store.fetch_params_since(version).unwrap().is_none());
    }

    #[test]
    fn partial_deltas_converge_by_redelivery() {
        let (mem, store) = wrap(32, FaultSpec::quiet(11).with_partial_deltas(1.0));
        let d0 = store.fetch_weights_since(0).unwrap();
        let mut mirror = d0.to_snapshot().unwrap();
        let mut cursor = d0.seq;
        let vals: Vec<f32> = (0..16).map(|i| i as f32 + 2.0).collect();
        mem.push_weights(4, &vals, 1).unwrap();
        // Partial deliveries never advance the cursor, so each fetch
        // re-scans the same writes; the subset applied is always a subset
        // of the truth (absolute values).
        let mut saw_partial = false;
        for _ in 0..6 {
            let d = store.fetch_weights_since(cursor).unwrap();
            if d.seq == cursor && d.len() < 16 {
                saw_partial = true;
            }
            d.apply_to(&mut mirror).unwrap();
            cursor = d.seq;
        }
        assert!(saw_partial, "partial injection never fired");
        store.set_enabled(false);
        let d = store.fetch_weights_since(cursor).unwrap();
        d.apply_to(&mut mirror).unwrap();
        assert_eq!(mirror, mem.fetch_weights().unwrap());
    }

    #[test]
    fn schedule_is_deterministic_in_seed_and_op_order() {
        let run = |seed: u64| -> (FaultStats, Vec<u64>) {
            let (mem, store) = wrap(
                16,
                FaultSpec::quiet(seed)
                    .with_errors(0.3)
                    .with_withholding(0.3)
                    .with_latency(5, 10),
            );
            let mut outcomes = Vec::new();
            let mut cursor = 0;
            for i in 0..40u64 {
                mem.push_weights((i % 16) as usize, &[i as f32], i + 1).unwrap();
                match store.fetch_weights_since(cursor) {
                    Ok(d) => {
                        outcomes.push(d.seq);
                        cursor = d.seq;
                    }
                    Err(_) => outcomes.push(u64::MAX),
                }
            }
            outcomes.push(store.clock().now());
            (store.fault_stats(), outcomes)
        };
        let (sa, oa) = run(42);
        let (sb, ob) = run(42);
        assert_eq!(sa, sb);
        assert_eq!(oa, ob);
        let (sc, oc) = run(43);
        assert!(sa != sc || oa != oc, "different seeds gave identical schedules");
    }

    #[test]
    fn fault_until_horizon_ends_the_outage() {
        let (mem, store) = wrap(
            4,
            FaultSpec::quiet(5).with_errors(1.0).with_latency(10, 0).with_fault_until(100),
        );
        let mut failures = 0;
        // 10 ns/op: faults stop once the clock crosses 100 ns.
        for i in 0..30u64 {
            if store.push_weights(0, &[i as f32 + 1.0], i + 1).is_err() {
                failures += 1;
            }
        }
        assert!(failures > 0, "horizon never saw a fault");
        assert!(failures < 30, "faults never expired");
        // Post-horizon ops all succeed.
        store.push_weights(1, &[7.0], 99).unwrap();
        assert_eq!(mem.fetch_weights().unwrap().weights[1], 7.0);
    }

    #[test]
    fn fault_window_spares_setup_traffic() {
        let (_mem, store) = wrap(
            4,
            FaultSpec::quiet(9).with_errors(1.0).with_latency(10, 0).with_fault_window(50, 150),
        );
        // Before the window: clean.
        store.push_weights(0, &[1.0], 1).unwrap();
        // Inside the window (clock at 10, 20, ... crosses 50): faulty.
        let mut failures = 0;
        for i in 0..20u64 {
            if store.push_weights(0, &[i as f32 + 1.0], i + 2).is_err() {
                failures += 1;
            }
        }
        assert!(failures > 0, "window never activated");
        // After the horizon: clean again.
        store.push_weights(0, &[3.0], 99).unwrap();
    }

    #[test]
    fn virtual_now_tracks_the_clock() {
        let (_mem, store) = wrap(2, FaultSpec::quiet(1).with_latency(50, 0));
        let a = store.now().unwrap();
        store.params_version().unwrap();
        let b = store.now().unwrap();
        assert!(b >= a + 50);
    }
}
